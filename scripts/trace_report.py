#!/usr/bin/env python3
"""Offline analysis of QUOKA engine lifecycle traces.

Input is the JSONL written by `--trace-out` (serve), the `flush_trace`
wire command, or `Engine::write_trace`: one event per line,
`{"t_us": ..., "id": ..., "ev": "...", ...payload}`. Request ids are
engine ids; `id == 0` marks engine-scope events (step occupancy,
evictions, phase samples).

Modes:

  trace_report.py TRACE.jsonl              full report: per-request
                                           waterfall, step-occupancy
                                           timeline, phase-time table
  trace_report.py TRACE.jsonl --validate   well-formedness checks only;
                                           exit 1 on any violation

Validation enforces the span grammar the engine promises:

  * every line parses and carries t_us / id / ev
  * timestamps are monotonically non-decreasing in ring order
  * every submitted request reaches a terminal event
    (finish | cancel | reject)
  * first_token precedes finish
  * a parked follower (park_on_prefix) adopts pages (adopt_pages)
    before it wakes (wake), or has a spill-tier promotion in flight
    (promote — emitted at submit, before the park)

Stdlib only — runs anywhere CI can run python3.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

TERMINAL = ("finish", "cancel", "reject")
PHASES = ("scan", "attn", "append", "gemm")


def load(path):
    """Parse a trace file into a list of event dicts (ring order)."""
    events = []
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: bad JSON: {e}") from e
            events.append(ev)
    return events


def by_request(events):
    """Group request-scope events by id, preserving ring order."""
    reqs = defaultdict(list)
    for ev in events:
        rid = ev.get("id")
        if rid:  # id 0 = engine scope
            reqs[rid].append(ev)
    return reqs


def validate(events):
    """Return a list of violation strings (empty = well-formed)."""
    problems = []
    last_t = None
    for i, ev in enumerate(events):
        for key in ("t_us", "id", "ev"):
            if key not in ev:
                problems.append(f"event {i}: missing '{key}': {ev}")
        t = ev.get("t_us")
        if isinstance(t, (int, float)):
            if last_t is not None and t < last_t:
                problems.append(f"event {i}: timestamp regressed {last_t} -> {t}")
            last_t = t

    for rid, evs in sorted(by_request(events).items()):
        names = [e.get("ev") for e in evs]
        if "submit" not in names:
            # Ring wrap can drop a request's head; that is not a grammar
            # violation, but nothing else can be checked for it.
            continue
        term = [n for n in names if n in TERMINAL]
        if not term:
            problems.append(f"request {rid}: submit without terminal event {TERMINAL}")
            continue
        if "first_token" in names and "finish" in names:
            if names.index("first_token") > names.index("finish"):
                problems.append(f"request {rid}: first_token after finish")
        if "finish" in names and "first_token" not in names:
            problems.append(f"request {rid}: finished without a first_token span")
        if "park_on_prefix" in names:
            park = names.index("park_on_prefix")
            if "wake" in names:
                wake = names.index("wake")
                adopts = [i for i, n in enumerate(names) if n == "adopt_pages"]
                # A spill-tier promotion kicked at submit also legitimises
                # the park: the request waits on promoted pages, not on a
                # producer's publishes, and a failed promotion may wake it
                # with zero adopts (degrading to recompute).
                promotes = [i for i, n in enumerate(names) if n == "promote"]
                if not adopts and not promotes:
                    problems.append(
                        f"request {rid}: parked follower woke without adopt_pages or promote"
                    )
                elif not any(park < a < wake for a in adopts) and not any(
                    p < wake for p in promotes
                ):
                    problems.append(
                        f"request {rid}: no adopt_pages between park_on_prefix and wake "
                        f"and no promote before wake"
                    )
            elif "finish" in names:
                problems.append(f"request {rid}: parked follower finished without waking")
    return problems


def fmt_ms(us):
    return f"{us / 1000.0:.2f}"


def waterfall(events):
    """Per-request lifecycle table. Returns the printed rows as dicts."""
    rows = []
    for rid, evs in sorted(by_request(events).items()):
        t = {}
        for e in evs:
            name = e.get("ev")
            # Keep the FIRST occurrence of each span kind.
            if name not in t:
                t[name] = e
        if "submit" not in t:
            continue
        t0 = t["submit"]["t_us"]
        terminal = next((n for n in TERMINAL if n in t), None)
        row = {
            "id": rid,
            "prompt": t["submit"].get("prompt", 0),
            "submit_us": t0,
            "admit_ms": fmt_ms(t["admit"]["t_us"] - t0) if "admit" in t else "-",
            "first_chunk_ms": fmt_ms(t["chunk_start"]["t_us"] - t0)
            if "chunk_start" in t
            else "-",
            "ttft_ms": fmt_ms(t["first_token"]["t_us"] - t0) if "first_token" in t else "-",
            "finish_ms": fmt_ms(t[terminal]["t_us"] - t0) if terminal else "-",
            "terminal": terminal or "-",
            "prefix_pages": t.get("prefix_hit", {}).get("pages", 0),
            "promoted": t.get("promote", {}).get("pages", 0),
            "parked": "yes" if "park_on_prefix" in t else "",
        }
        rows.append(row)

    cols = [
        ("id", 5),
        ("prompt", 7),
        ("admit_ms", 9),
        ("first_chunk_ms", 15),
        ("ttft_ms", 9),
        ("finish_ms", 10),
        ("terminal", 9),
        ("prefix_pages", 13),
        ("promoted", 9),
        ("parked", 7),
    ]
    print("per-request waterfall (times relative to submit):")
    print("  " + " ".join(f"{name:>{w}}" for name, w in cols))
    for row in rows:
        print("  " + " ".join(f"{str(row[name]):>{w}}" for name, w in cols))
    demoted = sum(e.get("pages", 0) for e in events if e.get("ev") == "spill")
    evicted = sum(e.get("pages", 0) for e in events if e.get("ev") == "evict")
    promoted = sum(e.get("pages", 0) for e in events if e.get("ev") == "promote")
    if demoted or evicted or promoted:
        print(
            f"  kv tiering: {demoted} pages demoted to spill, "
            f"{promoted} promotion pages kicked, {evicted} pages hard-evicted"
        )
    print()
    return rows


def occupancy(events, max_rows=24):
    """Step-occupancy timeline from step_end records."""
    steps = [e for e in events if e.get("ev") == "step_end"]
    print(f"step occupancy ({len(steps)} steps):")
    if not steps:
        print("  (no step_end records)\n")
        return steps
    shown = steps
    if len(steps) > max_rows:
        head = steps[: max_rows // 2]
        tail = steps[-(max_rows - len(head)) :]
        shown = head + [None] + tail
    print(f"  {'t_ms':>10} {'prefill_tok':>12} {'decode_seqs':>12} {'verify_seqs':>12}")
    for s in shown:
        if s is None:
            print(f"  {'...':>10}")
            continue
        print(
            f"  {fmt_ms(s['t_us']):>10} {s.get('prefill_tokens', 0):>12} "
            f"{s.get('decode_seqs', 0):>12} {s.get('verify_seqs', 0):>12}"
        )
    busy = sum(1 for s in steps if s.get("prefill_tokens", 0) > 0)
    print(f"  steps with prefill work: {busy}/{len(steps)}\n")
    return steps


def phase_table(events):
    """Aggregate phase_sample records into a per-phase time table."""
    totals = dict.fromkeys(PHASES, 0)
    n = 0
    for e in events:
        if e.get("ev") != "phase_sample":
            continue
        n += 1
        for p in PHASES:
            totals[p] += e.get(p, 0)
    print(f"phase time ({n} samples):")
    if n == 0:
        print("  (no phase_sample records)\n")
        return totals
    grand = sum(totals.values()) or 1
    for p in PHASES:
        pct = 100.0 * totals[p] / grand
        print(f"  {p:>8} {fmt_ms(totals[p]):>12} ms  {pct:5.1f}%")
    print()
    return totals


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSONL written by the engine")
    ap.add_argument(
        "--validate",
        action="store_true",
        help="check span-grammar well-formedness only; exit 1 on violation",
    )
    args = ap.parse_args(argv)

    try:
        events = load(args.trace)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1

    problems = validate(events)
    if args.validate:
        if problems:
            for p in problems:
                print(f"VIOLATION: {p}", file=sys.stderr)
            print(f"{len(problems)} violation(s) in {args.trace}", file=sys.stderr)
            return 1
        n_req = len(by_request(events))
        print(f"ok: {len(events)} events, {n_req} requests, span grammar holds")
        return 0

    print(f"trace: {args.trace} — {len(events)} events\n")
    waterfall(events)
    occupancy(events)
    phase_table(events)
    if problems:
        for p in problems:
            print(f"VIOLATION: {p}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
