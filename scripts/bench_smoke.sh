#!/usr/bin/env bash
# Perf smoke benches, run PR over PR:
#
# 1. Hot path: `cargo bench --bench micro_hotpath` in the reduced
#    configuration (one 16k-token cache, GQA 32q/8kv, d=128, QUOKA budget
#    ≈ 12 % of T, 3 measured iters) → BENCH_hotpath.json at the repo root
#    (one entry per measured piece: `config`, `wall-ns`, `GFLOP/s`).
# 2. Shared-prefix serving: `cargo bench --bench prefix_serving` — 8
#    requests sharing a 12k-token prefix over the paged KV pool, radix
#    prefix cache on/off → BENCH_prefix.json (prefix-hit rate, TTFT
#    with/without the cache, prefill tokens, KV bytes saved).
#
# Usage: scripts/bench_smoke.sh
#   BENCH_OUT=/path/to.json   override the hot-path output location
#   PREFIX_OUT=/path/to.json  override the prefix-serving output location
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_SMOKE=1
export BENCH_OUT="${BENCH_OUT:-$PWD/BENCH_hotpath.json}"
export PREFIX_OUT="${PREFIX_OUT:-$PWD/BENCH_prefix.json}"

cargo bench --manifest-path rust/Cargo.toml --bench micro_hotpath
cargo bench --manifest-path rust/Cargo.toml --bench prefix_serving

echo "bench_smoke: wrote $BENCH_OUT and $PREFIX_OUT"
