#!/usr/bin/env bash
# Perf smoke benches, run PR over PR:
#
# 1. Hot path: `cargo bench --bench micro_hotpath` in the reduced
#    configuration (one 16k-token cache, GQA 32q/8kv, d=128, QUOKA budget
#    ≈ 12 % of T, 3 measured iters) → BENCH_hotpath.json at the repo root
#    (one entry per measured piece: `config`, `wall-ns`, `GFLOP/s`).
# 2. Shared-prefix serving: `cargo bench --bench prefix_serving` — 8
#    requests sharing a 12k-token prefix over the paged KV pool, radix
#    prefix cache on/off → BENCH_prefix.json (prefix-hit rate, TTFT
#    with/without the cache, prefill tokens, KV bytes saved).
# 3. Decode serving: `cargo bench --bench decode_serving` — 8 concurrent
#    sequences × 64 decode steps, serial (B=1 loop) vs one GEMM-batched
#    forward per step → BENCH_decode.json (tokens/sec each + speedup;
#    identical generations asserted).
#
# Usage: scripts/bench_smoke.sh
#   BENCH_OUT=/path/to.json   override the hot-path output location
#   PREFIX_OUT=/path/to.json  override the prefix-serving output location
#   DECODE_OUT=/path/to.json  override the decode-serving output location
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_SMOKE=1
export BENCH_OUT="${BENCH_OUT:-$PWD/BENCH_hotpath.json}"
export PREFIX_OUT="${PREFIX_OUT:-$PWD/BENCH_prefix.json}"
export DECODE_OUT="${DECODE_OUT:-$PWD/BENCH_decode.json}"

cargo bench --manifest-path rust/Cargo.toml --bench micro_hotpath
cargo bench --manifest-path rust/Cargo.toml --bench prefix_serving
cargo bench --manifest-path rust/Cargo.toml --bench decode_serving

echo "bench_smoke: wrote $BENCH_OUT, $PREFIX_OUT and $DECODE_OUT"
