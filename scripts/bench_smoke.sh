#!/usr/bin/env bash
# Perf smoke benches, run PR over PR (locally and by the CI `bench` job):
#
# 1. Hot path: `cargo bench --bench micro_hotpath` in the reduced
#    configuration (one 16k-token cache, GQA 32q/8kv, d=128, QUOKA budget
#    ≈ 12 % of T, 3 measured iters) → BENCH_hotpath.json at the repo root
#    (one entry per measured piece: `config`, `wall-ns`, `GFLOP/s`).
# 2. Shared-prefix serving: `cargo bench --bench prefix_serving` — 8
#    requests sharing a 12k-token prefix over the paged KV pool; three
#    arms: cache off, warm cache, and the in-flight burst (followers park
#    behind the leader's mid-prefill page publishes; the prefix prefills
#    exactly once across the batch) → BENCH_prefix.json.
# 3. Decode serving: `cargo bench --bench decode_serving` — 8 concurrent
#    sequences × 64 decode steps, serial (B=1 loop) vs one GEMM-batched
#    forward per step → BENCH_decode.json (tokens/sec each + speedup;
#    identical generations asserted).
# 4. Speculative decode: `cargo bench --bench spec_serving` — copy-heavy
#    single-sequence decode, prompt-lookup drafting + one multi-token
#    verify per step vs one token per step → BENCH_spec.json (speedup +
#    acceptance rate; identical generations asserted).
# 5. Quantized KV: `cargo bench --bench quant_serving` — 8 sequences × 64
#    fused decode steps with fp32 vs int8 private KV, plus the QUOKA
#    paged key scan at pool geometry → BENCH_quant.json (decode tokens/sec
#    each + speedup, scan seconds each + speedup).
# 6. Dense GEMM: `cargo bench --bench gemm_serving` — the pool-backed
#    packed projection/FFN kernel vs the seed serial loop on prefill- and
#    decode-shaped operands, plus the gemm phase share of a real chunked
#    prefill at workers=1 vs the full pool → BENCH_gemm.json (serial and
#    parallel GFLOP/s, speedups, TTFT + phase shares; packed serial ==
#    packed parallel asserted bitwise).
# 7. Open-loop serving: `cargo bench --bench serving_load` — Poisson
#    arrivals over the real TCP server (streaming, cancels, tenants,
#    shared prefixes) → BENCH_serving.json (client + server TTFT/ITL
#    p50/p99, queue wait, goodput, cancel latency).
# 8. Tiered KV pool: `cargo bench --bench tiered_serving` — 8 requests
#    re-using a 12k-token prefix after pool-pressure eviction; three
#    arms: warm-from-RAM, warm-from-spill (pages promoted back off the
#    mmap spill file) and cold recompute → BENCH_tiered.json (TTFT per
#    arm, spill-warm speedup, promotion counts; identical generations
#    asserted).
#
# CI bench gate: the `bench` job in .github/workflows/ci.yml runs this
# script on a CI-sized config, uploads the eight JSONs as the
# `bench-results` artifact, and then runs `scripts/check_bench.py`, which
# FAILS the job when tiled-vs-seed speedup, warm-vs-cold or
# in-flight-vs-cold prefix TTFT ratio, batched-vs-serial decode
# throughput, speculative-vs-plain decode throughput, int8-vs-fp32
# decode throughput, parallel-vs-serial GEMM speedup (waived on
# runners with fewer than 4 cores), the serving TTFT p50/p99 tail
# ratio, or the spill-warm-vs-cold tiered TTFT ratio fall below
# absolute floors or regress beyond tolerance
# against the committed baselines in bench/baselines/ (bootstrap stubs
# until the first CI artifacts are committed — see bench/baselines/README.md).
#
# Usage: scripts/bench_smoke.sh
#   BENCH_OUT=/path/to.json   override the hot-path output location
#   PREFIX_OUT=/path/to.json  override the prefix-serving output location
#   DECODE_OUT=/path/to.json  override the decode-serving output location
#   SPEC_OUT=/path/to.json    override the speculative-decode output location
#   QUANT_OUT=/path/to.json   override the quantized-KV output location
#   GEMM_OUT=/path/to.json    override the dense-GEMM output location
#   SERVING_OUT=/path/to.json override the open-loop serving output location
#   TIERED_OUT=/path/to.json  override the tiered-KV-pool output location
#   BENCH_CHECK=1             run the regression gate after the benches
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_SMOKE=1
export BENCH_OUT="${BENCH_OUT:-$PWD/BENCH_hotpath.json}"
export PREFIX_OUT="${PREFIX_OUT:-$PWD/BENCH_prefix.json}"
export DECODE_OUT="${DECODE_OUT:-$PWD/BENCH_decode.json}"
export SPEC_OUT="${SPEC_OUT:-$PWD/BENCH_spec.json}"
export QUANT_OUT="${QUANT_OUT:-$PWD/BENCH_quant.json}"
export GEMM_OUT="${GEMM_OUT:-$PWD/BENCH_gemm.json}"
export SERVING_OUT="${SERVING_OUT:-$PWD/BENCH_serving.json}"
export TIERED_OUT="${TIERED_OUT:-$PWD/BENCH_tiered.json}"

cargo bench --manifest-path rust/Cargo.toml --bench micro_hotpath
cargo bench --manifest-path rust/Cargo.toml --bench prefix_serving
cargo bench --manifest-path rust/Cargo.toml --bench decode_serving
cargo bench --manifest-path rust/Cargo.toml --bench spec_serving
cargo bench --manifest-path rust/Cargo.toml --bench quant_serving
cargo bench --manifest-path rust/Cargo.toml --bench gemm_serving
cargo bench --manifest-path rust/Cargo.toml --bench serving_load
cargo bench --manifest-path rust/Cargo.toml --bench tiered_serving

echo "bench_smoke: wrote $BENCH_OUT, $PREFIX_OUT, $DECODE_OUT, $SPEC_OUT, $QUANT_OUT, $GEMM_OUT, $SERVING_OUT and $TIERED_OUT"

if [[ "${BENCH_CHECK:-0}" == "1" ]]; then
  python3 scripts/check_bench.py
fi
