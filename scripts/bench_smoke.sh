#!/usr/bin/env bash
# Hot-path perf smoke: runs `cargo bench --bench micro_hotpath` in the
# reduced configuration (one 16k-token cache, GQA 32q/8kv, d=128, QUOKA
# budget ≈ 12 % of T, 3 measured iters) and writes BENCH_hotpath.json at
# the repo root — one entry per measured piece with keys `config`,
# `wall-ns`, `GFLOP/s` — so the perf trajectory is tracked PR over PR.
#
# Usage: scripts/bench_smoke.sh
#   BENCH_OUT=/path/to.json  override the output location
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_SMOKE=1
export BENCH_OUT="${BENCH_OUT:-$PWD/BENCH_hotpath.json}"

cargo bench --manifest-path rust/Cargo.toml --bench micro_hotpath

echo "bench_smoke: wrote $BENCH_OUT"
