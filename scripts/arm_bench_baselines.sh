#!/usr/bin/env bash
# Arm the CI bench-regression gate: copy a green main run's bench-results
# artifact over the bootstrap stubs in bench/baselines/.
#
# Usage: scripts/arm_bench_baselines.sh /path/to/unzipped/bench-results
#
# The directory must contain ALL gated artifacts (a partial copy would
# silently leave some metrics on the floor-only bootstrap path, which
# reads as "armed" in CI logs when it isn't). After running, review the
# diff and commit; commit the same run's `cargo-lock` artifact as
# rust/Cargo.lock alongside it.
set -euo pipefail
cd "$(dirname "$0")/.."

src="${1:?usage: scripts/arm_bench_baselines.sh /path/to/bench-results}"
files=(BENCH_hotpath.json BENCH_prefix.json BENCH_decode.json BENCH_spec.json BENCH_quant.json BENCH_gemm.json BENCH_serving.json BENCH_tiered.json)

for f in "${files[@]}"; do
  [[ -s "$src/$f" ]] || { echo "error: $src/$f missing or empty — need the full artifact set" >&2; exit 1; }
  python3 -c "import json,sys; json.load(open(sys.argv[1]))" "$src/$f" \
    || { echo "error: $src/$f is not valid JSON" >&2; exit 1; }
done

for f in "${files[@]}"; do
  cp "$src/$f" "bench/baselines/$f"
  echo "armed bench/baselines/$f"
done

echo "done — review 'git diff bench/baselines' and commit"
