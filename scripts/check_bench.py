#!/usr/bin/env python3
"""Bench regression gate for CI.

Reads the eight bench artifacts written by scripts/bench_smoke.sh

  BENCH_hotpath.json  — tiled-vs-seed chunk-attention kernel speedup
  BENCH_prefix.json   — warm-vs-cold and in-flight-vs-cold prefix TTFT
  BENCH_decode.json   — batched-vs-serial decode throughput
  BENCH_spec.json     — speculative-vs-plain decode throughput
  BENCH_quant.json    — int8-vs-fp32 KV decode throughput
  BENCH_gemm.json     — parallel-vs-serial packed GEMM speedup (prefill
                        shape; the floor is waived when the artifact
                        reports fewer than 4 cores — a 2x parallel
                        speedup is not achievable there)
  BENCH_serving.json  — open-loop serving TTFT tail tightness: the
                        p50/p99 ratio of the server's TTFT histogram
                        (1.0 = flat; the floor keeps p99 within a
                        bounded multiple of p50 under Poisson load)
  BENCH_tiered.json   — tiered KV pool: warm-from-spill vs cold-recompute
                        TTFT for a re-requested shared prefix evicted
                        under pool pressure (promoting page images off
                        the mmap spill tier must beat recomputing them)

and fails (exit 1) when a headline metric

  * falls below its absolute floor (a hard sanity bound: the optimization
    must still be an optimization), or
  * regresses by more than --tolerance relative to the committed baseline
    in bench/baselines/ (same file names).

Baseline entries that are missing, null, or measured under a different
`config` string are skipped with a warning — that is the bootstrap path:
the first CI run on real hardware uploads its artifacts, which get
committed to bench/baselines/ to arm the relative gate.

Environment overrides (floors): CHECK_BENCH_MIN_HOTPATH,
CHECK_BENCH_MIN_PREFIX_WARM, CHECK_BENCH_MIN_PREFIX_INFLIGHT,
CHECK_BENCH_MIN_DECODE, CHECK_BENCH_MIN_SPEC, CHECK_BENCH_MIN_QUANT,
CHECK_BENCH_MIN_GEMM, CHECK_BENCH_MIN_SERVING, CHECK_BENCH_MIN_TIERED;
relative tolerance: CHECK_BENCH_TOL (fraction, default 0.35 — CI runners
are noisy).

Usage: scripts/check_bench.py [--bench-dir DIR] [--baseline-dir DIR]
"""

import argparse
import json
import os
import sys


def env_float(name, default):
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


FLOORS = {
    "hotpath-tiled-speedup": env_float("CHECK_BENCH_MIN_HOTPATH", 1.2),
    "prefix-warm-ttft-speedup": env_float("CHECK_BENCH_MIN_PREFIX_WARM", 1.5),
    "prefix-inflight-ttft-speedup": env_float("CHECK_BENCH_MIN_PREFIX_INFLIGHT", 1.2),
    "decode-batched-speedup": env_float("CHECK_BENCH_MIN_DECODE", 1.2),
    "spec-decode-speedup": env_float("CHECK_BENCH_MIN_SPEC", 1.5),
    "quant-decode-speedup": env_float("CHECK_BENCH_MIN_QUANT", 1.5),
    "gemm-parallel-speedup": env_float("CHECK_BENCH_MIN_GEMM", 2.0),
    # TTFT p50/p99 under open-loop load: 0.02 means p99 may be at most
    # 50x the median before the gate trips.
    "serving-ttft-tail": env_float("CHECK_BENCH_MIN_SERVING", 0.02),
    # Re-serving an evicted prefix from the spill tier must beat
    # recomputing it by at least this factor.
    "tiered-spill-ttft-speedup": env_float("CHECK_BENCH_MIN_TIERED", 2.0),
}

# The parallel-GEMM floor assumes enough cores to scale; below this the
# absolute floor is waived (the relative gate still applies).
GEMM_MIN_CORES = 4


def load(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def hotpath_speedup(doc):
    """Worst-case tiled-vs-seed speedup across measured shapes.

    Entries look like {"config": "attn_tiled T=16384 ...", "wall-ns": ...};
    the seed kernel entry for the same shape is "attn_seed T=16384 ...".
    """
    if not doc or "entries" not in doc:
        return None, None
    tiled, seed = {}, {}
    for e in doc["entries"]:
        cfg = e.get("config", "")
        kind, _, shape = cfg.partition(" ")
        if kind == "attn_tiled":
            tiled[shape] = e.get("wall-ns")
        elif kind == "attn_seed":
            seed[shape] = e.get("wall-ns")
    ratios = [
        seed[s] / tiled[s]
        for s in tiled
        if s in seed and tiled[s] and seed[s] is not None
    ]
    return (min(ratios) if ratios else None), doc.get("mode")


def metric(doc, key):
    if not doc:
        return None
    v = doc.get(key)
    return float(v) if isinstance(v, (int, float)) else None


def gather(bench_dir):
    """Headline metrics of one artifact directory: name -> (value, config)."""
    out = {}
    hp = load(os.path.join(bench_dir, "BENCH_hotpath.json"))
    sp, mode = hotpath_speedup(hp)
    out["hotpath-tiled-speedup"] = (sp, mode)
    px = load(os.path.join(bench_dir, "BENCH_prefix.json"))
    pcfg = px.get("config") if px else None
    out["prefix-warm-ttft-speedup"] = (metric(px, "ttft-speedup"), pcfg)
    out["prefix-inflight-ttft-speedup"] = (metric(px, "inflight-speedup"), pcfg)
    dc = load(os.path.join(bench_dir, "BENCH_decode.json"))
    out["decode-batched-speedup"] = (metric(dc, "speedup"), dc.get("config") if dc else None)
    sp = load(os.path.join(bench_dir, "BENCH_spec.json"))
    out["spec-decode-speedup"] = (metric(sp, "speedup"), sp.get("config") if sp else None)
    qt = load(os.path.join(bench_dir, "BENCH_quant.json"))
    out["quant-decode-speedup"] = (metric(qt, "speedup"), qt.get("config") if qt else None)
    gm = load(os.path.join(bench_dir, "BENCH_gemm.json"))
    out["gemm-parallel-speedup"] = (
        metric(gm, "parallel-speedup"),
        gm.get("config") if gm else None,
    )
    sv = load(os.path.join(bench_dir, "BENCH_serving.json"))
    out["serving-ttft-tail"] = (
        metric(sv, "ttft-p50-over-p99"),
        sv.get("config") if sv else None,
    )
    td = load(os.path.join(bench_dir, "BENCH_tiered.json"))
    out["tiered-spill-ttft-speedup"] = (
        metric(td, "spill-warm-speedup"),
        td.get("config") if td else None,
    )
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench-dir", default=".", help="where the fresh BENCH_*.json live")
    ap.add_argument(
        "--baseline-dir", default="bench/baselines", help="committed baseline BENCH_*.json"
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=env_float("CHECK_BENCH_TOL", 0.35),
        help="allowed relative regression vs baseline (fraction)",
    )
    args = ap.parse_args()

    fresh = gather(args.bench_dir)
    base = gather(args.baseline_dir)
    gemm_doc = load(os.path.join(args.bench_dir, "BENCH_gemm.json"))
    gemm_cores = metric(gemm_doc, "cores")
    failures, rows = [], []
    for name, (value, cfg) in fresh.items():
        floor = FLOORS[name]
        if (
            name == "gemm-parallel-speedup"
            and gemm_cores is not None
            and gemm_cores < GEMM_MIN_CORES
        ):
            print(
                f"note: {name} floor waived — runner has {gemm_cores:.0f} cores "
                f"(< {GEMM_MIN_CORES})"
            )
            floor = 0.0
        bvalue, bcfg = base.get(name, (None, None))
        if value is None:
            failures.append(f"{name}: missing from fresh bench output")
            rows.append((name, "MISSING", floor, bvalue, "FAIL"))
            continue
        status, why = "ok", []
        if value < floor:
            status = "FAIL"
            why.append(f"below absolute floor {floor:.2f}")
        if bvalue is None:
            why.append("no baseline (bootstrap: commit this run's artifacts)")
        elif bcfg != cfg:
            why.append("baseline config differs; relative gate skipped")
        elif value < (1.0 - args.tolerance) * bvalue:
            status = "FAIL"
            why.append(
                f"regressed vs baseline {bvalue:.2f} beyond tolerance {args.tolerance:.0%}"
            )
        if status == "FAIL":
            failures.append(f"{name}: {value:.3f} — " + "; ".join(why))
        rows.append((name, f"{value:.3f}", floor, bvalue, status + (": " + "; ".join(why) if why else "")))

    w = max(len(r[0]) for r in rows)
    print(f"{'metric':<{w}}  {'value':>8}  {'floor':>6}  {'baseline':>8}  status")
    for name, value, floor, bvalue, status in rows:
        b = f"{bvalue:.3f}" if isinstance(bvalue, float) else "—"
        print(f"{name:<{w}}  {value:>8}  {floor:>6.2f}  {b:>8}  {status}")
    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
