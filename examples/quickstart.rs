//! Quickstart: spin up the engine in-process, serve a few requests with
//! QUOKA selection, and print latency numbers.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! Uses the host backend (no artifacts needed). For the compiled PJRT
//! path, see `examples/serve_e2e.rs`.

use quoka::coordinator::{Engine, EngineCfg, PolicySpec, SchedCfg};
use quoka::workload::corpus::{ByteTokenizer, Corpus};

fn main() -> anyhow::Result<()> {
    // 1. An engine over the small GQA model with chunked prefill (B_CP=128)
    //    and continuous batching.
    let mut engine = Engine::new_host(
        "serve-small",
        EngineCfg {
            sched: SchedCfg { b_cp: 128, step_tokens: 256, max_running: 4 },
            ..EngineCfg::default()
        },
    )?;
    let tok = ByteTokenizer::new(engine.model_cfg().vocab);

    // 2. Three prompts; each request picks its own selection policy.
    let mut corpus = Corpus::new(7);
    let prompts = [
        (corpus.text(2000), "quoka", 512),
        (corpus.text(3000), "dense", 0),
        (corpus.text(2500), "sample", 512),
    ];
    for (text, policy, budget) in &prompts {
        let id = engine.submit(
            tok.encode(text),
            8,
            PolicySpec { name: policy.to_string(), budget: *budget },
        )?;
        println!("submitted request {id} with policy={policy}");
    }

    // 3. Run the engine to completion and report.
    let results = engine.run_to_completion()?;
    for r in &results {
        println!(
            "request {}: prompt={} tok, generated={} tok, ttft={:.1} ms, tpot={:.2} ms",
            r.id,
            r.prompt_tokens,
            r.generated.len(),
            r.ttft_s * 1e3,
            r.tpot_s * 1e3,
        );
    }
    println!("\nengine: {}", engine.metrics.summary());
    Ok(())
}
