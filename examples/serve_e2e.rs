//! End-to-end validation run (EXPERIMENTS.md §E2E): start the full stack —
//! PJRT artifacts compiled from the JAX/Pallas model, the Rust engine with
//! Sarathi-style chunked prefill, the TCP server — and serve a batched
//! request mix, comparing dense vs QUOKA TTFT/throughput on the same
//! workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! QUOKA_E2E_BACKEND=host cargo run --release --example serve_e2e   # no artifacts
//! ```

use quoka::coordinator::{Engine, EngineCfg, SchedCfg};
use quoka::server::{serve, Client, WireRequest};
use quoka::workload::corpus::{request_mix, Corpus};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let backend = std::env::var("QUOKA_E2E_BACKEND").unwrap_or_else(|_| {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            "pjrt".into()
        } else {
            "host".into()
        }
    });
    println!("== QUOKA-Serve end-to-end validation (backend: {backend}) ==");

    let cfg = EngineCfg {
        sched: SchedCfg { b_cp: 128, step_tokens: 384, max_running: 8 },
        pool_blocks: 8192,
        block_tokens: 128,
        seed: 0,
        ..EngineCfg::default()
    };
    let b2 = backend.clone();
    let handle = serve(
        move || match b2.as_str() {
            "pjrt" => Engine::new_pjrt("artifacts", cfg),
            _ => Engine::new_host("serve-small", cfg),
        },
        "127.0.0.1:0",
    )?;
    let addr = handle.addr;
    println!("server on {addr}");

    // A mixed batch: prompt lengths log-uniform in [512, 3072], 16 decode
    // tokens each (kept modest so the dense baseline finishes on CPU).
    let mix = request_mix(6, 512, 3072, 16, 42);
    let mut corpus = Corpus::new(9);
    let prompts: Vec<String> = mix.iter().map(|r| corpus.text(r.prompt_tokens)).collect();

    for (policy, budget) in [("dense", 0usize), ("quoka", 1024)] {
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for (i, prompt) in prompts.iter().enumerate() {
            let prompt = prompt.clone();
            let max_new = mix[i].decode_tokens;
            let policy = policy.to_string();
            handles.push(std::thread::spawn(move || -> anyhow::Result<_> {
                let mut c = Client::connect(addr)?;
                c.request(&WireRequest { prompt, max_new, policy, budget, spec: None })
            }));
        }
        let mut ttfts = Vec::new();
        let mut total_tokens = 0usize;
        for h in handles {
            let r = h.join().unwrap()?;
            ttfts.push(r.ttft_ms);
            total_tokens += r.prompt_tokens + r.generated;
        }
        let wall = t0.elapsed().as_secs_f64();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = ttfts.iter().sum::<f64>() / ttfts.len() as f64;
        println!(
            "[{policy:>5}] batch of {}: wall {:.2}s, throughput {:.0} tok/s, \
             TTFT mean {:.0}ms / p50 {:.0}ms / max {:.0}ms",
            prompts.len(),
            wall,
            total_tokens as f64 / wall,
            mean,
            ttfts[ttfts.len() / 2],
            ttfts[ttfts.len() - 1],
        );
    }
    println!("expected shape: quoka TTFT <= dense, gap widening with prompt length");
    handle.shutdown();
    Ok(())
}
