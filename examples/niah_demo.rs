//! Needle-in-a-haystack demo: plant a fact in a long prompt and show which
//! selection policies retain the needle's KV entries across depth × length
//! (the paper's Fig. 4 mechanism, condensed).
//!
//! ```bash
//! cargo run --release --example niah_demo
//! ```

use quoka::eval::{eval_policy, EvalOpts};
use quoka::select::policy_by_name;
use quoka::util::timing::heatmap;
use quoka::workload::niah::{build, grid};

fn main() -> anyhow::Result<()> {
    println!("== NIAH demo: needle recall by depth x length, B_SA=512 ==\n");
    let lengths = [2048usize, 4096, 8192];
    let depths = 5usize;
    let cells = grid(&lengths, depths);
    for method in ["dense", "quoka", "sample", "keydiff"] {
        let policy = policy_by_name(method)?;
        let mut rows = vec![vec![0.0f32; lengths.len()]; depths];
        for cell in &cells {
            let task = build(cell, 128, 3);
            let s = eval_policy(
                &task,
                policy.as_ref(),
                512,
                &EvalOpts { skip_fidelity: true, ..Default::default() },
            );
            let li = lengths.iter().position(|&l| l == cell.length).unwrap();
            let di = ((cell.depth * depths as f32) as usize).min(depths - 1);
            rows[di][li] = s.recall();
        }
        let row_labels: Vec<String> =
            (0..depths).map(|d| format!("{:>3}%", 100 * d / depths)).collect();
        let col_labels: Vec<String> = lengths.iter().map(|l| l.to_string()).collect();
        println!("{}", heatmap(&format!("[{method}]"), &row_labels, &col_labels, &rows));
    }
    println!("reading: '@@' = needle always retrieved, blank = lost.");
    println!("quoka should match dense; keydiff (query-agnostic) should fade with length.");
    Ok(())
}
