//! Ablation sweep: the efficiency–accuracy trade-off surface of QUOKA on
//! one workload — budget × N_Q × scoring/aggregation variants — in one run
//! (paper §4.5 condensed).
//!
//! ```bash
//! cargo run --release --example ablation_sweep
//! ```

use quoka::eval::EvalOpts;
use quoka::select::{Quoka, QuokaConfig, QueryAgg, Scoring};
use quoka::util::timing::Table;
use quoka::workload::ruler;

fn main() -> anyhow::Result<()> {
    println!("== QUOKA ablation sweep (RULER proxy, t=4096, B_CP=128) ==\n");
    let t_len = 4096usize;
    let opts = EvalOpts { skip_fidelity: true, ..Default::default() };

    // Budget sweep.
    let mut budget_table = Table::new(&["B_SA", "score", "kv fraction"]);
    for budget in [128usize, 256, 512, 1024, 2048] {
        let q = Quoka::default();
        let s = ruler::score(&q, budget, t_len, 128, 5, &opts);
        budget_table.row(vec![
            budget.to_string(),
            format!("{s:.1}"),
            format!("{:.1}%", 100.0 * budget as f32 / t_len as f32),
        ]);
    }
    println!("budget sweep:");
    budget_table.print();

    // N_Q sweep.
    let mut nq_table = Table::new(&["N_Q", "score"]);
    for nq in [2usize, 4, 8, 16, 32, 64] {
        let q = Quoka::new(QuokaConfig { n_q: nq, ..QuokaConfig::default() });
        let s = ruler::score(&q, 512, t_len, 128, 5, &opts);
        nq_table.row(vec![nq.to_string(), format!("{s:.1}")]);
    }
    println!("\nN_Q sweep (B_SA=512):");
    nq_table.print();

    // Design-choice ablations.
    let mut var_table = Table::new(&["variant", "score"]);
    let variants: Vec<(&str, QuokaConfig)> = vec![
        ("cosine+max (QUOKA)", QuokaConfig::default()),
        ("dot+max", QuokaConfig { scoring: Scoring::Dot, ..QuokaConfig::default() }),
        ("cosine+mean", QuokaConfig { query_agg: QueryAgg::Mean, ..QuokaConfig::default() }),
        (
            "dot+mean",
            QuokaConfig {
                scoring: Scoring::Dot,
                query_agg: QueryAgg::Mean,
                ..QuokaConfig::default()
            },
        ),
    ];
    for (name, cfg) in variants {
        let s = ruler::score(&Quoka::new(cfg), 512, t_len, 128, 5, &opts);
        var_table.row(vec![name.to_string(), format!("{s:.1}")]);
    }
    println!("\ndesign ablations (B_SA=512):");
    var_table.print();
    println!("\nexpected shape: graceful budget degradation; flat N_Q; cosine+max on top.");
    Ok(())
}
