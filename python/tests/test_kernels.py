"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
``ref.py``. This is the gate before kernels are embedded in artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.chunk_attn import chunk_attention
from compile.kernels.quoka_select import quoka_scores
from compile.kernels.ref import (
    attention_ref,
    preaggregate_ref,
    query_subselect_ref,
    quoka_scores_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------- scores


@settings(max_examples=20, deadline=None)
@given(
    n_kv=st.sampled_from([1, 2, 4]),
    n_q=st.sampled_from([1, 4, 16]),
    d=st.sampled_from([8, 32, 64]),
    tiles=st.integers(1, 3),
    frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31),
)
def test_quoka_scores_matches_ref(n_kv, n_q, d, tiles, frac, seed):
    rng = np.random.default_rng(seed)
    t = 512 * tiles
    t_len = max(1, int(t * frac))
    qbar = rand(rng, (n_kv, n_q, d))
    k = rand(rng, (n_kv, t, d))
    ref = quoka_scores_ref(qbar, k, t_len)
    got = quoka_scores(qbar, k, t_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_quoka_scores_bf16_inputs():
    rng = np.random.default_rng(0)
    qbar = rand(rng, (2, 4, 32), jnp.bfloat16)
    k = rand(rng, (2, 512, 32), jnp.bfloat16)
    got = quoka_scores(qbar, k, 300)
    ref = quoka_scores_ref(qbar.astype(jnp.float32), k.astype(jnp.float32), 300)
    np.testing.assert_allclose(np.asarray(got)[:, :300], np.asarray(ref)[:, :300], rtol=2e-2, atol=2e-2)


def test_quoka_scores_zero_key_row_defined():
    qbar = jnp.ones((1, 2, 8))
    k = jnp.zeros((1, 512, 8))
    got = quoka_scores(qbar, k, 512)
    assert bool(jnp.all(jnp.isfinite(got))), "zero keys must not produce NaN"
    np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)


def test_quoka_scores_tail_is_masked():
    rng = np.random.default_rng(1)
    got = quoka_scores(rand(rng, (1, 4, 8)), rand(rng, (1, 1024, 8)), 700)
    assert bool(jnp.all(got[:, 700:] == -jnp.inf))
    assert bool(jnp.all(jnp.isfinite(got[:, :700])))


# -------------------------------------------------------------- attention


@settings(max_examples=20, deadline=None)
@given(
    n_kv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([1, 3, 16]),
    d=st.sampled_from([8, 32]),
    tiles=st.integers(1, 2),
    frac=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31),
)
def test_chunk_attention_matches_ref(n_kv, g, s, d, tiles, frac, seed):
    rng = np.random.default_rng(seed)
    length = 512 * tiles
    n_past = min(int(length * frac), length - s)
    q = rand(rng, (n_kv * g, s, d))
    k = rand(rng, (n_kv, length, d))
    v = rand(rng, (n_kv, length, d))
    ref = attention_ref(q, k, v, n_past, True)
    got = chunk_attention(q, k, v, n_past)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_chunk_attention_decode_no_causal():
    rng = np.random.default_rng(3)
    q = rand(rng, (4, 1, 16))
    k = rand(rng, (2, 512, 16))
    v = rand(rng, (2, 512, 16))
    ref = attention_ref(q, k, v, 200, False)
    got = chunk_attention(q, k, v, 200, causal_self=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_chunk_attention_zero_past_is_pure_causal_self():
    rng = np.random.default_rng(4)
    s, d = 8, 16
    q = rand(rng, (2, s, d))
    k = rand(rng, (1, 512, d))
    v = rand(rng, (1, 512, d))
    got = chunk_attention(q, k, v, 0)
    ref = attention_ref(q, k, v, 0, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)
    # Row 0 attends only to self position 0: output == v[:, 0].
    np.testing.assert_allclose(np.asarray(got[0, 0]), np.asarray(v[0, 0]), rtol=1e-4, atol=1e-5)


def test_chunk_attention_weights_sum_to_one():
    rng = np.random.default_rng(5)
    q = rand(rng, (2, 4, 8))
    k = rand(rng, (1, 512, 8))
    v = jnp.full((1, 512, 8), 3.25)
    got = chunk_attention(q, k, v, 100)
    np.testing.assert_allclose(np.asarray(got), 3.25, rtol=1e-5)


# ------------------------------------------------------ query subselection


@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([8, 32, 128]),
    d=st.sampled_from([8, 64]),
    n_sel=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31),
)
def test_query_subselect_picks_most_dissimilar(h, s, d, n_sel, seed):
    if n_sel > s:
        return
    rng = np.random.default_rng(seed)
    q = rand(rng, (h, s, d))
    got = query_subselect_ref(q, n_sel)
    assert got.shape == (h, n_sel, d)
    # Oracle: recompute similarities and check the retained set matches the
    # n_sel lowest.
    qn = np.asarray(q)
    for hh in range(h):
        m = qn[hh].mean(0)
        sims = np.array([
            np.dot(row, m) / (np.linalg.norm(row) * np.linalg.norm(m) + 1e-30)
            for row in qn[hh]
        ])
        want = set(np.argsort(sims)[:n_sel])
        got_rows = {tuple(np.round(r, 4)) for r in np.asarray(got[hh])}
        want_rows = {tuple(np.round(qn[hh][i], 4)) for i in want}
        assert got_rows == want_rows


def test_preaggregation_identity():
    """Group-mean of normalized queries ∘ dot == mean of cosine scores —
    the linearity identity behind QUOKA's pre-aggregation (paper §3.3)."""
    rng = np.random.default_rng(7)
    h, nq, d, n_kv, t = 4, 8, 16, 2, 64
    q = rand(rng, (h, nq, d))
    k = rand(rng, (n_kv, t, d))
    qbar = preaggregate_ref(q, n_kv)
    pre = quoka_scores_ref(qbar, k, t)  # [n_kv, t]
    # Post-aggregation oracle: per-head cosine scores, averaged over group.
    kn = np.asarray(k) / np.linalg.norm(np.asarray(k), axis=-1, keepdims=True)
    qn = np.asarray(q) / np.linalg.norm(np.asarray(q), axis=-1, keepdims=True)
    g = h // n_kv
    for kv in range(n_kv):
        cos = np.einsum("gqd,td->gqt", qn[kv * g:(kv + 1) * g], kn[kv])
        post = cos.mean(axis=0).max(axis=0)
        np.testing.assert_allclose(np.asarray(pre[kv]), post, rtol=1e-5, atol=1e-5)


def test_scores_invariant_to_key_scale():
    """Cosine scoring is scale-free (Table 9's motivation)."""
    rng = np.random.default_rng(8)
    qbar = rand(rng, (1, 4, 16))
    k = rand(rng, (1, 512, 16))
    a = quoka_scores(qbar, k, 512)
    b = quoka_scores(qbar, k * 37.5, 512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
