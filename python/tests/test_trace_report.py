"""Smoke tests for scripts/trace_report.py (stdlib-only — no jax).

Builds synthetic traces matching the engine's JSONL schema and checks the
validator accepts well-formed span sequences, rejects broken ones, and
that the report renders without crashing.
"""

import importlib.util
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[2]
SCRIPT = REPO / "scripts" / "trace_report.py"

spec = importlib.util.spec_from_file_location("trace_report", SCRIPT)
trace_report = importlib.util.module_from_spec(spec)
spec.loader.exec_module(trace_report)


def ev(t_us, rid, name, **payload):
    return {"t_us": t_us, "id": rid, "ev": name, **payload}


def good_trace():
    return [
        ev(0, 1, "submit", prompt=128),
        ev(5, 1, "admit"),
        ev(6, 1, "chunk_start", start=0, len=64),
        ev(40, 1, "chunk_end", tokens=64),
        ev(41, 0, "step_end", prefill_tokens=64, decode_seqs=0, verify_seqs=0),
        ev(42, 2, "submit", prompt=128),
        ev(43, 2, "prefix_hit", pages=2),
        ev(44, 2, "park_on_prefix", on=1),
        ev(50, 1, "chunk_start", start=64, len=64),
        ev(90, 1, "first_token"),
        ev(91, 2, "adopt_pages", pages=3),
        ev(92, 2, "wake"),
        ev(95, 0, "phase_sample", scan=10, attn=20, append=5, gemm=30),
        ev(96, 0, "step_end", prefill_tokens=64, decode_seqs=1, verify_seqs=0),
        ev(120, 1, "finish"),
        ev(130, 2, "chunk_start", start=128, len=16),
        ev(150, 2, "first_token"),
        ev(180, 2, "finish"),
    ]


def write(tmp_path, events, name="trace.jsonl"):
    path = tmp_path / name
    path.write_text("".join(json.dumps(e) + "\n" for e in events))
    return path


def test_validate_accepts_well_formed(tmp_path):
    path = write(tmp_path, good_trace())
    assert trace_report.validate(trace_report.load(path)) == []
    assert trace_report.main([str(path), "--validate"]) == 0


def test_validate_catches_missing_terminal(tmp_path):
    events = [e for e in good_trace() if not (e["id"] == 2 and e["ev"] == "finish")]
    problems = trace_report.validate(trace_report.load(write(tmp_path, events)))
    assert any("without terminal" in p for p in problems)
    assert trace_report.main([str(write(tmp_path, events)), "--validate"]) == 1


def test_validate_catches_first_token_after_finish():
    events = good_trace()
    # Swap request 1's first_token and finish spans in ring order.
    i = next(k for k, e in enumerate(events) if e["ev"] == "first_token")
    j = next(k for k, e in enumerate(events) if e["ev"] == "finish")
    events[i], events[j] = events[j], events[i]
    events[i]["t_us"], events[j]["t_us"] = events[j]["t_us"], events[i]["t_us"]
    problems = trace_report.validate(events)
    assert any("first_token after finish" in p for p in problems)


def test_validate_catches_wake_without_adopt():
    events = [e for e in good_trace() if e["ev"] != "adopt_pages"]
    problems = trace_report.validate(events)
    assert any("adopt_pages" in p for p in problems)


def test_validate_accepts_promote_in_place_of_adopt():
    # Spill-tier promotion: the promote kick lands at submit (before the
    # park) and a promoted waiter may wake with zero adopt_pages.
    events = [e for e in good_trace() if e["ev"] != "adopt_pages"]
    i = next(k for k, e in enumerate(events) if e["ev"] == "park_on_prefix")
    events.insert(i, ev(events[i]["t_us"], 2, "promote", pages=3))
    assert trace_report.validate(events) == []


def test_waterfall_renders_tiering(capsys):
    events = good_trace()
    i = next(k for k, e in enumerate(events) if e["ev"] == "park_on_prefix")
    events.insert(i, ev(events[i]["t_us"], 2, "promote", pages=3))
    events.insert(i, ev(events[i]["t_us"], 0, "spill", pages=5))
    rows = trace_report.waterfall(events)
    by_id = {r["id"]: r for r in rows}
    assert by_id[2]["promoted"] == 3
    assert by_id[1]["promoted"] == 0
    out = capsys.readouterr().out
    assert "5 pages demoted to spill" in out
    assert "3 promotion pages kicked" in out


def test_validate_catches_timestamp_regression():
    events = good_trace()
    events[3]["t_us"] = 1  # earlier than its predecessor
    problems = trace_report.validate(events)
    assert any("regressed" in p for p in problems)


def test_report_renders(tmp_path, capsys):
    path = write(tmp_path, good_trace())
    assert trace_report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "per-request waterfall" in out
    assert "step occupancy (2 steps)" in out
    assert "phase time (1 samples)" in out
    # TTFT reconstructed from the trace: request 1 submit@0 -> first_token@90.
    assert "0.09" in out


def test_waterfall_numbers():
    rows = trace_report.waterfall(good_trace())
    by_id = {r["id"]: r for r in rows}
    assert by_id[1]["ttft_ms"] == "0.09"
    assert by_id[1]["terminal"] == "finish"
    assert by_id[2]["parked"] == "yes"
    assert by_id[2]["prefix_pages"] == 2
