"""L2 correctness: layer steps, RoPE semantics, QUOKA-vs-dense agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.model_config("tiny")


def weights(rng, cfg):
    return {n: jnp.asarray(rng.normal(size=sh) / np.sqrt(sh[0]), jnp.float32)
            for n, sh in M.layer_weight_shapes(cfg)}


def test_rope_positional_invariance():
    """<rope(q,m), rope(k,n)> depends only on m−n (the property the Rust
    implementation is also tested for — shared semantics)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(1, 8)), jnp.float32)
    dots = []
    for m, n in [(5, 2), (13, 10), (103, 100)]:
        a = M.rope(x, jnp.asarray([m], jnp.int32), 10_000.0)
        b = M.rope(y, jnp.asarray([n], jnp.int32), 10_000.0)
        dots.append(float(jnp.sum(a * b)))
    assert abs(dots[0] - dots[1]) < 1e-4
    assert abs(dots[1] - dots[2]) < 1e-4


def test_rope_matches_rust_formula():
    """Pairs (2i, 2i+1) rotated by pos * theta^(-2i/d) — exact match with
    rust/src/tensor/ops.rs::rope."""
    x = jnp.asarray([[1.0, 2.0, 3.0, 4.0]], jnp.float32)
    out = np.asarray(M.rope(x, jnp.asarray([7], jnp.int32), 10_000.0))[0]
    d, pos = 4, 7.0
    want = np.zeros(4, np.float32)
    for i in range(2):
        freq = 10_000.0 ** (-2.0 * i / d)
        ang = pos * freq
        a, b = x[0, 2 * i], x[0, 2 * i + 1]
        want[2 * i] = a * np.cos(ang) - b * np.sin(ang)
        want[2 * i + 1] = a * np.sin(ang) + b * np.cos(ang)
    np.testing.assert_allclose(out, want, rtol=1e-5)


def test_head_split_merge_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(5, 4 * 8)), jnp.float32)
    h = M.split_heads(x, 4, 8)
    assert h.shape == (4, 5, 8)
    back = M.merge_heads(h)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def _run_layer(kind, cfg, s, bucket, t_len, seed=3, **kw):
    rng = np.random.default_rng(seed)
    lw = weights(rng, cfg)
    nkv, dh = cfg["n_kv_heads"], cfg["d_head"]
    hidden = jnp.asarray(rng.normal(size=(s, cfg["d_model"])), jnp.float32)
    k_cache = jnp.zeros((nkv, bucket, dh), jnp.float32)
    v_cache = jnp.zeros((nkv, bucket, dh), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(nkv, t_len, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(nkv, t_len, dh)), jnp.float32)
    k_cache = k_cache.at[:, :t_len].set(kc)
    v_cache = v_cache.at[:, :t_len].set(vc)
    if kind == "dense":
        out = M.layer_dense(cfg, hidden, lw, k_cache, v_cache, t_len, 40)
    else:
        out = M.layer_quoka(cfg, hidden, lw, k_cache, v_cache, t_len, 40, **kw)
    return out


def test_layer_dense_shapes():
    cfg = CFG
    h, ks, vs = _run_layer("dense", cfg, 8, 512, 100)
    assert h.shape == (8, cfg["d_model"])
    assert ks.shape == (cfg["n_kv_heads"], 8, cfg["d_head"])
    assert bool(jnp.all(jnp.isfinite(h)))


def test_layer_quoka_full_budget_equals_dense():
    """With B_SA >= t_len QUOKA keeps the whole cache: outputs must match
    the dense layer exactly (selection only reorders keys, and attention is
    permutation-invariant)."""
    cfg = CFG
    hd, kd, vd = _run_layer("dense", cfg, 8, 512, 100, seed=5)
    hq, kq, vq = _run_layer("quoka", cfg, 8, 512, 100, seed=5, b_sa=128, n_q_sel=16)
    np.testing.assert_allclose(np.asarray(hd), np.asarray(hq), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kd), np.asarray(kq), rtol=1e-5, atol=1e-6)


def test_layer_quoka_tight_budget_runs_and_differs():
    cfg = CFG
    hd, _, _ = _run_layer("dense", cfg, 8, 512, 400, seed=6)
    hq, _, _ = _run_layer("quoka", cfg, 8, 512, 400, seed=6, b_sa=32, n_q_sel=4)
    assert bool(jnp.all(jnp.isfinite(hq)))
    assert float(jnp.max(jnp.abs(hd - hq))) > 1e-6, "tight budget must actually sparsify"


def test_layer_quoka_decode_path():
    cfg = CFG
    h, ks, vs = _run_layer("quoka", cfg, 1, 512, 300, seed=7, b_sa=64, n_q_sel=16, causal_self=False)
    assert h.shape == (1, cfg["d_model"])
    assert bool(jnp.all(jnp.isfinite(h)))


def test_empty_cache_chunk():
    """First chunk: t_len = 0 — both paths must work (pure self attention)."""
    cfg = CFG
    hd, _, _ = _run_layer("dense", cfg, 8, 512, 0, seed=8)
    hq, _, _ = _run_layer("quoka", cfg, 8, 512, 0, seed=8, b_sa=64, n_q_sel=16)
    np.testing.assert_allclose(np.asarray(hd), np.asarray(hq), rtol=2e-4, atol=2e-5)


def test_logits_tied_head():
    cfg = CFG
    rng = np.random.default_rng(9)
    emb = jnp.asarray(rng.normal(size=(cfg["vocab"], cfg["d_model"])), jnp.float32)
    row = jnp.asarray(rng.normal(size=(cfg["d_model"],)), jnp.float32)
    norm = jnp.ones((cfg["d_model"],), jnp.float32)
    out = M.logits(row, norm, emb, cfg["norm_eps"])
    assert out.shape == (cfg["vocab"],)
    # Tied head: logits = emb @ rmsnorm(row).
    normed = np.asarray(M.rmsnorm(row[None, :], norm, cfg["norm_eps"]))[0]
    want = np.asarray(emb) @ normed
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


def test_embed_gather():
    cfg = CFG
    emb = jnp.arange(cfg["vocab"] * cfg["d_model"], dtype=jnp.float32).reshape(cfg["vocab"], -1)
    toks = jnp.asarray([0, 5, 2], jnp.int32)
    out = M.embed(toks, emb)
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(emb[5]))
