"""AOT pipeline: artifacts are written, loadable, and the manifest contract
matches what the Rust runtime expects."""

import json
import os
import subprocess
import sys

import pytest

ART = "/tmp/quoka_aot_test"


@pytest.fixture(scope="module")
def artifacts():
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", ART, "--quick",
         "--buckets", "1024", "--b-sa", "512"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_contract(artifacts):
    m = artifacts
    assert m["model"]["name"] == "serve-small"
    assert m["buckets"] == [1024]
    assert m["b_sa"] == 512
    names = {a["name"] for a in m["artifacts"]}
    for want in [
        "layer_dense_T1024", "layer_quoka_T1024",
        "layer_dense_decode_T1024", "layer_quoka_decode_T1024",
        "embed_p", "embed_d", "logits", "quoka_select_T1024",
    ]:
        assert want in names, want
    # Layer artifacts declare the full argument order.
    layer = next(a for a in m["artifacts"] if a["name"] == "layer_quoka_T1024")
    assert layer["args"][0] == "hidden"
    assert layer["args"][-4:] == ["k_cache", "v_cache", "t_len", "pos0"]
    assert layer["outs"] == ["hidden", "k_self", "v_self"]


def test_hlo_files_exist_and_are_text(artifacts):
    for a in artifacts["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{a['file']} does not look like HLO text"


def test_artifacts_reload_and_execute(artifacts):
    """Round-trip: parse the HLO text back and execute via jax's CPU client
    (the same check the Rust runtime performs via the xla crate)."""
    import numpy as np
    from jax._src.lib import xla_client as xc

    path = os.path.join(ART, "logits.hlo.txt")
    with open(path) as f:
        text = f.read()
    comp = xc._xla.mlir.mlir_module_to_xla_computation  # noqa: F841 (text parse below)
    # Parse HLO text through the XLA client API.
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
