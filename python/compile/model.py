"""L2: the serving model's compute graph in JAX, calling the L1 kernels.

Mirrors ``rust/src/model/transformer.rs`` operation-for-operation (RMSNorm →
QKV+RoPE → attention → output projection → SwiGLU FFN, tied LM head) so the
host backend and the PJRT artifact path are numerically interchangeable
(checked by ``rust/tests/parity.rs``).

Two layer-step variants are lowered per KV bucket:

- ``layer_dense``  — attention over the full (bucketed) cache: the paper's
  dense chunked-prefill baseline.
- ``layer_quoka``  — Algorithm 1 end-to-end *inside XLA*: query
  subselection → pre-aggregation → the Pallas scoring kernel → static
  ``top_k(B_SA)`` → gather → dense attention over the reduced buffer. The
  whole selection pipeline lowers into the same HLO module as the layer.

Python runs only at AOT time; the Rust engine feeds these graphs weights
and caches as PJRT buffers.
"""

import jax
import jax.numpy as jnp

from .kernels.chunk_attn import chunk_attention
from .kernels.quoka_select import quoka_scores
from .kernels.ref import preaggregate_ref, query_subselect_ref, topk_desc


def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, positions, theta):
    """Rotary embedding matching the Rust implementation: pairs
    ``(x[2i], x[2i+1])`` rotated by ``pos * theta^(-2i/d)``.

    x: ``[..., s, d]``; positions: ``[s]`` int32.
    """
    d = x.shape[-1]
    half = d // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = theta ** (-2.0 * i / d)  # [half]
    angle = positions.astype(jnp.float32)[:, None] * freq[None, :]  # [s, half]
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    shape = x.shape[:-1] + (half, 2)
    x2 = x.reshape(shape)
    a, b = x2[..., 0], x2[..., 1]
    ra = a * cos - b * sin
    rb = a * sin + b * cos
    return jnp.stack([ra, rb], axis=-1).reshape(x.shape)


def split_heads(x, n_heads, d_head):
    """``[s, H*dh] -> [H, s, dh]``."""
    s = x.shape[0]
    return x.reshape(s, n_heads, d_head).transpose(1, 0, 2)


def merge_heads(x):
    """``[H, s, dh] -> [s, H*dh]``."""
    h, s, dh = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * dh)


def embed(tokens, embedding):
    """Token embedding gather. tokens: ``[s]`` int32."""
    return jnp.take(embedding, tokens, axis=0)


def logits(hidden_row, final_norm, embedding, eps):
    """Tied LM head over one hidden row ``[d_model]``."""
    normed = rmsnorm(hidden_row[None, :], final_norm, eps)[0]
    return embedding @ normed


def _qkv(hidden, cfg, lw, positions):
    """Shared prefix: norm, projections, head split, RoPE."""
    normed = rmsnorm(hidden, lw["attn_norm"], cfg["norm_eps"])
    q = split_heads(normed @ lw["wq"], cfg["n_q_heads"], cfg["d_head"])
    k = split_heads(normed @ lw["wk"], cfg["n_kv_heads"], cfg["d_head"])
    v = split_heads(normed @ lw["wv"], cfg["n_kv_heads"], cfg["d_head"])
    if cfg["use_rope"]:
        q = rope(q, positions, cfg["rope_theta"])
        k = rope(k, positions, cfg["rope_theta"])
    return normed, q, k, v


def _ffn(hidden, cfg, lw):
    normed = rmsnorm(hidden, lw["ffn_norm"], cfg["norm_eps"])
    gate = normed @ lw["w_gate"]
    up = normed @ lw["w_up"]
    act = jax.nn.silu(gate) * up
    return act @ lw["w_down"]


def _finish_layer(hidden, attn_heads, cfg, lw):
    hidden = hidden + merge_heads(attn_heads) @ lw["wo"]
    hidden = hidden + _ffn(hidden, cfg, lw)
    return hidden


def layer_dense(cfg, hidden, lw, k_cache, v_cache, t_len, pos0, causal_self=True):
    """Dense-baseline layer step over a bucketed cache.

    Args:
      hidden: ``[s, d_model]``; k_cache/v_cache: ``[n_kv, L, d]`` with
        ``t_len`` valid rows; pos0: scalar — absolute position of the
        chunk's first token.

    Returns:
      (hidden', k_self, v_self) — the chunk's KV for the Rust engine to
      append to its cache.
    """
    s = hidden.shape[0]
    positions = pos0 + jnp.arange(s, dtype=jnp.int32)
    _, q, k_self, v_self = _qkv(hidden, cfg, lw, positions)
    # Combined [past | self] buffer: write self keys after the valid past.
    # The bucket always leaves >= s rows of headroom (enforced at AOT time).
    k_comb = jax.lax.dynamic_update_slice(k_cache, k_self, (0, t_len, 0))
    v_comb = jax.lax.dynamic_update_slice(v_cache, v_self, (0, t_len, 0))
    attn = chunk_attention(q, k_comb, v_comb, t_len, causal_self=causal_self)
    return _finish_layer(hidden, attn, cfg, lw), k_self, v_self


def layer_quoka(cfg, hidden, lw, k_cache, v_cache, t_len, pos0, *, b_sa, n_q_sel, causal_self=True):
    """QUOKA layer step: Algorithm 1 + dense attention on the reduced set.

    ``b_sa`` (selection budget) and ``n_q_sel`` (max retained queries) are
    static — baked into the artifact and recorded in the manifest.
    """
    s = hidden.shape[0]
    positions = pos0 + jnp.arange(s, dtype=jnp.int32)
    _, q, k_self, v_self = _qkv(hidden, cfg, lw, positions)

    # --- Algorithm 1 ---
    n_q_eff = min(n_q_sel, s)
    q_sel = query_subselect_ref(q, n_q_eff) if s > n_q_eff else q
    qbar = preaggregate_ref(q_sel, cfg["n_kv_heads"])  # [n_kv, n_q_eff, d]
    scores = quoka_scores(qbar, k_cache, t_len)  # [n_kv, L]
    _, idx = topk_desc(scores, b_sa)  # [n_kv, b_sa]; -inf tail sorts last
    k_sel = jnp.take_along_axis(k_cache, idx[:, :, None], axis=1)  # [n_kv, b_sa, d]
    v_sel = jnp.take_along_axis(v_cache, idx[:, :, None], axis=1)
    n_valid = jnp.minimum(t_len, b_sa)

    # --- dense kernel over [selected | self] (fixed shape: QUOKA's point) ---
    # Extend by s rows first so the self-KV write never clamps into the
    # selected region when n_valid == b_sa.
    n_kv, _, dh = k_sel.shape
    zpad = jnp.zeros((n_kv, s, dh), k_sel.dtype)
    k_comb = jax.lax.dynamic_update_slice(
        jnp.concatenate([k_sel, zpad], axis=1), k_self, (0, n_valid, 0)
    )
    v_comb = jax.lax.dynamic_update_slice(
        jnp.concatenate([v_sel, zpad], axis=1), v_self, (0, n_valid, 0)
    )
    # Pad the combined buffer to a tile multiple for the Pallas kernel.
    length = k_comb.shape[1]
    pad = (-length) % 128
    if pad:
        k_comb = jnp.pad(k_comb, ((0, 0), (0, pad), (0, 0)))
        v_comb = jnp.pad(v_comb, ((0, 0), (0, pad), (0, 0)))
    attn = chunk_attention(q, k_comb, v_comb, n_valid, l_tile=128, causal_self=causal_self)
    return _finish_layer(hidden, attn, cfg, lw), k_self, v_self


def model_config(name="serve-small"):
    """Python mirror of ``ModelConfig::serve_small()`` / ``tiny()``."""
    if name == "serve-small":
        return dict(
            name="serve-small",
            vocab=4096,
            d_model=256,
            n_layers=4,
            n_q_heads=8,
            n_kv_heads=2,
            d_head=32,
            d_ff=768,
            rope_theta=500_000.0,
            use_rope=True,
            n_experts=0,
            norm_eps=1e-5,
            max_seq=65_536,
        )
    if name == "tiny":
        return dict(
            name="tiny",
            vocab=257,
            d_model=32,
            n_layers=2,
            n_q_heads=4,
            n_kv_heads=2,
            d_head=8,
            d_ff=64,
            rope_theta=10_000.0,
            use_rope=True,
            n_experts=0,
            norm_eps=1e-5,
            max_seq=4096,
        )
    raise ValueError(f"unknown python model config {name!r}")


def layer_weight_shapes(cfg):
    """Ordered (name, shape) list — the artifact argument contract."""
    dm, dh = cfg["d_model"], cfg["d_head"]
    dq, dkv = cfg["n_q_heads"] * dh, cfg["n_kv_heads"] * dh
    return [
        ("attn_norm", (dm,)),
        ("wq", (dm, dq)),
        ("wk", (dm, dkv)),
        ("wv", (dm, dkv)),
        ("wo", (dq, dm)),
        ("ffn_norm", (dm,)),
        ("w_gate", (dm, cfg["d_ff"])),
        ("w_up", (dm, cfg["d_ff"])),
        ("w_down", (cfg["d_ff"], dm)),
    ]
