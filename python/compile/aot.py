"""AOT pipeline: lower the L2/L1 graphs to HLO *text* artifacts.

Python runs exactly once (``make artifacts``); the Rust engine loads the
text with ``HloModuleProto::from_text_file``, compiles on the PJRT CPU
client, and executes with weights/caches as device buffers.

HLO **text** — not ``serialize()`` — is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Artifacts (per KV bucket L, serve-small config):
  layer_dense_T{L}        hidden[128,dm] ... -> (hidden', k_self, v_self)
  layer_quoka_T{L}        same, with Alg. 1 inside (B_SA, N_Q static)
  layer_dense_decode_T{L} s = 1 variant
  layer_quoka_decode_T{L} s = 1 variant
  embed_p / embed_d       token embedding for prefill chunk / decode step
  logits                  tied LM head over one hidden row
  quoka_select_T{L}       standalone Alg. 1 scorer (parity tests / hybrid)

``manifest.json`` records the model config, bucket list, static
hyperparameters and the exact argument order of every artifact — the
contract the Rust runtime loads.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.quoka_select import quoka_scores
from .kernels.ref import preaggregate_ref, query_subselect_ref, topk_desc

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(fn, example_args):
    """jit → lower → StableHLO → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def layer_arg_specs(cfg, s, bucket):
    """(name, spec) list for a layer-step artifact, in call order."""
    dm, dh, nkv = cfg["d_model"], cfg["d_head"], cfg["n_kv_heads"]
    args = [("hidden", spec((s, dm)))]
    args += [(n, spec(sh)) for n, sh in M.layer_weight_shapes(cfg)]
    args += [
        ("k_cache", spec((nkv, bucket, dh))),
        ("v_cache", spec((nkv, bucket, dh))),
        ("t_len", spec((), I32)),
        ("pos0", spec((), I32)),
    ]
    return args


def build_layer(cfg, s, bucket, kind, b_sa, n_q_sel):
    """Return (fn, example_specs) for one layer-step artifact."""
    names = [n for n, _ in M.layer_weight_shapes(cfg)]
    causal = s > 1

    def fn(hidden, *rest):
        lw = dict(zip(names, rest[: len(names)]))
        k_cache, v_cache, t_len, pos0 = rest[len(names):]
        if kind == "dense":
            out = M.layer_dense(cfg, hidden, lw, k_cache, v_cache, t_len, pos0, causal_self=causal)
        else:
            out = M.layer_quoka(
                cfg, hidden, lw, k_cache, v_cache, t_len, pos0,
                b_sa=b_sa, n_q_sel=n_q_sel, causal_self=causal,
            )
        return out  # (hidden', k_self, v_self)

    specs = [sp for _, sp in layer_arg_specs(cfg, s, bucket)]
    return fn, specs


def build_embed(cfg, s):
    def fn(tokens, embedding):
        return (M.embed(tokens, embedding),)

    return fn, [spec((s,), I32), spec((cfg["vocab"], cfg["d_model"]))]


def build_logits(cfg):
    def fn(hidden_row, final_norm, embedding):
        return (M.logits(hidden_row, final_norm, embedding, cfg["norm_eps"]),)

    return fn, [spec((cfg["d_model"],)), spec((cfg["d_model"],)), spec((cfg["vocab"], cfg["d_model"]))]


def build_select(cfg, s, bucket, b_sa, n_q_sel):
    """Standalone Algorithm-1 scorer: q + cache -> (indices, scores)."""
    nkv, dh = cfg["n_kv_heads"], cfg["d_head"]

    def fn(q, k_cache, t_len):
        n_q_eff = min(n_q_sel, s)
        q_sel = query_subselect_ref(q, n_q_eff) if s > n_q_eff else q
        qbar = preaggregate_ref(q_sel, nkv)
        scores = quoka_scores(qbar, k_cache, t_len)
        top_scores, idx = topk_desc(scores, b_sa)
        return idx.astype(I32), top_scores

    return fn, [spec((cfg["n_q_heads"], s, dh)), spec((nkv, bucket, dh)), spec((), I32)]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--model", default="serve-small")
    ap.add_argument("--buckets", default="1024,4096,16384,65536",
                    help="KV bucket lengths (comma separated, multiples of 512)")
    ap.add_argument("--b-cp", type=int, default=128, help="prefill chunk size")
    ap.add_argument("--b-sa", type=int, default=1024, help="selection budget baked into quoka artifacts")
    ap.add_argument("--n-q", type=int, default=16, help="max retained queries (N_Q)")
    ap.add_argument("--quick", action="store_true", help="only the smallest bucket (CI)")
    args = ap.parse_args()

    cfg = M.model_config(args.model)
    buckets = [int(b) for b in args.buckets.split(",")]
    if args.quick:
        buckets = buckets[:1]
    for b in buckets:
        assert b % 512 == 0, f"bucket {b} must be a multiple of the kernel tile (512)"
        assert b >= args.b_sa, f"bucket {b} < B_SA {args.b_sa}"

    os.makedirs(args.out_dir, exist_ok=True)
    artifacts = []

    def emit(name, fn, specs, **meta):
        path = f"{name}.hlo.txt"
        text = to_hlo_text(fn, specs)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        artifacts.append(dict(name=name, file=path, **meta))
        print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")

    print(f"AOT-lowering model={cfg['name']} buckets={buckets} "
          f"B_CP={args.b_cp} B_SA={args.b_sa} N_Q={args.n_q}")

    for s, tag in [(args.b_cp, ""), (1, "_decode")]:
        for bucket in buckets:
            for kind in ["dense", "quoka"]:
                fn, specs = build_layer(cfg, s, bucket, kind, args.b_sa, args.n_q)
                emit(
                    f"layer_{kind}{tag}_T{bucket}", fn, specs,
                    kind=kind, s=s, bucket=bucket,
                    args=[n for n, _ in layer_arg_specs(cfg, s, bucket)],
                    outs=["hidden", "k_self", "v_self"],
                )

    for s, tag in [(args.b_cp, "embed_p"), (1, "embed_d")]:
        fn, specs = build_embed(cfg, s)
        emit(tag, fn, specs, kind="embed", s=s, args=["tokens", "embedding"], outs=["hidden"])

    fn, specs = build_logits(cfg)
    emit("logits", fn, specs, kind="logits", args=["hidden_row", "final_norm", "embedding"], outs=["logits"])

    for bucket in buckets:
        fn, specs = build_select(cfg, args.b_cp, bucket, args.b_sa, args.n_q)
        emit(
            f"quoka_select_T{bucket}", fn, specs,
            kind="select", s=args.b_cp, bucket=bucket,
            args=["q", "k_cache", "t_len"], outs=["indices", "scores"],
        )

    manifest = dict(
        model=cfg,
        buckets=buckets,
        b_cp=args.b_cp,
        b_sa=args.b_sa,
        n_q_sel=args.n_q,
        layer_weights=[n for n, _ in M.layer_weight_shapes(cfg)],
        artifacts=artifacts,
    )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest.json ({len(artifacts)} artifacts)")


if __name__ == "__main__":
    main()
