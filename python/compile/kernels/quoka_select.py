"""L1 Pallas kernel: QUOKA cosine scoring with max aggregation.

The hot loop of Algorithm 1 (lines 6-10): stream the key cache through
VMEM in tiles along the sequence axis, normalize each tile, multiply by the
tiny pre-aggregated query block ``Q̄`` (resident in VMEM for the whole
grid), and max-reduce over the query axis.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is
``(n_kv, T // K_TILE)``; per step the kernel touches one ``[K_TILE, d]``
key tile (128 KiB at the default 512×64 f32) plus the ``[N_Q, d]`` query
block (4 KiB) — far under VMEM, with the ``N_Q×d×K_TILE`` matmul feeding
the MXU. A CUDA port would assign the same tile to a threadblock; the
BlockSpec expresses the identical HBM→scratch schedule.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode emits plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Keys processed per grid step.
K_TILE = 512


def _score_kernel(qbar_ref, k_ref, t_len_ref, out_ref, *, k_tile):
    """One (kv_head, key-tile) grid cell.

    qbar_ref: [n_q, d] — this head's pre-aggregated queries (whole block).
    k_ref:    [k_tile, d] — one tile of this head's keys.
    t_len_ref:[1] int32 — valid cache length.
    out_ref:  [k_tile] — max-aggregated cosine scores for the tile.
    """
    tile_idx = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)  # [k_tile, d]
    # Normalize keys (cosine scoring): zero rows stay zero.
    norms = jnp.sqrt(jnp.sum(k * k, axis=-1, keepdims=True))
    kn = k / jnp.maximum(norms, 1e-9)
    qb = qbar_ref[0].astype(jnp.float32)  # [n_q, d]
    # [n_q, k_tile] similarity block on the MXU, then max over queries.
    s = jax.lax.dot_general(qb, kn, (((1,), (1,)), ((), ())))
    smax = jnp.max(s, axis=0)
    # Mask the invalid tail of the cache.
    base = tile_idx * k_tile
    pos = base + jax.lax.iota(jnp.int32, k_tile)
    valid = pos < t_len_ref[0]
    out_ref[0, :] = jnp.where(valid, smax, -jnp.inf).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k_tile",))
def quoka_scores(qbar, k, t_len, k_tile=K_TILE):
    """Pallas-backed QUOKA scores.

    Args:
      qbar: ``[n_kv, n_q, d]`` pre-aggregated normalized queries.
      k: ``[n_kv, T, d]`` raw keys; ``T`` must be a multiple of ``k_tile``
         (the AOT pipeline buckets T in powers of two ≥ ``k_tile``).
      t_len: scalar int32 valid length.

    Returns:
      ``[n_kv, T]`` scores, -inf on the invalid tail.
    """
    n_kv, n_q, d = qbar.shape
    _, t, _ = k.shape
    assert t % k_tile == 0, f"T={t} must be a multiple of k_tile={k_tile}"
    t_len_arr = jnp.asarray(t_len, jnp.int32).reshape(1)
    grid = (n_kv, t // k_tile)
    return pl.pallas_call(
        functools.partial(_score_kernel, k_tile=k_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_q, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, k_tile, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1,), lambda h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, k_tile), lambda h, i: (h, i)),
        out_shape=jax.ShapeDtypeStruct((n_kv, t), jnp.float32),
        interpret=True,
    )(qbar, k, t_len_arr)
