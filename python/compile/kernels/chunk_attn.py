"""L1 Pallas kernel: flash-style chunk attention over [past | self] KV.

One grid cell per query head. Inside, the key/value buffer is streamed in
``L_TILE`` tiles with the classic online-softmax recurrence (running max
``m``, running normalizer ``l``, rescaled accumulator ``acc``), so the
working set per step is one K tile + one V tile + the chunk's query block —
the FlashAttention HBM→VMEM schedule expressed with a ``fori_loop`` instead
of CUDA threadblocks (DESIGN.md §Hardware-Adaptation).

Masking follows the engine's combined-buffer layout: columns ``< n_past``
are selected past tokens (always visible), columns ``n_past .. n_past+s``
are the chunk's own tokens (causally visible), everything after is padding.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

L_TILE = 512
NEG = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, n_past_ref, o_ref, *, l_tile, causal_self, g):
    """One query head.

    q_ref: [1, s, d]; k_ref/v_ref: [1, L, d] (this head's KV-group slab);
    n_past_ref: [1] int32; o_ref: [1, s, d].
    """
    q = q_ref[0].astype(jnp.float32)  # [s, d]
    s, d = q.shape
    length = k_ref.shape[1]
    n_past = n_past_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    rows = jax.lax.iota(jnp.int32, s)[:, None]  # [s, 1]

    def body(i, carry):
        m, l, acc = carry
        start = i * l_tile
        kt = jax.lax.dynamic_slice(k_ref[0], (start, 0), (l_tile, d)).astype(jnp.float32)
        vt = jax.lax.dynamic_slice(v_ref[0], (start, 0), (l_tile, d)).astype(jnp.float32)
        logits = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ()))) * scale  # [s, l_tile]
        cols = start + jax.lax.iota(jnp.int32, l_tile)[None, :]  # [1, l_tile]
        past_ok = cols < n_past
        if causal_self:
            self_ok = (cols >= n_past) & (cols - n_past <= rows) & (cols < n_past + s)
        else:
            self_ok = (cols >= n_past) & (cols < n_past + s)
        logits = jnp.where(past_ok | self_ok, logits, NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=1))  # [s]
        p = jnp.exp(logits - m_new[:, None])  # [s, l_tile]
        alpha = jnp.exp(m - m_new)  # [s]
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ vt
        return m_new, l_new, acc_new

    m0 = jnp.full((s,), NEG, jnp.float32)
    l0 = jnp.zeros((s,), jnp.float32)
    acc0 = jnp.zeros((s, d), jnp.float32)
    n_tiles = length // l_tile
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, :] = out.astype(o_ref.dtype)
    del g


@functools.partial(jax.jit, static_argnames=("l_tile", "causal_self"))
def chunk_attention(q, k, v, n_past, l_tile=L_TILE, causal_self=True):
    """Pallas-backed chunk attention.

    Args:
      q: ``[n_q_heads, s, d]``.
      k, v: ``[n_kv, L, d]`` with ``L`` a multiple of ``l_tile``.
      n_past: scalar int32 — valid past rows.
      causal_self: apply the in-chunk causal mask (False for decode).

    Returns:
      ``[n_q_heads, s, d]``.
    """
    n_q, s, d = q.shape
    n_kv, length, _ = k.shape
    g = n_q // n_kv
    assert length % l_tile == 0, f"L={length} not a multiple of {l_tile}"
    n_past_arr = jnp.asarray(n_past, jnp.int32).reshape(1)
    return pl.pallas_call(
        functools.partial(_attn_kernel, l_tile=l_tile, causal_self=causal_self, g=g),
        grid=(n_q,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda h: (h, 0, 0)),
            # Each query head reads its KV-group head h // g.
            pl.BlockSpec((1, length, d), lambda h: (h // g, 0, 0)),
            pl.BlockSpec((1, length, d), lambda h: (h // g, 0, 0)),
            pl.BlockSpec((1,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, s, d), q.dtype),
        interpret=True,
    )(q, k, v, n_past_arr)
