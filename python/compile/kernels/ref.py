"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is checked against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps over shapes) before the
AOT pipeline is allowed to embed it in an artifact.
"""

import jax
import jax.numpy as jnp


def topk_desc(x, k):
    """Sort-based descending top-k: ``(values, indices)`` along the last
    axis, ties broken by lower index.

    ``jax.lax.top_k`` lowers to the dedicated ``topk(..., largest=true)``
    HLO op, which the xla_extension 0.5.1 text parser (the Rust runtime's
    XLA) rejects; a comparator ``sort`` parses everywhere. Used by every
    graph that gets AOT-lowered.
    """
    n = x.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), x.shape)
    neg_sorted, idx_sorted = jax.lax.sort((-x, idx), dimension=-1, num_keys=1, is_stable=True)
    return -neg_sorted[..., :k], idx_sorted[..., :k]


def quoka_scores_ref(qbar, k, t_len):
    """QUOKA cosine scores with max aggregation (paper Alg. 1, lines 6-10).

    Args:
      qbar: ``[n_kv, n_q, d]`` pre-aggregated (group-mean of normalized)
        queries. NOT re-normalized here — normalization happened before the
        group mean, per the pre-aggregation identity.
      k: ``[n_kv, T, d]`` raw keys.
      t_len: scalar — valid prefix of the T axis.

    Returns:
      ``[n_kv, T]`` scores; invalid tail = -inf.
    """
    kn = k / jnp.maximum(jnp.linalg.norm(k, axis=-1, keepdims=True), 1e-9)
    s = jnp.einsum("hqd,htd->hqt", qbar, kn)  # [n_kv, n_q, T]
    smax = jnp.max(s, axis=1)  # [n_kv, T]
    valid = jnp.arange(k.shape[1])[None, :] < t_len
    return jnp.where(valid, smax, -jnp.inf)


def attention_ref(q, k, v, n_past, causal_self):
    """Masked attention over a combined [past | self] KV buffer.

    Args:
      q: ``[n_q_heads, s, d]``.
      k, v: ``[n_kv, L, d]`` — the first ``n_past`` rows are past (always
        visible), rows ``n_past..n_past+s`` are the chunk's own tokens
        (causally visible when ``causal_self``), anything beyond is padding.
      n_past: scalar int32.
      causal_self: python bool — False for pure decode (s == 1).

    Returns:
      ``[n_q_heads, s, d]``.
    """
    n_q, s, d = q.shape
    n_kv, length, _ = k.shape
    g = n_q // n_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32)).astype(q.dtype)
    kk = jnp.repeat(k, g, axis=0)  # [n_q, L, d]
    vv = jnp.repeat(v, g, axis=0)
    logits = jnp.einsum("hsd,htd->hst", q, kk) * scale  # [n_q, s, L]
    cols = jnp.arange(length)[None, None, :]
    rows = jnp.arange(s)[None, :, None]
    past_ok = cols < n_past
    if causal_self:
        self_ok = (cols >= n_past) & (cols - n_past <= rows) & (cols < n_past + s)
    else:
        self_ok = (cols >= n_past) & (cols < n_past + s)
    mask = past_ok | self_ok
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("hst,htd->hsd", w, vv)


def query_subselect_ref(q, n_q_sel):
    """Stage-1 query subselection (Alg. 1 lines 1-5), per Q head.

    Args:
      q: ``[n_heads, s, d]``.
      n_q_sel: static int — queries retained per head.

    Returns:
      ``[n_heads, n_q_sel, d]`` the retained queries (most-dissimilar-from-
      mean first).
    """
    m = jnp.mean(q, axis=1, keepdims=True)  # [h, 1, d]
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    mn = m / jnp.maximum(jnp.linalg.norm(m, axis=-1, keepdims=True), 1e-9)
    sims = jnp.sum(qn * mn, axis=-1)  # [h, s]
    _, idx = topk_desc(-sims, n_q_sel)  # most dissimilar
    return jnp.take_along_axis(q, idx[:, :, None], axis=1)


def preaggregate_ref(q_sel, n_kv):
    """Normalize retained queries and mean them over each KV group.

    Args:
      q_sel: ``[n_q_heads, n_q_sel, d]``.
      n_kv: number of KV heads.

    Returns:
      ``[n_kv, n_q_sel, d]``.
    """
    qn = q_sel / jnp.maximum(jnp.linalg.norm(q_sel, axis=-1, keepdims=True), 1e-9)
    h, nq, d = qn.shape
    g = h // n_kv
    return jnp.mean(qn.reshape(n_kv, g, nq, d), axis=1)
