//! `cargo bench --bench serving_load` — open-loop Poisson load over the
//! real TCP server with streaming + cancellation (writes BENCH_serving.json).
fn main() {
    quoka::bench::serving::serving_load();
}
