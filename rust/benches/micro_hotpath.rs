//! `cargo bench --bench micro_hotpath` — regenerates the paper's §Perf hot-path microbench.
fn main() {
    quoka::bench::latency::micro_hotpath();
}
