//! `cargo bench --bench decode_serving` — batched-vs-serial decode
//! throughput at 8 concurrent sequences (writes BENCH_decode.json).
fn main() {
    quoka::bench::decode::decode_serving();
}
