//! `cargo bench --bench prefix_serving` — shared-prefix serving benchmark
//! over the paged KV pool + radix prefix cache (writes BENCH_prefix.json).
fn main() {
    quoka::bench::prefix::prefix_serving();
}
