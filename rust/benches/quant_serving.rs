fn main() {
    quoka::bench::quant::quant_serving();
}
