//! `cargo bench --bench table8_math500` — regenerates the paper's Table 8.
fn main() {
    quoka::bench::tables::table8_math500();
}
