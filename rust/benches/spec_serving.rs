//! Speculative-decode serving bench target: prompt-lookup drafting +
//! batched multi-token verification vs the plain one-token decode loop.
//! Writes `BENCH_spec.json` (see `scripts/bench_smoke.sh` and the CI
//! gate in `scripts/check_bench.py`).

fn main() {
    quoka::bench::spec::spec_serving();
}
