//! `cargo bench --bench table11_bcp` — regenerates the paper's Table 11.
fn main() {
    quoka::bench::tables::table11_bcp();
}
