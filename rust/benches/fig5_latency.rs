//! `cargo bench --bench fig5_latency` — regenerates the paper's Figure 5.
fn main() {
    quoka::bench::latency::fig5_attention();
    quoka::bench::latency::fig5_ttft();
}
