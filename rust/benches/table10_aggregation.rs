//! `cargo bench --bench table10_aggregation` — regenerates the paper's Table 10.
fn main() {
    quoka::bench::tables::table10_aggregation();
}
