//! `cargo bench --bench fig6_decode` — regenerates the paper's Figure 6.
fn main() {
    quoka::bench::latency::fig6_decode();
}
