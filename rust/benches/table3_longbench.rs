//! `cargo bench --bench table3_longbench` — regenerates the paper's Tables 3, 6 and 7.
fn main() {
    quoka::bench::tables::table3_longbench();
}
