//! `cargo bench --bench fig2_geometry` — regenerates the paper's Figure 2.
fn main() {
    quoka::bench::tables::fig2_geometry();
}
