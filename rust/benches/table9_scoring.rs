//! `cargo bench --bench table9_scoring` — regenerates the paper's Table 9.
fn main() {
    quoka::bench::tables::table9_scoring();
}
