//! `cargo bench --bench table1_ruler` — regenerates the paper's Table 1.
fn main() {
    quoka::bench::tables::table1_ruler();
}
