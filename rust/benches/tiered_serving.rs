//! Tiered KV pool: warm-from-RAM vs warm-from-spill vs cold TTFT for a
//! re-requested shared prefix under pool pressure (`BENCH_tiered.json`).

fn main() {
    quoka::bench::tiered::tiered_serving();
}
