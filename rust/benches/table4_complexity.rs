//! `cargo bench --bench table4_complexity` — regenerates the paper's Table 4.
fn main() {
    quoka::bench::tables::table4_complexity();
}
