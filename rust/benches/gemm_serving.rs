//! Dense-GEMM benchmark: packed pool-parallel kernel vs the seed serial
//! loop, plus the gemm phase share of a real chunked prefill.

fn main() {
    quoka::bench::gemm::gemm_serving();
}
