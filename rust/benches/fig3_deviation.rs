//! `cargo bench --bench fig3_deviation` — regenerates the paper's Figure 3.
fn main() {
    quoka::bench::tables::fig3_deviation();
}
