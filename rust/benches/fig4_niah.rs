//! `cargo bench --bench fig4_niah` — regenerates the paper's Figures 4 and 7.
fn main() {
    quoka::bench::tables::fig4_niah();
}
