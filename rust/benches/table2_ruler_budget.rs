//! `cargo bench --bench table2_ruler_budget` — regenerates the paper's Tables 2 and 5.
fn main() {
    quoka::bench::tables::table2_ruler_budget();
}
