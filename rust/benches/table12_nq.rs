//! `cargo bench --bench table12_nq` — regenerates the paper's Table 12.
fn main() {
    quoka::bench::tables::table12_nq();
}
