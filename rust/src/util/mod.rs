//! Hand-rolled substrates.
//!
//! The build image is offline and only the `xla` crate's dependency closure
//! is available, so the conveniences a production engine would pull from
//! crates.io (tokio, clap, serde, criterion, proptest, rand) are built
//! in-tree. Each module is small, dependency-free and unit-tested.

pub mod rng;
pub mod json;
pub mod cli;
pub mod timing;
pub mod prop;
pub mod threadpool;

pub use rng::Rng;
pub use json::Json;
