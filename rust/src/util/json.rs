//! Minimal JSON: parser + writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by the
//! python AOT pipeline) and the server wire protocol. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (sufficient for both
//! producers, which emit ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Require a key on an object, with a readable error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    // ---- parse -----------------------------------------------------------

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- write -----------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: re-decode from the source slice.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested() {
        let src = r#"{"a": [1, 2, {"b": "c\nd"}], "e": null, "f": 1e3}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c\nd"
        );
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"\\x\""] {
            assert!(Json::parse(src).is_err(), "should reject {src:?}");
        }
    }

    #[test]
    fn integers_stay_integers() {
        let v = Json::Num(128.0);
        assert_eq!(v.to_string(), "128");
    }

    #[test]
    fn object_access_helpers() {
        let v = Json::obj(vec![("n", Json::num(3)), ("s", Json::str("x"))]);
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert!(v.req("missing").is_err());
    }
}
