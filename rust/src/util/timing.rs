//! Benchmark timing harness (criterion is unavailable offline).
//!
//! Provides warmup + measured iterations with mean / median / p99 / stddev
//! statistics, plus a table formatter used by every paper-figure bench
//! target so their output matches the rows/series the paper reports.

use std::time::Instant;

/// Statistics over a set of per-iteration wall-clock samples.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Cap total measured wall time; iterations stop early past this.
    pub max_seconds: f64,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg { warmup_iters: 3, measure_iters: 20, max_seconds: 10.0 }
    }
}

impl BenchCfg {
    pub fn quick() -> Self {
        BenchCfg { warmup_iters: 1, measure_iters: 5, max_seconds: 5.0 }
    }
}

/// Time `f` under `cfg`, returning summary statistics.
pub fn bench<F: FnMut()>(cfg: BenchCfg, mut f: F) -> Stats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let start = Instant::now();
    for _ in 0..cfg.measure_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed().as_secs_f64() > cfg.max_seconds && samples.len() >= 3 {
            break;
        }
    }
    stats_of(&mut samples)
}

fn stats_of(samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        iters: n,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p99_ns: samples[((n as f64 * 0.99) as usize).min(n - 1)],
        min_ns: samples[0],
        stddev_ns: var.sqrt(),
    }
}

/// Plain-text table writer for paper-style rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV form, for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// ASCII heatmap (for NIAH depth × length figures).
pub fn heatmap(title: &str, row_labels: &[String], col_labels: &[String], vals: &[Vec<f32>]) -> String {
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut out = format!("{title}\n");
    let lw = row_labels.iter().map(|l| l.len()).max().unwrap_or(0);
    for (r, label) in row_labels.iter().enumerate() {
        out.push_str(&format!("{:>w$} |", label, w = lw));
        for v in &vals[r] {
            let idx = ((v.clamp(0.0, 1.0)) * (shades.len() - 1) as f32).round() as usize;
            out.push(shades[idx]);
            out.push(shades[idx]);
        }
        out.push_str(&format!("| {:.3}\n", vals[r].iter().sum::<f32>() / vals[r].len() as f32));
    }
    out.push_str(&format!(
        "{:>w$}  cols: {} .. {} (score: ' '=0 .. '@'=1)\n",
        "",
        col_labels.first().map(|s| s.as_str()).unwrap_or(""),
        col_labels.last().map(|s| s.as_str()).unwrap_or(""),
        w = lw
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench(BenchCfg { warmup_iters: 1, measure_iters: 10, max_seconds: 5.0 }, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p99_ns + 1.0);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["method", "4k", "8k"]);
        t.row(vec!["quoka".into(), "86.7".into(), "80.2".into()]);
        let s = t.render();
        assert!(s.contains("quoka"));
        assert!(s.contains("86.7"));
        assert_eq!(t.to_csv().lines().count(), 2);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn heatmap_renders() {
        let h = heatmap(
            "t",
            &["0%".into(), "50%".into()],
            &["1k".into(), "2k".into()],
            &[vec![1.0, 0.0], vec![0.5, 0.5]],
        );
        assert!(h.contains("@@"));
        assert!(h.contains("  "));
    }
}
