//! Deterministic pseudo-random number generation.
//!
//! A SplitMix64-seeded xoshiro256++ generator: fast, high quality, and —
//! crucially for this repo — *deterministic across runs and platforms*, so
//! every workload, weight set and benchmark is reproducible from a seed
//! recorded in EXPERIMENTS.md.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. per layer / per head).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high-quality mantissa bits.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; generation is not on the request hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-7 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with `N(0, sigma^2)` samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// A normally distributed vector of length `n`, scaled by `sigma`.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, sigma);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed; uses a
    /// rejection set, falls back to shuffle when k is large).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut sum, mut sum2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(6);
        for &(n, k) in &[(100usize, 5usize), (50, 40), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
