//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! seed and case number so the exact case replays deterministically, and
//! performs a simple size-reduction pass for generators that expose one.

use super::rng::Rng;

/// Number of cases per property (overridable via `QUOKA_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("QUOKA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` random inputs produced by `gen`.
///
/// `gen` receives an `Rng` plus a *size hint* in `[1, max_size]`; properties
/// are exercised on growing sizes so small counterexamples surface first.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, max_size: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let cases = default_cases();
    let base_seed = 0xC0FFEE ^ name.len() as u64;
    for case in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15)));
        let size = 1 + (case * max_size) / cases.max(1);
        let input = gen(&mut rng, size.max(1));
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case}/{cases} (size {size}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_eq<A: PartialEq + std::fmt::Debug>(a: A, b: A, ctx: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Approximate float comparison for numeric properties.
pub fn ensure_close(a: f32, b: f32, tol: f32, ctx: &str) -> Result<(), String> {
    let denom = 1f32.max(a.abs()).max(b.abs());
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 16, |r, size| r.sample_indices(size, size), |v| {
            ensure(v.windows(2).all(|w| w[0] < w[1]), "sorted unique")
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_context() {
        check("fails", 8, |r, s| r.below(s.max(1)), |&v| ensure(v == usize::MAX, "never"));
    }

    #[test]
    fn ensure_close_tolerates() {
        assert!(ensure_close(1.0, 1.0 + 1e-6, 1e-4, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-4, "x").is_err());
    }
}
