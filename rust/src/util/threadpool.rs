//! Fixed-size worker pools (tokio is unavailable offline; the engine is
//! CPU-bound anyway, so OS threads + condvars are the right substrate).
//!
//! Two primitives:
//! - [`ThreadPool`] — long-lived workers consuming boxed jobs, used by the
//!   serving engine for per-sequence layer work.
//! - [`parallel_for`] — fork-join helper over index ranges, used by the
//!   packed GEMM, the tiled-attention fan-out, the QUOKA key scan and
//!   benchmark sweeps.
//!
//! ## The fork-join fan-out pool
//!
//! `parallel_for` used to spawn fresh OS threads through `thread::scope`
//! on every call — fine for one 32k-context attention pass, ruinous for
//! the per-layer projection GEMMs that fan out thousands of times per
//! request. It now publishes each job to a single lazily-initialized
//! process-wide pool ([`fan`]):
//!
//! - **Zero allocation per call.** The closure is published as a raw
//!   `(data, call)` pair — a pointer to the caller's stack plus a
//!   monomorphized shim — never boxed. The caller blocks until every
//!   participant has retired, so the borrow cannot escape the call.
//! - **Chunked work-stealing.** Participants claim `grain`-sized index
//!   chunks from one shared atomic (`fetch_add(grain)`), one RMW per
//!   chunk instead of one per index, while irregular per-index cost still
//!   rebalances across workers.
//! - **Caller participation.** The publishing thread drains chunks like
//!   any worker, so a job completes even on a pool of size zero, and
//!   `threads` participants need only `threads - 1` pool workers.
//! - **Serial fallback under contention.** Publication is serialized by a
//!   `try_lock`; a nested or concurrent fork-join (two engine sequences
//!   projecting at once) runs inline on its own thread instead of
//!   deadlocking or queueing.
//!
//! The pool is sized once, on first use, from [`default_workers`] — set
//! [`set_workers`] (or `QUOKA_WORKERS`) before the first fan-out. Later
//! `set_workers` calls still cap per-job participation via the `threads`
//! argument plumbed by callers, which is how benches sweep worker counts
//! without resizing the pool.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Raw mutable pointer wrapper for [`parallel_for`] bodies that write
/// disjoint regions of a shared buffer.
///
/// Safety contract (on the *user*, not this type): every task must touch a
/// region no other concurrent task touches, and the pointee must outlive
/// the fork-join call that uses it.
pub struct SyncPtr<T>(*mut T);

// `T: Send` keeps the guard rail: handing `&mut T` to another worker is
// a cross-thread move of T, so wrapping a pointer to a non-Send payload
// must stay a compile error.
unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    pub fn new(p: *mut T) -> SyncPtr<T> {
        SyncPtr(p)
    }

    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SyncPtr<T> {
    fn clone(&self) -> Self {
        SyncPtr(self.0)
    }
}

impl<T> Copy for SyncPtr<T> {}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("quoka-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Persistent fork-join fan-out pool backing `parallel_for`.
// ---------------------------------------------------------------------------

/// A published fork-join job, type-erased. `data` points at the caller's
/// stack-borrowed closure; `call` is the monomorphized shim that invokes
/// it for one index. Valid only while the publishing `parallel_for_grain`
/// call is blocked (it waits for `in_flight == 0` before returning).
#[derive(Clone, Copy)]
struct RawJob {
    data: *const (),
    call: unsafe fn(*const (), usize),
    n: usize,
    grain: usize,
}

// The pointer pair crosses threads only inside the publisher's blocking
// window; `F: Sync` on the closure is enforced at the `parallel_for` API.
unsafe impl Send for RawJob {}

unsafe fn noop_shim(_: *const (), _: usize) {}

const NO_JOB: RawJob = RawJob { data: std::ptr::null(), call: noop_shim, n: 0, grain: 1 };

struct FanState {
    /// Bumped once per published job; workers key their wake-up off it.
    seq: u64,
    job: RawJob,
    /// Worker participation slots remaining for the current job.
    slots: usize,
    /// Workers currently draining the current job.
    in_flight: usize,
    /// A worker's chunk panicked during the current job.
    panicked: bool,
}

/// The process-wide fan-out pool: publication state + wake/quiesce
/// condvars + the shared chunk cursor.
struct Fan {
    state: Mutex<FanState>,
    /// Workers park here between jobs.
    start: Condvar,
    /// The publisher parks here until `in_flight` drops to zero.
    quiet: Condvar,
    /// Shared chunk cursor for the current job (reset per publication;
    /// publication is serialized by `FANOUT`, so generations never mix).
    next: AtomicUsize,
    /// Number of pool workers (participants minus the caller).
    size: usize,
}

static FAN: OnceLock<&'static Fan> = OnceLock::new();
/// Serializes fork-join publication; losers of the flag run inline.
/// (A plain atomic rather than a `Mutex` so a panicking job can never
/// poison publication for the rest of the process.)
static FANOUT: AtomicBool = AtomicBool::new(false);
/// Worker count override installed by `set_workers` (0 = unset).
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// Releases the publication flag even if the caller's chunks panic.
struct FanoutGuard;

impl Drop for FanoutGuard {
    fn drop(&mut self) {
        FANOUT.store(false, Ordering::Release);
    }
}

/// Runs the quiesce protocol on drop, so a panic while the caller drains
/// its own chunks still waits out in-flight workers before the stack
/// frame holding the job's closure unwinds.
struct Quiesce<'a>(&'a Fan);

impl Drop for Quiesce<'_> {
    fn drop(&mut self) {
        let fan = self.0;
        let mut st = fan.state.lock().unwrap();
        // Close the slot window so late-waking workers skip this job, then
        // wait out the ones already in flight.
        st.slots = 0;
        while st.in_flight > 0 {
            st = fan.quiet.wait(st).unwrap();
        }
    }
}

fn worker_loop(fan: &'static Fan) {
    let mut last_seq = 0u64;
    loop {
        let job;
        {
            let mut st = fan.state.lock().unwrap();
            loop {
                if st.seq != last_seq {
                    last_seq = st.seq;
                    if st.slots > 0 {
                        st.slots -= 1;
                        st.in_flight += 1;
                        job = st.job;
                        break;
                    }
                    // No slot on this job; wait for the next one.
                }
                st = fan.start.wait(st).unwrap();
            }
        }
        let drained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let start = fan.next.fetch_add(job.grain, Ordering::Relaxed);
            if start >= job.n {
                break;
            }
            for i in start..(start + job.grain).min(job.n) {
                // SAFETY: the publisher blocks until `in_flight` hits zero,
                // so the closure behind `job.data` is still alive.
                unsafe { (job.call)(job.data, i) };
            }
        }));
        let mut st = fan.state.lock().unwrap();
        if drained.is_err() {
            st.panicked = true;
        }
        st.in_flight -= 1;
        if st.in_flight == 0 {
            fan.quiet.notify_all();
        }
    }
}

/// The lazily-built fan-out pool. Sized once from [`default_workers`]
/// minus one (the publishing thread is itself a participant).
fn fan() -> &'static Fan {
    *FAN.get_or_init(|| {
        let size = default_workers().saturating_sub(1);
        let fan: &'static Fan = Box::leak(Box::new(Fan {
            state: Mutex::new(FanState {
                seq: 0,
                job: NO_JOB,
                slots: 0,
                in_flight: 0,
                panicked: false,
            }),
            start: Condvar::new(),
            quiet: Condvar::new(),
            next: AtomicUsize::new(0),
            size,
        }));
        for i in 0..size {
            thread::Builder::new()
                .name(format!("quoka-fan-{i}"))
                .spawn(move || worker_loop(fan))
                .expect("spawn fan worker");
        }
        fan
    })
}

/// Fork-join: run `f(i)` for `i in 0..n` across up to `threads`
/// participants (the calling thread plus pool workers), claiming
/// `grain`-sized index chunks from a shared work-stealing cursor.
pub fn parallel_for_grain<F: Fn(usize) + Sync>(n: usize, threads: usize, grain: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let fan = fan();
    // One fan-out at a time; a nested or concurrent fork-join runs inline
    // (never blocks, never deadlocks).
    if fan.size == 0
        || FANOUT.compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed).is_err()
    {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let _publication = FanoutGuard;
    let grain = grain.max(1);
    unsafe fn shim<F: Fn(usize) + Sync>(data: *const (), i: usize) {
        (*(data as *const F))(i)
    }
    fan.next.store(0, Ordering::Relaxed);
    {
        let mut st = fan.state.lock().unwrap();
        debug_assert_eq!(st.in_flight, 0, "publication while a job is live");
        st.seq += 1;
        st.job = RawJob { data: &f as *const F as *const (), call: shim::<F>, n, grain };
        st.slots = (threads - 1).min(fan.size);
        st.panicked = false;
    }
    fan.start.notify_all();
    {
        // Quiesces on drop — including a panic unwind out of `f` below —
        // so `f` (and the buffers it borrows) outlives every worker.
        let _quiesce = Quiesce(fan);
        // Participate: drain chunks alongside the workers.
        loop {
            let start = fan.next.fetch_add(grain, Ordering::Relaxed);
            if start >= n {
                break;
            }
            for i in start..(start + grain).min(n) {
                f(i);
            }
        }
    }
    if fan.state.lock().unwrap().panicked {
        panic!("parallel_for worker panicked");
    }
}

/// Fork-join: run `f(i)` for `i in 0..n` across up to `threads`
/// participants with a default grain of ~4 chunks per participant —
/// coarse enough to amortize the shared-cursor RMW, fine enough that
/// irregular per-index cost (different sequence lengths) stays balanced.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let grain = (n / (threads.max(1) * 4)).max(1);
    parallel_for_grain(n, threads, grain, f)
}

/// Pin the worker count used by [`default_workers`] (and hence every
/// fan-out call site that doesn't pass an explicit thread count).
/// Call before the first `parallel_for` to also size the pool itself;
/// afterwards it only caps/raises per-job participation.
pub fn set_workers(n: usize) {
    CONFIGURED.store(n.max(1), Ordering::Relaxed);
}

/// `QUOKA_WORKERS` env override, probed once (0 = unset).
fn env_workers() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("QUOKA_WORKERS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(0)
    })
}

/// Default worker count: [`set_workers`] override, else `QUOKA_WORKERS`,
/// else physical parallelism minus one for the scheduler.
pub fn default_workers() -> usize {
    let configured = CONFIGURED.load(Ordering::Relaxed);
    if configured > 0 {
        return configured;
    }
    let env = env_workers();
    if env > 0 {
        return env;
    }
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        parallel_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_covers_range_at_every_grain() {
        for grain in [1usize, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..129).map(|_| AtomicU64::new(0)).collect();
            parallel_for_grain(129, 4, grain, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "grain {grain} missed or duplicated indices"
            );
        }
    }

    #[test]
    fn parallel_for_single_thread_fallback() {
        let hits = AtomicU64::new(0);
        parallel_for(5, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn parallel_for_is_reentrant_via_serial_fallback() {
        // A fan-out inside a fan-out must not deadlock: the inner call
        // loses the publication try_lock and runs inline.
        let hits: Vec<AtomicU64> = (0..8 * 8).map(|_| AtomicU64::new(0)).collect();
        parallel_for(8, 4, |i| {
            parallel_for(8, 4, |j| {
                hits[i * 8 + j].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_back_to_back_jobs_stay_isolated() {
        // Successive jobs reuse the same pool; indices from one must never
        // leak into the next (the quiesce step guarantees this).
        for round in 0..50u64 {
            let n = 16 + (round as usize % 7);
            let sum = AtomicU64::new(0);
            parallel_for(n, 4, |i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            let want = (n as u64) * (n as u64 + 1) / 2;
            assert_eq!(sum.load(Ordering::Relaxed), want, "round {round}");
        }
    }
}
