//! Fixed-size scoped worker pool (tokio is unavailable offline; the engine
//! is CPU-bound anyway, so OS threads + channels are the right substrate).
//!
//! Two primitives:
//! - [`ThreadPool`] — long-lived workers consuming boxed jobs, used by the
//!   serving engine for per-sequence layer work.
//! - [`parallel_for`] — fork-join helper over index ranges, used by the
//!   host tensor backend's blocked matmul and by benchmark sweeps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Raw mutable pointer wrapper for [`parallel_for`] bodies that write
/// disjoint regions of a shared buffer.
///
/// Safety contract (on the *user*, not this type): every task must touch a
/// region no other concurrent task touches, and the pointee must outlive
/// the fork-join call that uses it.
pub struct SyncPtr<T>(*mut T);

// `T: Send` keeps the guard rail: handing `&mut T` to another worker is
// a cross-thread move of T, so wrapping a pointer to a non-Send payload
// must stay a compile error.
unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    pub fn new(p: *mut T) -> SyncPtr<T> {
        SyncPtr(p)
    }

    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SyncPtr<T> {
    fn clone(&self) -> Self {
        SyncPtr(self.0)
    }
}

impl<T> Copy for SyncPtr<T> {}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("quoka-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join: run `f(i)` for `i in 0..n` across up to `threads` OS threads.
///
/// `f` must be `Sync`; chunks are balanced by an atomic work-stealing index
/// so irregular per-index cost (e.g. different sequence lengths) stays
/// balanced.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Default worker count: physical parallelism minus one for the scheduler.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        parallel_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_fallback() {
        let hits = AtomicU64::new(0);
        parallel_for(5, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }
}
