//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generates usage text from registered option metadata.

use std::collections::BTreeMap;

/// Declarative option spec used for `--help` generation and validation.
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub boolean: bool,
}

/// Parsed arguments: `--key value` pairs plus positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw process args (everything after the subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, specs: &[OptSpec]) -> anyhow::Result<Args> {
        let bools: std::collections::HashSet<&str> = specs
            .iter()
            .filter(|s| s.boolean)
            .map(|s| s.name)
            .collect();
        let known: std::collections::HashSet<&str> = specs.iter().map(|s| s.name).collect();
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if !known.is_empty() && !known.contains(key.as_str()) {
                    anyhow::bail!("unknown flag --{key} (try --help)");
                }
                let val = if bools.contains(key.as_str()) {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| anyhow::anyhow!("flag --{key} expects a value"))?
                };
                args.flags.insert(key, val);
            } else {
                args.positional.push(a);
            }
        }
        // Apply defaults.
        for s in specs {
            if let Some(d) = s.default {
                args.flags.entry(s.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    pub fn usize(&self, key: &str) -> anyhow::Result<usize> {
        let v = self.str(key)?;
        v.parse()
            .map_err(|_| anyhow::anyhow!("flag --{key} expects an integer, got '{v}'"))
    }

    pub fn f32(&self, key: &str) -> anyhow::Result<f32> {
        let v = self.str(key)?;
        v.parse()
            .map_err(|_| anyhow::anyhow!("flag --{key} expects a float, got '{v}'"))
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated integer list, e.g. `--lengths 4096,8192`.
    pub fn usize_list(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        let v = self.str(key)?;
        v.split(',')
            .map(|p| {
                p.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("flag --{key}: bad integer '{p}'"))
            })
            .collect()
    }

    /// Comma-separated string list.
    pub fn str_list(&self, key: &str) -> anyhow::Result<Vec<String>> {
        Ok(self
            .str(key)?
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUSAGE:\n  quoka {cmd} [OPTIONS]\n\nOPTIONS:\n");
    for spec in specs {
        let d = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", help: "count", default: Some("4"), boolean: false },
            OptSpec { name: "verbose", help: "talk", default: None, boolean: true },
            OptSpec { name: "name", help: "name", default: None, boolean: false },
        ]
    }

    fn parse(args: &[&str]) -> anyhow::Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), &specs())
    }

    #[test]
    fn parses_pairs_and_defaults() {
        let a = parse(&["--name", "x"]).unwrap();
        assert_eq!(a.str("name").unwrap(), "x");
        assert_eq!(a.usize("n").unwrap(), 4);
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--n=9"]).unwrap();
        assert_eq!(a.usize("n").unwrap(), 9);
    }

    #[test]
    fn boolean_flag() {
        let a = parse(&["--verbose", "--n", "2"]).unwrap();
        assert!(a.bool("verbose"));
        assert_eq!(a.usize("n").unwrap(), 2);
    }

    #[test]
    fn positionals() {
        let a = parse(&["file1", "--n", "2", "file2"]).unwrap();
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn rejects_unknown() {
        assert!(parse(&["--bogus", "1"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&["--name"]).is_err());
    }

    #[test]
    fn lists() {
        let sp = vec![OptSpec { name: "ls", help: "", default: None, boolean: false }];
        let a = Args::parse(["--ls".to_string(), "1, 2,3".to_string()], &sp).unwrap();
        assert_eq!(a.usize_list("ls").unwrap(), vec![1, 2, 3]);
    }
}
