//! QUOKA-Serve CLI — the leader entrypoint.
//!
//! ```text
//! quoka serve   --backend pjrt --artifacts artifacts --addr 127.0.0.1:7700
//! quoka request --addr 127.0.0.1:7700 --prompt "…" --policy quoka
//! quoka bench   table1_ruler            (any DESIGN.md §6 experiment id)
//! quoka eval    --workload ruler --policy quoka --budget 1024 --length 4096
//! quoka inspect --artifacts artifacts
//! ```

use quoka::bench::{gemm, latency, prefix, serving, spec, tables, tiered};
use quoka::coordinator::{Engine, EngineCfg, KvLayout, SchedCfg};
use quoka::server::{serve_with_opts, Client, ServeOpts, WireRequest};
use quoka::util::cli::{usage, Args, OptSpec};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let result = match cmd.as_str() {
        "serve" => cmd_serve(argv),
        "request" => cmd_request(argv),
        "stats" => cmd_stats(argv),
        "bench" => cmd_bench(argv),
        "eval" => cmd_eval(argv),
        "inspect" => cmd_inspect(argv),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "QUOKA-Serve — query-oriented KV selection for efficient LLM prefill\n\n\
         COMMANDS:\n\
         \x20 serve     start the serving engine (TCP, newline-JSON)\n\
         \x20 request   send one request to a running server\n\
         \x20 stats     fetch metrics from a running server (JSON or Prometheus)\n\
         \x20 bench     regenerate a paper table/figure (see DESIGN.md §6)\n\
         \x20 eval      score one policy on one workload\n\
         \x20 inspect   print the artifact manifest + model summary\n\n\
         Run 'quoka <command> --help' for options."
    );
}

fn serve_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "backend", help: "host | pjrt", default: Some("host"), boolean: false },
        OptSpec { name: "preset", help: "model preset for --backend host", default: Some("serve-small"), boolean: false },
        OptSpec { name: "artifacts", help: "artifact dir for --backend pjrt", default: Some("artifacts"), boolean: false },
        OptSpec { name: "addr", help: "listen address", default: Some("127.0.0.1:7700"), boolean: false },
        OptSpec { name: "b-cp", help: "prefill chunk size", default: Some("128"), boolean: false },
        OptSpec { name: "step-tokens", help: "token budget per engine step", default: Some("256"), boolean: false },
        OptSpec { name: "max-running", help: "max concurrent sequences", default: Some("8"), boolean: false },
        OptSpec { name: "pool-blocks", help: "KV pool blocks (x block-tokens capacity)", default: Some("4096"), boolean: false },
        OptSpec { name: "block-tokens", help: "tokens per KV block", default: Some("128"), boolean: false },
        OptSpec { name: "seed", help: "weight seed", default: Some("0"), boolean: false },
        OptSpec { name: "paged", help: "shared paged KV pool (host backend; dense/quoka*)", default: None, boolean: true },
        OptSpec { name: "prefix-cache", help: "radix prefix cache over the paged pool (implies --paged)", default: None, boolean: true },
        OptSpec { name: "spec-gamma", help: "speculative decode: max draft tokens per step (0 = off)", default: Some("0"), boolean: false },
        OptSpec { name: "spec-policy", help: "speculative draft policy (off | pld)", default: Some("pld"), boolean: false },
        OptSpec { name: "workers", help: "fan-out worker count for GEMM/attention (0 = QUOKA_WORKERS env or all cores minus one)", default: Some("0"), boolean: false },
        OptSpec { name: "kv-dtype", help: "KV cache element type: f32 | int8 (int8 = 4x smaller cache, dequantized in-tile; host backend, dense/quoka* policies)", default: Some("f32"), boolean: false },
        OptSpec { name: "kv-spill", help: "mmap-backed cold-tier spill file: prefix-cache pages evicted under pool pressure demote here and promote back on a radix hit (requires --prefix-cache)", default: None, boolean: false },
        OptSpec { name: "kv-spill-cap", help: "spill file capacity in bytes; must be a whole number of page slots (a page image rounded up to 64 bytes)", default: Some("0"), boolean: false },
        OptSpec { name: "trace-out", help: "write the request-lifecycle trace (JSONL) here at shutdown and on the flush_trace wire command; enables tracing", default: None, boolean: false },
        OptSpec { name: "trace-events", help: "lifecycle-trace ring capacity in events (0 = off unless --trace-out is set)", default: Some("0"), boolean: false },
        OptSpec { name: "max-queue", help: "admission backpressure: reject new requests while this many wait for admission (0 = unbounded)", default: Some("0"), boolean: false },
        OptSpec { name: "help", help: "show help", default: None, boolean: true },
    ]
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let specs = serve_specs();
    let a = Args::parse(argv, &specs)?;
    if a.bool("help") {
        println!("{}", usage("serve", "Start the serving engine.", &specs));
        return Ok(());
    }
    let prefix_cache = a.bool("prefix-cache");
    let kv = if a.bool("paged") || prefix_cache {
        KvLayout::Paged { prefix_cache }
    } else {
        KvLayout::Private
    };
    let cfg = EngineCfg {
        sched: SchedCfg {
            b_cp: a.usize("b-cp")?,
            step_tokens: a.usize("step-tokens")?,
            max_running: a.usize("max-running")?,
            ..SchedCfg::default()
        },
        pool_blocks: a.usize("pool-blocks")?,
        block_tokens: a.usize("block-tokens")?,
        seed: a.usize("seed")? as u64,
        kv,
        // Engine-wide default; per-request `spec_gamma` / `spec_policy`
        // wire fields override it.
        spec: quoka::spec::SpecCfg::parse(&a.str("spec-policy")?, a.usize("spec-gamma")?)?,
        kv_dtype: quoka::kvpool::KvDtype::parse(&a.str("kv-dtype")?)?,
        workers: a.usize("workers")?,
        spill_path: a.get("kv-spill").map(std::path::PathBuf::from),
        spill_cap_bytes: a.usize("kv-spill-cap")?,
    };
    let backend = a.str("backend")?;
    let preset = a.str("preset")?;
    let artifacts = a.str("artifacts")?;
    let addr = a.str("addr")?;
    let opts = ServeOpts {
        trace_events: a.usize("trace-events")?,
        trace_out: a.get("trace-out").map(std::path::PathBuf::from),
        max_queue: a.usize("max-queue")?,
    };
    println!("starting quoka-serve backend={backend} addr={addr}");
    let handle = serve_with_opts(
        move || match backend.as_str() {
            "host" => Engine::new_host(&preset, cfg),
            "pjrt" => Engine::new_pjrt(&artifacts, cfg),
            other => anyhow::bail!("unknown backend '{other}'"),
        },
        &addr,
        opts,
    )?;
    println!("listening on {} — newline-JSON requests; Ctrl-C to stop", handle.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_request(argv: Vec<String>) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "addr", help: "server address", default: Some("127.0.0.1:7700"), boolean: false },
        OptSpec { name: "prompt", help: "prompt text", default: None, boolean: false },
        OptSpec { name: "max-new", help: "tokens to generate", default: Some("16"), boolean: false },
        OptSpec { name: "policy", help: "selection policy", default: Some("quoka"), boolean: false },
        OptSpec { name: "budget", help: "selection budget B_SA", default: Some("1024"), boolean: false },
        OptSpec { name: "spec-gamma", help: "speculative decode: max draft tokens per step (0 = off)", default: None, boolean: false },
        OptSpec { name: "spec-policy", help: "speculative draft policy (off | pld); server resolves gamma when omitted", default: None, boolean: false },
        OptSpec { name: "tenant", help: "fair-share scheduling group (empty = default pool)", default: Some(""), boolean: false },
        OptSpec { name: "tenant-weight", help: "admission weight of the tenant (>= 1)", default: Some("1"), boolean: false },
        OptSpec { name: "stream", help: "stream per-token delta frames as they are generated", default: None, boolean: true },
        OptSpec { name: "help", help: "show help", default: None, boolean: true },
    ];
    let a = Args::parse(argv, &specs)?;
    if a.bool("help") {
        println!("{}", usage("request", "Send one request to a running server.", &specs));
        return Ok(());
    }
    let addr: std::net::SocketAddr = a.str("addr")?.parse()?;
    let mut c = Client::connect(addr)?;
    // Either flag passed explicitly is an override (so `--spec-policy off`
    // alone disables speculation); neither leaves the server default.
    let spec = if a.get("spec-gamma").is_some() || a.get("spec-policy").is_some() {
        Some(quoka::server::WireSpec {
            policy: a.get("spec-policy").unwrap_or("pld").to_string(),
            gamma: match a.get("spec-gamma") {
                Some(_) => Some(a.usize("spec-gamma")?),
                None => None,
            },
        })
    } else {
        None
    };
    let req = WireRequest {
        prompt: a.str("prompt")?,
        max_new: a.usize("max-new")?,
        policy: a.str("policy")?,
        budget: a.usize("budget")?,
        spec,
        tenant: a.str("tenant")?,
        tenant_weight: a.usize("tenant-weight")?.max(1),
        stream: a.bool("stream"),
    };
    let resp = if req.stream {
        // Print deltas as they arrive; the final line repeats the full text
        // with the timing fields, exactly like the blocking shape.
        c.send(&req)?;
        use std::io::Write as _;
        loop {
            match c.read_frame()? {
                quoka::server::WireFrame::Token { delta, .. } => {
                    print!("{delta}");
                    std::io::stdout().flush().ok();
                }
                quoka::server::WireFrame::Done(resp) => {
                    println!();
                    break resp;
                }
            }
        }
    } else {
        c.request(&req)?
    };
    println!(
        "id={} ttft={:.1}ms tpot={:.2}ms prompt_tokens={} generated={} \
         spec_drafted={} spec_accepted={}\ntext: {:?}",
        resp.id,
        resp.ttft_ms,
        resp.tpot_ms,
        resp.prompt_tokens,
        resp.generated,
        resp.spec_drafted_tokens,
        resp.spec_accepted_tokens,
        resp.text
    );
    Ok(())
}

fn cmd_stats(argv: Vec<String>) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "addr", help: "server address", default: Some("127.0.0.1:7700"), boolean: false },
        OptSpec { name: "prometheus", help: "print the Prometheus text exposition instead of JSON", default: None, boolean: true },
        OptSpec { name: "flush-trace", help: "also flush the server's trace ring to its --trace-out path", default: None, boolean: true },
        OptSpec { name: "help", help: "show help", default: None, boolean: true },
    ];
    let a = Args::parse(argv, &specs)?;
    if a.bool("help") {
        println!("{}", usage("stats", "Fetch metrics from a running server.", &specs));
        return Ok(());
    }
    let addr: std::net::SocketAddr = a.str("addr")?.parse()?;
    let mut c = Client::connect(addr)?;
    let stats = c.stats()?;
    if a.bool("prometheus") {
        let text = stats
            .get("prometheus")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("stats reply missing prometheus text"))?;
        print!("{text}");
    } else {
        println!("{}", stats.to_string());
    }
    if a.bool("flush-trace") {
        let flush = c.flush_trace()?;
        eprintln!("{}", flush.to_string());
    }
    Ok(())
}

fn cmd_bench(argv: Vec<String>) -> anyhow::Result<()> {
    let id = argv.first().map(|s| s.as_str()).unwrap_or("list");
    match id {
        "fig2_geometry" => drop(tables::fig2_geometry()),
        "fig3_deviation" => drop(tables::fig3_deviation()),
        "fig4_niah" => drop(tables::fig4_niah()),
        "table1_ruler" => drop(tables::table1_ruler()),
        "table2_ruler_budget" => drop(tables::table2_ruler_budget()),
        "table3_longbench" => drop(tables::table3_longbench()),
        "table4_complexity" => drop(tables::table4_complexity()),
        "table8_math500" => drop(tables::table8_math500()),
        "table9_scoring" => drop(tables::table9_scoring()),
        "table10_aggregation" => drop(tables::table10_aggregation()),
        "table11_bcp" => drop(tables::table11_bcp()),
        "table12_nq" => drop(tables::table12_nq()),
        "fig5_latency" => {
            latency::fig5_attention();
            latency::fig5_ttft();
        }
        "fig6_decode" => drop(latency::fig6_decode()),
        "micro_hotpath" => drop(latency::micro_hotpath()),
        "prefix_serving" => drop(prefix::prefix_serving()),
        "spec_serving" => drop(spec::spec_serving()),
        "gemm_serving" => drop(gemm::gemm_serving()),
        "serving_load" => drop(serving::serving_load()),
        "tiered_serving" => drop(tiered::tiered_serving()),
        "all" => {
            for id in [
                "fig2_geometry", "fig3_deviation", "fig4_niah", "table1_ruler",
                "table2_ruler_budget", "table3_longbench", "table4_complexity",
                "table8_math500", "table9_scoring", "table10_aggregation",
                "table11_bcp", "table12_nq", "fig5_latency", "fig6_decode",
                "micro_hotpath", "prefix_serving", "spec_serving", "gemm_serving",
                "serving_load", "tiered_serving",
            ] {
                cmd_bench(vec![id.to_string()])?;
            }
        }
        _ => {
            println!(
                "experiments (DESIGN.md §6):\n  fig2_geometry fig3_deviation fig4_niah\n  \
                 table1_ruler table2_ruler_budget table3_longbench table4_complexity\n  \
                 table8_math500 table9_scoring table10_aggregation table11_bcp table12_nq\n  \
                 fig5_latency fig6_decode micro_hotpath prefix_serving spec_serving gemm_serving\n  \
                 serving_load tiered_serving all\n\n\
                 QUOKA_BENCH_FULL=1 for paper-scale grids."
            );
        }
    }
    Ok(())
}

fn cmd_eval(argv: Vec<String>) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "workload", help: "ruler | longbench | niah | math500", default: Some("ruler"), boolean: false },
        OptSpec { name: "policy", help: "selection policy", default: Some("quoka"), boolean: false },
        OptSpec { name: "budget", help: "B_SA", default: Some("1024"), boolean: false },
        OptSpec { name: "length", help: "prompt length", default: Some("4096"), boolean: false },
        OptSpec { name: "b-cp", help: "chunk size", default: Some("128"), boolean: false },
        OptSpec { name: "seed", help: "workload seed", default: Some("0"), boolean: false },
        OptSpec { name: "help", help: "show help", default: None, boolean: true },
    ];
    let a = Args::parse(argv, &specs)?;
    if a.bool("help") {
        println!("{}", usage("eval", "Score one policy on one workload.", &specs));
        return Ok(());
    }
    let policy = quoka::select::policy_by_name(&a.str("policy")?)?;
    let budget = a.usize("budget")?;
    let (t, b_cp, seed) = (a.usize("length")?, a.usize("b-cp")?, a.usize("seed")? as u64);
    let opts = quoka::eval::EvalOpts::default();
    match a.str("workload")?.as_str() {
        "ruler" => {
            let s = quoka::workload::ruler::score(policy.as_ref(), budget, t, b_cp, seed, &opts);
            println!("RULER score: {s:.2}");
        }
        "longbench" => {
            let (per, mean) =
                quoka::workload::longbench::scores(policy.as_ref(), budget, t, b_cp, seed, &opts);
            for (fam, v) in per {
                println!("  {fam:<14} {v:.3}");
            }
            println!("LongBench normalized mean: {mean:.3}");
        }
        "niah" => {
            let cell = quoka::workload::niah::NiahCell { length: t, depth: 0.5 };
            let task = quoka::workload::niah::build(&cell, b_cp, seed);
            let s = quoka::eval::eval_policy(&task, policy.as_ref(), budget, &opts);
            println!(
                "NIAH recall={:.3} fidelity={:.3} kv_frac={:.3}",
                s.recall(),
                s.fidelity,
                s.kv_frac
            );
        }
        "math500" => {
            let task = quoka::workload::math500::build(t, 6, b_cp, seed);
            let s = quoka::workload::math500::run(&task, policy.as_ref(), budget, 128, seed);
            println!("Math500 flex={:.3} exact={:.3} gen_len={:.1}", s.flex, s.exact, s.gen_len);
        }
        other => anyhow::bail!("unknown workload '{other}'"),
    }
    Ok(())
}

fn cmd_inspect(argv: Vec<String>) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "artifacts", help: "artifact dir", default: Some("artifacts"), boolean: false },
        OptSpec { name: "help", help: "show help", default: None, boolean: true },
    ];
    let a = Args::parse(argv, &specs)?;
    if a.bool("help") {
        println!("{}", usage("inspect", "Print manifest + model summary.", &specs));
        return Ok(());
    }
    let dir = a.str("artifacts")?;
    let m = quoka::runtime::Manifest::load(format!("{dir}/manifest.json"))?;
    let cfg = &m.model;
    println!(
        "model {} — {} params, {} layers, {}q/{}kv heads (g={}), d_head {}, vocab {}",
        cfg.name,
        cfg.param_count(),
        cfg.n_layers,
        cfg.n_q_heads,
        cfg.n_kv_heads,
        cfg.group_size(),
        cfg.d_head,
        cfg.vocab
    );
    println!(
        "chunked prefill: B_CP={}  selection: B_SA={} N_Q={}  buckets {:?}",
        m.b_cp, m.b_sa, m.n_q_sel, m.buckets
    );
    println!("{} artifacts:", m.artifacts.len());
    for art in &m.artifacts {
        println!("  {:<28} {:<8} s={:<4} bucket={}", art.name, art.kind, art.s, art.bucket);
    }
    Ok(())
}
