//! PJRT runtime: load AOT artifacts, compile once, execute from the hot
//! path with weights resident as device buffers.
//!
//! The interchange contract is `artifacts/manifest.json` +
//! `artifacts/*.hlo.txt`, produced by `python/compile/aot.py`. Python never
//! runs at serve time; this module is the only consumer of the artifacts.

pub mod artifacts;
pub mod exec;

pub use artifacts::{ArtifactEntry, Manifest};
pub use exec::{PjrtBackend, PjrtSeq};

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled-executable cache over one PJRT client.
pub struct Runtime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory, create the CPU PJRT client and compile
    /// every artifact listed in the manifest (compile-once semantics; a
    /// few hundred ms per module on the CPU plugin).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut rt = Runtime { client, dir, manifest, execs: HashMap::new() };
        let names: Vec<String> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for name in names {
            rt.compile(&name)?;
        }
        Ok(rt)
    }

    /// Load lazily (compile on first use) — faster startup for tools that
    /// touch one artifact.
    pub fn load_lazy(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, execs: HashMap::new() })
    }

    fn compile(&mut self, name: &str) -> Result<()> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Fetch a compiled executable, compiling lazily if needed.
    pub fn exec(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.compile(name)?;
        Ok(self.execs.get(name).unwrap())
    }

    /// Upload a host f32 slice as a device buffer with the given dims.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a host i32 slice.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Scalar i32 buffer.
    pub fn buf_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// Run an executable on buffers; returns the un-tupled output buffers.
    pub fn run(
        &mut self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.compile(name)?;
        let exe = self.execs.get(name).unwrap();
        let outs = exe.execute_b(args).with_context(|| format!("executing {name}"))?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Copy a buffer back to host as f32.
    pub fn to_host_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Copy a buffer back to host as i32.
    pub fn to_host_i32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<i32>()?)
    }
}
