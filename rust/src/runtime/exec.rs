//! The PJRT execution backend: the artifact-driven counterpart of
//! [`crate::model::HostModel`].
//!
//! Weights are uploaded once as device buffers; per chunk the engine
//! uploads the (bucketed) KV cache and hidden state, runs one layer-step
//! executable per layer (`layer_dense_T{b}` or `layer_quoka_T{b}`), and
//! appends the returned self-KV to the host-side cache. The QUOKA variant
//! runs Algorithm 1 *inside* the artifact — selection, gather and reduced
//! attention all in one XLA module.

use super::{Manifest, Runtime};
use crate::model::{ModelConfig, Weights};
use anyhow::{Context, Result};
use xla::PjRtBuffer;

/// Per-layer uploaded weight buffers (order = manifest.layer_weights).
struct LayerBufs(Vec<PjRtBuffer>);

/// Uploaded model parameters.
struct WeightBufs {
    embedding: PjRtBuffer,
    final_norm: PjRtBuffer,
    layers: Vec<LayerBufs>,
}

/// Attention mode per request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnMode {
    Dense,
    Quoka,
}

impl AttnMode {
    pub fn tag(&self) -> &'static str {
        match self {
            AttnMode::Dense => "dense",
            AttnMode::Quoka => "quoka",
        }
    }
}

/// Per-sequence state: host-side per-layer KV caches stored at the stride
/// of the current bucket (so uploads are direct slices).
pub struct PjrtSeq {
    /// `[n_layers][n_kv * bucket * d]`.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Current bucket (stride) of the caches.
    bucket: usize,
    /// Valid rows.
    pub t: usize,
    pub pos: usize,
}

impl PjrtSeq {
    pub fn new(m: &Manifest) -> PjrtSeq {
        let cfg = &m.model;
        let bucket = m.buckets[0];
        let n = cfg.n_kv_heads * bucket * cfg.d_head;
        PjrtSeq {
            k: (0..cfg.n_layers).map(|_| vec![0.0; n]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; n]).collect(),
            bucket,
            t: 0,
            pos: 0,
        }
    }

    /// Grow the caches to `bucket`, re-striding each head slab.
    fn grow(&mut self, cfg: &ModelConfig, bucket: usize) {
        if bucket <= self.bucket {
            return;
        }
        let (nkv, d) = (cfg.n_kv_heads, cfg.d_head);
        for layer in 0..self.k.len() {
            let mut k2 = vec![0.0; nkv * bucket * d];
            let mut v2 = vec![0.0; nkv * bucket * d];
            for h in 0..nkv {
                let src = h * self.bucket * d;
                let dst = h * bucket * d;
                let n = self.t * d;
                k2[dst..dst + n].copy_from_slice(&self.k[layer][src..src + n]);
                v2[dst..dst + n].copy_from_slice(&self.v[layer][src..src + n]);
            }
            self.k[layer] = k2;
            self.v[layer] = v2;
        }
        self.bucket = bucket;
    }

    /// Append `s_real` rows of self-KV (layout `[n_kv, s_art, d]`, first
    /// `s_real` rows of each head valid).
    fn append(&mut self, cfg: &ModelConfig, layer: usize, k_self: &[f32], v_self: &[f32], s_art: usize, s_real: usize) {
        let (nkv, d) = (cfg.n_kv_heads, cfg.d_head);
        for h in 0..nkv {
            let dst = h * self.bucket * d + self.t * d;
            let src = h * s_art * d;
            let n = s_real * d;
            self.k[layer][dst..dst + n].copy_from_slice(&k_self[src..src + n]);
            self.v[layer][dst..dst + n].copy_from_slice(&v_self[src..src + n]);
        }
    }

    /// KV bytes resident.
    pub fn kv_bytes(&self, cfg: &ModelConfig) -> usize {
        2 * self.k.len() * cfg.n_kv_heads * self.bucket * cfg.d_head * 4
    }

    /// Benchmark helper: fill the caches with `t` random rows (standing in
    /// for an already-prefilled context) so per-chunk latency can be
    /// measured at arbitrary cache depths without paying a full prefill.
    pub fn fill_random(&mut self, m: &Manifest, t: usize, seed: u64) {
        let cfg = m.model.clone();
        let bucket = m.bucket_for(t, m.b_cp).expect("t exceeds largest bucket");
        self.grow(&cfg, bucket);
        let mut rng = crate::util::Rng::new(seed);
        let (nkv, d) = (cfg.n_kv_heads, cfg.d_head);
        for layer in 0..self.k.len() {
            for h in 0..nkv {
                let base = h * self.bucket * d;
                rng.fill_normal(&mut self.k[layer][base..base + t * d], 0.5);
                rng.fill_normal(&mut self.v[layer][base..base + t * d], 0.5);
            }
        }
        self.t = t;
        self.pos = t;
    }
}

/// The PJRT-backed model backend.
pub struct PjrtBackend {
    pub rt: Runtime,
    w: WeightBufs,
}

impl PjrtBackend {
    /// Load artifacts and upload the weights generated from `seed`.
    pub fn load(artifact_dir: &str, seed: u64) -> Result<PjrtBackend> {
        let rt = Runtime::load(artifact_dir)?;
        Self::with_runtime(rt, seed)
    }

    /// Lazy-compile variant (artifacts compiled on first use).
    pub fn load_lazy(artifact_dir: &str, seed: u64) -> Result<PjrtBackend> {
        let rt = Runtime::load_lazy(artifact_dir)?;
        Self::with_runtime(rt, seed)
    }

    fn with_runtime(rt: Runtime, seed: u64) -> Result<PjrtBackend> {
        let weights = Weights::generate(&rt.manifest.model, seed);
        let cfg = &rt.manifest.model;
        let embedding = rt.buf_f32(weights.embedding.data(), &[cfg.vocab, cfg.d_model])?;
        let final_norm = rt.buf_f32(weights.final_norm.data(), &[cfg.d_model])?;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for lw in &weights.layers {
            let named: Vec<(&str, &crate::tensor::Tensor)> = vec![
                ("attn_norm", &lw.attn_norm),
                ("wq", &lw.wq),
                ("wk", &lw.wk),
                ("wv", &lw.wv),
                ("wo", &lw.wo),
                ("ffn_norm", &lw.ffn_norm),
                ("w_gate", &lw.w_gate),
                ("w_up", &lw.w_up),
                ("w_down", &lw.w_down),
            ];
            let mut bufs = Vec::new();
            for want in &rt.manifest.layer_weights {
                let (_, t) = named
                    .iter()
                    .find(|(n, _)| n == want)
                    .with_context(|| format!("unknown layer weight '{want}' in manifest"))?;
                bufs.push(rt.buf_f32(t.data(), t.shape())?);
            }
            layers.push(LayerBufs(bufs));
        }
        Ok(PjrtBackend { rt, w: WeightBufs { embedding, final_norm, layers } })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.rt.manifest.model
    }

    /// Run one prefill chunk (`tokens.len() <= B_CP`). Returns the hidden
    /// rows `[s_real, d_model]`.
    pub fn prefill_chunk(
        &mut self,
        seq: &mut PjrtSeq,
        tokens: &[u32],
        mode: AttnMode,
    ) -> Result<Vec<f32>> {
        let b_cp = self.rt.manifest.b_cp;
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() <= b_cp,
            "chunk must be 1..={b_cp} tokens"
        );
        self.forward(seq, tokens, mode, false)
    }

    /// Run one decode step; returns the next token (greedy) and its logits.
    pub fn decode_step(
        &mut self,
        seq: &mut PjrtSeq,
        token: u32,
        mode: AttnMode,
    ) -> Result<(u32, Vec<f32>)> {
        let hidden = self.forward(seq, &[token], mode, true)?;
        let logits = self.logits(&hidden)?;
        let next = crate::tensor::ops::topk_indices(&logits, 1)[0] as u32;
        Ok((next, logits))
    }

    /// Logits for one hidden row.
    pub fn logits(&mut self, hidden_row: &[f32]) -> Result<Vec<f32>> {
        let cfg = self.cfg().clone();
        let h = self.rt.buf_f32(&hidden_row[..cfg.d_model], &[cfg.d_model])?;
        let outs = self.rt.run("logits", &[&h, &self.w.final_norm, &self.w.embedding])?;
        let mut lit = outs[0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        Ok(parts[0].to_vec::<f32>()?)
    }

    fn forward(
        &mut self,
        seq: &mut PjrtSeq,
        tokens: &[u32],
        mode: AttnMode,
        decode: bool,
    ) -> Result<Vec<f32>> {
        let cfg = self.cfg().clone();
        let m_bcp = self.rt.manifest.b_cp;
        let s_real = tokens.len();
        let s_art = if decode { 1 } else { m_bcp };
        // Pick and, if needed, grow into the bucket for this step.
        let bucket = self.rt.manifest.bucket_for(seq.t, s_art)?;
        seq.grow(&cfg, bucket);

        // Embed (pad the chunk to the artifact width).
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(s_art, 0);
        let tok_buf = self.rt.buf_i32(&padded, &[s_art])?;
        let embed_name = if decode { "embed_d" } else { "embed_p" };
        let outs = self.rt.run(embed_name, &[&tok_buf, &self.w.embedding])?;
        let mut lit = outs[0].to_literal_sync()?;
        let mut hidden = lit.decompose_tuple()?[0].to_vec::<f32>()?;

        let tag = mode.tag();
        let layer_name = if decode {
            format!("layer_{tag}_decode_T{bucket}")
        } else {
            format!("layer_{tag}_T{bucket}")
        };
        let (nkv, d) = (cfg.n_kv_heads, cfg.d_head);
        let t_len = self.rt.buf_scalar_i32(seq.t as i32)?;
        let pos0 = self.rt.buf_scalar_i32(seq.pos as i32)?;

        for layer in 0..cfg.n_layers {
            let h_buf = self.rt.buf_f32(&hidden, &[s_art, cfg.d_model])?;
            let k_buf = self.rt.buf_f32(&seq.k[layer], &[nkv, bucket, d])?;
            let v_buf = self.rt.buf_f32(&seq.v[layer], &[nkv, bucket, d])?;
            let mut args: Vec<&PjRtBuffer> = vec![&h_buf];
            for wbuf in &self.w.layers[layer].0 {
                args.push(wbuf);
            }
            args.push(&k_buf);
            args.push(&v_buf);
            args.push(&t_len);
            args.push(&pos0);
            let outs = self.rt.run(&layer_name, &args)?;
            let mut lit = outs[0].to_literal_sync()?;
            let parts = lit.decompose_tuple()?;
            hidden = parts[0].to_vec::<f32>()?;
            let k_self = parts[1].to_vec::<f32>()?;
            let v_self = parts[2].to_vec::<f32>()?;
            seq.append(&cfg, layer, &k_self, &v_self, s_art, s_real);
        }
        seq.t += s_real;
        seq.pos += s_real;

        hidden.truncate(s_real * cfg.d_model);
        Ok(hidden)
    }
}
