//! Manifest parsing — the AOT ↔ runtime contract.

use crate::model::ModelConfig;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// One artifact entry from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    /// Chunk width this artifact was lowered for (0 when n/a).
    pub s: usize,
    /// KV bucket length (0 when n/a).
    pub bucket: usize,
    /// Argument order.
    pub args: Vec<String>,
    /// Output order.
    pub outs: Vec<String>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelConfig,
    /// Ascending KV bucket lengths.
    pub buckets: Vec<usize>,
    pub b_cp: usize,
    /// Selection budget baked into the quoka artifacts.
    pub b_sa: usize,
    pub n_q_sel: usize,
    pub layer_weights: Vec<String>,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        Manifest::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let model = ModelConfig::from_json(j.req("model")?)?;
        let buckets = j
            .req("buckets")?
            .as_arr()
            .context("buckets must be an array")?
            .iter()
            .map(|b| b.as_usize().unwrap())
            .collect::<Vec<_>>();
        let artifacts = j
            .req("artifacts")?
            .as_arr()
            .context("artifacts must be an array")?
            .iter()
            .map(|a| {
                let strs = |key: &str| -> Vec<String> {
                    a.get(key)
                        .and_then(|v| v.as_arr())
                        .map(|v| v.iter().filter_map(|s| s.as_str()).map(String::from).collect())
                        .unwrap_or_default()
                };
                Ok(ArtifactEntry {
                    name: a.req("name")?.as_str().unwrap().to_string(),
                    file: a.req("file")?.as_str().unwrap().to_string(),
                    kind: a.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string(),
                    s: a.get("s").and_then(|v| v.as_usize()).unwrap_or(0),
                    bucket: a.get("bucket").and_then(|v| v.as_usize()).unwrap_or(0),
                    args: strs("args"),
                    outs: strs("outs"),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            model,
            buckets,
            b_cp: j.req("b_cp")?.as_usize().unwrap(),
            b_sa: j.req("b_sa")?.as_usize().unwrap(),
            n_q_sel: j.req("n_q_sel")?.as_usize().unwrap(),
            layer_weights: j
                .req("layer_weights")?
                .as_arr()
                .context("layer_weights")?
                .iter()
                .filter_map(|s| s.as_str())
                .map(String::from)
                .collect(),
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest bucket with room for `t_past + s` rows.
    pub fn bucket_for(&self, t_past: usize, s: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| t_past + s <= b)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bucket fits t={} + s={} (buckets: {:?})",
                    t_past,
                    s,
                    self.buckets
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "model": {"name":"tiny","vocab":257,"d_model":32,"n_layers":2,
                    "n_q_heads":4,"n_kv_heads":2,"d_head":8,"d_ff":64,
                    "rope_theta":10000.0,"use_rope":true,"n_experts":0,
                    "norm_eps":1e-5,"max_seq":4096},
          "buckets": [1024, 4096],
          "b_cp": 128, "b_sa": 1024, "n_q_sel": 16,
          "layer_weights": ["attn_norm","wq"],
          "artifacts": [
            {"name":"layer_dense_T1024","file":"layer_dense_T1024.hlo.txt",
             "kind":"dense","s":128,"bucket":1024,
             "args":["hidden","attn_norm"],"outs":["hidden","k_self","v_self"]}
          ]
        }"#
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&Json::parse(sample()).unwrap()).unwrap();
        assert_eq!(m.model.name, "tiny");
        assert_eq!(m.buckets, vec![1024, 4096]);
        assert_eq!(m.artifact("layer_dense_T1024").unwrap().s, 128);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::from_json(&Json::parse(sample()).unwrap()).unwrap();
        assert_eq!(m.bucket_for(0, 128).unwrap(), 1024);
        assert_eq!(m.bucket_for(896, 128).unwrap(), 1024);
        assert_eq!(m.bucket_for(897, 128).unwrap(), 4096);
        assert!(m.bucket_for(4096, 128).is_err());
    }
}
