//! L3 coordinator: the serving system.
//!
//! Request lifecycle, paged KV-block accounting, Sarathi-style chunked
//! prefill + decode scheduling, and the engine loop over either execution
//! backend. This is where the paper's method lives as a *system feature*:
//! QUOKA (or any baseline policy) is a per-request `PolicySpec` applied at
//! every layer of every scheduled chunk.

pub mod request;
pub mod kv_blocks;
pub mod scheduler;
pub mod metrics;
pub mod engine;

pub use engine::{Backend, Engine, EngineCfg, KvLayout};
pub use kv_blocks::BlockAllocator;
pub use metrics::Metrics;
pub use request::{PolicySpec, Request, RequestResult};
pub use scheduler::{SchedCfg, Scheduler, StepPlan, WorkItem};
