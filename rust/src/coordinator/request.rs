//! Request lifecycle types.

use crate::kvpool::RadixCursor;
use crate::spec::SpecCfg;
use std::time::Instant;

/// How a request's attention is sparsified.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicySpec {
    /// Selection policy name (see `select::policy_by_name`). The PJRT
    /// backend supports `dense` and `quoka`; all names run on `host`.
    pub name: String,
    /// Selection budget `B_SA`.
    pub budget: usize,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec { name: "quoka".into(), budget: 1024 }
    }
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub max_new_tokens: usize,
    pub policy: PolicySpec,
    /// Speculative-decode configuration (off by default): when enabled,
    /// decode steps draft up to `spec.gamma` tokens and verify them in
    /// one multi-token forward ([`WorkItem::Verify`]).
    ///
    /// [`WorkItem::Verify`]: super::scheduler::WorkItem::Verify
    pub spec: SpecCfg,
}

/// Terminal result for one request.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub generated: Vec<u32>,
    /// Time to first token (prefill complete + 1 decode), seconds.
    pub ttft_s: f64,
    /// Mean time per output token (after the first), seconds.
    pub tpot_s: f64,
    pub prompt_tokens: usize,
    /// Prompt tokens served from the shared prefix cache — their prefill
    /// chunks were never scheduled (0 without the paged prefix cache).
    pub cached_prefix_tokens: usize,
    /// Speculative decode: draft tokens proposed / accepted for this
    /// request (both 0 when speculation was off).
    pub spec_drafted_tokens: usize,
    pub spec_accepted_tokens: usize,
    /// Wall time in the engine (admission → completion).
    pub total_s: f64,
}

/// Scheduler-visible sequence phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `next` = offset of the next un-prefilled prompt token.
    Prefill { next: usize },
    /// Parked follower of an in-flight prefill publishing the same prefix:
    /// the scheduler gives it no step budget; the engine keeps extending
    /// `next` as the producing sequence publishes pages, and wakes it into
    /// `Prefill { next }` when the shared region is covered or the
    /// producer stops producing (retired, cancelled, rejected) — whatever
    /// the cache does not cover by then is recomputed normally.
    WaitingOnPrefix { next: usize },
    Decode,
    Finished,
}

/// Engine-internal per-sequence bookkeeping.
pub struct SeqEntry {
    pub req: Request,
    pub phase: Phase,
    pub generated: Vec<u32>,
    pub admitted_at: Instant,
    pub first_token_at: Option<Instant>,
    /// When the most recent token(s) were emitted — the anchor for the
    /// inter-token-latency histogram. Set with the first token, advanced
    /// on every subsequent emission (a batched verify emission advances
    /// it once and contributes per-token samples).
    pub last_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// KV blocks currently leased from the block allocator. In paged mode
    /// these are pool page ids; a prefix-cache hit pre-populates the head
    /// of the table with shared pages before admission.
    pub blocks: Vec<u32>,
    /// Prompt tokens covered by shared prefix pages (prefill starts after
    /// them). Grows while parked in [`Phase::WaitingOnPrefix`] as the
    /// producing sequence publishes more pages.
    pub cached_tokens: usize,
    /// In-flight subscription: the sequence id whose prefill this follower
    /// is waiting on, if any.
    pub waiting_on: Option<u64>,
    /// Page count at which the in-flight wait ends (the shared prefix in
    /// whole pages, capped so at least one token is always left to
    /// prefill).
    pub wait_pages: usize,
    /// Spill-tier promotions still in flight for this sequence's prefix.
    /// While non-zero the sequence stays parked in
    /// [`Phase::WaitingOnPrefix`] even with no producing leader
    /// (`waiting_on == None`): the pages it waits for are coming off
    /// disk, not off another sequence's prefill.
    pub promote_pending: usize,
    /// Pages of this sequence's own prompt already in the radix cache
    /// (publish watermark; starts at the submit-time match and advances as
    /// completed pages are published mid-prefill).
    pub published_pages: usize,
    /// Remembered radix-tree position for this sequence's prompt chain:
    /// in-flight publishes and follower adoption polls resume the walk
    /// here instead of re-walking from the root (O(new pages) per call).
    /// Node indices are stable while the sequence holds references on its
    /// chain's pages — eviction and abort withdrawal never touch a page
    /// with a live owner.
    pub radix_cursor: Option<RadixCursor>,
    /// Speculative decode accounting: draft tokens proposed / accepted.
    pub spec_drafted: usize,
    pub spec_accepted: usize,
}

impl SeqEntry {
    pub fn new(req: Request) -> SeqEntry {
        SeqEntry {
            req,
            phase: Phase::Prefill { next: 0 },
            generated: Vec::new(),
            admitted_at: Instant::now(),
            first_token_at: None,
            last_token_at: None,
            finished_at: None,
            blocks: Vec::new(),
            cached_tokens: 0,
            waiting_on: None,
            wait_pages: 0,
            promote_pending: 0,
            published_pages: 0,
            radix_cursor: None,
            spec_drafted: 0,
            spec_accepted: 0,
        }
    }

    /// Blocks this sequence still needs to cover its whole prompt + decode
    /// budget, net of blocks already held (prefix-cache pages included).
    /// The single source of truth for admission, the engine's reject
    /// check, and eviction pressure — the three must agree or an
    /// unfittable head-of-line request wedges the queue.
    pub fn residual_blocks(&self, blocks: &super::kv_blocks::BlockAllocator) -> usize {
        blocks
            .blocks_for(self.req.tokens.len() + self.req.max_new_tokens)
            .saturating_sub(self.blocks.len())
    }

    /// Total tokens this sequence holds in the KV cache right now.
    pub fn cache_tokens(&self) -> usize {
        let prefilled = match self.phase {
            Phase::Prefill { next } | Phase::WaitingOnPrefix { next } => next,
            _ => self.req.tokens.len(),
        };
        prefilled + self.generated.len()
    }

    pub fn result(&self) -> RequestResult {
        let end = self.finished_at.unwrap_or_else(Instant::now);
        let ttft = self
            .first_token_at
            .map(|t| (t - self.admitted_at).as_secs_f64())
            .unwrap_or_default();
        let n_out = self.generated.len();
        let tpot = if n_out > 1 {
            self.first_token_at
                .map(|t| (end - t).as_secs_f64() / (n_out - 1) as f64)
                .unwrap_or_default()
        } else {
            0.0
        };
        RequestResult {
            id: self.req.id,
            generated: self.generated.clone(),
            ttft_s: ttft,
            tpot_s: tpot,
            prompt_tokens: self.req.tokens.len(),
            cached_prefix_tokens: self.cached_tokens,
            spec_drafted_tokens: self.spec_drafted,
            spec_accepted_tokens: self.spec_accepted,
            total_s: (end - self.admitted_at).as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            id: 1,
            tokens: vec![1; 300],
            max_new_tokens: 4,
            policy: PolicySpec::default(),
            spec: SpecCfg::off(),
        }
    }

    #[test]
    fn cache_tokens_tracks_phase() {
        let mut e = SeqEntry::new(req());
        assert_eq!(e.cache_tokens(), 0);
        e.phase = Phase::Prefill { next: 128 };
        assert_eq!(e.cache_tokens(), 128);
        e.phase = Phase::Decode;
        e.generated.push(9);
        assert_eq!(e.cache_tokens(), 301);
    }

    #[test]
    fn parked_follower_counts_only_adopted_tokens() {
        // A WaitingOnPrefix sequence has prefilled nothing itself; its KV
        // residency is exactly the pages it adopted so far.
        let mut e = SeqEntry::new(req());
        e.phase = Phase::WaitingOnPrefix { next: 64 };
        e.cached_tokens = 64;
        assert_eq!(e.cache_tokens(), 64);
    }

    #[test]
    fn result_times_are_ordered() {
        let mut e = SeqEntry::new(req());
        e.first_token_at = Some(e.admitted_at + std::time::Duration::from_millis(50));
        e.generated = vec![1, 2, 3];
        e.finished_at = Some(e.admitted_at + std::time::Duration::from_millis(150));
        let r = e.result();
        assert!((r.ttft_s - 0.05).abs() < 1e-6);
        assert!((r.tpot_s - 0.05).abs() < 1e-6);
        assert!((r.total_s - 0.15).abs() < 1e-6);
    }
}
