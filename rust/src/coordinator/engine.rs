//! The serving engine: continuous batching over chunked prefill + decode.
//!
//! One `step()` = one scheduler plan executed: decodes first, then prefill
//! chunks, exactly as planned by the Sarathi-style scheduler. Works over
//! either execution backend:
//! - **host** — the pure-Rust transformer with *any* selection policy;
//! - **pjrt** — AOT artifacts (dense / QUOKA variants compiled from JAX).
//!
//! Python never runs here; the PJRT backend only replays compiled HLO.

use super::kv_blocks::BlockAllocator;
use super::metrics::Metrics;
use super::request::{Phase, PolicySpec, Request, RequestResult, SeqEntry};
use super::scheduler::{SchedCfg, Scheduler, WorkItem};
use crate::kvpool::{
    policy_ns, slot_stride, KvDtype, KvPool, PoolCfg, PromoteDone, Promoter, RadixCache, SpillFile,
};
use crate::model::{DecodeKv, DecodeSeq, HostModel, ModelConfig, SeqState, Weights};
use crate::obs::{self, TraceEventKind, Tracer};
use crate::runtime::exec::{AttnMode, PjrtBackend, PjrtSeq};
use crate::select::{SelectCtx, SelectionPolicy};
use crate::spec::{drafter_for, DraftSource, SpecCfg};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::time::Instant;

/// Execution backend.
pub enum Backend {
    Host(HostModel),
    Pjrt(Box<PjrtBackend>),
}

/// Length of the longest common prefix of two token sequences.
fn common_prefix_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

enum SeqBack {
    Host { state: SeqState, last_hidden: Vec<f32> },
    /// Host backend over the shared paged pool: no private KV — the block
    /// table lives on the `SeqEntry`, only the token cursor and the TTFT
    /// hidden row live here.
    HostPaged { len: usize, last_hidden: Vec<f32> },
    Pjrt { state: PjrtSeq, last_hidden: Vec<f32> },
}

/// Where a sequence's physical KV lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// Private per-sequence buffers (the block allocator is accounting
    /// only). Any selection policy; both backends.
    Private,
    /// Shared paged pool (`kvpool::KvPool`): block tables, refcounted
    /// pages, copy-on-write, and — when `prefix_cache` — radix prefix
    /// reuse that skips prefill for cached prompt pages. Host backend;
    /// block-table-aware policies (`dense`, `quoka*`).
    Paged { prefix_cache: bool },
}

impl Default for KvLayout {
    fn default() -> Self {
        KvLayout::Private
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineCfg {
    pub sched: SchedCfg,
    /// KV pool: total blocks × tokens/block of admission capacity.
    pub pool_blocks: usize,
    pub block_tokens: usize,
    pub seed: u64,
    /// Physical KV layout (private buffers vs shared paged pool).
    pub kv: KvLayout,
    /// Engine-wide default speculative-decode configuration, applied to
    /// requests submitted without an explicit override
    /// ([`Engine::submit_spec`]). Off by default.
    pub spec: SpecCfg,
    /// KV cache element type (`--kv-dtype`): fp32 slabs (exact, the parity
    /// oracle) or int8 rows with per-row fp32 scales (4x smaller cache,
    /// dequantized inside the attention tiles). Applies to both layouts;
    /// host backend only — a pjrt engine downgrades to f32 with a warning.
    pub kv_dtype: KvDtype,
    /// Fan-out worker count for the parallel GEMM / attention pool
    /// (`--workers`). `0` keeps the `QUOKA_WORKERS` env override or the
    /// auto-detected `available_parallelism - 1`. Pinned at engine
    /// construction, before the first forward pass sizes the shared pool.
    pub workers: usize,
    /// Cold-tier spill file (`--kv-spill`): radix-cached pages evicted
    /// under pool pressure are demoted to this mmap-backed file instead
    /// of destroyed, and promoted back on a radix hit. Requires the
    /// paged prefix cache; `None` disables the tier.
    pub spill_path: Option<std::path::PathBuf>,
    /// Spill file capacity in bytes (`--kv-spill-cap`). Must be a whole
    /// number of page slots — engine construction hard-errors otherwise
    /// (a slot is the checksummed page image rounded to 64 bytes; see
    /// [`slot_stride`]).
    pub spill_cap_bytes: usize,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg {
            sched: SchedCfg::default(),
            pool_blocks: 4096,
            block_tokens: 128,
            seed: 0,
            kv: KvLayout::Private,
            spec: SpecCfg::off(),
            kv_dtype: KvDtype::env_default(),
            workers: 0,
            spill_path: None,
            spill_cap_bytes: 0,
        }
    }
}

/// One in-flight background promotion: the radix node (and its liveness
/// generation) a spill slot will be restored into, plus every sequence
/// parked on the result.
struct PendingPromotion {
    node: usize,
    gen: u64,
    waiters: Vec<u64>,
    t_kick: Instant,
}

/// The engine.
pub struct Engine {
    backend: Backend,
    pub sched: Scheduler,
    pub blocks: BlockAllocator,
    /// Shared paged KV storage (paged mode only).
    pub pool: Option<KvPool>,
    /// Radix prefix cache (paged mode with `prefix_cache` only).
    pub radix: Option<RadixCache>,
    seqs: HashMap<u64, SeqEntry>,
    backs: HashMap<u64, SeqBack>,
    policies: HashMap<String, Box<dyn SelectionPolicy>>,
    /// Per-sequence draft sources for speculating requests (created at
    /// submit, dropped at retire/cancel/reject).
    drafters: HashMap<u64, Box<dyn DraftSource>>,
    /// Engine-wide default spec config for plain [`Engine::submit`] calls.
    default_spec: SpecCfg,
    /// KV element type every sequence's cache (private or pooled) uses.
    kv_dtype: KvDtype,
    ctx: SelectCtx,
    pub metrics: Metrics,
    /// Lifecycle event ring ([`crate::obs::tracer`]). Disabled (and
    /// unallocated) by default; [`Engine::enable_tracing`] turns it on.
    pub tracer: Tracer,
    /// Cold spill tier (paged prefix-cache mode with `--kv-spill` only):
    /// demoted page images live here until promoted back or dropped.
    spill: Option<SpillFile>,
    /// Background promotion thread staging spilled slots back into RAM.
    promoter: Option<Promoter>,
    /// In-flight promotions by spill slot.
    promos: HashMap<u32, PendingPromotion>,
    /// Completed promotions waiting for a free RAM page. Applying a
    /// promotion consumes one page and each follower adoption releases
    /// one reservation page back, so under full-pool pressure the two
    /// drain in lockstep across steps — a completion that cannot get a
    /// page *this* step is retried, never dropped.
    promo_backlog: Vec<PromoteDone>,
    results: Vec<RequestResult>,
    next_id: u64,
}

impl Engine {
    /// Host-backend engine for a model preset.
    pub fn new_host(preset: &str, cfg: EngineCfg) -> Result<Engine> {
        let mc = ModelConfig::preset(preset)?;
        let model = HostModel::new(Weights::generate(&mc, cfg.seed));
        Self::with_backend(Backend::Host(model), cfg)
    }

    /// PJRT-backend engine over an artifact directory.
    pub fn new_pjrt(artifact_dir: &str, cfg: EngineCfg) -> Result<Engine> {
        let be = PjrtBackend::load_lazy(artifact_dir, cfg.seed)?;
        Self::with_backend(Backend::Pjrt(Box::new(be)), cfg)
    }

    pub fn with_backend(backend: Backend, mut cfg: EngineCfg) -> Result<Engine> {
        // Pin the fan-out worker count before the first forward pass
        // lazily sizes the shared pool (0 = QUOKA_WORKERS / auto).
        if cfg.workers > 0 {
            crate::util::threadpool::set_workers(cfg.workers);
        }
        // A PJRT engine with an enabled engine-wide spec default would
        // reject every plain submit() (compiled artifacts have a fixed
        // single-token decode shape) — catch the misconfiguration at
        // construction instead of failing one request at a time.
        // Per-request overrides are still rejected explicitly in
        // submit_spec.
        if matches!(backend, Backend::Pjrt(_)) && cfg.spec.enabled() {
            eprintln!(
                "quoka: speculative decode requires the host backend; disabling the \
                 engine-wide default (--spec-gamma) for this pjrt engine"
            );
            cfg.spec = SpecCfg::off();
        }
        // Same construction-time downgrade for quantized KV: the compiled
        // PJRT artifacts stream their own fp32 cache, so an int8 request
        // could never be served — fall back to the exact representation
        // instead of failing every submit.
        if matches!(backend, Backend::Pjrt(_)) && cfg.kv_dtype == KvDtype::Int8 {
            eprintln!(
                "quoka: int8 KV requires the host backend; falling back to \
                 --kv-dtype f32 for this pjrt engine"
            );
            cfg.kv_dtype = KvDtype::F32;
        }
        // Prefix-cache mode publishes KV pages: pin chunk boundaries to
        // the prompt (never truncated by step-budget pressure) so cached
        // KV is bit-identical to a cold serial recompute under any load.
        if matches!(cfg.kv, KvLayout::Paged { prefix_cache: true }) {
            cfg.sched.deterministic_chunks = true;
            // Cache cursors advance in lcm(chunk width, page size) units
            // (see `Engine::grid_pages`); when neither divides the other
            // that quantum balloons and silently discards short matches.
            let w = cfg.sched.det_chunk_width();
            if w % cfg.block_tokens != 0 && cfg.block_tokens % w != 0 {
                eprintln!(
                    "quoka: prefix-cache reuse quantized to lcm({w}-token chunks, \
                     {}-token pages) = {} tokens; align b_cp/step_tokens/block_tokens \
                     for finer-grained reuse",
                    cfg.block_tokens,
                    w / gcd(w, cfg.block_tokens) * cfg.block_tokens,
                );
            }
        }
        let pool = match cfg.kv {
            KvLayout::Private => None,
            KvLayout::Paged { .. } => {
                let mc = match &backend {
                    Backend::Host(m) => m.cfg().clone(),
                    Backend::Pjrt(b) => b.cfg().clone(),
                };
                Some(KvPool::new_with_dtype(
                    PoolCfg {
                        n_layers: mc.n_layers,
                        n_kv: mc.n_kv_heads,
                        d: mc.d_head,
                        block_tokens: cfg.block_tokens,
                        total_blocks: cfg.pool_blocks,
                    },
                    cfg.kv_dtype,
                ))
            }
        };
        let radix = match cfg.kv {
            KvLayout::Paged { prefix_cache: true } => Some(RadixCache::new(cfg.block_tokens)),
            _ => None,
        };
        // Cold spill tier. A misconfigured capacity is a hard error — a
        // cap that is not a whole number of page slots silently strands
        // the remainder, so it is almost certainly a typo. A path whose
        // filesystem lacks mmap write-back support, by contrast, degrades
        // to no-spill with a warning (the PJRT-downgrade pattern): the
        // engine still serves, just without a cold tier.
        let mut spill = None;
        if let Some(path) = cfg.spill_path.as_deref() {
            if let (Some(pool), true) = (&pool, radix.is_some()) {
                let payload = pool.page_image_bytes();
                let slot = slot_stride(payload);
                anyhow::ensure!(
                    cfg.spill_cap_bytes > 0 && cfg.spill_cap_bytes % slot == 0,
                    "--kv-spill-cap {} is not a whole number of {slot}-byte page slots \
                     (one slot per {}-token page image); use a multiple of {slot}",
                    cfg.spill_cap_bytes,
                    cfg.block_tokens,
                );
                match SpillFile::open(path, cfg.spill_cap_bytes, payload) {
                    Ok(sf) => spill = Some(sf),
                    Err(e) => eprintln!(
                        "quoka: --kv-spill {}: {e:#}; the path lacks mmap write-back \
                         support — running without a cold KV tier",
                        path.display()
                    ),
                }
            } else {
                eprintln!(
                    "quoka: --kv-spill requires the paged prefix cache \
                     (--prefix-cache); running without a cold KV tier"
                );
            }
        }
        let promoter = spill.as_ref().map(|sf| Promoter::spawn(sf.reader()));
        Ok(Engine {
            backend,
            sched: Scheduler::new(cfg.sched),
            blocks: BlockAllocator::new(cfg.pool_blocks, cfg.block_tokens),
            pool,
            radix,
            seqs: HashMap::new(),
            backs: HashMap::new(),
            policies: HashMap::new(),
            drafters: HashMap::new(),
            default_spec: cfg.spec,
            kv_dtype: cfg.kv_dtype,
            ctx: SelectCtx::new(cfg.seed ^ 0xE1),
            metrics: Metrics::default(),
            tracer: Tracer::disabled(),
            spill,
            promoter,
            promos: HashMap::new(),
            promo_backlog: Vec::new(),
            results: Vec::new(),
            next_id: 1,
        })
    }

    /// The cold spill tier, when configured (`--kv-spill`); test and
    /// bench hook for slot-occupancy assertions.
    pub fn spill(&self) -> Option<&SpillFile> {
        self.spill.as_ref()
    }

    /// Turn on lifecycle tracing with a ring of `capacity` events
    /// (oldest overwritten beyond that; see [`Tracer::overwritten`]).
    /// The ring is allocated here, once — recording never allocates.
    pub fn enable_tracing(&mut self, capacity: usize) {
        self.tracer = Tracer::new(capacity);
    }

    /// Flush the trace ring to `path` as JSONL (oldest event first);
    /// returns the number of events written. The ring is left intact.
    pub fn write_trace(&self, path: &std::path::Path) -> std::io::Result<usize> {
        self.tracer.write_jsonl(path)
    }

    /// The engine-wide default speculative-decode configuration (what a
    /// plain [`Engine::submit`] applies); wire-level overrides resolve
    /// against it.
    pub fn default_spec(&self) -> SpecCfg {
        self.default_spec
    }

    /// The KV element type this engine's caches store (post any
    /// construction-time backend downgrade).
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }

    pub fn model_cfg(&self) -> ModelConfig {
        match &self.backend {
            Backend::Host(m) => m.cfg().clone(),
            Backend::Pjrt(b) => b.cfg().clone(),
        }
    }

    /// Prefix-cache cursor quantum, in pages: the smallest page count
    /// whose token length is a multiple of BOTH the page size and the
    /// deterministic chunk width. Every cache-resume cursor (submit-time
    /// match, in-flight adoption, wake) is kept a multiple of this, so a
    /// resumed prefill always restarts ON the deterministic chunk grid —
    /// off-grid boundaries would make a sparse policy's recomputed (and
    /// republished!) KV differ from a cold run — and always at a page
    /// boundary, so it writes only its own fresh reserved pages (no
    /// copy-on-write, no allocation beyond the admission reservation).
    ///
    /// When the chunk width and page size divide evenly (either way) the
    /// quantum is at most one chunk; otherwise it balloons to their lcm
    /// and short matches quantize away — `with_backend` warns about such
    /// geometries at engine construction.
    fn grid_pages(&self) -> usize {
        let bt = self.blocks.block_tokens();
        let w = self.sched.cfg.det_chunk_width();
        // lcm(w, bt) / bt
        (w / gcd(w, bt)).max(1)
    }

    /// Submit a request; returns its id. Fails fast for policies the
    /// backend cannot execute. In paged+prefix mode the radix cache is
    /// probed here: matched pages are retained and become the head of the
    /// sequence's block table, and the prefill cursor starts after them —
    /// those chunks are never scheduled. If a sequence in the same
    /// namespace is *still prefilling* a longer shared prefix, the new
    /// request additionally subscribes to it ([`Phase::WaitingOnPrefix`]):
    /// it consumes no step budget while the producer publishes the shared
    /// pages, adopts each page as it lands, and only ever prefills what
    /// the producer will not cover.
    pub fn submit(&mut self, tokens: Vec<u32>, max_new: usize, policy: PolicySpec) -> Result<u64> {
        let spec = self.default_spec;
        self.submit_spec(tokens, max_new, policy, spec)
    }

    /// [`Engine::submit`] with an explicit per-request speculative-decode
    /// configuration (overriding the engine default): when enabled, the
    /// request's decode steps draft up to `spec.gamma` tokens and verify
    /// them in one multi-token forward. Host backend only — the PJRT
    /// artifacts have a fixed single-token decode shape.
    pub fn submit_spec(
        &mut self,
        tokens: Vec<u32>,
        max_new: usize,
        policy: PolicySpec,
        spec: SpecCfg,
    ) -> Result<u64> {
        self.submit_tagged(tokens, max_new, policy, spec, "", 1)
    }

    /// [`Engine::submit_spec`] with a fair-share tag: `tenant` names the
    /// scheduler's weighted round-robin admission group (empty = the
    /// shared default tenant — what every untagged submit uses) and
    /// `weight` its admissions per turn. See `Scheduler::enqueue_as`.
    pub fn submit_tagged(
        &mut self,
        tokens: Vec<u32>,
        max_new: usize,
        policy: PolicySpec,
        spec: SpecCfg,
        tenant: &str,
        tenant_weight: usize,
    ) -> Result<u64> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        if spec.enabled() {
            anyhow::ensure!(
                matches!(self.backend, Backend::Host(_)),
                "speculative decode requires the host backend (pjrt artifacts \
                 have a fixed single-token decode shape)"
            );
        }
        if matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::ensure!(
                policy.name == "dense" || policy.name == "quoka",
                "pjrt backend serves 'dense' or 'quoka' (got '{}'); other \
                 baselines run with --backend host",
                policy.name
            );
        }
        if self.pool.is_some() {
            anyhow::ensure!(
                matches!(self.backend, Backend::Host(_)),
                "the paged KV pool requires the host backend"
            );
            anyhow::ensure!(
                policy.name == "dense" || policy.name.starts_with("quoka"),
                "paged KV serves block-table-aware policies 'dense'/'quoka*' \
                 (got '{}'); other baselines run with private KV buffers",
                policy.name
            );
        }
        if self.kv_dtype == KvDtype::Int8 {
            // Quantized caches expose int8 codes + scales, never fp32 key
            // rows; only policies that go through the quantization-aware
            // scan (or skip scanning entirely) can run over them.
            anyhow::ensure!(
                policy.name == "dense" || policy.name.starts_with("quoka"),
                "int8 KV serves 'dense'/'quoka*' (got '{}'); other baselines \
                 read fp32 key rows — rerun with --kv-dtype f32",
                policy.name
            );
        }
        if !self.policies.contains_key(&policy.name) {
            self.policies
                .insert(policy.name.clone(), crate::select::policy_by_name(&policy.name)?);
        }
        let id = self.next_id;
        self.next_id += 1;
        if let Some(d) = drafter_for(&spec) {
            self.drafters.insert(id, d);
        }
        let req = Request { id, tokens, max_new_tokens: max_new.max(1), policy, spec };
        let mut entry = SeqEntry::new(req);
        self.tracer
            .record(id, TraceEventKind::Submit { prompt: entry.req.tokens.len() as u32 });
        let grid = self.grid_pages();
        if let (Some(pool), Some(radix)) = (self.pool.as_mut(), self.radix.as_mut()) {
            self.metrics.record_prefix_lookup(entry.req.tokens.len());
            let ns =
                policy_ns(&entry.req.policy.name, entry.req.policy.budget, self.sched.cfg.b_cp);
            let mut matched = radix.lookup(ns, &entry.req.tokens);
            // Keep the match a multiple of the cursor quantum (see
            // [`Engine::grid_pages`]): resuming off the deterministic
            // chunk grid would recompute — and republish — KV with
            // boundaries no cold run has.
            matched.truncate(matched.len() - matched.len() % grid);
            if !matched.is_empty() {
                for &b in &matched {
                    pool.retain(b);
                }
                let cached = matched.len() * self.blocks.block_tokens();
                self.metrics.record_prefix_hit(cached, cached * pool.token_bytes());
                entry.cached_tokens = cached;
                entry.phase = Phase::Prefill { next: cached };
                entry.blocks = matched;
                self.tracer
                    .record(id, TraceEventKind::PrefixHit { pages: entry.blocks.len() as u32 });
            }
            entry.published_pages = entry.blocks.len();

            // In-flight subscription: when a sequence in the same
            // namespace is still prefilling a longer shared prefix than
            // the cache holds, park behind it instead of recomputing
            // tokens it is about to publish. The wait target is the
            // shared prefix in whole pages, capped by the producer's own
            // full pages and by the never-match-the-whole-prompt rule.
            let bt = self.blocks.block_tokens();
            let cap = (entry.req.tokens.len() - 1) / bt;
            let matched_pages = entry.blocks.len();
            let mut best: Option<(usize, u64)> = None; // (target, producer)
            // Oldest-first scan with an early exit at the cap: deepest
            // shared prefix wins, oldest producer breaks ties, and a burst
            // of identical prompts costs one prefix comparison per submit
            // (the first candidate — the original leader — hits the cap).
            let mut cands: Vec<u64> = self
                .seqs
                .iter()
                .filter(|(_, le)| {
                    matches!(le.phase, Phase::Prefill { .. } | Phase::WaitingOnPrefix { .. })
                })
                .map(|(&lid, _)| lid)
                .collect();
            cands.sort_unstable();
            for lid in cands {
                let le = &self.seqs[&lid];
                let lns =
                    policy_ns(&le.req.policy.name, le.req.policy.budget, self.sched.cfg.b_cp);
                if lns != ns {
                    continue;
                }
                let shared = common_prefix_len(&entry.req.tokens, &le.req.tokens);
                // Quantized like the match above: the wait ends on a
                // cursor the resumed prefill can continue from exactly.
                let mut target = (shared / bt).min(le.req.tokens.len() / bt).min(cap);
                target -= target % grid;
                if target > matched_pages && best.map(|(t, _)| target > t).unwrap_or(true) {
                    best = Some((target, lid));
                    if target + grid > cap {
                        break; // nothing deeper exists at this quantum
                    }
                }
            }
            // Spill-tier readahead: when the cached chain continues past
            // the resident match with demoted pages, kick their async
            // promotion now — at submit, before admission — and park the
            // sequence until they land. The fp32 scoring metadata never
            // left RAM, so only the page images come off disk; each
            // promotion flips its node back to `Resident` and the parked
            // sequence adopts the pages through the normal follower poll.
            let mut promo_target = matched_pages;
            if self.spill.is_some() && self.promoter.is_some() {
                let run = radix.spilled_run(ns, &entry.req.tokens, matched_pages);
                // Grid-quantized like the resident match: promoting a
                // tail this sequence could never resume from would spend
                // RAM on pages it will not adopt.
                let usable = run.len() - run.len() % grid;
                if usable > 0 {
                    let sp = self.spill.as_mut().unwrap();
                    let promoter = self.promoter.as_ref().unwrap();
                    for &(node, gen, slot) in &run[..usable] {
                        match self.promos.entry(slot) {
                            std::collections::hash_map::Entry::Occupied(mut o) => {
                                o.get_mut().waiters.push(id);
                            }
                            std::collections::hash_map::Entry::Vacant(v) => {
                                sp.pin(slot);
                                promoter.request(slot);
                                v.insert(PendingPromotion {
                                    node,
                                    gen,
                                    waiters: vec![id],
                                    t_kick: Instant::now(),
                                });
                            }
                        }
                        entry.promote_pending += 1;
                    }
                    promo_target = matched_pages + usable;
                    self.tracer.record(id, TraceEventKind::Promote { pages: usable as u32 });
                }
            }
            if let Some((target, lid)) = best {
                entry.waiting_on = Some(lid);
                entry.wait_pages = target.max(promo_target);
                entry.phase = Phase::WaitingOnPrefix { next: entry.cached_tokens };
                self.metrics.inflight_followers += 1;
                self.tracer.record(id, TraceEventKind::ParkOnPrefix { on: lid });
            } else if promo_target > matched_pages {
                // Parked on the spill tier alone: no producing leader
                // (`waiting_on == None`) — `promote_pending` is what keeps
                // the sequence in WaitingOnPrefix until the pages land.
                entry.wait_pages = promo_target;
                entry.phase = Phase::WaitingOnPrefix { next: entry.cached_tokens };
                self.tracer.record(id, TraceEventKind::ParkOnPrefix { on: 0 });
            }
        }
        self.seqs.insert(id, entry);
        self.sched.enqueue_as(id, tenant, tenant_weight);
        Ok(id)
    }

    /// Number of unfinished requests.
    pub fn pending(&self) -> usize {
        self.seqs.len()
    }

    /// Number of requests still waiting for admission — the quantity the
    /// serving front-end's backpressure limit is measured against.
    pub fn queue_depth(&self) -> usize {
        self.sched.waiting.len()
    }

    /// The tokens request `id` has generated so far (`None` once it
    /// finished or was never submitted). The streaming front-end polls
    /// this between steps to emit `delta` frames.
    pub fn generated_so_far(&self, id: u64) -> Option<&[u32]> {
        self.seqs.get(&id).map(|e| e.generated.as_slice())
    }

    /// Cancel a queued or running request (client abort). Its pages are
    /// released and it reports an empty generation through
    /// [`Engine::take_results`]. A paged publisher cancelled mid-prefill
    /// also withdraws the pages it published in flight that no other
    /// sequence adopted (adopted and shared pages survive — the radix
    /// tail-unpublish is refcount-guarded), and any follower parked behind
    /// it falls back to normal prefill at its next step, keeping
    /// everything it adopted so far. Returns false for unknown ids.
    pub fn cancel(&mut self, id: u64) -> bool {
        let Some(entry) = self.seqs.remove(&id) else {
            return false;
        };
        self.sched.waiting.retain(|&w| w != id);
        self.sched.retire(id);
        self.backs.remove(&id);
        self.metrics.requests_cancelled += 1;
        self.tracer.record(id, TraceEventKind::Cancel);
        self.discard(entry);
        true
    }

    /// Shared teardown for a request that ends unserved (queue rejection
    /// or cancel): every page goes back through the pool's refcounts;
    /// pages the request published in flight beyond its adopted prefix
    /// are withdrawn if no other sequence adopted them (a completed
    /// prefill's pages stay — they are whole, exact, and useful); and an
    /// empty-generation result is reported.
    fn discard(&mut self, mut entry: SeqEntry) {
        self.drafters.remove(&entry.req.id);
        let mid_prefill =
            matches!(entry.phase, Phase::Prefill { .. } | Phase::WaitingOnPrefix { .. });
        if let Some(pool) = self.pool.as_mut() {
            pool.release_seq(&mut entry.blocks, &mut self.blocks);
            let keep = entry.cached_tokens / self.blocks.block_tokens();
            if mid_prefill && entry.published_pages > keep {
                if let Some(radix) = self.radix.as_mut() {
                    let ns = policy_ns(
                        &entry.req.policy.name,
                        entry.req.policy.budget,
                        self.sched.cfg.b_cp,
                    );
                    radix.unpublish_tail(ns, &entry.req.tokens, keep, pool, &mut self.blocks);
                }
            }
        } else {
            self.blocks.release(&mut entry.blocks);
        }
        // Residency moves at teardown too: an out-of-step cancel that
        // frees the last leased pages must be visible in the stats gauge
        // without waiting for another step to sample it.
        if let Some(pool) = &self.pool {
            self.metrics.note_kv_resident(pool.resident_bytes(self.blocks.leased_blocks()));
        }
        // Unpublishing can remove spilled nodes too — return their slots.
        self.drain_freed_slots();
        // The empty generation IS the unserved sentinel (the only signal
        // `RequestResult` carries): a decode-phase cancel must not hand
        // back a truncated generation that reads as a completed request.
        entry.generated.clear();
        entry.finished_at = Some(Instant::now());
        self.results.push(entry.result());
    }

    /// Poll every parked follower against the radix cache: adopt pages its
    /// producer published since the last poll (handing back the follower's
    /// own fresh reservation page for each slot in exchange for the shared
    /// one), and wake it into `Prefill` once the shared region is covered
    /// or its producer stopped producing (retired, cancelled, rejected).
    /// Whatever the cache does not cover by wake time is recomputed
    /// normally — the abort fallback; adopted pages are always kept.
    fn advance_followers(&mut self) {
        if self.radix.is_none() {
            return;
        }
        let mut ids: Vec<u64> = self
            .seqs
            .iter()
            .filter(|(_, e)| matches!(e.phase, Phase::WaitingOnPrefix { .. }))
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        let bt = self.blocks.block_tokens();
        let b_cp = self.sched.cfg.b_cp;
        let grid = self.grid_pages();
        for id in ids {
            let (ns, producing, producer_watermark) = {
                let e = &self.seqs[&id];
                let producer = e.waiting_on.and_then(|lid| self.seqs.get(&lid));
                let producing = producer
                    .map(|l| {
                        matches!(l.phase, Phase::Prefill { .. } | Phase::WaitingOnPrefix { .. })
                    })
                    .unwrap_or(false);
                let watermark = producer.map(|l| l.published_pages).unwrap_or(usize::MAX);
                (policy_ns(&e.req.policy.name, e.req.policy.budget, b_cp), producing, watermark)
            };
            let radix = self.radix.as_mut().unwrap();
            let pool = self.pool.as_mut().unwrap();
            let entry = self.seqs.get_mut(&id).unwrap();
            let cur_pages = entry.cached_tokens / bt;
            // Skip the tree walk while a live producer's publish watermark
            // has nothing new for this cursor (within the wait window the
            // producer's pages ARE the shared pages, so its watermark is
            // exact); a vanished producer gets one final full poll below.
            // When the walk does run, it resumes at the follower's
            // remembered node — O(newly published pages) per poll.
            let mut fresh = if producing && producer_watermark <= cur_pages {
                Vec::new()
            } else {
                radix.extend_match_at(ns, &entry.req.tokens, cur_pages, &mut entry.radix_cursor)
            };
            // Adopt in cursor-quantum units only (see
            // [`Engine::grid_pages`]): the cursor must sit on the
            // deterministic chunk grid at every possible wake point, so a
            // producer abort never strands it mid-chunk.
            fresh.truncate(fresh.len() - fresh.len() % grid);
            let adopted = fresh.len();
            for (off, &b) in fresh.iter().enumerate() {
                let j = cur_pages + off;
                pool.retain(b);
                if j < entry.blocks.len() {
                    // Admitted follower: swap its untouched reservation
                    // page for the shared one and hand the former back.
                    let old = entry.blocks[j];
                    entry.blocks[j] = b;
                    pool.release_block(old, &mut self.blocks);
                } else {
                    // Still queued: the table is just the adopted head.
                    entry.blocks.push(b);
                }
            }
            if adopted > 0 {
                let first = entry.cached_tokens == 0;
                entry.cached_tokens += adopted * bt;
                entry.published_pages = entry.published_pages.max(cur_pages + adopted);
                let bytes = adopted * bt * pool.token_bytes();
                self.metrics.record_inflight_adopt(adopted * bt, bytes, first);
                self.tracer.record(id, TraceEventKind::AdoptPages { pages: adopted as u32 });
                if let Some(SeqBack::HostPaged { len, .. }) = self.backs.get_mut(&id) {
                    *len = entry.cached_tokens;
                }
            }
            let cursor = entry.cached_tokens;
            // Wake once the wait window is covered, or once there is
            // nothing left to wait for: no producing leader AND no
            // promotion still in flight (a spill-parked sequence has
            // `waiting_on == None` from the start — `promote_pending` is
            // its park signal).
            if cursor / bt >= entry.wait_pages || (!producing && entry.promote_pending == 0) {
                // Wake. The cursor is on the deterministic chunk grid by
                // construction (match, adoption and the wait target are
                // all quantized to [`Engine::grid_pages`]), so the resumed
                // prefill continues with exactly a cold run's chunk
                // boundaries and writes only its own reserved pages.
                debug_assert_eq!(cursor % (grid * bt), 0, "wake cursor off the chunk grid");
                entry.waiting_on = None;
                entry.phase = Phase::Prefill { next: cursor };
                self.tracer.record(id, TraceEventKind::Wake);
            } else {
                entry.phase = Phase::WaitingOnPrefix { next: cursor };
            }
        }
    }

    /// Drain finished results.
    pub fn take_results(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.results)
    }

    /// Apply every completed background promotion: restore the verified
    /// page image into a freshly leased RAM page, flip the radix node
    /// back to `Resident`, and release the parked waiters' pending
    /// counts. A promotion that fails — checksum mismatch, node dropped
    /// or re-evicted since the kick, no RAM page obtainable — drops the
    /// unrecoverable spilled subtree; its waiters wake through the normal
    /// follower poll and recompute the tail like a producer abort.
    fn apply_promotions(&mut self) {
        if self.promoter.is_none() {
            return;
        }
        let mut queue = std::mem::take(&mut self.promo_backlog);
        while let Some(done) = self.promoter.as_ref().unwrap().try_recv() {
            queue.push(done);
        }
        let mut queue = queue.into_iter();
        for done in queue.by_ref() {
            if let Some(deferred) = self.apply_one_promotion(done) {
                // No RAM page this step: follower adoptions will free
                // reservation pages — retry the rest next step, in order.
                self.promo_backlog.push(deferred);
                break;
            }
        }
        self.promo_backlog.extend(queue);
        self.drain_freed_slots();
    }

    /// Apply one completed promotion; returns it back when no RAM page
    /// could be obtained (retry next step). Any other failure — checksum
    /// error or a node the tree dropped/re-evicted since the kick — is
    /// terminal and drops the unrecoverable spilled subtree.
    fn apply_one_promotion(&mut self, done: PromoteDone) -> Option<PromoteDone> {
        let slot = done.slot;
        if !self.promos.contains_key(&slot) {
            // Nothing waiting (tier raced a teardown): just release the pin.
            if let Some(sp) = self.spill.as_mut() {
                sp.unpin(slot);
            }
            return None;
        }
        if done.bytes.is_ok() {
            // A promoted page is charged like any reservation: its RAM
            // page comes off the free list, demoting colder pages first
            // when the pool is at pressure.
            if self.blocks.free_blocks() == 0 {
                let pool = self.pool.as_mut().expect("promotion without a pool");
                let radix = self.radix.as_mut().expect("promotion without a radix cache");
                radix.evict_until_spill(
                    1,
                    pool,
                    &mut self.blocks,
                    self.spill.as_mut(),
                    &mut self.tracer,
                );
            }
            if self.blocks.free_blocks() == 0 {
                return Some(done); // keep the pin and the pending entry
            }
        }
        if let Some(sp) = self.spill.as_mut() {
            sp.unpin(slot);
        }
        let p = self.promos.remove(&slot).unwrap();
        let pool = self.pool.as_mut().expect("promotion without a pool");
        let radix = self.radix.as_mut().expect("promotion without a radix cache");
        let mut promoted = false;
        if let Ok(img) = &done.bytes {
            if let Some(pages) = self.blocks.alloc(1) {
                let b = pages[0];
                pool.adopt_new(&pages);
                let ok = pool.restore_page_image(b, img).is_ok()
                    && radix.promote_node(p.node, p.gen, slot, b);
                if ok {
                    promoted = true;
                    self.metrics
                        .note_kv_resident(pool.resident_bytes(self.blocks.leased_blocks()));
                } else {
                    // Stale node: the tree moved on — hand the page back.
                    pool.release_block(b, &mut self.blocks);
                }
            }
        }
        if !promoted {
            radix.drop_spilled_subtree(p.node, p.gen);
        }
        let wait = p.t_kick.elapsed();
        for id in p.waiters {
            self.metrics.promote_wait_hist.record(wait);
            if let Some(e) = self.seqs.get_mut(&id) {
                e.promote_pending = e.promote_pending.saturating_sub(1);
            }
        }
        None
    }

    /// Hand slots the radix tree released (promoted nodes, dropped
    /// subtrees, hard-evicted or unpublished spilled nodes) back to the
    /// spill file's free list and refresh the spill-tier gauges. Called
    /// after every pass that can touch spilled nodes; a slot still pinned
    /// by an in-flight read is deferred inside the spill file until its
    /// unpin.
    fn drain_freed_slots(&mut self) {
        let Some(sp) = self.spill.as_mut() else {
            return;
        };
        if let Some(radix) = self.radix.as_mut() {
            for slot in radix.take_freed_slots() {
                sp.free_slot(slot);
            }
            self.metrics.spilled_pages = radix.stats.spilled_blocks;
            self.metrics.promotions = radix.stats.promoted_blocks;
        }
        self.metrics.spill_bytes = sp.used_bytes();
    }

    /// Execute one engine step. Returns false when fully idle.
    pub fn step(&mut self) -> Result<bool> {
        // Land completed background promotions FIRST: their pages become
        // adoptable in this step's follower poll, and the slots they free
        // are reusable by this step's demotions.
        self.apply_promotions();
        // Reject requests that can never fit the pool (otherwise an
        // unfittable admission candidate would wedge the queue forever).
        // The whole queue is swept, not just the front: fair-share
        // admission can make ANY tenant's oldest request the candidate,
        // so an unfittable request parked mid-queue would still jam its
        // tenant's turn. The bound is the blocks the request could ever
        // obtain: total MINUS the pages it already holds — those stay
        // leased (and un-evictable, refcount >= 2) for as long as the
        // entry references them, so comparing against the raw total would
        // let an unfittable prefix-hit request spin the engine forever.
        let queued: Vec<u64> = self.sched.waiting.iter().copied().collect();
        for id in queued {
            let entry = &self.seqs[&id];
            let held = entry.blocks.len();
            let need = entry.residual_blocks(&self.blocks);
            if need > self.blocks.total_blocks().saturating_sub(held) {
                self.sched.waiting.retain(|&w| w != id);
                self.sched.retire(id);
                let entry = self.seqs.remove(&id).unwrap();
                // Pages (and the empty-generation rejection result) go
                // through the shared unserved-teardown path.
                self.metrics.requests_rejected += 1;
                self.tracer.record(id, TraceEventKind::Reject);
                self.discard(entry);
            }
        }
        // Extend and wake parked followers BEFORE planning: a producer
        // that retired, aborted or was rejected since the last step must
        // not leave its followers parked, and pages adopted here shrink
        // the pool pressure the admission/evict checks below see.
        self.advance_followers();
        // Paged mode: when the admission candidate (the fair-share pick,
        // not necessarily the queue front) can't be admitted from the free
        // list alone, evict cold prefix-cache pages (LRU leaves with no
        // live owner) to make room before planning.
        if let (Some(pool), Some(radix)) = (self.pool.as_mut(), self.radix.as_mut()) {
            if self.sched.running.len() < self.sched.cfg.max_running {
                if let Some(cand) = self.sched.admission_candidate() {
                    let need = self.seqs[&cand].residual_blocks(&self.blocks);
                    if need > self.blocks.free_blocks() {
                        radix.evict_until_spill(
                            need,
                            pool,
                            &mut self.blocks,
                            self.spill.as_mut(),
                            &mut self.tracer,
                        );
                    }
                }
            }
        }
        self.drain_freed_slots();
        let plan = self.sched.plan_traced(&mut self.seqs, &mut self.blocks, &mut self.tracer);
        // Materialize backend state for newly admitted sequences; in paged
        // mode, adopt the freshly leased pages (refcount 1, zeroed
        // metadata) — prefix pages retained at submit keep their counts.
        for id in &plan.admitted {
            let entry = &self.seqs[id];
            self.metrics.queue_wait_hist.record(entry.admitted_at.elapsed());
            let back = if let Some(pool) = self.pool.as_mut() {
                pool.adopt_new(&entry.blocks);
                // Admission is a pool-growth point: freshly leased pages
                // must move the peak even if the step aborts early.
                self.metrics
                    .note_kv_resident(pool.resident_bytes(self.blocks.leased_blocks()));
                SeqBack::HostPaged { len: entry.cached_tokens, last_hidden: Vec::new() }
            } else {
                match &self.backend {
                    Backend::Host(m) => SeqBack::Host {
                        state: SeqState::new_with_dtype(m.cfg(), self.kv_dtype),
                        last_hidden: Vec::new(),
                    },
                    Backend::Pjrt(b) => SeqBack::Pjrt {
                        state: PjrtSeq::new(b.manifest()),
                        last_hidden: Vec::new(),
                    },
                }
            };
            self.backs.insert(*id, back);
        }
        if plan.items.is_empty() {
            // Parked followers are forward progress in disguise: their
            // producer chain bottoms out at a queued or schedulable
            // prefill, so keep stepping (the wake pass above unparks them
            // the moment their producer stops producing).
            let parked =
                self.seqs.values().any(|e| matches!(e.phase, Phase::WaitingOnPrefix { .. }));
            // A step idled by in-flight promotions blocks briefly on the
            // promoter channel instead of spinning: whatever lands is
            // applied now, so the follower poll of the NEXT step adopts
            // it — the park→adopt→wake latency is disk time, not a
            // busy-wait race.
            if parked && self.seqs.values().any(|e| e.promote_pending > 0) {
                if let Some(done) = self
                    .promoter
                    .as_ref()
                    .and_then(|p| p.recv_timeout(std::time::Duration::from_millis(1)))
                {
                    if let Some(deferred) = self.apply_one_promotion(done) {
                        self.promo_backlog.push(deferred);
                    }
                    self.drain_freed_slots();
                }
            }
            return Ok(!self.seqs.is_empty() && (!self.sched.waiting.is_empty() || parked));
        }

        let t0 = Instant::now();
        let mut prefill_toks = 0usize;
        // All decode items of the step run as ONE batched forward pass:
        // weights stream once per step regardless of decode concurrency.
        // Speculating sequences draft FIRST: a sequence whose drafter
        // abstains this step joins the fused batch like any plain decode
        // (drafting is advisory — an empty draft must never cost a
        // sequence its batching), while sequences with a live draft run
        // their own multi-token verify forward, amortizing the weight
        // stream across the gamma + 1 draft positions instead of across
        // the batch.
        let mut decode_ids: Vec<u64> = Vec::new();
        let mut verify_jobs: Vec<(u64, Vec<u32>)> = Vec::new();
        for item in &plan.items {
            match *item {
                WorkItem::Decode { id } => decode_ids.push(id),
                WorkItem::Verify { id, gamma } => {
                    let td = Instant::now();
                    let draft = self.draft_for(id, gamma);
                    // Drafting is decode-phase work even when it abstains.
                    let spent = td.elapsed().as_secs_f64();
                    self.metrics.decode_s += spent;
                    self.metrics.spec_s += spent;
                    if draft.is_empty() {
                        decode_ids.push(id);
                    } else {
                        verify_jobs.push((id, draft));
                    }
                }
                WorkItem::PrefillChunk { .. } => {}
            }
        }
        let n_verify = verify_jobs.len();
        let mut fused_decode = None;
        if !decode_ids.is_empty() {
            let td = Instant::now();
            let fused = self.run_decode_batch(&decode_ids)?;
            if fused {
                fused_decode = Some(td.elapsed());
            }
            self.tracer
                .record(0, TraceEventKind::DecodeStep { batch: decode_ids.len() as u32 });
        }
        for (id, draft) in verify_jobs {
            self.run_verify(id, draft)?;
        }
        for item in &plan.items {
            if let WorkItem::PrefillChunk { id, start, len } = *item {
                self.tracer.record(
                    id,
                    TraceEventKind::ChunkStart { start: start as u32, len: len as u32 },
                );
                let tc = Instant::now();
                self.run_prefill(id, start, len)?;
                self.metrics.chunk_hist.record(tc.elapsed());
                self.tracer.record(id, TraceEventKind::ChunkEnd { tokens: len as u32 });
                prefill_toks += len;
            }
        }
        // Pages published by this step's chunks are adoptable immediately:
        // poll the followers again so a wake never costs an extra step.
        self.advance_followers();
        // Drain the forward path's per-phase timers (thread-local to this
        // engine thread — the kernels block the caller) into the metrics
        // table and, when tracing, an engine-scope sample event.
        let phase_ns = obs::phase::take();
        if phase_ns.iter().any(|&v| v > 0) {
            self.metrics.add_phase_ns(phase_ns);
            if self.tracer.is_enabled() {
                let mut us = [0u32; obs::N_PHASES];
                for (o, &v) in us.iter_mut().zip(phase_ns.iter()) {
                    *o = (v / 1_000).min(u32::MAX as u64) as u32;
                }
                self.tracer.record(0, TraceEventKind::PhaseSample { us });
            }
        }
        self.tracer.record(
            0,
            TraceEventKind::StepEnd {
                prefill_tokens: prefill_toks as u32,
                decode_seqs: decode_ids.len() as u32,
                verify_seqs: n_verify as u32,
            },
        );
        self.metrics
            .record_step(t0.elapsed(), prefill_toks, decode_ids.len(), fused_decode);
        if let Some(pool) = &self.pool {
            self.metrics
                .note_kv_resident(pool.resident_bytes(self.blocks.leased_blocks()));
        }

        // Retire finished sequences. In paged mode, blocks go back through
        // the pool's refcounts: pages the radix cache still references stay
        // leased (that's the prefix cache's working set).
        let done: Vec<u64> = self
            .seqs
            .iter()
            .filter(|(_, e)| e.phase == Phase::Finished)
            .map(|(&id, _)| id)
            .collect();
        for id in done {
            let mut entry = self.seqs.remove(&id).unwrap();
            self.backs.remove(&id);
            self.drafters.remove(&id);
            if let Some(pool) = self.pool.as_mut() {
                pool.release_seq(&mut entry.blocks, &mut self.blocks);
            } else {
                self.blocks.release(&mut entry.blocks);
            }
            self.sched.retire(id);
            let r = entry.result();
            if entry.first_token_at.is_some() {
                // Same quantity `RequestResult::ttft_s` reports: the
                // trace-report cross-check holds to the histogram too.
                self.metrics.ttft_hist.record_secs(r.ttft_s);
            }
            self.tracer.record(id, TraceEventKind::Finish);
            self.metrics
                .record_finish(r.ttft_s, r.tpot_s, entry.generated.len() > 1);
            self.results.push(r);
        }
        // Mid-step demotions/evictions (decode-path pressure) may have
        // released spill slots after the planning-time drain.
        self.drain_freed_slots();
        Ok(!self.seqs.is_empty())
    }

    /// Run until every submitted request completes; returns all results.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        while self.step()? {}
        Ok(self.take_results())
    }

    fn run_prefill(&mut self, id: u64, start: usize, len: usize) -> Result<()> {
        if self.pool.is_some() {
            return self.run_prefill_paged(id, start, len);
        }
        let entry = self.seqs.get_mut(&id).context("unknown seq")?;
        let chunk: Vec<u32> = entry.req.tokens[start..start + len].to_vec();
        let spec = entry.req.policy.clone();
        let is_last = start + len == entry.req.tokens.len();
        let back = self.backs.get_mut(&id).context("missing backend state")?;

        let ta = Instant::now();
        match (&mut self.backend, back) {
            (Backend::Host(m), SeqBack::Host { state, last_hidden }) => {
                self.ctx.begin_step();
                let policy = self.policies.get(&spec.name).unwrap();
                let hidden = m.forward_chunk(state, &chunk, policy.as_ref(), spec.budget, &mut self.ctx);
                if is_last {
                    let dm = m.cfg().d_model;
                    *last_hidden = hidden[hidden.len() - dm..].to_vec();
                }
                self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(state.kv_bytes());
            }
            (Backend::Pjrt(b), SeqBack::Pjrt { state, last_hidden }) => {
                let mode = if spec.name == "dense" { AttnMode::Dense } else { AttnMode::Quoka };
                let hidden = b.prefill_chunk(state, &chunk, mode)?;
                if is_last {
                    let dm = b.cfg().d_model;
                    *last_hidden = hidden[hidden.len() - dm..].to_vec();
                }
                self.metrics.peak_kv_bytes =
                    self.metrics.peak_kv_bytes.max(state.kv_bytes(b.cfg()));
            }
            _ => unreachable!("backend/seq-state mismatch"),
        }
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        let entry = self.seqs.get_mut(&id).unwrap();
        if is_last {
            // Sample the first token straight from the prefill's last
            // hidden row — this is the TTFT point.
            let back = self.backs.get_mut(&id).unwrap();
            let first = match (&mut self.backend, back) {
                (Backend::Host(m), SeqBack::Host { last_hidden, .. }) => {
                    // Fused GEMV+argmax: no per-token vocab materialization.
                    m.greedy_next(last_hidden)
                }
                (Backend::Pjrt(b), SeqBack::Pjrt { last_hidden, .. }) => {
                    let logits = b.logits(last_hidden)?;
                    crate::tensor::ops::topk_indices(&logits, 1)[0] as u32
                }
                _ => unreachable!(),
            };
            entry.generated.push(first);
            let now = Instant::now();
            entry.first_token_at = Some(now);
            entry.last_token_at = Some(now);
            self.tracer.record(id, TraceEventKind::FirstToken);
            if entry.generated.len() >= entry.req.max_new_tokens {
                entry.phase = Phase::Finished;
                entry.finished_at = Some(Instant::now());
            } else {
                entry.phase = Phase::Decode;
            }
        } else {
            entry.phase = Phase::Prefill { next: start + len };
        }
        Ok(())
    }

    /// Prefill one chunk through the shared paged pool. The chunk's target
    /// pages were reserved at admission; shared pages in the write range
    /// (only possible through unusual block-table surgery — prefix pages
    /// are never in the write range) are copy-on-write'd first. Every
    /// prompt page the chunk completes is published to the radix cache
    /// immediately — mid-prefill, not at completion — so concurrent
    /// requests sharing the prefix adopt pages while they are hot.
    fn run_prefill_paged(&mut self, id: u64, start: usize, len: usize) -> Result<()> {
        let entry = self.seqs.get_mut(&id).context("unknown seq")?;
        let chunk: Vec<u32> = entry.req.tokens[start..start + len].to_vec();
        let spec = entry.req.policy.clone();
        let is_last = start + len == entry.req.tokens.len();
        let mut blocks = std::mem::take(&mut entry.blocks);

        let pool = self.pool.as_mut().expect("paged prefill without a pool");
        if let Err(e) = pool.make_writable(&mut blocks, start, len, &mut self.blocks) {
            // Put the (still refcounted, still leased) table back before
            // propagating, or its pages leak for the engine's lifetime.
            self.seqs.get_mut(&id).unwrap().blocks = blocks;
            return Err(e);
        }

        let back = self.backs.get_mut(&id).context("missing backend state")?;
        let ta = Instant::now();
        {
            let (m, seq_len, last_hidden) = match (&mut self.backend, back) {
                (Backend::Host(m), SeqBack::HostPaged { len, last_hidden }) => {
                    (m, len, last_hidden)
                }
                _ => unreachable!("paged mode requires the host backend"),
            };
            debug_assert_eq!(*seq_len, start, "prefill cursor out of sync with pool cursor");
            self.ctx.begin_step();
            let policy = self.policies.get(&spec.name).unwrap();
            let hidden = m.forward_chunk_paged(
                pool,
                &blocks,
                start,
                &chunk,
                policy.as_ref(),
                spec.budget,
                &mut self.ctx,
            );
            *seq_len = start + len;
            if is_last {
                let dm = m.cfg().d_model;
                *last_hidden = hidden[hidden.len() - dm..].to_vec();
            }
        }
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        // Publish every prompt page this chunk completed — in flight, not
        // at prefill completion — so a concurrent request sharing the
        // prefix adopts pages while this sequence is still prefilling.
        // Only whole pages are ever inserted; a page straddling the chunk
        // boundary waits for the chunk that writes its last slot.
        if let Some(radix) = self.radix.as_mut() {
            let bt = self.blocks.block_tokens();
            let entry = self.seqs.get_mut(&id).unwrap();
            let n_full = (start + len) / bt; // start + len <= prompt_len
            if n_full > entry.published_pages {
                let ns = policy_ns(&spec.name, spec.budget, self.sched.cfg.b_cp);
                let inserted = radix.stats.inserted_blocks;
                // Remembered-cursor publish: the walk resumes at the
                // sequence's last published node, so each chunk's publish
                // hashes only its newly completed pages.
                let w = radix.publish_upto_at(
                    ns,
                    &entry.req.tokens[..n_full * bt],
                    &blocks[..n_full],
                    n_full * bt,
                    pool,
                    &mut entry.radix_cursor,
                );
                // Count pages this prefill actually inserted — a span
                // already cached by an earlier request's pages is a no-op
                // in the tree and must not inflate the metric.
                self.metrics.inflight_published_pages +=
                    radix.stats.inserted_blocks - inserted;
                entry.published_pages = w;
            }
        }

        let entry = self.seqs.get_mut(&id).unwrap();
        entry.blocks = blocks;
        if is_last {
            // Sample the first token straight from the prefill's last
            // hidden row — this is the TTFT point.
            let back = self.backs.get_mut(&id).unwrap();
            let first = match (&mut self.backend, back) {
                (Backend::Host(m), SeqBack::HostPaged { last_hidden, .. }) => {
                    m.greedy_next(last_hidden)
                }
                _ => unreachable!(),
            };
            let entry = self.seqs.get_mut(&id).unwrap();
            entry.generated.push(first);
            let now = Instant::now();
            entry.first_token_at = Some(now);
            entry.last_token_at = Some(now);
            self.tracer.record(id, TraceEventKind::FirstToken);
            if entry.generated.len() >= entry.req.max_new_tokens {
                entry.phase = Phase::Finished;
                entry.finished_at = Some(Instant::now());
            } else {
                entry.phase = Phase::Decode;
            }
        } else {
            entry.phase = Phase::Prefill { next: start + len };
        }
        Ok(())
    }

    /// Execute every decode item of the step as **one** batched forward:
    /// per-sequence KV leases are grown (and, in paged mode, COW-guarded)
    /// in a pre-pass, then the whole batch runs through
    /// [`HostModel::forward_decode_batch`] — a single pass per layer over
    /// all `B` rows plus one fused logits GEMM+argmax. This is the only
    /// decode implementation; B = 1 is just a batch of one. The PJRT
    /// backend replays its compiled single-token artifact per sequence
    /// (compiled HLO has a fixed batch shape), but goes through the same
    /// entry point and accounting. Returns whether the fused host batch
    /// ran (false for the PJRT serial fallback, so the metrics histogram
    /// only reports real batching).
    /// Decode-path write guard, shared by the batched decode pre-pass
    /// (`write_len` = 1) and the speculative verify pre-pass (`write_len`
    /// = draft + 1): grow the sequence's block lease to
    /// `cache_tokens() + extra_tokens` — admission reserved max_new up
    /// front, so this normally no-ops; in paged mode a dry free list
    /// sheds cold prefix-cache pages first — and make the `write_len`
    /// tokens at the sequence's cursor exclusively owned (COW-cloning any
    /// page shared through the radix cache *before* KV lands in it).
    /// Returns the write cursor: tokens currently resident in the cache.
    fn ensure_decode_writable(
        &mut self,
        id: u64,
        extra_tokens: usize,
        write_len: usize,
    ) -> Result<usize> {
        let entry = self.seqs.get_mut(&id).context("unknown seq")?;
        let need = entry.cache_tokens() + extra_tokens;
        let mut lease = std::mem::take(&mut entry.blocks);
        let mut ok = self.blocks.ensure(&mut lease, need);
        if !ok {
            if let (Some(pool), Some(radix)) = (self.pool.as_mut(), self.radix.as_mut()) {
                let missing = self.blocks.blocks_for(need).saturating_sub(lease.len());
                radix.evict_until_spill(
                    missing,
                    pool,
                    &mut self.blocks,
                    self.spill.as_mut(),
                    &mut self.tracer,
                );
            }
            ok = self.blocks.ensure(&mut lease, need);
        }
        if let Some(pool) = self.pool.as_mut() {
            pool.adopt_new(&lease);
            // Decode-path lease growth moves the pool peak too, not just
            // the end-of-step snapshot.
            self.metrics.note_kv_resident(pool.resident_bytes(self.blocks.leased_blocks()));
        }
        self.seqs.get_mut(&id).unwrap().blocks = lease;
        anyhow::ensure!(ok, "KV pool exhausted mid-decode (seq {id})");
        // The backend cursor, not `need - write_len`: `cache_tokens()`
        // already counts the sampled-but-not-yet-appended token.
        let pos = match self.backs.get(&id) {
            Some(SeqBack::HostPaged { len, .. }) => *len,
            Some(SeqBack::Host { state, .. }) => state.pos,
            Some(SeqBack::Pjrt { .. }) | None => {
                anyhow::bail!("missing host backend state for decode write (seq {id})")
            }
        };
        debug_assert!(pos + write_len <= need, "decode cursor ahead of reservation");
        if self.pool.is_some() {
            let mut blocks = std::mem::take(&mut self.seqs.get_mut(&id).unwrap().blocks);
            let res = self.pool.as_mut().unwrap().make_writable(
                &mut blocks,
                pos,
                write_len,
                &mut self.blocks,
            );
            // Restore the (still leased) table before any propagation,
            // or its pages leak for the engine's lifetime.
            self.seqs.get_mut(&id).unwrap().blocks = blocks;
            res?;
        }
        Ok(pos)
    }

    fn run_decode_batch(&mut self, ids: &[u64]) -> Result<bool> {
        if ids.is_empty() {
            return Ok(false);
        }
        if matches!(self.backend, Backend::Pjrt(_)) {
            for &id in ids {
                self.run_decode_pjrt(id)?;
            }
            return Ok(false);
        }
        let paged = self.pool.is_some();

        // ---- pre-pass: grow each sequence's lease for its new token ----
        for &id in ids {
            self.ensure_decode_writable(id, 1, 1)?;
        }

        // ---- assemble the batch ----
        let specs: Vec<PolicySpec> =
            ids.iter().map(|id| self.seqs[id].req.policy.clone()).collect();
        let mut last_toks: Vec<u32> = Vec::with_capacity(ids.len());
        for id in ids {
            last_toks
                .push(*self.seqs[id].generated.last().context("decode before first token")?);
        }
        // SeqBack slots come out of the map so the batch can hold B
        // simultaneous mutable borrows of their SeqStates.
        let mut taken: Vec<SeqBack> = ids
            .iter()
            .map(|id| self.backs.remove(id).expect("missing backend state"))
            .collect();
        let mut batch: Vec<DecodeSeq<'_>> = Vec::with_capacity(ids.len());
        for (i, back) in taken.iter_mut().enumerate() {
            let id = ids[i];
            let last_tok = last_toks[i];
            let kv = if paged {
                let pos = match back {
                    SeqBack::HostPaged { len, .. } => *len,
                    _ => unreachable!("paged mode requires HostPaged state"),
                };
                DecodeKv::Paged { blocks: &self.seqs[&id].blocks, pos }
            } else {
                match back {
                    SeqBack::Host { state, .. } => DecodeKv::Private(state),
                    _ => unreachable!("private host decode requires Host state"),
                }
            };
            batch.push(DecodeSeq {
                kv,
                token: last_tok,
                policy: self.policies.get(&specs[i].name).unwrap().as_ref(),
                budget: specs[i].budget,
            });
        }

        // ---- one fused forward for the whole batch ----
        let ta = Instant::now();
        self.ctx.begin_step();
        let model = match &self.backend {
            Backend::Host(m) => m,
            Backend::Pjrt(_) => unreachable!("handled above"),
        };
        let next = model.forward_decode_batch(&mut batch, self.pool.as_mut(), &mut self.ctx);
        drop(batch);
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        // ---- post: reinsert state, advance cursors, record tokens ----
        let now = Instant::now();
        for (i, mut back) in taken.into_iter().enumerate() {
            let id = ids[i];
            if let SeqBack::HostPaged { len, .. } = &mut back {
                *len += 1;
            }
            self.backs.insert(id, back);
            let entry = self.seqs.get_mut(&id).unwrap();
            entry.generated.push(next[i]);
            if let Some(prev) = entry.last_token_at.replace(now) {
                self.metrics.itl_hist.record(now - prev);
            }
            if entry.generated.len() >= entry.req.max_new_tokens {
                entry.phase = Phase::Finished;
                entry.finished_at = Some(now);
            }
        }
        Ok(true)
    }

    /// Draft for one speculating sequence (a [`WorkItem::Verify`] of this
    /// step), clamped so a verify can never emit past max_new. An empty
    /// result means the drafter abstained — the caller folds the sequence
    /// into the step's fused decode batch instead.
    fn draft_for(&mut self, id: u64, gamma: usize) -> Vec<u32> {
        let entry = &self.seqs[&id];
        let remaining = entry.req.max_new_tokens.saturating_sub(entry.generated.len());
        // emitted = accepted + 1 <= gamma + 1 <= remaining.
        let gamma = gamma.min(remaining.saturating_sub(1));
        let mut draft = match self.drafters.get_mut(&id) {
            Some(d) if gamma > 0 => d.draft(&entry.req.tokens, &entry.generated, gamma),
            _ => Vec::new(),
        };
        // The gamma cap is load-bearing (step-budget accounting and the
        // max_new clamp both assume it), so enforce it on the trait
        // boundary rather than trusting every DraftSource.
        draft.truncate(gamma);
        draft
    }

    /// One speculative decode step for sequence `id` with a non-empty
    /// `draft` (see [`Engine::draft_for`]): verify the pending token plus
    /// the whole draft in **one** multi-token forward
    /// ([`HostModel::forward_verify`]), keep the agreeing draft prefix
    /// plus the model's own correction token, and roll the rejected KV
    /// tail back out of the cache. Greedy acceptance against per-position
    /// exact targets makes the emitted tokens bit-identical to
    /// non-speculative decode — a verify step only changes how many of
    /// those tokens one weight stream produces.
    fn run_verify(&mut self, id: u64, draft: Vec<u32>) -> Result<()> {
        debug_assert!(!draft.is_empty(), "abstaining sequences join the decode batch");
        let t0 = Instant::now();
        let s = draft.len() + 1;

        // ---- pre-pass: lease growth + COW exclusivity over the whole
        // gamma + 1 write range (the shared decode-path guard) ----
        let pos0 = self.ensure_decode_writable(id, draft.len(), s)?;

        // ---- one fused forward over [pending, draft...] ----
        let entry = self.seqs.get(&id).unwrap();
        let last = *entry.generated.last().context("verify before first token")?;
        let spec_pol = entry.req.policy.clone();
        let mut tokens = Vec::with_capacity(s);
        tokens.push(last);
        tokens.extend_from_slice(&draft);
        let mut back = self.backs.remove(&id).expect("missing backend state");
        let ta = Instant::now();
        self.ctx.begin_step();
        let targets = {
            let model = match &self.backend {
                Backend::Host(m) => m,
                Backend::Pjrt(_) => unreachable!("verify requires the host backend"),
            };
            let mut kvref = match &mut back {
                SeqBack::Host { state, .. } => DecodeKv::Private(state),
                SeqBack::HostPaged { .. } => {
                    DecodeKv::Paged { blocks: &self.seqs[&id].blocks, pos: pos0 }
                }
                SeqBack::Pjrt { .. } => unreachable!("verify requires the host backend"),
            };
            let policy = self.policies.get(&spec_pol.name).unwrap();
            model.forward_verify(
                &mut kvref,
                &tokens,
                policy.as_ref(),
                spec_pol.budget,
                self.pool.as_mut(),
                &mut self.ctx,
            )
        };
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        // ---- greedy acceptance + rollback of the rejected KV tail ----
        // targets[i] is the model's token after tokens[..=i]; draft[i] is
        // tokens[i + 1] — accept while they agree, then targets[accepted]
        // is the model's own next token (the "free" correction).
        let accepted = targets.iter().zip(&draft).take_while(|(t, d)| *t == *d).count();
        let pos_keep = pos0 + 1 + accepted;
        match &mut back {
            SeqBack::Host { state, .. } => state.truncate(pos_keep),
            SeqBack::HostPaged { len, .. } => {
                self.pool.as_mut().unwrap().truncate_seq(
                    &self.seqs[&id].blocks,
                    pos_keep,
                    pos0 + s,
                );
                *len = pos_keep;
            }
            SeqBack::Pjrt { .. } => unreachable!(),
        }
        self.backs.insert(id, back);

        let emitted = accepted + 1;
        let now = Instant::now();
        let entry = self.seqs.get_mut(&id).unwrap();
        entry.generated.extend_from_slice(&draft[..accepted]);
        entry.generated.push(targets[accepted]);
        entry.spec_drafted += draft.len();
        entry.spec_accepted += accepted;
        // One verify emits `emitted` tokens at one instant: amortize the
        // span since the previous emission over them so the ITL histogram
        // reflects per-token pacing, not per-forward pacing.
        if let Some(prev) = entry.last_token_at.replace(now) {
            let per = (now - prev) / emitted as u32;
            for _ in 0..emitted {
                self.metrics.itl_hist.record(per);
            }
        }
        if entry.generated.len() >= entry.req.max_new_tokens {
            entry.phase = Phase::Finished;
            entry.finished_at = Some(now);
        }
        if let Some(d) = self.drafters.get_mut(&id) {
            d.observe(draft.len(), accepted);
        }
        self.tracer.record(
            id,
            TraceEventKind::VerifyStep { gamma: draft.len() as u32, accepted: accepted as u32 },
        );
        self.metrics.verify_hist.record(t0.elapsed());
        self.metrics.record_verify(t0.elapsed(), draft.len(), accepted, emitted);
        Ok(())
    }

    /// One PJRT decode step (compiled artifacts have a fixed single-token
    /// batch shape; the host backend is the batched path).
    fn run_decode_pjrt(&mut self, id: u64) -> Result<()> {
        let entry = self.seqs.get_mut(&id).context("unknown seq")?;
        let spec = entry.req.policy.clone();
        let last_tok = *entry.generated.last().context("decode before first token")?;
        // Grow the block lease for the new token; preempt-free because
        // admission reserved max_new up front.
        let need = entry.cache_tokens() + 1;
        let mut lease = std::mem::take(&mut entry.blocks);
        let ok = self.blocks.ensure(&mut lease, need);
        let entry = self.seqs.get_mut(&id).unwrap();
        entry.blocks = lease;
        anyhow::ensure!(ok, "KV pool exhausted mid-decode (seq {id})");

        let back = self.backs.get_mut(&id).context("missing backend state")?;
        let ta = Instant::now();
        let next = match (&mut self.backend, back) {
            (Backend::Pjrt(b), SeqBack::Pjrt { state, .. }) => {
                let mode = if spec.name == "dense" { AttnMode::Dense } else { AttnMode::Quoka };
                let (next, _) = b.decode_step(state, last_tok, mode)?;
                next
            }
            _ => unreachable!("run_decode_pjrt requires the pjrt backend"),
        };
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        let entry = self.seqs.get_mut(&id).unwrap();
        entry.generated.push(next);
        let now = Instant::now();
        if let Some(prev) = entry.last_token_at.replace(now) {
            self.metrics.itl_hist.record(now - prev);
        }
        if entry.generated.len() >= entry.req.max_new_tokens {
            entry.phase = Phase::Finished;
            entry.finished_at = Some(now);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The helpers inherit the env-selected KV dtype (QUOKA_KV_DTYPE), so
    // the CI int8 matrix leg runs the whole engine suite over quantized
    // caches; tests that compare against a raw fp32 model or use policies
    // that read fp32 key rows pin `KvDtype::F32` explicitly.
    fn engine() -> Engine {
        engine_dt(KvDtype::env_default())
    }

    fn engine_dt(kv_dtype: KvDtype) -> Engine {
        Engine::new_host(
            "tiny",
            EngineCfg {
                sched: SchedCfg { b_cp: 16, step_tokens: 48, max_running: 4, ..SchedCfg::default() },
                pool_blocks: 64,
                block_tokens: 16,
                seed: 1,
                kv: KvLayout::Private,
                kv_dtype,
                ..EngineCfg::default()
            },
        )
        .unwrap()
    }

    fn paged_engine(prefix_cache: bool) -> Engine {
        paged_engine_dt(prefix_cache, KvDtype::env_default())
    }

    fn paged_engine_dt(prefix_cache: bool, kv_dtype: KvDtype) -> Engine {
        Engine::new_host(
            "tiny",
            EngineCfg {
                sched: SchedCfg { b_cp: 16, step_tokens: 48, max_running: 4, ..SchedCfg::default() },
                pool_blocks: 64,
                block_tokens: 16,
                seed: 1,
                kv: KvLayout::Paged { prefix_cache },
                kv_dtype,
                ..EngineCfg::default()
            },
        )
        .unwrap()
    }

    fn prompt(n: usize, salt: u64) -> Vec<u32> {
        (0..n).map(|i| ((i as u64 * 31 + salt) % 251) as u32).collect()
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine();
        let id = e
            .submit(prompt(40, 1), 4, PolicySpec { name: "quoka".into(), budget: 32 })
            .unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.id, id);
        assert_eq!(r.generated.len(), 4);
        assert!(r.ttft_s > 0.0);
        assert_eq!(e.blocks.free_blocks(), 64, "all blocks returned");
    }

    #[test]
    fn batch_of_requests_with_mixed_policies() {
        // 'sample'/'keydiff' read fp32 key rows: fp32-only policies.
        let mut e = engine_dt(KvDtype::F32);
        for (i, name) in ["dense", "quoka", "sample", "keydiff"].iter().enumerate() {
            e.submit(
                prompt(30 + i * 7, i as u64),
                3,
                PolicySpec { name: name.to_string(), budget: 24 },
            )
            .unwrap();
        }
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.generated.len() == 3));
        assert_eq!(e.metrics.requests_finished, 4);
        assert!(e.metrics.decode_tokens >= 8);
    }

    #[test]
    fn deterministic_generation_at_fixed_seed() {
        let run = || {
            let mut e = engine();
            e.submit(prompt(33, 5), 6, PolicySpec { name: "quoka".into(), budget: 16 }).unwrap();
            e.run_to_completion().unwrap()[0].generated.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn dense_engine_matches_raw_model() {
        // The engine's chunked output must equal driving HostModel by hand
        // (a raw fp32 SeqState — so pin the engine to fp32 KV too).
        let mut e = engine_dt(KvDtype::F32);
        let toks = prompt(40, 9);
        e.submit(toks.clone(), 3, PolicySpec { name: "dense".into(), budget: 0 }).unwrap();
        let got = e.run_to_completion().unwrap()[0].generated.clone();

        let mc = ModelConfig::preset("tiny").unwrap();
        let m = HostModel::new(Weights::generate(&mc, 1));
        let mut st = SeqState::new(&mc);
        let mut ctx = SelectCtx::new(0);
        let mut h = Vec::new();
        for c in toks.chunks(16) {
            h = m.forward_chunk(&mut st, c, &crate::select::dense::Dense, usize::MAX, &mut ctx);
        }
        let mut want = vec![m.greedy_next(&h)];
        for _ in 0..2 {
            let h = m.forward_chunk(
                &mut st,
                &[*want.last().unwrap()],
                &crate::select::dense::Dense,
                usize::MAX,
                &mut ctx,
            );
            want.push(m.greedy_next(&h));
        }
        assert_eq!(got, want);
    }

    #[test]
    fn admission_respects_pool_capacity() {
        let mut e = Engine::new_host(
            "tiny",
            EngineCfg {
                sched: SchedCfg { b_cp: 16, step_tokens: 64, max_running: 8, ..SchedCfg::default() },
                pool_blocks: 4, // 64 tokens of capacity
                block_tokens: 16,
                seed: 1,
                kv: KvLayout::Private,
                ..EngineCfg::default()
            },
        )
        .unwrap();
        e.submit(prompt(40, 1), 2, PolicySpec::default()).unwrap(); // 3 blocks
        e.submit(prompt(40, 2), 2, PolicySpec::default()).unwrap(); // must wait
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 2, "second request runs after the first frees blocks");
    }

    #[test]
    fn rejects_bad_submissions() {
        let mut e = engine();
        assert!(e.submit(vec![], 2, PolicySpec::default()).is_err());
        assert!(e
            .submit(vec![1], 1, PolicySpec { name: "not-a-policy".into(), budget: 1 })
            .is_err());
        // Paged mode only serves block-table-aware policies.
        let mut p = paged_engine(false);
        assert!(p.submit(vec![1; 8], 1, PolicySpec { name: "sample".into(), budget: 8 }).is_err());
        assert!(p.submit(vec![1; 8], 1, PolicySpec { name: "quoka".into(), budget: 8 }).is_ok());
    }

    #[test]
    fn paged_engine_completes_and_conserves_pages() {
        let mut e = paged_engine(false);
        for (i, (name, budget)) in
            [("quoka", 24usize), ("dense", 0), ("quoka", 12)].iter().enumerate()
        {
            e.submit(
                prompt(30 + i * 13, i as u64),
                3,
                PolicySpec { name: name.to_string(), budget: *budget },
            )
            .unwrap();
        }
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| r.generated.len() == 3));
        assert_eq!(e.blocks.free_blocks(), 64, "no prefix cache ⇒ every page returned");
        assert!(e.metrics.peak_kv_bytes > 0, "pool residency must be reported");
    }

    #[test]
    fn paged_generation_is_deterministic() {
        let run = |prefix_cache: bool| {
            let mut e = paged_engine(prefix_cache);
            e.submit(prompt(40, 5), 5, PolicySpec { name: "quoka".into(), budget: 16 }).unwrap();
            e.run_to_completion().unwrap()[0].generated.clone()
        };
        assert_eq!(run(false), run(false));
        // An empty prefix cache must not change the numerics.
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn unfittable_prefix_hit_request_is_rejected_not_wedged() {
        // A prefix hit shrinks a request's residual need but also pins its
        // cached pages; rejection must measure against total − held or the
        // engine spins forever on an unfittable head-of-line request.
        let mut e = Engine::new_host(
            "tiny",
            EngineCfg {
                sched: SchedCfg { b_cp: 16, step_tokens: 48, max_running: 4, ..SchedCfg::default() },
                pool_blocks: 4, // 64-token capacity
                block_tokens: 16,
                seed: 1,
                kv: KvLayout::Paged { prefix_cache: true },
                ..EngineCfg::default()
            },
        )
        .unwrap();
        let spec = || PolicySpec { name: "quoka".into(), budget: 16 };
        let pfx = prompt(32, 3);
        e.submit(pfx.clone(), 1, spec()).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.radix.as_ref().unwrap().cached_blocks(), 2);
        // 80-token prompt + 16 decodes needs 6 pages; 2 are cached, but
        // only total − held = 2 can ever be allocated fresh.
        let mut big = pfx;
        big.extend(prompt(48, 9));
        e.submit(big, 16, spec()).unwrap();
        let mut steps = 0;
        while e.step().unwrap() && steps < 50 {
            steps += 1;
        }
        assert!(steps < 50, "engine wedged on unfittable prefix-hit request");
        let r = e.take_results();
        assert_eq!(r.len(), 1);
        assert!(r[0].generated.is_empty(), "rejected, not served");
        // The rejected request's page references were handed back.
        assert_eq!(
            e.blocks.free_blocks() + e.radix.as_ref().unwrap().cached_blocks(),
            4,
            "only the tree keeps pages leased"
        );
    }

    #[test]
    fn follower_parks_and_adopts_pages_published_in_flight() {
        // A second identical prompt submitted mid-prefill must not
        // recompute pages the first is publishing: it parks, adopts, and
        // prefills only the never-cacheable final page. (The lone
        // prefiller takes 3 deterministic 16-token chunks per 48-token
        // step, so one step publishes 3 pages.)
        let mut e = paged_engine(true);
        let spec = || PolicySpec { name: "quoka".into(), budget: 24 };
        let toks = prompt(96, 3); // 6 pages at bt=16
        let a = e.submit(toks.clone(), 3, spec()).unwrap();
        e.step().unwrap(); // A prefills [0,48): pages 0-2 published in flight
        assert_eq!(e.metrics.inflight_published_pages, 3);
        let b = e.submit(toks.clone(), 3, spec()).unwrap();
        assert_eq!(e.metrics.inflight_followers, 1, "B parks behind A");
        let mut results = e.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 2);
        // B's prefix: 3 pages matched at submit + 2 adopted while parked
        // (the 6th page is capped — at least one token always prefills).
        let rb = results.iter().find(|r| r.id == b).unwrap();
        assert_eq!(rb.cached_prefix_tokens, 80);
        assert_eq!(e.metrics.inflight_adopted_tokens, 32);
        assert_eq!(
            e.metrics.prefill_tokens, 112,
            "prefix chunks run exactly once: 96 (A) + 16 (B's final page)"
        );
        // Shared pages + a deterministic tail ⇒ identical generations.
        let ra = results.iter().find(|r| r.id == a).unwrap();
        assert_eq!(ra.generated, rb.generated);
        assert_eq!(ra.generated.len(), 3);
    }

    #[test]
    fn lone_prefiller_takes_multiple_chunks_per_step() {
        // ROADMAP open item: while nothing else wants the step budget, a
        // single prefilling sequence takes several deterministic-width
        // chunks per step — fewer steps to first token, identical chunk
        // boundaries (pinned by the bit-equality assertions of the cache
        // tests, which all run through this path).
        let mut e = paged_engine(true); // deterministic mode forced on
        let spec = || PolicySpec { name: "quoka".into(), budget: 24 };
        e.submit(prompt(96, 5), 1, spec()).unwrap();
        let mut steps = 0;
        while e.step().unwrap() {
            steps += 1;
        }
        // 96 prompt tokens at 48-token steps (3 × 16-wide chunks): two
        // prefill steps, the second of which also samples the only token.
        assert_eq!(steps + 1, 2, "96-token prompt must prefill in 2 steps, not 6");
        assert_eq!(e.metrics.prefill_tokens, 96);

        // Private non-deterministic engines keep the one-chunk-per-step
        // schedule (no pinned grid to preserve): 6 × 16-token chunks.
        let mut p = engine();
        p.submit(prompt(96, 5), 1, PolicySpec { name: "quoka".into(), budget: 24 }).unwrap();
        let mut steps = 0;
        while p.step().unwrap() {
            steps += 1;
        }
        assert_eq!(steps + 1, 6, "non-deterministic schedule: one b_cp chunk per step");
    }

    #[test]
    fn cache_resume_stays_on_the_deterministic_chunk_grid() {
        // b_cp spans 2 pages, so a cached chain with an odd page count
        // must be matched only in whole-chunk units: resuming mid-chunk
        // would recompute — and republish — KV with boundaries no cold
        // run has (sparse KV depends on chunk boundaries).
        let mk = || {
            Engine::new_host(
                "tiny",
                EngineCfg {
                    sched: SchedCfg {
                        b_cp: 32,
                        step_tokens: 96,
                        max_running: 4,
                        ..SchedCfg::default()
                    },
                    pool_blocks: 64,
                    block_tokens: 16,
                    seed: 1,
                    kv: KvLayout::Paged { prefix_cache: true },
                    ..EngineCfg::default()
                },
            )
            .unwrap()
        };
        let spec = || PolicySpec { name: "quoka".into(), budget: 24 };
        let long = prompt(80, 7); // 5 pages — odd at a 2-page chunk grid
        let mut e = mk();
        e.submit(long.clone(), 1, spec()).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.radix.as_ref().unwrap().cached_blocks(), 5);
        // A prompt extending the first 50 tokens could match 3 pages, but
        // only 2 of them lie on the 32-token chunk grid.
        let warm_prompt: Vec<u32> = long[..50].to_vec();
        e.submit(warm_prompt.clone(), 2, spec()).unwrap();
        let r = e.run_to_completion().unwrap().remove(0);
        assert_eq!(r.cached_prefix_tokens, 32, "match truncated to the chunk grid");
        // Exactness: the warm resume equals a cold run of the same prompt.
        let mut cold = mk();
        cold.submit(warm_prompt, 2, spec()).unwrap();
        let want = cold.run_to_completion().unwrap().remove(0);
        assert_eq!(want.cached_prefix_tokens, 0);
        assert_eq!(r.generated, want.generated, "grid-aligned resume is bit-exact");
    }

    #[test]
    fn cancel_mid_prefill_unpublishes_unadopted_tail() {
        let mut e = paged_engine(true);
        let spec = || PolicySpec { name: "quoka".into(), budget: 24 };
        let id = e.submit(prompt(128, 5), 2, spec()).unwrap();
        e.step().unwrap();
        e.step().unwrap(); // 96 of 128 tokens prefilled, 6 pages published
        assert_eq!(e.radix.as_ref().unwrap().cached_blocks(), 6);
        assert!(e.cancel(id), "known id cancels");
        assert!(!e.cancel(id), "already gone");
        assert_eq!(
            e.radix.as_ref().unwrap().cached_blocks(),
            0,
            "aborted publisher's unadopted pages are withdrawn"
        );
        assert_eq!(e.blocks.free_blocks(), 64, "every page returned");
        assert_eq!(e.pending(), 0);
        let r = e.take_results();
        assert_eq!(r.len(), 1);
        assert!(r[0].generated.is_empty(), "cancelled, not served");
    }

    #[test]
    fn cancel_after_prefill_keeps_published_pages() {
        // Cancelling a decoding sequence is not an abort of its prefill:
        // the published prompt pages are whole and exact — they stay.
        let mut e = paged_engine(true);
        let spec = || PolicySpec { name: "quoka".into(), budget: 24 };
        let toks = prompt(32, 6);
        let id = e.submit(toks.clone(), 8, spec()).unwrap();
        for _ in 0..4 {
            e.step().unwrap(); // prefill completes, decode begins
        }
        assert!(e.cancel(id));
        let rc = e.take_results();
        assert_eq!(rc.len(), 1);
        assert!(
            rc[0].generated.is_empty(),
            "a decode-phase cancel reports the unserved sentinel, not a truncated generation"
        );
        assert_eq!(e.radix.as_ref().unwrap().cached_blocks(), 2);
        // A later identical request reuses them.
        e.submit(toks, 2, spec()).unwrap();
        let r = e.run_to_completion().unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].cached_prefix_tokens, 16, "one page reused (cap leaves one)");
    }

    #[test]
    fn prefix_cache_reuses_pages_and_skips_prefill() {
        let mut e = paged_engine(true);
        let spec = || PolicySpec { name: "quoka".into(), budget: 24 };
        // 64-token shared prefix (4 pages), differing 16-token suffixes.
        let mut prompt_a = prompt(64, 7);
        let mut prompt_b = prompt_a.clone();
        prompt_a.extend(prompt(16, 100));
        prompt_b.extend(prompt(16, 200));

        e.submit(prompt_a, 2, spec()).unwrap();
        let results_a = e.run_to_completion().unwrap();
        assert_eq!(results_a[0].cached_prefix_tokens, 0);
        let prefill_after_a = e.metrics.prefill_tokens;
        assert_eq!(prefill_after_a, 80);
        let cached = e.radix.as_ref().unwrap().cached_blocks();
        assert_eq!(cached, 5, "A's full prompt pages are cached");
        assert_eq!(e.blocks.free_blocks() + cached, 64, "tree pages stay leased");

        e.submit(prompt_b, 2, spec()).unwrap();
        let results_b = e.run_to_completion().unwrap();
        assert_eq!(results_b[0].cached_prefix_tokens, 64, "4 shared pages reused");
        assert_eq!(
            e.metrics.prefill_tokens - prefill_after_a,
            16,
            "zero prefill chunks for the cached prefix"
        );
        assert!(e.metrics.prefix_hit_rate() > 0.0);
        assert!(e.metrics.prefix_bytes_saved > 0);
    }

    #[test]
    fn int8_engine_serves_both_layouts_and_shrinks_the_pool() {
        // Private layout: an int8 engine serves the full request, and —
        // since per-row quantization is deterministic — so does a rerun,
        // bit-identically.
        let run = |dt: KvDtype| {
            let mut e = engine_dt(dt);
            e.submit(prompt(40, 3), 4, PolicySpec { name: "quoka".into(), budget: 16 }).unwrap();
            e.run_to_completion().unwrap()[0].generated.clone()
        };
        assert_eq!(run(KvDtype::Int8).len(), 4);
        assert_eq!(run(KvDtype::Int8), run(KvDtype::Int8), "int8 decode is deterministic");

        // Policies that read fp32 key rows are rejected at submit, not at
        // kernel time deep inside a forward pass.
        let mut e = engine_dt(KvDtype::Int8);
        assert!(e.submit(vec![1; 8], 1, PolicySpec { name: "sample".into(), budget: 8 }).is_err());

        // Paged layout: same prompt under both dtypes; the quantized
        // pool's residency must report the dtype-true (smaller) bytes.
        let bytes = |dt: KvDtype| {
            let mut e = paged_engine_dt(false, dt);
            e.submit(prompt(64, 9), 3, PolicySpec { name: "quoka".into(), budget: 24 }).unwrap();
            e.run_to_completion().unwrap();
            e.metrics.peak_kv_bytes
        };
        let (f32b, i8b) = (bytes(KvDtype::F32), bytes(KvDtype::Int8));
        assert!(i8b > 0 && i8b * 2 < f32b, "int8 pool bytes {i8b} not well under fp32 {f32b}");
    }
}
