//! The serving engine: continuous batching over chunked prefill + decode.
//!
//! One `step()` = one scheduler plan executed: decodes first, then prefill
//! chunks, exactly as planned by the Sarathi-style scheduler. Works over
//! either execution backend:
//! - **host** — the pure-Rust transformer with *any* selection policy;
//! - **pjrt** — AOT artifacts (dense / QUOKA variants compiled from JAX).
//!
//! Python never runs here; the PJRT backend only replays compiled HLO.

use super::kv_blocks::BlockAllocator;
use super::metrics::Metrics;
use super::request::{Phase, PolicySpec, Request, RequestResult, SeqEntry};
use super::scheduler::{SchedCfg, Scheduler, WorkItem};
use crate::model::{HostModel, ModelConfig, SeqState, Weights};
use crate::runtime::exec::{AttnMode, PjrtBackend, PjrtSeq};
use crate::select::{SelectCtx, SelectionPolicy};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::time::Instant;

/// Execution backend.
pub enum Backend {
    Host(HostModel),
    Pjrt(Box<PjrtBackend>),
}

enum SeqBack {
    Host { state: SeqState, last_hidden: Vec<f32> },
    Pjrt { state: PjrtSeq, last_hidden: Vec<f32> },
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineCfg {
    pub sched: SchedCfg,
    /// KV pool: total blocks × tokens/block of admission capacity.
    pub pool_blocks: usize,
    pub block_tokens: usize,
    pub seed: u64,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg { sched: SchedCfg::default(), pool_blocks: 4096, block_tokens: 128, seed: 0 }
    }
}

/// The engine.
pub struct Engine {
    backend: Backend,
    pub sched: Scheduler,
    pub blocks: BlockAllocator,
    seqs: HashMap<u64, SeqEntry>,
    backs: HashMap<u64, SeqBack>,
    policies: HashMap<String, Box<dyn SelectionPolicy>>,
    ctx: SelectCtx,
    pub metrics: Metrics,
    results: Vec<RequestResult>,
    next_id: u64,
}

impl Engine {
    /// Host-backend engine for a model preset.
    pub fn new_host(preset: &str, cfg: EngineCfg) -> Result<Engine> {
        let mc = ModelConfig::preset(preset)?;
        let model = HostModel::new(Weights::generate(&mc, cfg.seed));
        Ok(Self::with_backend(Backend::Host(model), cfg))
    }

    /// PJRT-backend engine over an artifact directory.
    pub fn new_pjrt(artifact_dir: &str, cfg: EngineCfg) -> Result<Engine> {
        let be = PjrtBackend::load_lazy(artifact_dir, cfg.seed)?;
        Ok(Self::with_backend(Backend::Pjrt(Box::new(be)), cfg))
    }

    pub fn with_backend(backend: Backend, cfg: EngineCfg) -> Engine {
        Engine {
            backend,
            sched: Scheduler::new(cfg.sched),
            blocks: BlockAllocator::new(cfg.pool_blocks, cfg.block_tokens),
            seqs: HashMap::new(),
            backs: HashMap::new(),
            policies: HashMap::new(),
            ctx: SelectCtx::new(cfg.seed ^ 0xE1),
            metrics: Metrics::default(),
            results: Vec::new(),
            next_id: 1,
        }
    }

    pub fn model_cfg(&self) -> ModelConfig {
        match &self.backend {
            Backend::Host(m) => m.cfg().clone(),
            Backend::Pjrt(b) => b.cfg().clone(),
        }
    }

    /// Submit a request; returns its id. Fails fast for policies the
    /// backend cannot execute.
    pub fn submit(&mut self, tokens: Vec<u32>, max_new: usize, policy: PolicySpec) -> Result<u64> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        if matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::ensure!(
                policy.name == "dense" || policy.name == "quoka",
                "pjrt backend serves 'dense' or 'quoka' (got '{}'); other \
                 baselines run with --backend host",
                policy.name
            );
        }
        if !self.policies.contains_key(&policy.name) {
            self.policies
                .insert(policy.name.clone(), crate::select::policy_by_name(&policy.name)?);
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request { id, tokens, max_new_tokens: max_new.max(1), policy };
        self.seqs.insert(id, SeqEntry::new(req));
        self.sched.enqueue(id);
        Ok(id)
    }

    /// Number of unfinished requests.
    pub fn pending(&self) -> usize {
        self.seqs.len()
    }

    /// Drain finished results.
    pub fn take_results(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.results)
    }

    /// Execute one engine step. Returns false when fully idle.
    pub fn step(&mut self) -> Result<bool> {
        // Reject requests that can never fit the pool (otherwise FCFS
        // head-of-line would wedge the queue forever).
        while let Some(&head) = self.sched.waiting.front() {
            let entry = &self.seqs[&head];
            let need =
                self.blocks.blocks_for(entry.req.tokens.len() + entry.req.max_new_tokens);
            if need > self.blocks.total_blocks() {
                self.sched.waiting.pop_front();
                let mut entry = self.seqs.remove(&head).unwrap();
                entry.finished_at = Some(Instant::now());
                let r = entry.result(); // empty generation marks rejection
                self.results.push(r);
            } else {
                break;
            }
        }
        let plan = self.sched.plan(&mut self.seqs, &mut self.blocks);
        // Materialize backend state for newly admitted sequences.
        for id in &plan.admitted {
            let back = match &self.backend {
                Backend::Host(m) => SeqBack::Host {
                    state: SeqState::new(m.cfg()),
                    last_hidden: Vec::new(),
                },
                Backend::Pjrt(b) => SeqBack::Pjrt {
                    state: PjrtSeq::new(b.manifest()),
                    last_hidden: Vec::new(),
                },
            };
            self.backs.insert(*id, back);
        }
        if plan.items.is_empty() {
            return Ok(!self.seqs.is_empty() && !self.sched.waiting.is_empty());
        }

        let t0 = Instant::now();
        let (mut prefill_toks, mut decode_toks) = (0usize, 0usize);
        for item in &plan.items {
            match *item {
                WorkItem::PrefillChunk { id, start, len } => {
                    self.run_prefill(id, start, len)?;
                    prefill_toks += len;
                }
                WorkItem::Decode { id } => {
                    self.run_decode(id)?;
                    decode_toks += 1;
                }
            }
        }
        self.metrics.record_step(t0.elapsed(), prefill_toks, decode_toks);

        // Retire finished sequences.
        let done: Vec<u64> = self
            .seqs
            .iter()
            .filter(|(_, e)| e.phase == Phase::Finished)
            .map(|(&id, _)| id)
            .collect();
        for id in done {
            let mut entry = self.seqs.remove(&id).unwrap();
            self.backs.remove(&id);
            self.blocks.release(&mut entry.blocks);
            self.sched.retire(id);
            let r = entry.result();
            self.metrics
                .record_finish(r.ttft_s, r.tpot_s, entry.generated.len() > 1);
            self.results.push(r);
        }
        Ok(!self.seqs.is_empty())
    }

    /// Run until every submitted request completes; returns all results.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        while self.step()? {}
        Ok(self.take_results())
    }

    fn run_prefill(&mut self, id: u64, start: usize, len: usize) -> Result<()> {
        let entry = self.seqs.get_mut(&id).context("unknown seq")?;
        let chunk: Vec<u32> = entry.req.tokens[start..start + len].to_vec();
        let spec = entry.req.policy.clone();
        let is_last = start + len == entry.req.tokens.len();
        let back = self.backs.get_mut(&id).context("missing backend state")?;

        let ta = Instant::now();
        match (&mut self.backend, back) {
            (Backend::Host(m), SeqBack::Host { state, last_hidden }) => {
                self.ctx.begin_step();
                let policy = self.policies.get(&spec.name).unwrap();
                let hidden = m.forward_chunk(state, &chunk, policy.as_ref(), spec.budget, &mut self.ctx);
                if is_last {
                    let dm = m.cfg().d_model;
                    *last_hidden = hidden[hidden.len() - dm..].to_vec();
                }
                self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(state.kv_bytes());
            }
            (Backend::Pjrt(b), SeqBack::Pjrt { state, last_hidden }) => {
                let mode = if spec.name == "dense" { AttnMode::Dense } else { AttnMode::Quoka };
                let hidden = b.prefill_chunk(state, &chunk, mode)?;
                if is_last {
                    let dm = b.cfg().d_model;
                    *last_hidden = hidden[hidden.len() - dm..].to_vec();
                }
                self.metrics.peak_kv_bytes =
                    self.metrics.peak_kv_bytes.max(state.kv_bytes(b.cfg()));
            }
            _ => unreachable!("backend/seq-state mismatch"),
        }
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        let entry = self.seqs.get_mut(&id).unwrap();
        if is_last {
            // Sample the first token straight from the prefill's last
            // hidden row — this is the TTFT point.
            let back = self.backs.get_mut(&id).unwrap();
            let first = match (&mut self.backend, back) {
                (Backend::Host(m), SeqBack::Host { last_hidden, .. }) => {
                    let logits = m.logits(last_hidden);
                    crate::tensor::ops::topk_indices(&logits, 1)[0] as u32
                }
                (Backend::Pjrt(b), SeqBack::Pjrt { last_hidden, .. }) => {
                    let logits = b.logits(last_hidden)?;
                    crate::tensor::ops::topk_indices(&logits, 1)[0] as u32
                }
                _ => unreachable!(),
            };
            entry.generated.push(first);
            entry.first_token_at = Some(Instant::now());
            if entry.generated.len() >= entry.req.max_new_tokens {
                entry.phase = Phase::Finished;
                entry.finished_at = Some(Instant::now());
            } else {
                entry.phase = Phase::Decode;
            }
        } else {
            entry.phase = Phase::Prefill { next: start + len };
        }
        Ok(())
    }

    fn run_decode(&mut self, id: u64) -> Result<()> {
        let entry = self.seqs.get_mut(&id).context("unknown seq")?;
        let spec = entry.req.policy.clone();
        let last_tok = *entry.generated.last().context("decode before first token")?;
        // Grow the block lease for the new token; preempt-free because
        // admission reserved max_new up front.
        let need = entry.cache_tokens() + 1;
        let mut lease = std::mem::take(&mut entry.blocks);
        let ok = self.blocks.ensure(&mut lease, need);
        let entry = self.seqs.get_mut(&id).unwrap();
        entry.blocks = lease;
        anyhow::ensure!(ok, "KV pool exhausted mid-decode (seq {id})");

        let back = self.backs.get_mut(&id).context("missing backend state")?;
        let ta = Instant::now();
        let next = match (&mut self.backend, back) {
            (Backend::Host(m), SeqBack::Host { state, .. }) => {
                self.ctx.begin_step();
                let policy = self.policies.get(&spec.name).unwrap();
                let hidden =
                    m.forward_chunk(state, &[last_tok], policy.as_ref(), spec.budget, &mut self.ctx);
                m.greedy_next(&hidden)
            }
            (Backend::Pjrt(b), SeqBack::Pjrt { state, .. }) => {
                let mode = if spec.name == "dense" { AttnMode::Dense } else { AttnMode::Quoka };
                let (next, _) = b.decode_step(state, last_tok, mode)?;
                next
            }
            _ => unreachable!(),
        };
        self.metrics.attention_s += ta.elapsed().as_secs_f64();

        let entry = self.seqs.get_mut(&id).unwrap();
        entry.generated.push(next);
        if entry.generated.len() >= entry.req.max_new_tokens {
            entry.phase = Phase::Finished;
            entry.finished_at = Some(Instant::now());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::new_host(
            "tiny",
            EngineCfg {
                sched: SchedCfg { b_cp: 16, step_tokens: 48, max_running: 4 },
                pool_blocks: 64,
                block_tokens: 16,
                seed: 1,
            },
        )
        .unwrap()
    }

    fn prompt(n: usize, salt: u64) -> Vec<u32> {
        (0..n).map(|i| ((i as u64 * 31 + salt) % 251) as u32).collect()
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine();
        let id = e
            .submit(prompt(40, 1), 4, PolicySpec { name: "quoka".into(), budget: 32 })
            .unwrap();
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.id, id);
        assert_eq!(r.generated.len(), 4);
        assert!(r.ttft_s > 0.0);
        assert_eq!(e.blocks.free_blocks(), 64, "all blocks returned");
    }

    #[test]
    fn batch_of_requests_with_mixed_policies() {
        let mut e = engine();
        for (i, name) in ["dense", "quoka", "sample", "keydiff"].iter().enumerate() {
            e.submit(
                prompt(30 + i * 7, i as u64),
                3,
                PolicySpec { name: name.to_string(), budget: 24 },
            )
            .unwrap();
        }
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.generated.len() == 3));
        assert_eq!(e.metrics.requests_finished, 4);
        assert!(e.metrics.decode_tokens >= 8);
    }

    #[test]
    fn deterministic_generation_at_fixed_seed() {
        let run = || {
            let mut e = engine();
            e.submit(prompt(33, 5), 6, PolicySpec { name: "quoka".into(), budget: 16 }).unwrap();
            e.run_to_completion().unwrap()[0].generated.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn dense_engine_matches_raw_model() {
        // The engine's chunked output must equal driving HostModel by hand.
        let mut e = engine();
        let toks = prompt(40, 9);
        e.submit(toks.clone(), 3, PolicySpec { name: "dense".into(), budget: 0 }).unwrap();
        let got = e.run_to_completion().unwrap()[0].generated.clone();

        let mc = ModelConfig::preset("tiny").unwrap();
        let m = HostModel::new(Weights::generate(&mc, 1));
        let mut st = SeqState::new(&mc);
        let mut ctx = SelectCtx::new(0);
        let mut h = Vec::new();
        for c in toks.chunks(16) {
            h = m.forward_chunk(&mut st, c, &crate::select::dense::Dense, usize::MAX, &mut ctx);
        }
        let mut want = vec![m.greedy_next(&h)];
        for _ in 0..2 {
            let h = m.forward_chunk(
                &mut st,
                &[*want.last().unwrap()],
                &crate::select::dense::Dense,
                usize::MAX,
                &mut ctx,
            );
            want.push(m.greedy_next(&h));
        }
        assert_eq!(got, want);
    }

    #[test]
    fn admission_respects_pool_capacity() {
        let mut e = Engine::new_host(
            "tiny",
            EngineCfg {
                sched: SchedCfg { b_cp: 16, step_tokens: 64, max_running: 8 },
                pool_blocks: 4, // 64 tokens of capacity
                block_tokens: 16,
                seed: 1,
            },
        )
        .unwrap();
        e.submit(prompt(40, 1), 2, PolicySpec::default()).unwrap(); // 3 blocks
        e.submit(prompt(40, 2), 2, PolicySpec::default()).unwrap(); // must wait
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 2, "second request runs after the first frees blocks");
    }

    #[test]
    fn rejects_bad_submissions() {
        let mut e = engine();
        assert!(e.submit(vec![], 2, PolicySpec::default()).is_err());
        assert!(e
            .submit(vec![1], 1, PolicySpec { name: "not-a-policy".into(), budget: 1 })
            .is_err());
    }
}
