//! Paged KV-block allocator (vLLM-style accounting) — the **lease layer**
//! of the KV store.
//!
//! Token storage is accounted in fixed-size blocks: admission is denied
//! when the pool is exhausted, and completed sequences return their
//! blocks. In the engine's private-buffer mode this is accounting only
//! (physical KV lives in per-sequence buffers); in paged mode the ids it
//! hands out are *page ids* of the shared `kvpool::KvPool`, which layers
//! refcounts, copy-on-write and prefix sharing on top — every page the
//! pool owns is a block leased here, so `free + leased == total` spans
//! both modes. Invariants (never lease a block twice, exact free
//! accounting, zero-sized ops are no-ops) are property-tested in
//! `rust/tests/coordinator_props.rs` and `rust/tests/kvpool_props.rs`.

/// Fixed-size block allocator over a bounded pool.
#[derive(Debug)]
pub struct BlockAllocator {
    block_tokens: usize,
    free: Vec<u32>,
    total: usize,
    leased: std::collections::HashSet<u32>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> BlockAllocator {
        assert!(block_tokens > 0 && total_blocks > 0);
        BlockAllocator {
            block_tokens,
            free: (0..total_blocks as u32).rev().collect(),
            total: total_blocks,
            leased: Default::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks needed to store `tokens`.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// True when `n` more blocks can be leased.
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Blocks currently leased out.
    pub fn leased_blocks(&self) -> usize {
        self.total - self.free.len()
    }

    /// Lease `n` blocks (all-or-nothing; `n == 0` is a no-op returning an
    /// empty lease, so residency-aware admission can "grow" a fully cached
    /// sequence without touching the pool).
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        if n == 0 {
            return Some(Vec::new());
        }
        if self.free.len() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free.pop().unwrap();
            let fresh = self.leased.insert(b);
            debug_assert!(fresh, "double lease of block {b}");
            out.push(b);
        }
        Some(out)
    }

    /// Grow a lease so it covers `tokens` total; appends new blocks to
    /// `blocks`. Returns false (and changes nothing) when the pool is dry.
    /// Ensuring 0 tokens — or re-ensuring an already-covered count — is a
    /// no-op that always succeeds and never touches the free list.
    pub fn ensure(&mut self, blocks: &mut Vec<u32>, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc(need - blocks.len()) {
            Some(mut more) => {
                blocks.append(&mut more);
                true
            }
            None => false,
        }
    }

    /// Return one block to the pool (the paged pool's refcount layer frees
    /// pages one at a time as their last owner drops them).
    pub fn release_one(&mut self, b: u32) {
        assert!(self.leased.remove(&b), "release of un-leased block {b}");
        self.free.push(b);
    }

    /// Return blocks to the pool. Releasing an empty lease is a no-op (a
    /// retired sequence whose blocks were already handed off — e.g. to the
    /// prefix cache — must not double-account).
    pub fn release(&mut self, blocks: &mut Vec<u32>) {
        for b in blocks.drain(..) {
            self.release_one(b);
        }
    }

    /// Pool utilization in [0,1].
    pub fn utilization(&self) -> f32 {
        1.0 - self.free.len() as f32 / self.total as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(8, 128);
        let mut lease = a.alloc(3).unwrap();
        assert_eq!(a.free_blocks(), 5);
        a.release(&mut lease);
        assert_eq!(a.free_blocks(), 8);
        assert!(lease.is_empty());
    }

    #[test]
    fn all_or_nothing() {
        let mut a = BlockAllocator::new(4, 128);
        assert!(a.alloc(5).is_none());
        assert_eq!(a.free_blocks(), 4);
    }

    #[test]
    fn ensure_grows_incrementally() {
        let mut a = BlockAllocator::new(10, 100);
        let mut lease = Vec::new();
        assert!(a.ensure(&mut lease, 250)); // 3 blocks
        assert_eq!(lease.len(), 3);
        assert!(a.ensure(&mut lease, 300)); // still 3
        assert_eq!(lease.len(), 3);
        assert!(a.ensure(&mut lease, 301)); // 4th
        assert_eq!(lease.len(), 4);
        assert_eq!(a.free_blocks(), 6);
    }

    #[test]
    fn ensure_fails_cleanly_when_dry() {
        let mut a = BlockAllocator::new(2, 100);
        let mut lease = Vec::new();
        assert!(!a.ensure(&mut lease, 500));
        assert!(lease.is_empty());
        assert_eq!(a.free_blocks(), 2);
    }

    #[test]
    fn zero_sized_ops_are_noops() {
        // The double-accounting edge: ensure(…, 0), alloc(0) and releasing
        // an empty lease must not move a single block.
        let mut a = BlockAllocator::new(4, 100);
        let mut lease = Vec::new();
        assert!(a.ensure(&mut lease, 0));
        assert!(lease.is_empty());
        assert_eq!(a.free_blocks(), 4);
        assert_eq!(a.alloc(0), Some(vec![]));
        assert_eq!(a.free_blocks(), 4);
        a.release(&mut lease);
        assert_eq!(a.free_blocks(), 4);
        assert_eq!(a.leased_blocks(), 0);
        // Re-ensuring an already-covered count is idempotent.
        assert!(a.ensure(&mut lease, 150));
        assert_eq!(lease.len(), 2);
        assert!(a.ensure(&mut lease, 150));
        assert!(a.ensure(&mut lease, 0));
        assert_eq!(lease.len(), 2);
        assert_eq!(a.leased_blocks(), 2);
    }

    #[test]
    #[should_panic(expected = "un-leased")]
    fn double_release_panics() {
        let mut a = BlockAllocator::new(4, 100);
        let lease = a.alloc(1).unwrap();
        let mut l1 = lease.clone();
        let mut l2 = lease;
        a.release(&mut l1);
        a.release(&mut l2);
    }
}
