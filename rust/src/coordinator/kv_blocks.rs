//! Paged KV-block allocator (vLLM-style accounting).
//!
//! The engine's physical KV floats live in per-sequence buffers (host or
//! PJRT); this allocator is the *capacity manager*: token storage is
//! accounted in fixed-size blocks, admission is denied when the pool is
//! exhausted, and completed sequences return their blocks. Invariants
//! (never lease a block twice, exact free accounting) are property-tested
//! in `rust/tests/coordinator_props.rs`.

/// Fixed-size block allocator over a bounded pool.
#[derive(Debug)]
pub struct BlockAllocator {
    block_tokens: usize,
    free: Vec<u32>,
    total: usize,
    leased: std::collections::HashSet<u32>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> BlockAllocator {
        assert!(block_tokens > 0 && total_blocks > 0);
        BlockAllocator {
            block_tokens,
            free: (0..total_blocks as u32).rev().collect(),
            total: total_blocks,
            leased: Default::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks needed to store `tokens`.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// True when `n` more blocks can be leased.
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Lease `n` blocks (all-or-nothing).
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        if self.free.len() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free.pop().unwrap();
            let fresh = self.leased.insert(b);
            debug_assert!(fresh, "double lease of block {b}");
            out.push(b);
        }
        Some(out)
    }

    /// Grow a lease so it covers `tokens` total; appends new blocks to
    /// `blocks`. Returns false (and changes nothing) when the pool is dry.
    pub fn ensure(&mut self, blocks: &mut Vec<u32>, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc(need - blocks.len()) {
            Some(mut more) => {
                blocks.append(&mut more);
                true
            }
            None => false,
        }
    }

    /// Return blocks to the pool.
    pub fn release(&mut self, blocks: &mut Vec<u32>) {
        for b in blocks.drain(..) {
            assert!(self.leased.remove(&b), "release of un-leased block {b}");
            self.free.push(b);
        }
    }

    /// Pool utilization in [0,1].
    pub fn utilization(&self) -> f32 {
        1.0 - self.free.len() as f32 / self.total as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(8, 128);
        let mut lease = a.alloc(3).unwrap();
        assert_eq!(a.free_blocks(), 5);
        a.release(&mut lease);
        assert_eq!(a.free_blocks(), 8);
        assert!(lease.is_empty());
    }

    #[test]
    fn all_or_nothing() {
        let mut a = BlockAllocator::new(4, 128);
        assert!(a.alloc(5).is_none());
        assert_eq!(a.free_blocks(), 4);
    }

    #[test]
    fn ensure_grows_incrementally() {
        let mut a = BlockAllocator::new(10, 100);
        let mut lease = Vec::new();
        assert!(a.ensure(&mut lease, 250)); // 3 blocks
        assert_eq!(lease.len(), 3);
        assert!(a.ensure(&mut lease, 300)); // still 3
        assert_eq!(lease.len(), 3);
        assert!(a.ensure(&mut lease, 301)); // 4th
        assert_eq!(lease.len(), 4);
        assert_eq!(a.free_blocks(), 6);
    }

    #[test]
    fn ensure_fails_cleanly_when_dry() {
        let mut a = BlockAllocator::new(2, 100);
        let mut lease = Vec::new();
        assert!(!a.ensure(&mut lease, 500));
        assert!(lease.is_empty());
        assert_eq!(a.free_blocks(), 2);
    }

    #[test]
    #[should_panic(expected = "un-leased")]
    fn double_release_panics() {
        let mut a = BlockAllocator::new(4, 100);
        let lease = a.alloc(1).unwrap();
        let mut l1 = lease.clone();
        let mut l2 = lease;
        a.release(&mut l1);
        a.release(&mut l2);
    }
}
