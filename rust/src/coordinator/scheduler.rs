//! Sarathi-style chunked-prefill + decode scheduler.
//!
//! Each engine step gets a *token budget*. Decodes (one token each) are
//! scheduled first — they are latency-critical — and the remaining budget
//! is filled with prefill chunks of at most `B_CP` tokens, FCFS across
//! running sequences. Waiting sequences are admitted while the KV block
//! pool and the running-set cap allow. This is the interleaving that makes
//! chunked prefill (and thus QUOKA) matter: prefill work is sliced so
//! decode latency stays bounded (Agrawal et al., 2023/2024).
//!
//! Admission is fair-share across *tenants* (the wire `tenant` field):
//! tenants take weighted round-robin turns at the admission slot, FIFO
//! within each tenant. Untagged requests all share the default tenant, so
//! a single-tenant workload reduces exactly to the original FCFS order.

use super::kv_blocks::BlockAllocator;
use super::request::{Phase, SeqEntry};
use crate::obs::{TraceEventKind, Tracer};
use std::collections::{HashMap, VecDeque};

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct SchedCfg {
    /// Prefill chunk size `B_CP`.
    pub b_cp: usize,
    /// Max tokens processed per engine step (decode + prefill).
    pub step_tokens: usize,
    /// Max concurrently running sequences.
    pub max_running: usize,
    /// Never schedule a prefill chunk truncated below `b_cp` by step-budget
    /// pressure — defer it to a later step instead (a prompt's final short
    /// tail still runs). Chunk boundaries then depend only on the prompt,
    /// not on concurrent load, so the KV a sparse policy publishes to the
    /// prefix cache is bit-identical to a cold serial recompute. The
    /// engine enables this in paged + prefix-cache mode, where sequences
    /// publish pages.
    pub deterministic_chunks: bool,
}

impl SchedCfg {
    /// The load-independent prefill chunk width used when
    /// `deterministic_chunks` is on: `b_cp` capped so that even a
    /// worst-case decode-loaded step (one decode per other running
    /// sequence) always fits one full-width chunk. Every deterministic
    /// chunk starts at a multiple of this width — the "chunk grid" that
    /// cache-published KV is computed on; resume cursors must land on it
    /// (see `Engine::advance_followers` and the warm-submit path).
    pub fn det_chunk_width(&self) -> usize {
        self.b_cp.min(self.step_tokens.saturating_sub(self.max_running - 1).max(1)).max(1)
    }
}

impl Default for SchedCfg {
    fn default() -> Self {
        SchedCfg { b_cp: 128, step_tokens: 256, max_running: 8, deterministic_chunks: false }
    }
}

/// One unit of scheduled work.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkItem {
    /// Prefill `tokens[range]` of sequence `id`.
    PrefillChunk { id: u64, start: usize, len: usize },
    /// One decode step for sequence `id`.
    Decode { id: u64 },
    /// One speculative decode step for sequence `id`: draft up to `gamma`
    /// tokens and verify them (plus the pending token) in one multi-token
    /// forward. Charged `gamma + 1` tokens of step budget — the width of
    /// the verified chunk; the engine falls back to a plain decode when
    /// the drafter proposes nothing.
    Verify { id: u64, gamma: usize },
}

/// The per-step plan.
#[derive(Clone, Debug, Default)]
pub struct StepPlan {
    pub items: Vec<WorkItem>,
    pub admitted: Vec<u64>,
    pub scheduled_tokens: usize,
    /// Running sequences parked in [`Phase::WaitingOnPrefix`]: they hold
    /// their KV reservation but consume zero step budget — their prefix is
    /// being produced by another sequence's in-flight prefill.
    pub parked: usize,
}

/// A waiting request's fair-share tag. Only non-default tags are stored;
/// absent ⇒ the default tenant (`""`) at weight 1.
struct TenantTag {
    name: String,
    weight: usize,
}

/// Scheduler state: FIFO per tenant, weighted round-robin across tenants.
pub struct Scheduler {
    pub cfg: SchedCfg,
    /// Request ids waiting for admission, in arrival order.
    pub waiting: VecDeque<u64>,
    /// Running ids in admission order.
    pub running: Vec<u64>,
    /// Fair-share tags of waiting requests (non-default only).
    tenants: HashMap<u64, TenantTag>,
    /// The tenant the last admission went to, and how many more
    /// back-to-back admissions its weight still entitles it to.
    rr_last: Option<String>,
    rr_credit: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedCfg) -> Scheduler {
        Scheduler {
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            tenants: HashMap::new(),
            rr_last: None,
            rr_credit: 0,
        }
    }

    pub fn enqueue(&mut self, id: u64) {
        self.enqueue_as(id, "", 1);
    }

    /// [`Scheduler::enqueue`] with a fair-share tag: `tenant` names the
    /// round-robin group (empty = the shared default tenant), `weight` how
    /// many back-to-back admissions a turn is worth (clamped to ≥ 1).
    pub fn enqueue_as(&mut self, id: u64, tenant: &str, weight: usize) {
        if !tenant.is_empty() || weight > 1 {
            self.tenants.insert(id, TenantTag { name: tenant.to_string(), weight: weight.max(1) });
        }
        self.waiting.push_back(id);
    }

    /// Remove a finished/cancelled/rejected id from the scheduler.
    pub fn retire(&mut self, id: u64) {
        self.running.retain(|&r| r != id);
        self.tenants.remove(&id);
    }

    fn tenant_of(&self, id: u64) -> &str {
        self.tenants.get(&id).map(|t| t.name.as_str()).unwrap_or("")
    }

    /// The id the next admission attempt will consider: the FIFO head of
    /// the tenant whose round-robin turn it is. Tenant order is the
    /// arrival order of each tenant's oldest waiting request; the last
    /// admitted tenant keeps the slot while its weight credit lasts (and
    /// it still has waiting work), then the turn passes to its cyclic
    /// successor. With a single tenant this is exactly `waiting.front()`.
    ///
    /// Pure query — admission itself calls [`Scheduler::plan`], which
    /// advances the round-robin state only when the candidate is actually
    /// admitted, so a failed block reservation retries the same candidate
    /// (no head-of-line bypass within or across tenants).
    pub fn admission_candidate(&self) -> Option<u64> {
        let mut order: Vec<&str> = Vec::new();
        for &id in &self.waiting {
            let t = self.tenant_of(id);
            if !order.contains(&t) {
                order.push(t);
            }
        }
        let pick: &str = match &self.rr_last {
            _ if order.is_empty() => return None,
            Some(last) if self.rr_credit > 0 && order.contains(&last.as_str()) => last.as_str(),
            Some(last) => match order.iter().position(|t| *t == last.as_str()) {
                Some(i) => order[(i + 1) % order.len()],
                None => order[0], // the last tenant has nothing waiting
            },
            None => order[0],
        };
        self.waiting.iter().copied().find(|&id| self.tenant_of(id) == pick)
    }

    /// Advance the round-robin state after `id` was admitted.
    fn note_admitted(&mut self, id: u64) {
        let (name, weight) = match self.tenants.get(&id) {
            Some(t) => (t.name.clone(), t.weight.max(1)),
            None => (String::new(), 1),
        };
        match &self.rr_last {
            Some(last) if *last == name => self.rr_credit = self.rr_credit.saturating_sub(1),
            _ => {
                self.rr_last = Some(name);
                self.rr_credit = weight - 1;
            }
        }
    }

    /// Build the next step plan.
    ///
    /// `seqs` must resolve every id in `waiting`/`running`. Admission
    /// reserves KV blocks for the *whole prompt plus one decode block* up
    /// front (conservative, prevents mid-prefill eviction).
    pub fn plan(
        &mut self,
        seqs: &mut std::collections::HashMap<u64, SeqEntry>,
        blocks: &mut BlockAllocator,
    ) -> StepPlan {
        self.plan_traced(seqs, blocks, &mut Tracer::disabled())
    }

    /// [`Scheduler::plan`] with lifecycle tracing: admissions emit an
    /// `Admit` event at the decision site (the engine passes its
    /// tracer; [`Scheduler::plan`] passes a disabled one).
    pub fn plan_traced(
        &mut self,
        seqs: &mut std::collections::HashMap<u64, SeqEntry>,
        blocks: &mut BlockAllocator,
        tracer: &mut Tracer,
    ) -> StepPlan {
        let mut plan = StepPlan::default();

        // ---- admission (by real residency) ----
        // A sequence is charged the blocks for its whole prompt + decode
        // budget MINUS whatever it already holds — prefix-cache hits arrive
        // with shared pages at the head of their block table, so a mostly
        // cached request admits almost for free. The candidate each slot
        // considers is the fair-share pick ([`admission_candidate`]):
        // weighted round-robin across tenants, FIFO within one.
        while self.running.len() < self.cfg.max_running {
            let Some(cand) = self.admission_candidate() else { break };
            let entry = seqs.get_mut(&cand).expect("waiting id unknown");
            let need = entry.residual_blocks(blocks);
            match blocks.alloc(need) {
                Some(mut lease) => {
                    entry.blocks.append(&mut lease);
                    self.waiting.retain(|&w| w != cand);
                    self.running.push(cand);
                    self.note_admitted(cand);
                    plan.admitted.push(cand);
                    tracer.record(cand, TraceEventKind::Admit);
                }
                None => break, // don't skip ahead of the fair-share pick
            }
        }

        // ---- decodes first (latency-critical) ----
        // A speculating sequence gets a Verify item charged gamma + 1
        // tokens (the verified chunk width: pending token + gamma drafts),
        // capped so a step can never emit past max_new. When the residual
        // budget can't hold the full chunk the sequence degrades to a
        // plain one-token decode rather than waiting — decode latency
        // outranks speculation depth.
        //
        // Speculation must not starve prefill: the deterministic-width
        // guarantee ("deferral can delay a chunk, never starve it")
        // assumes each decoder costs ONE token per step, so while any
        // sequence still has prefill work, verify charges additionally
        // reserve one full chunk of headroom — a step full of speculating
        // decoders degrades (some of) them to plain decodes instead of
        // deferring the prefill chunk forever. Without prefill work the
        // whole budget is speculation's to spend.
        let prefill_pending = self.running.iter().any(|id| {
            matches!(seqs[id].phase, Phase::Prefill { next } if next < seqs[id].req.tokens.len())
        });
        // One full chunk of headroom in both modes: deterministic chunks
        // must fit at full width or defer, and non-deterministic chunks
        // shrink to whatever is left — reserving less (say one token)
        // would let sustained speculation collapse a concurrent prefill
        // to one token per step, a b_cp-fold TTFT regression.
        // `det_chunk_width()` is the right quantum for both: b_cp capped
        // so worst-case one-token-per-decoder load still fits a chunk.
        let headroom = if prefill_pending { self.cfg.det_chunk_width() } else { 0 };
        // Every decoder not yet visited still needs its guaranteed one
        // token, so a verify may only spend what's left after reserving
        // both the chunk headroom and those tokens — otherwise an early
        // verify lets later plain decodes erode the reservation.
        let mut decoders_left = self
            .running
            .iter()
            .filter(|id| matches!(seqs[id].phase, Phase::Decode))
            .count();
        let mut budget = self.cfg.step_tokens;
        for &id in &self.running {
            if budget == 0 {
                break;
            }
            let entry = &seqs[&id];
            if matches!(entry.phase, Phase::Decode) {
                decoders_left -= 1;
                let remaining = entry.req.max_new_tokens.saturating_sub(entry.generated.len());
                let gamma = if entry.req.spec.enabled() {
                    entry.req.spec.gamma.min(remaining.saturating_sub(1))
                } else {
                    0
                };
                if gamma > 0 && budget >= 1 + gamma + headroom + decoders_left {
                    plan.items.push(WorkItem::Verify { id, gamma });
                    budget -= 1 + gamma;
                } else {
                    plan.items.push(WorkItem::Decode { id });
                    budget -= 1;
                }
            }
        }

        // ---- prefill chunks with the remaining budget ----
        // Followers of an in-flight prefill are parked, not scheduled:
        // their next tokens are being produced by another sequence, so a
        // chunk here would be pure duplicate work.
        plan.parked = self
            .running
            .iter()
            .filter(|id| matches!(seqs[id].phase, Phase::WaitingOnPrefix { .. }))
            .count();
        for &id in &self.running {
            if budget == 0 {
                break;
            }
            if let Phase::Prefill { next } = seqs[&id].phase {
                let remaining = seqs[&id].req.tokens.len() - next;
                if remaining == 0 {
                    continue;
                }
                let want = remaining.min(self.cfg.b_cp);
                let len = if self.cfg.deterministic_chunks {
                    // Deterministic boundaries: the chunk width is a pure
                    // function of the scheduler config — never of how
                    // loaded this particular step happened to be. A chunk
                    // the current budget cannot hold at full width is
                    // deferred to a later step, not truncated
                    // (cache-published KV must match a cold serial
                    // recompute bit for bit). See
                    // [`SchedCfg::det_chunk_width`]: the width reserves
                    // worst-case decode headroom, so a full step ALWAYS
                    // has room for the first prefill candidate — deferral
                    // can delay a chunk, never starve it.
                    let det_len = want.min(self.cfg.det_chunk_width());
                    if budget < det_len {
                        continue;
                    }
                    det_len
                } else {
                    want.min(budget)
                };
                plan.items.push(WorkItem::PrefillChunk { id, start: next, len });
                budget -= len;
            }
        }

        // ---- lone-prefiller multi-chunk (deterministic mode only) ----
        // When exactly one sequence has prefill work left, nothing else
        // wants the residual budget: give the lone prefiller additional
        // full deterministic-width chunks this step (its in-flight page
        // publishes land sooner, cutting burst TTFT for parked followers).
        // Chunk *boundaries* stay on the deterministic grid — only the
        // number of chunks per step changes — so published KV remains
        // bit-identical to a serial cold run. Non-deterministic mode is
        // left alone: without pinned boundaries, extra chunks would just
        // re-slice the same work the next step would do anyway.
        if self.cfg.deterministic_chunks {
            let mut lone: Option<(u64, usize)> = None; // (id, next unscheduled)
            for &id in &self.running {
                if let Phase::Prefill { next } = seqs[&id].phase {
                    let scheduled: usize = plan
                        .items
                        .iter()
                        .filter_map(|it| match it {
                            WorkItem::PrefillChunk { id: cid, len, .. } if *cid == id => {
                                Some(*len)
                            }
                            _ => None,
                        })
                        .sum();
                    if next + scheduled < seqs[&id].req.tokens.len() {
                        if lone.replace((id, next + scheduled)).is_some() {
                            lone = None; // two sequences still want budget
                            break;
                        }
                    }
                }
            }
            if let Some((id, mut cursor)) = lone {
                let total = seqs[&id].req.tokens.len();
                let det = self.cfg.det_chunk_width();
                while budget > 0 && cursor < total {
                    let len = (total - cursor).min(self.cfg.b_cp).min(det);
                    if budget < len {
                        break; // never truncate a deterministic chunk
                    }
                    plan.items.push(WorkItem::PrefillChunk { id, start: cursor, len });
                    cursor += len;
                    budget -= len;
                }
            }
        }

        plan.scheduled_tokens = self.cfg.step_tokens - budget;
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{PolicySpec, Request};
    use std::collections::HashMap;

    fn mk(seqs: &mut HashMap<u64, SeqEntry>, id: u64, prompt: usize, max_new: usize) {
        seqs.insert(
            id,
            SeqEntry::new(Request {
                id,
                tokens: vec![1; prompt],
                max_new_tokens: max_new,
                policy: PolicySpec::default(),
                spec: crate::spec::SpecCfg::off(),
            }),
        );
    }

    #[test]
    fn admits_fcfs_until_blocks_exhausted() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(6, 128); // 768 tokens capacity
        let mut s = Scheduler::new(SchedCfg::default());
        mk(&mut seqs, 1, 300, 10); // needs 3 blocks
        mk(&mut seqs, 2, 300, 10); // needs 3 blocks
        mk(&mut seqs, 3, 100, 10); // needs 1 — but FCFS blocked
        s.enqueue(1);
        s.enqueue(2);
        s.enqueue(3);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, vec![1, 2]);
        assert_eq!(s.waiting.len(), 1, "id 3 must wait (no head-of-line bypass)");
    }

    #[test]
    fn decode_scheduled_before_prefill() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let mut s = Scheduler::new(SchedCfg {
            b_cp: 128,
            step_tokens: 160,
            max_running: 4,
            ..SchedCfg::default()
        });
        mk(&mut seqs, 1, 512, 4);
        mk(&mut seqs, 2, 512, 4);
        s.enqueue(1);
        s.enqueue(2);
        let _ = s.plan(&mut seqs, &mut blocks);
        seqs.get_mut(&1).unwrap().phase = Phase::Decode;
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.items[0], WorkItem::Decode { id: 1 });
        // Remaining 159 tokens go to seq 2's prefill, capped at b_cp=128.
        assert_eq!(plan.items[1], WorkItem::PrefillChunk { id: 2, start: 0, len: 128 });
        assert_eq!(plan.scheduled_tokens, 129);
    }

    #[test]
    fn step_token_budget_respected() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let cfg = SchedCfg { b_cp: 128, step_tokens: 200, max_running: 8, ..SchedCfg::default() };
        let mut s = Scheduler::new(cfg);
        for id in 1..=4 {
            mk(&mut seqs, id, 1000, 4);
            s.enqueue(id);
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        let total: usize = plan
            .items
            .iter()
            .map(|i| match i {
                WorkItem::Decode { .. } => 1,
                WorkItem::PrefillChunk { len, .. } => *len,
            })
            .sum();
        assert!(total <= 200);
        assert_eq!(plan.scheduled_tokens, total);
    }

    #[test]
    fn short_tail_chunk() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let mut s = Scheduler::new(SchedCfg::default());
        mk(&mut seqs, 1, 130, 2);
        s.enqueue(1);
        let p1 = s.plan(&mut seqs, &mut blocks);
        assert_eq!(p1.items[0], WorkItem::PrefillChunk { id: 1, start: 0, len: 128 });
        seqs.get_mut(&1).unwrap().phase = Phase::Prefill { next: 128 };
        let p2 = s.plan(&mut seqs, &mut blocks);
        assert_eq!(p2.items[0], WorkItem::PrefillChunk { id: 1, start: 128, len: 2 });
    }

    #[test]
    fn deterministic_chunks_defer_instead_of_truncate() {
        // Budget 40, b_cp 16, two full-width prefills fit (32), the third
        // would be truncated to 8 — with deterministic_chunks it must wait
        // for a later step instead.
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 16);
        let cfg = SchedCfg { b_cp: 16, step_tokens: 40, max_running: 4, deterministic_chunks: true };
        let mut s = Scheduler::new(cfg);
        for id in 1..=3 {
            mk(&mut seqs, id, 64, 2);
            s.enqueue(id);
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::PrefillChunk { id: 1, start: 0, len: 16 },
                WorkItem::PrefillChunk { id: 2, start: 0, len: 16 },
            ],
            "third chunk must be deferred, not truncated to 8"
        );
        assert_eq!(plan.scheduled_tokens, 32);

        // A prompt's final short tail is not a truncation: it still runs
        // even when it is under b_cp.
        seqs.get_mut(&1).unwrap().phase = Phase::Prefill { next: 60 };
        seqs.get_mut(&2).unwrap().phase = Phase::Finished;
        seqs.get_mut(&3).unwrap().phase = Phase::Finished;
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.items, vec![WorkItem::PrefillChunk { id: 1, start: 60, len: 4 }]);

        // b_cp >= step_tokens: the deterministic width caps at
        // step_tokens - (max_running - 1) = 29, so even a worst-case
        // decode-loaded step can hold one full-width chunk — identical
        // boundaries idle or loaded, and no prefill starvation.
        let mut s2 = Scheduler::new(SchedCfg {
            b_cp: 64,
            step_tokens: 32,
            max_running: 4,
            deterministic_chunks: true,
        });
        let mut seqs2 = HashMap::new();
        mk(&mut seqs2, 9, 128, 2);
        mk(&mut seqs2, 10, 128, 2);
        s2.enqueue(9);
        s2.enqueue(10);
        let plan = s2.plan(&mut seqs2, &mut blocks);
        assert_eq!(
            plan.items,
            vec![WorkItem::PrefillChunk { id: 9, start: 0, len: 29 }],
            "29-wide chunk fits; the second sequence's chunk defers (3 budget left)"
        );
        // With a decode eating into the budget, the SAME width is
        // scheduled (never the load-dependent remainder) — boundaries are
        // a pure function of the config.
        seqs2.get_mut(&9).unwrap().phase = Phase::Decode;
        seqs2.get_mut(&9).unwrap().generated.push(1);
        seqs2.get_mut(&10).unwrap().phase = Phase::Prefill { next: 29 };
        let plan = s2.plan(&mut seqs2, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::Decode { id: 9 },
                WorkItem::PrefillChunk { id: 10, start: 29, len: 29 },
            ],
            "decode-loaded step must still fit one full deterministic chunk"
        );
    }

    #[test]
    fn waiting_on_prefix_is_admitted_but_never_scheduled() {
        // A parked follower holds its reservation (admission) but gets no
        // work items — its prefix tokens are in flight on another
        // sequence — and the freed budget flows to real prefills.
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 16);
        let cfg = SchedCfg { b_cp: 16, step_tokens: 32, max_running: 4, ..SchedCfg::default() };
        let mut s = Scheduler::new(cfg);
        mk(&mut seqs, 1, 64, 2); // the producer
        mk(&mut seqs, 2, 64, 2); // the follower
        seqs.get_mut(&2).unwrap().phase = Phase::WaitingOnPrefix { next: 0 };
        s.enqueue(1);
        s.enqueue(2);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, vec![1, 2], "parked follower still reserves KV");
        assert_eq!(plan.parked, 1);
        assert!(
            plan.items.iter().all(|it| !matches!(it, WorkItem::PrefillChunk { id: 2, .. })),
            "no chunk may be scheduled for a parked follower: {:?}",
            plan.items
        );
        // Woken into Prefill at its adopted cursor, it schedules normally.
        seqs.get_mut(&2).unwrap().phase = Phase::Prefill { next: 48 };
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.parked, 0);
        assert!(plan
            .items
            .iter()
            .any(|it| matches!(it, WorkItem::PrefillChunk { id: 2, start: 48, .. })));
    }

    fn mk_spec(
        seqs: &mut HashMap<u64, SeqEntry>,
        id: u64,
        max_new: usize,
        generated: usize,
        gamma: usize,
    ) {
        let mut e = SeqEntry::new(Request {
            id,
            tokens: vec![1; 32],
            max_new_tokens: max_new,
            policy: PolicySpec::default(),
            spec: crate::spec::SpecCfg::prompt_lookup(gamma),
        });
        e.phase = Phase::Decode;
        e.generated = vec![9; generated];
        seqs.insert(id, e);
    }

    #[test]
    fn verify_items_charge_the_chunk_width_and_degrade_under_pressure() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 16);
        let cfg = SchedCfg { b_cp: 16, step_tokens: 12, max_running: 8, ..SchedCfg::default() };
        let mut s = Scheduler::new(cfg);
        // Three speculating decoders at gamma 4 (charge 5 each) + a plain
        // one: budget 12 holds two full verifies, then the third degrades
        // to a plain decode, and the non-speculating one is untouched.
        for id in 1..=3 {
            mk_spec(&mut seqs, id, 64, 1, 4);
            s.enqueue(id);
        }
        mk(&mut seqs, 4, 32, 8);
        seqs.get_mut(&4).unwrap().phase = Phase::Decode;
        s.enqueue(4);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::Verify { id: 1, gamma: 4 },
                WorkItem::Verify { id: 2, gamma: 4 },
                WorkItem::Decode { id: 3 },
                WorkItem::Decode { id: 4 },
            ],
            "verify charges gamma + 1; the residual budget degrades to plain decode"
        );
        assert_eq!(plan.scheduled_tokens, 12);
    }

    #[test]
    fn speculation_never_starves_a_prefilling_sequence() {
        // Two speculating decoders at gamma 8 would eat the whole 24-token
        // budget every step, deferring the deterministic 16-wide chunk
        // forever; with prefill work pending, verify charges must leave
        // one full chunk of headroom — the decoders degrade to plain
        // decodes and the chunk is scheduled.
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 16);
        let cfg = SchedCfg { b_cp: 16, step_tokens: 24, max_running: 4, deterministic_chunks: true };
        let mut s = Scheduler::new(cfg);
        mk_spec(&mut seqs, 1, 64, 1, 8);
        mk_spec(&mut seqs, 2, 64, 1, 8);
        mk(&mut seqs, 3, 64, 2);
        for id in 1..=3 {
            s.enqueue(id);
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::Decode { id: 1 },
                WorkItem::Decode { id: 2 },
                WorkItem::PrefillChunk { id: 3, start: 0, len: 16 },
            ],
            "verify charges must respect the prefill chunk's headroom"
        );
        // Once the prefiller is done, the full budget belongs to
        // speculation again.
        seqs.get_mut(&3).unwrap().phase = Phase::Finished;
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![WorkItem::Verify { id: 1, gamma: 8 }, WorkItem::Verify { id: 2, gamma: 8 }],
        );

        // Mixed erosion: a speculating decoder AHEAD of seven plain
        // decoders must also reserve their guaranteed tokens — otherwise
        // its verify passes the headroom check and the plain decodes
        // behind it erode the budget below the chunk width anyway.
        let mut seqs = HashMap::new();
        let cfg = SchedCfg { b_cp: 16, step_tokens: 24, max_running: 9, deterministic_chunks: true };
        let mut s = Scheduler::new(cfg);
        mk_spec(&mut seqs, 1, 64, 1, 4);
        for id in 2..=8 {
            mk(&mut seqs, id, 32, 4);
            seqs.get_mut(&id).unwrap().phase = Phase::Decode;
            seqs.get_mut(&id).unwrap().generated.push(1);
        }
        mk(&mut seqs, 9, 64, 2);
        for id in 1..=9 {
            s.enqueue(id);
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.items[0], WorkItem::Decode { id: 1 }, "verify must degrade");
        assert!(
            plan.items.contains(&WorkItem::PrefillChunk { id: 9, start: 0, len: 16 }),
            "the deterministic chunk must fit after all decoders: {:?}",
            plan.items
        );
    }

    #[test]
    fn verify_gamma_is_capped_by_remaining_tokens() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 16);
        let mut s = Scheduler::new(SchedCfg::default());
        // 3 of max_new 5 generated: only 2 remain, so at most 1 draft
        // token is worth verifying (accepted + correction <= remaining).
        mk_spec(&mut seqs, 1, 5, 3, 8);
        s.enqueue(1);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.items, vec![WorkItem::Verify { id: 1, gamma: 1 }]);
        // One remaining token: a verify step cannot help — plain decode.
        let mut seqs2 = HashMap::new();
        mk_spec(&mut seqs2, 2, 5, 4, 8);
        let mut s2 = Scheduler::new(SchedCfg::default());
        s2.enqueue(2);
        let plan = s2.plan(&mut seqs2, &mut blocks);
        assert_eq!(plan.items, vec![WorkItem::Decode { id: 2 }]);
    }

    #[test]
    fn lone_prefiller_takes_extra_deterministic_chunks() {
        let mut blocks = BlockAllocator::new(64, 16);
        let cfg = SchedCfg { b_cp: 16, step_tokens: 64, max_running: 4, deterministic_chunks: true };
        // Alone: the whole budget becomes full-width chunks on the grid.
        let mut seqs = HashMap::new();
        let mut s = Scheduler::new(cfg);
        mk(&mut seqs, 1, 80, 2);
        s.enqueue(1);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::PrefillChunk { id: 1, start: 0, len: 16 },
                WorkItem::PrefillChunk { id: 1, start: 16, len: 16 },
                WorkItem::PrefillChunk { id: 1, start: 32, len: 16 },
                WorkItem::PrefillChunk { id: 1, start: 48, len: 16 },
            ],
            "a lone prefiller fills the step with deterministic-width chunks"
        );
        assert_eq!(plan.scheduled_tokens, 64);

        // The prompt tail still runs short, and the sweep stops there.
        let mut seqs = HashMap::new();
        let mut s = Scheduler::new(cfg);
        mk(&mut seqs, 2, 40, 2);
        s.enqueue(2);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::PrefillChunk { id: 2, start: 0, len: 16 },
                WorkItem::PrefillChunk { id: 2, start: 16, len: 16 },
                WorkItem::PrefillChunk { id: 2, start: 32, len: 8 },
            ],
        );

        // Two prefillers: nobody is alone — one chunk each, rest deferred
        // (boundaries may never depend on who shares the step).
        let mut seqs = HashMap::new();
        let mut s = Scheduler::new(cfg);
        mk(&mut seqs, 3, 80, 2);
        mk(&mut seqs, 4, 80, 2);
        s.enqueue(3);
        s.enqueue(4);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::PrefillChunk { id: 3, start: 0, len: 16 },
                WorkItem::PrefillChunk { id: 4, start: 0, len: 16 },
            ],
        );

        // A decoding neighbour doesn't count as a prefiller, but its
        // token narrows the budget available for extra chunks.
        let mut seqs = HashMap::new();
        let mut s = Scheduler::new(cfg);
        mk(&mut seqs, 5, 80, 4);
        mk(&mut seqs, 6, 80, 4);
        s.enqueue(5);
        s.enqueue(6);
        let _ = s.plan(&mut seqs, &mut blocks);
        seqs.get_mut(&5).unwrap().phase = Phase::Decode;
        seqs.get_mut(&5).unwrap().generated.push(1);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.items,
            vec![
                WorkItem::Decode { id: 5 },
                WorkItem::PrefillChunk { id: 6, start: 0, len: 16 },
                WorkItem::PrefillChunk { id: 6, start: 16, len: 16 },
                WorkItem::PrefillChunk { id: 6, start: 32, len: 16 },
            ],
            "63 residual budget holds three full-width chunks, never a truncated fourth"
        );

        // Non-deterministic mode: no pinned grid, no multi-chunk sweep.
        let mut seqs = HashMap::new();
        let mut s = Scheduler::new(SchedCfg { deterministic_chunks: false, ..cfg });
        mk(&mut seqs, 7, 80, 2);
        s.enqueue(7);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.items, vec![WorkItem::PrefillChunk { id: 7, start: 0, len: 16 }]);
    }

    fn mk_tenant(
        seqs: &mut HashMap<u64, SeqEntry>,
        s: &mut Scheduler,
        id: u64,
        tenant: &str,
        weight: usize,
    ) {
        mk(seqs, id, 100, 2);
        s.enqueue_as(id, tenant, weight);
    }

    #[test]
    fn tenants_round_robin_fifo_within() {
        // Arrival order: a1 a2 a3 b1 b2 c1. Equal weights ⇒ admission
        // rotates a b c a b c-style, oldest request first within a tenant.
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let mut s = Scheduler::new(SchedCfg { max_running: 8, ..SchedCfg::default() });
        for (id, t) in [(1, "a"), (2, "a"), (3, "a"), (4, "b"), (5, "b"), (6, "c")] {
            mk_tenant(&mut seqs, &mut s, id, t, 1);
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(
            plan.admitted,
            vec![1, 4, 6, 2, 5, 3],
            "round-robin across tenants, FIFO within each"
        );
    }

    #[test]
    fn tenant_weights_scale_admission_share() {
        // Tenant a at weight 2, b at weight 1 ⇒ a a b a a b.
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let mut s = Scheduler::new(SchedCfg { max_running: 8, ..SchedCfg::default() });
        for (id, t, w) in [
            (1, "a", 2),
            (2, "a", 2),
            (3, "a", 2),
            (4, "a", 2),
            (5, "b", 1),
            (6, "b", 1),
        ] {
            mk_tenant(&mut seqs, &mut s, id, t, w);
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, vec![1, 2, 5, 3, 4, 6], "weight 2 takes two slots per turn");
    }

    #[test]
    fn single_tenant_reduces_to_fcfs() {
        // Untagged requests (the old wire shape) must admit in exactly
        // the order the pre-tenant scheduler used: arrival order.
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let mut s = Scheduler::new(SchedCfg { max_running: 8, ..SchedCfg::default() });
        for id in 1..=5 {
            mk(&mut seqs, id, 100, 2);
            s.enqueue(id);
            assert_eq!(s.admission_candidate(), Some(1), "candidate is always the queue head");
        }
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn tenant_candidate_survives_failed_admission_and_departures() {
        let mut seqs = HashMap::new();
        // One 128-token block: fits a single 100-token request, so
        // admission stalls after the first.
        let mut blocks = BlockAllocator::new(1, 128);
        let mut s = Scheduler::new(SchedCfg { max_running: 8, ..SchedCfg::default() });
        mk_tenant(&mut seqs, &mut s, 1, "a", 1);
        mk_tenant(&mut seqs, &mut s, 2, "b", 1);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, vec![1]);
        // b's turn now; a failed reservation must not rotate past b.
        assert_eq!(s.admission_candidate(), Some(2));
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, Vec::<u64>::new(), "no blocks — nobody admitted");
        assert_eq!(s.admission_candidate(), Some(2), "candidate unchanged after the failure");
        // The only waiting tenant departing (cancel path) falls back to
        // whoever is left — here, a fresh default-tenant request.
        s.waiting.retain(|&w| w != 2);
        s.retire(2);
        mk(&mut seqs, 3, 100, 2);
        s.enqueue(3);
        assert_eq!(s.admission_candidate(), Some(3));
    }

    #[test]
    fn retire_frees_running_slot() {
        let mut seqs = HashMap::new();
        let mut blocks = BlockAllocator::new(64, 128);
        let mut s = Scheduler::new(SchedCfg { max_running: 1, ..SchedCfg::default() });
        mk(&mut seqs, 1, 100, 2);
        mk(&mut seqs, 2, 100, 2);
        s.enqueue(1);
        s.enqueue(2);
        let _ = s.plan(&mut seqs, &mut blocks);
        assert_eq!(s.running, vec![1]);
        s.retire(1);
        let plan = s.plan(&mut seqs, &mut blocks);
        assert_eq!(plan.admitted, vec![2]);
    }
}
