//! Engine metrics: the numbers behind the paper's latency figures.

use std::time::Duration;

use crate::obs::phase::{N_PHASES, PHASE_NAMES};
use crate::obs::LatencyHist;
use crate::util::json::Json;

/// Running aggregate of engine activity.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub steps: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub requests_finished: u64,
    /// Requests ended unserved by [`Engine::cancel`] (client abort,
    /// disconnect) — they report an empty generation, never a finish.
    pub requests_cancelled: u64,
    /// Requests rejected at admission because they could never fit the
    /// KV pool (the engine's unfittable-queue sweep).
    pub requests_rejected: u64,
    /// Wall time inside attention+selection (the paper's "attention
    /// module" latency), seconds.
    pub attention_s: f64,
    /// Wall time of whole engine steps, seconds.
    pub step_s: f64,
    /// Wall time of the engine's decode phase, seconds — the whole
    /// batched path per step (lease growth / eviction / COW pre-pass,
    /// batch assembly, the fused forward, and per-sequence bookkeeping),
    /// not just the kernel. The denominator of
    /// [`Metrics::decode_tokens_per_s`]; `BENCH_decode.json` times the
    /// forward alone, so its tokens/sec reads slightly higher.
    pub decode_s: f64,
    /// Decode batch-size histogram: `decode_batch_hist[b]` counts engine
    /// steps whose decode phase ran `b` sequences through one fused
    /// forward (index 0 unused; grown on demand). The batching win shows
    /// up here as mass above index 1.
    pub decode_batch_hist: Vec<u64>,
    /// Sum of per-request TTFT / TPOT for averaging.
    pub ttft_sum_s: f64,
    pub tpot_sum_s: f64,
    pub tpot_count: u64,
    /// Peak KV bytes resident across sequences.
    pub peak_kv_bytes: usize,
    /// Current physical residency of the shared paged pool (leased pages ×
    /// page bytes, metadata included); 0 in private-buffer mode. **RAM
    /// tier only**: a demoted page releases its lease before its spill
    /// slot is charged to `spill_bytes`, so a page is never counted in
    /// both tiers at once.
    pub pool_resident_bytes: usize,
    /// Pages demoted to the mmap spill tier (cumulative; `kvpool/spill.rs`).
    pub spilled_pages: u64,
    /// Current payload bytes parked in the spill tier (gauge — rises on
    /// demote, falls on promote / slot reuse).
    pub spill_bytes: usize,
    /// Pages promoted back from the spill tier into the pool (cumulative).
    pub promotions: u64,
    /// Submit→pages-resident wait of promotion-parked requests (one
    /// sample per request whose prefix came off the spill tier).
    pub promote_wait_hist: LatencyHist,
    /// Prefix-cache lookups (one per submitted request in paged+prefix
    /// mode) and the prompt tokens they covered.
    pub prefix_lookups: u64,
    pub prefix_lookup_tokens: u64,
    /// Lookups that matched at least one page, and the tokens they reused.
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
    /// KV bytes whose recompute + storage the prefix cache avoided.
    pub prefix_bytes_saved: u64,
    /// Prompt pages the in-flight publish hook actually *inserted* into
    /// the radix cache (every paged+prefix prefill publishes as it goes;
    /// spans already cached by an earlier request are no-ops and are not
    /// counted).
    pub inflight_published_pages: u64,
    /// Requests that parked as followers of an in-flight prefill instead
    /// of recomputing a prefix another sequence was already producing.
    pub inflight_followers: u64,
    /// Prompt tokens followers adopted from pages published while the
    /// producing prefill was still running (work shared "while hot"; a
    /// subset of `prefix_hit_tokens`).
    pub inflight_adopted_tokens: u64,
    /// Speculative decode: verify steps executed (each one multi-token
    /// forward over a drafted chunk).
    pub spec_steps: u64,
    /// Draft tokens proposed / accepted across all verify steps. The
    /// acceptance rate ([`Metrics::spec_acceptance`]) is their ratio.
    pub spec_drafted_tokens: u64,
    pub spec_accepted_tokens: u64,
    /// Tokens emitted by verify steps (accepted drafts + one correction
    /// token each; a subset of `decode_tokens`).
    pub spec_emitted_tokens: u64,
    /// Wall time of speculative work: drafting (including steps whose
    /// drafter abstained — those sequences then ride the fused decode
    /// batch) plus each verify step's multi-token forward and rollback,
    /// seconds. Counted into `decode_s` as well — speculation IS the
    /// decode phase for a speculating sequence — and kept separately so
    /// speculative throughput is reportable on its own.
    pub spec_s: f64,
    /// Per-request time-to-first-token distribution (one sample per
    /// finished request).
    pub ttft_hist: LatencyHist,
    /// Inter-token latency distribution: one sample per generated token
    /// after the first, measured between consecutive emissions (a
    /// multi-token verify emission contributes its per-token share).
    pub itl_hist: LatencyHist,
    /// Submit→admission wait (one sample per admitted request).
    pub queue_wait_hist: LatencyHist,
    /// Prefill chunk wall-time distribution (one sample per chunk).
    pub chunk_hist: LatencyHist,
    /// Verify-step wall-time distribution (one sample per verify step).
    pub verify_hist: LatencyHist,
    /// Forward wall time split by phase (`obs::phase::PHASE_NAMES`
    /// order: scan/attn/append/gemm), nanoseconds. Fed by the scoped
    /// timers in `HostModel::forward_*` and the attention kernels,
    /// drained once per engine step.
    pub phase_ns: [u64; N_PHASES],
}

impl Metrics {
    /// Record one engine step: total wall time, token counts, and — when
    /// the decode phase ran as one fused forward — its batch size and
    /// duration. `fused_decode` is `None` for backends that fall back to a
    /// serial per-sequence decode loop (PJRT), so the histogram only ever
    /// reports real batching.
    pub fn record_step(
        &mut self,
        dur: Duration,
        prefill: usize,
        decode: usize,
        fused_decode: Option<Duration>,
    ) {
        self.steps += 1;
        self.step_s += dur.as_secs_f64();
        self.prefill_tokens += prefill as u64;
        self.decode_tokens += decode as u64;
        if decode > 0 {
            if let Some(decode_dur) = fused_decode {
                self.decode_s += decode_dur.as_secs_f64();
                if self.decode_batch_hist.len() <= decode {
                    self.decode_batch_hist.resize(decode + 1, 0);
                }
                self.decode_batch_hist[decode] += 1;
            }
        }
    }

    /// Record one speculative verify step: `drafted` tokens proposed,
    /// `accepted` survived greedy verification, `emitted` tokens entered
    /// the generation (accepted + the model's correction token), taking
    /// `dur` of wall time end to end (draft + forward + rollback). Token
    /// totals flow into the regular decode counters — speculation changes
    /// how decode tokens are produced, not what they are.
    pub fn record_verify(&mut self, dur: Duration, drafted: usize, accepted: usize, emitted: usize) {
        self.spec_steps += 1;
        self.spec_drafted_tokens += drafted as u64;
        self.spec_accepted_tokens += accepted as u64;
        self.spec_emitted_tokens += emitted as u64;
        let secs = dur.as_secs_f64();
        self.spec_s += secs;
        self.decode_s += secs;
        self.decode_tokens += emitted as u64;
    }

    /// Fraction of drafted tokens that greedy verification accepted.
    pub fn spec_acceptance(&self) -> f64 {
        if self.spec_drafted_tokens == 0 {
            0.0
        } else {
            self.spec_accepted_tokens as f64 / self.spec_drafted_tokens as f64
        }
    }

    /// Speculative decode throughput: tokens emitted by verify steps per
    /// second of verify wall time. `None` when no verify wall time has
    /// been recorded — a rate over a zero denominator is not a rate
    /// (the summary prints `n/a`).
    pub fn spec_tokens_per_s(&self) -> Option<f64> {
        (self.spec_s > 0.0).then(|| self.spec_emitted_tokens as f64 / self.spec_s)
    }

    pub fn record_finish(&mut self, ttft_s: f64, tpot_s: f64, had_tpot: bool) {
        self.requests_finished += 1;
        self.ttft_sum_s += ttft_s;
        if had_tpot {
            self.tpot_sum_s += tpot_s;
            self.tpot_count += 1;
        }
    }

    pub fn record_prefix_lookup(&mut self, prompt_tokens: usize) {
        self.prefix_lookups += 1;
        self.prefix_lookup_tokens += prompt_tokens as u64;
    }

    pub fn record_prefix_hit(&mut self, hit_tokens: usize, bytes_saved: usize) {
        self.prefix_hits += 1;
        self.prefix_hit_tokens += hit_tokens as u64;
        self.prefix_bytes_saved += bytes_saved as u64;
    }

    /// Record a follower adopting freshly published in-flight pages.
    /// Counts toward the prefix-hit token/byte totals; the request itself
    /// is counted as a hit only once (`first_for_request` — it may already
    /// have been counted at submit if the lookup matched pages then).
    pub fn record_inflight_adopt(&mut self, tokens: usize, bytes: usize, first_for_request: bool) {
        self.inflight_adopted_tokens += tokens as u64;
        self.prefix_hit_tokens += tokens as u64;
        self.prefix_bytes_saved += bytes as u64;
        if first_for_request {
            self.prefix_hits += 1;
        }
    }

    /// Fraction of looked-up prompt tokens served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookup_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.prefix_lookup_tokens as f64
        }
    }

    pub fn mean_ttft_s(&self) -> f64 {
        if self.requests_finished == 0 {
            0.0
        } else {
            self.ttft_sum_s / self.requests_finished as f64
        }
    }

    pub fn mean_tpot_s(&self) -> f64 {
        if self.tpot_count == 0 {
            0.0
        } else {
            self.tpot_sum_s / self.tpot_count as f64
        }
    }

    /// Total token throughput (prefill + decode) per engine-second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.step_s == 0.0 {
            0.0
        } else {
            (self.prefill_tokens + self.decode_tokens) as f64 / self.step_s
        }
    }

    /// Decode throughput: generated tokens per second of decode-phase
    /// time (see [`Metrics::decode_s`] for what the span covers).
    /// `None` when no decode-phase time has been recorded (e.g. the
    /// serial PJRT fallback counts tokens but no fused-decode span) —
    /// the summary prints `n/a` instead of a made-up zero.
    pub fn decode_tokens_per_s(&self) -> Option<f64> {
        (self.decode_s > 0.0).then(|| self.decode_tokens as f64 / self.decode_s)
    }

    /// Update the live pool residency and raise the peak watermark.
    /// Called at every pool *growth* point (lease growth, follower
    /// adoption, admission) as well as per step, so a peak reached and
    /// released mid-step is still captured.
    pub fn note_kv_resident(&mut self, bytes: usize) {
        self.pool_resident_bytes = bytes;
        self.peak_kv_bytes = self.peak_kv_bytes.max(bytes);
    }

    /// Fold one drained phase-timer sample (ns, `PHASE_NAMES` order)
    /// into the running totals.
    pub fn add_phase_ns(&mut self, sample: [u64; N_PHASES]) {
        for (acc, v) in self.phase_ns.iter_mut().zip(sample.iter()) {
            *acc += v;
        }
    }

    /// Compact `size:count` rendering of the decode batch histogram
    /// (zero-count sizes omitted), e.g. `1:3 8:40`.
    pub fn decode_batch_hist_compact(&self) -> String {
        self.decode_batch_hist
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(b, c)| format!("{b}:{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "steps={} prefill_tok={} decode_tok={} finished={} \
             mean_ttft={:.1}ms mean_tpot={:.1}ms throughput={:.0} tok/s \
             attention={:.1}% of step time workers={}",
            self.steps,
            self.prefill_tokens,
            self.decode_tokens,
            self.requests_finished,
            self.mean_ttft_s() * 1e3,
            self.mean_tpot_s() * 1e3,
            self.tokens_per_s(),
            if self.step_s > 0.0 { 100.0 * self.attention_s / self.step_s } else { 0.0 },
            // Effective fan-out width (--workers / QUOKA_WORKERS / auto):
            // the GEMM and attention pools both ride it.
            crate::util::threadpool::default_workers(),
        );
        if self.requests_cancelled > 0 || self.requests_rejected > 0 {
            s.push_str(&format!(
                " cancelled={} rejected={}",
                self.requests_cancelled, self.requests_rejected
            ));
        }
        if self.decode_tokens > 0 {
            match self.decode_tokens_per_s() {
                Some(v) => s.push_str(&format!(" decode_tok/s={v:.0}")),
                None => s.push_str(" decode_tok/s=n/a"),
            }
            if !self.decode_batch_hist.is_empty() {
                s.push_str(&format!(
                    " decode_batch_hist=[{}]",
                    self.decode_batch_hist_compact()
                ));
            }
        }
        if self.spec_steps > 0 {
            let spec_rate = match self.spec_tokens_per_s() {
                Some(v) => format!("{v:.0}"),
                None => "n/a".to_string(),
            };
            s.push_str(&format!(
                " spec_steps={} spec_accept_rate={:.1}% spec_drafted={} spec_accepted={} \
                 spec_tok/s={spec_rate}",
                self.spec_steps,
                100.0 * self.spec_acceptance(),
                self.spec_drafted_tokens,
                self.spec_accepted_tokens,
            ));
        }
        if self.peak_kv_bytes > 0 || self.pool_resident_bytes > 0 {
            // Byte figures come from the cache's actual element width
            // (`KvDtype::bytes`), so an int8 pool reports its true ~4x
            // savings here rather than fp32-assumed sizes.
            s.push_str(&format!(
                " kv_bytes_resident={} kv_bytes_peak={}",
                self.pool_resident_bytes, self.peak_kv_bytes,
            ));
        }
        if self.prefix_lookups > 0 {
            s.push_str(&format!(
                " prefix_hit_rate={:.1}% prefix_tok_reused={} kv_bytes_saved={}",
                100.0 * self.prefix_hit_rate(),
                self.prefix_hit_tokens,
                self.prefix_bytes_saved,
            ));
        }
        if self.spilled_pages > 0 || self.promotions > 0 {
            s.push_str(&format!(
                " spilled_pages={} spill_bytes={} promotions={}",
                self.spilled_pages, self.spill_bytes, self.promotions,
            ));
            if let Some((p50, p90, p99)) = self.promote_wait_hist.p50_p90_p99_ms() {
                s.push_str(&format!(
                    " promote_wait_p50/p90/p99={p50:.1}/{p90:.1}/{p99:.1}ms"
                ));
            }
        }
        if self.inflight_followers > 0 || self.inflight_published_pages > 0 {
            s.push_str(&format!(
                " inflight_followers={} inflight_adopted_tok={} inflight_published_pages={}",
                self.inflight_followers,
                self.inflight_adopted_tokens,
                self.inflight_published_pages,
            ));
        }
        if let Some((p50, p90, p99)) = self.ttft_hist.p50_p90_p99_ms() {
            s.push_str(&format!(" ttft_p50/p90/p99={p50:.1}/{p90:.1}/{p99:.1}ms"));
        }
        if let Some((p50, p90, p99)) = self.itl_hist.p50_p90_p99_ms() {
            s.push_str(&format!(" itl_p50/p90/p99={p50:.2}/{p90:.2}/{p99:.2}ms"));
        }
        if let Some((p50, p90, p99)) = self.queue_wait_hist.p50_p90_p99_ms() {
            s.push_str(&format!(" queue_p50/p90/p99={p50:.1}/{p90:.1}/{p99:.1}ms"));
        }
        let phase_total: u64 = self.phase_ns.iter().sum();
        if phase_total > 0 {
            s.push_str(" phase[");
            for (i, (name, ns)) in PHASE_NAMES.iter().zip(self.phase_ns.iter()).enumerate() {
                if i > 0 {
                    s.push(' ');
                }
                s.push_str(&format!(
                    "{name}={:.1}%",
                    100.0 * *ns as f64 / phase_total as f64
                ));
            }
            s.push(']');
        }
        s
    }

    /// Machine-readable snapshot: every counter, derived rate, latency
    /// histogram, and the phase breakdown, as one JSON object. The shape
    /// is the `stats` wire command's response body.
    pub fn snapshot_json(&self) -> Json {
        fn hist(h: &LatencyHist) -> Json {
            fn q(h: &LatencyHist, q: f64) -> Json {
                h.quantile_ms(q).map(Json::num).unwrap_or(Json::Null)
            }
            Json::obj(vec![
                ("count", Json::num(h.count() as f64)),
                (
                    "mean_ms",
                    h.mean_us().map(|v| Json::num(v / 1e3)).unwrap_or(Json::Null),
                ),
                ("p50_ms", q(h, 0.50)),
                ("p90_ms", q(h, 0.90)),
                ("p99_ms", q(h, 0.99)),
                (
                    "max_ms",
                    h.max_us()
                        .map(|v| Json::num(v as f64 / 1e3))
                        .unwrap_or(Json::Null),
                ),
            ])
        }
        let phases = Json::obj(
            PHASE_NAMES
                .iter()
                .zip(self.phase_ns.iter())
                .map(|(name, ns)| (*name, Json::num(*ns as f64 / 1e3)))
                .collect(),
        );
        Json::obj(vec![
            ("steps", Json::num(self.steps as f64)),
            ("prefill_tokens", Json::num(self.prefill_tokens as f64)),
            ("decode_tokens", Json::num(self.decode_tokens as f64)),
            ("requests_finished", Json::num(self.requests_finished as f64)),
            ("requests_cancelled", Json::num(self.requests_cancelled as f64)),
            ("requests_rejected", Json::num(self.requests_rejected as f64)),
            ("step_s", Json::num(self.step_s)),
            ("attention_s", Json::num(self.attention_s)),
            ("decode_s", Json::num(self.decode_s)),
            ("tokens_per_s", Json::num(self.tokens_per_s())),
            (
                "decode_tokens_per_s",
                self.decode_tokens_per_s().map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "spec_tokens_per_s",
                self.spec_tokens_per_s().map(Json::num).unwrap_or(Json::Null),
            ),
            ("mean_ttft_ms", Json::num(self.mean_ttft_s() * 1e3)),
            ("mean_tpot_ms", Json::num(self.mean_tpot_s() * 1e3)),
            ("kv_bytes_resident", Json::num(self.pool_resident_bytes as f64)),
            ("kv_bytes_peak", Json::num(self.peak_kv_bytes as f64)),
            ("spilled_pages", Json::num(self.spilled_pages as f64)),
            ("spill_bytes", Json::num(self.spill_bytes as f64)),
            ("promotions", Json::num(self.promotions as f64)),
            ("prefix_lookups", Json::num(self.prefix_lookups as f64)),
            ("prefix_hits", Json::num(self.prefix_hits as f64)),
            ("prefix_hit_tokens", Json::num(self.prefix_hit_tokens as f64)),
            ("prefix_hit_rate", Json::num(self.prefix_hit_rate())),
            ("prefix_bytes_saved", Json::num(self.prefix_bytes_saved as f64)),
            (
                "inflight_followers",
                Json::num(self.inflight_followers as f64),
            ),
            (
                "inflight_adopted_tokens",
                Json::num(self.inflight_adopted_tokens as f64),
            ),
            (
                "inflight_published_pages",
                Json::num(self.inflight_published_pages as f64),
            ),
            ("spec_steps", Json::num(self.spec_steps as f64)),
            (
                "spec_drafted_tokens",
                Json::num(self.spec_drafted_tokens as f64),
            ),
            (
                "spec_accepted_tokens",
                Json::num(self.spec_accepted_tokens as f64),
            ),
            ("spec_acceptance", Json::num(self.spec_acceptance())),
            ("ttft", hist(&self.ttft_hist)),
            ("itl", hist(&self.itl_hist)),
            ("queue_wait", hist(&self.queue_wait_hist)),
            ("chunk", hist(&self.chunk_hist)),
            ("verify", hist(&self.verify_hist)),
            ("promote_wait", hist(&self.promote_wait_hist)),
            ("phase_us", phases),
        ])
    }

    /// Prometheus text-exposition rendering of the snapshot: counters
    /// and gauges under a `quoka_` prefix, histograms as
    /// `quantile`-labelled summary series plus `_count`/`_sum`.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP quoka_{name} {help}\n# TYPE quoka_{name} counter\nquoka_{name} {v}\n"
            ));
        };
        counter("steps_total", "Engine steps executed.", self.steps as f64);
        counter(
            "prefill_tokens_total",
            "Prompt tokens prefilled.",
            self.prefill_tokens as f64,
        );
        counter(
            "decode_tokens_total",
            "Tokens generated.",
            self.decode_tokens as f64,
        );
        counter(
            "requests_finished_total",
            "Requests finished.",
            self.requests_finished as f64,
        );
        counter(
            "requests_cancelled_total",
            "Requests cancelled by the client.",
            self.requests_cancelled as f64,
        );
        counter(
            "requests_rejected_total",
            "Requests rejected at admission.",
            self.requests_rejected as f64,
        );
        counter(
            "prefix_hit_tokens_total",
            "Prompt tokens served from the prefix cache.",
            self.prefix_hit_tokens as f64,
        );
        counter(
            "spec_accepted_tokens_total",
            "Draft tokens accepted by verification.",
            self.spec_accepted_tokens as f64,
        );
        counter(
            "spilled_pages_total",
            "KV pages demoted to the spill tier.",
            self.spilled_pages as f64,
        );
        counter(
            "promotions_total",
            "KV pages promoted back from the spill tier.",
            self.promotions as f64,
        );
        let mut gauge = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP quoka_{name} {help}\n# TYPE quoka_{name} gauge\nquoka_{name} {v}\n"
            ));
        };
        gauge(
            "kv_bytes_resident",
            "Current pool residency, bytes.",
            self.pool_resident_bytes as f64,
        );
        gauge(
            "kv_bytes_peak",
            "Peak pool residency, bytes.",
            self.peak_kv_bytes as f64,
        );
        gauge(
            "spill_bytes",
            "Current spill-tier payload, bytes.",
            self.spill_bytes as f64,
        );
        gauge(
            "tokens_per_s",
            "Total token throughput.",
            self.tokens_per_s(),
        );
        for (name, help, ns) in PHASE_NAMES
            .iter()
            .zip(self.phase_ns.iter())
            .map(|(n, ns)| (*n, "Forward wall time in this phase, seconds.", *ns))
        {
            out.push_str(&format!(
                "# HELP quoka_phase_seconds {help}\n# TYPE quoka_phase_seconds gauge\n\
                 quoka_phase_seconds{{phase=\"{name}\"}} {}\n",
                ns as f64 / 1e9
            ));
        }
        for (name, h) in [
            ("ttft", &self.ttft_hist),
            ("itl", &self.itl_hist),
            ("queue_wait", &self.queue_wait_hist),
            ("chunk", &self.chunk_hist),
            ("verify", &self.verify_hist),
            ("promote_wait", &self.promote_wait_hist),
        ] {
            out.push_str(&format!(
                "# HELP quoka_{name}_seconds Latency summary.\n# TYPE quoka_{name}_seconds summary\n"
            ));
            for q in [0.5, 0.9, 0.99] {
                if let Some(v) = h.quantile_us(q) {
                    out.push_str(&format!(
                        "quoka_{name}_seconds{{quantile=\"{q}\"}} {}\n",
                        v as f64 / 1e6
                    ));
                }
            }
            out.push_str(&format!("quoka_{name}_seconds_count {}\n", h.count()));
            if let Some(mean) = h.mean_us() {
                out.push_str(&format!(
                    "quoka_{name}_seconds_sum {}\n",
                    mean * h.count() as f64 / 1e6
                ));
            } else {
                out.push_str(&format!("quoka_{name}_seconds_sum 0\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.record_step(Duration::from_millis(100), 128, 2, Some(Duration::from_millis(10)));
        m.record_step(Duration::from_millis(100), 0, 4, Some(Duration::from_millis(10)));
        m.record_finish(0.5, 0.01, true);
        m.record_finish(0.3, 0.0, false);
        assert_eq!(m.prefill_tokens, 128);
        assert_eq!(m.decode_tokens, 6);
        assert!((m.mean_ttft_s() - 0.4).abs() < 1e-9);
        assert!((m.mean_tpot_s() - 0.01).abs() < 1e-9);
        assert!((m.tokens_per_s() - 670.0).abs() < 1.0);
        assert!(m.summary().contains("finished=2"));
        // No KV residency recorded ⇒ no residency section; once recorded,
        // both the live and peak figures appear.
        assert!(!m.summary().contains("kv_bytes_resident"), "{}", m.summary());
        m.pool_resident_bytes = 4096;
        m.peak_kv_bytes = 8192;
        let s = m.summary();
        assert!(s.contains("kv_bytes_resident=4096"), "{s}");
        assert!(s.contains("kv_bytes_peak=8192"), "{s}");
    }

    #[test]
    fn decode_batch_histogram_and_throughput() {
        let mut m = Metrics::default();
        m.record_step(Duration::from_millis(20), 64, 0, None);
        m.record_step(Duration::from_millis(20), 0, 1, Some(Duration::from_millis(5)));
        m.record_step(Duration::from_millis(20), 0, 8, Some(Duration::from_millis(15)));
        m.record_step(Duration::from_millis(20), 16, 8, Some(Duration::from_millis(15)));
        assert_eq!(m.decode_tokens, 17);
        assert_eq!(m.decode_batch_hist[1], 1);
        assert_eq!(m.decode_batch_hist[8], 2);
        assert_eq!(m.decode_batch_hist_compact(), "1:1 8:2");
        assert!((m.decode_s - 0.035).abs() < 1e-9);
        assert!((m.decode_tokens_per_s().unwrap() - 17.0 / 0.035).abs() < 1e-6);
        let s = m.summary();
        assert!(s.contains("decode_tok/s="), "{s}");
        assert!(s.contains("decode_batch_hist=[1:1 8:2]"), "{s}");

        // A serial decode fallback (PJRT) still counts tokens but must not
        // claim a fused batch in the histogram, a throughput over a zero
        // decode span, or a batch section in the summary.
        let mut p = Metrics::default();
        p.record_step(Duration::from_millis(20), 0, 8, None);
        assert_eq!(p.decode_tokens, 8);
        assert!(p.decode_batch_hist.is_empty());
        assert_eq!(p.decode_tokens_per_s(), None, "zero decode_s is not a rate");
        assert!(p.summary().contains("decode_tok/s=n/a"), "{}", p.summary());
        assert!(!p.summary().contains("decode_batch_hist"), "{}", p.summary());
    }

    #[test]
    fn verify_steps_feed_spec_and_decode_counters() {
        let mut m = Metrics::default();
        // gamma 4: three drafted, two accepted, three emitted (2 + the
        // correction token).
        m.record_verify(Duration::from_millis(10), 3, 2, 3);
        // A fully accepted gamma-2 step.
        m.record_verify(Duration::from_millis(5), 2, 2, 3);
        assert_eq!(m.spec_steps, 2);
        assert_eq!(m.spec_drafted_tokens, 5);
        assert_eq!(m.spec_accepted_tokens, 4);
        assert_eq!(m.spec_emitted_tokens, 6);
        assert_eq!(m.decode_tokens, 6, "verify emissions are decode tokens");
        assert!((m.spec_acceptance() - 4.0 / 5.0).abs() < 1e-12);
        assert!((m.spec_s - 0.015).abs() < 1e-12);
        assert!((m.decode_s - 0.015).abs() < 1e-12, "verify time is decode time");
        assert!((m.spec_tokens_per_s().unwrap() - 6.0 / 0.015).abs() < 1e-6);
        let s = m.summary();
        assert!(s.contains("spec_accept_rate=80.0%"), "{s}");
        assert!(s.contains("spec_drafted=5"), "{s}");
        // No speculation ⇒ no spec section.
        let q = Metrics::default();
        assert!(!q.summary().contains("spec_"), "{}", q.summary());
    }

    #[test]
    fn zero_spec_span_reports_no_rate() {
        let mut m = Metrics::default();
        assert_eq!(m.spec_tokens_per_s(), None);
        // A verify step with a (degenerate) zero duration still has no
        // spec wall time: the summary must print n/a, not inf/NaN.
        m.record_verify(Duration::ZERO, 3, 2, 3);
        assert_eq!(m.spec_tokens_per_s(), None);
        assert!(m.summary().contains("spec_tok/s=n/a"), "{}", m.summary());
    }

    #[test]
    fn kv_peak_tracks_mid_step_growth() {
        let mut m = Metrics::default();
        m.note_kv_resident(10_000);
        m.note_kv_resident(50_000); // transient peak mid-step
        m.note_kv_resident(20_000); // released before the step ended
        assert_eq!(m.pool_resident_bytes, 20_000);
        assert_eq!(m.peak_kv_bytes, 50_000, "mid-step peak must not be lost");
    }

    #[test]
    fn summary_reports_latency_quantiles_and_phases() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.ttft_hist.record_us(i * 1000); // 1..100 ms
            m.itl_hist.record_us(i * 100); // 0.1..10 ms
        }
        m.queue_wait_hist.record_us(2_000);
        m.add_phase_ns([100, 200, 300, 400]);
        m.add_phase_ns([0, 100, 0, 0]);
        assert_eq!(m.phase_ns, [100, 300, 300, 400]);
        let s = m.summary();
        assert!(s.contains("ttft_p50/p90/p99="), "{s}");
        assert!(s.contains("itl_p50/p90/p99="), "{s}");
        assert!(s.contains("queue_p50/p90/p99="), "{s}");
        assert!(s.contains("phase[scan="), "{s}");
        assert!(s.contains("gemm="), "{s}");
        // Empty metrics stay clean: no quantile or phase sections.
        let q = Metrics::default();
        assert!(!q.summary().contains("ttft_p50"), "{}", q.summary());
        assert!(!q.summary().contains("phase["), "{}", q.summary());
    }

    #[test]
    fn snapshot_json_and_prometheus_render() {
        let mut m = Metrics::default();
        m.record_step(Duration::from_millis(100), 128, 2, Some(Duration::from_millis(10)));
        m.record_finish(0.05, 0.01, true);
        m.ttft_hist.record_secs(0.05);
        m.itl_hist.record_secs(0.01);
        m.note_kv_resident(4096);
        m.add_phase_ns([1_000_000, 2_000_000, 500_000, 3_000_000]);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("steps").and_then(Json::as_f64), Some(1.0));
        assert_eq!(snap.get("prefill_tokens").and_then(Json::as_f64), Some(128.0));
        let ttft = snap.get("ttft").expect("ttft histogram");
        assert_eq!(ttft.get("count").and_then(Json::as_f64), Some(1.0));
        assert!(ttft.get("p50_ms").and_then(Json::as_f64).is_some());
        let phases = snap.get("phase_us").expect("phase table");
        assert_eq!(phases.get("attn").and_then(Json::as_f64), Some(2000.0));
        // The snapshot round-trips through the JSON parser.
        let parsed = Json::parse(&snap.to_string()).expect("valid JSON");
        assert_eq!(parsed.get("kv_bytes_peak").and_then(Json::as_f64), Some(4096.0));
        // Null rates stay null, not 0.
        let empty = Metrics::default().snapshot_json();
        assert_eq!(empty.get("spec_tokens_per_s"), Some(&Json::Null));

        let prom = m.prometheus_text();
        assert!(prom.contains("# TYPE quoka_steps_total counter"), "{prom}");
        assert!(prom.contains("quoka_prefill_tokens_total 128"), "{prom}");
        assert!(prom.contains("quoka_ttft_seconds{quantile=\"0.5\"}"), "{prom}");
        assert!(prom.contains("quoka_phase_seconds{phase=\"attn\"} 0.002"), "{prom}");
        assert!(prom.contains("quoka_ttft_seconds_count 1"), "{prom}");
    }

    #[test]
    fn inflight_adoption_counts_toward_prefix_totals() {
        let mut m = Metrics::default();
        m.record_prefix_lookup(200);
        // Nothing cached at submit; the request parks and later adopts 128
        // tokens while the producer is still prefilling.
        m.inflight_followers += 1;
        m.record_inflight_adopt(96, 960, true);
        m.record_inflight_adopt(32, 320, false);
        assert_eq!(m.prefix_hits, 1, "one request, one hit");
        assert_eq!(m.prefix_hit_tokens, 128);
        assert_eq!(m.inflight_adopted_tokens, 128);
        assert_eq!(m.prefix_bytes_saved, 1280);
        assert!((m.prefix_hit_rate() - 128.0 / 200.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("inflight_followers=1"), "{s}");
        assert!(s.contains("inflight_adopted_tok=128"), "{s}");
        // No in-flight activity ⇒ no in-flight section in the summary.
        let q = Metrics::default();
        assert!(!q.summary().contains("inflight"), "{}", q.summary());
    }
}
