//! Blocked, packed, pool-parallel matrix multiplication.
//!
//! Three entry points cover the engine's needs:
//! - [`matmul`]: `C[m,n] = A[m,k] · B[k,n]` — projection layers with an
//!   ad-hoc `B` (packs into thread-local scratch on the fly).
//! - [`matmul_packed`]: the same product against a [`PackedB`] prepared
//!   once (per-layer weights are packed at model load, so the pack cost
//!   never rides the hot path).
//! - [`matmul_bt`]: `C[m,n] = A[m,k] · Bᵀ` with `B[n,k]` — the `QKᵀ` score
//!   shape, where both operands are row-major token matrices.
//!
//! ## The packed GEMM
//!
//! `B` is repacked into tile-major *panels* of [`NR`] = 16 columns
//! (`panel[kk * NR + j] = B[kk, p*NR + j]`, zero-padded tail), so the
//! micro-kernel streams one contiguous 64-byte line per `k` step instead
//! of striding across `B` rows. The AVX2 micro-kernel holds a 4-row ×
//! 16-column block of `C` in eight YMM accumulators and walks `k` once;
//! the scalar fallback replicates the identical lane structure.
//!
//! ## Determinism under parallelism
//!
//! Every output element is one strict left-fold over `k` in increasing
//! order — plain mul-then-add, one accumulator chain, no FMA (the PR-6
//! convention: AVX2 per-lane ops match the scalar two-rounding sequence
//! exactly). Parallelism only ever splits the *output* — row blocks for
//! prefill-shaped `m`, column panels for decode-shaped `m` — and never
//! splits `k`, so the packed kernel is bit-identical to its serial run at
//! every worker count, and each row's result is independent of the batch
//! it rides in (what keeps batched-vs-serial decode exact).

use super::ops::dot;
use crate::util::threadpool::{default_workers, parallel_for, SyncPtr};
use std::cell::RefCell;

/// Panel width of the packed layout: 16 columns = two AVX2 registers.
pub const NR: usize = 16;
/// Micro-kernel row block: 4 rows × 2 vectors = 8 YMM accumulators.
const MR: usize = 4;
/// Rows per parallel row-block work item.
const ROW_BLOCK: usize = 8;
/// Below this many MACs (`m*k*n`) the fork-join wake is not worth it.
const PAR_MIN_WORK: usize = 1 << 18;

/// `B[k,n]` repacked into tile-major panels of [`NR`] columns.
///
/// Layout: panel `p` occupies `data[p*k*NR .. (p+1)*k*NR]` with
/// `data[p*k*NR + kk*NR + j] = B[kk, p*NR + j]` (zero where the final
/// panel overhangs `n`).
#[derive(Clone, Debug)]
pub struct PackedB {
    k: usize,
    n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack a row-major `B[k,n]`.
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        let mut data = Vec::new();
        pack_into(b, k, n, &mut data);
        PackedB { k, n, data }
    }

    /// Reconstruct the row-major `B[k,n]` this packing came from.
    pub fn unpack(&self) -> Vec<f32> {
        let (k, n) = (self.k, self.n);
        let mut b = vec![0.0f32; k * n];
        for p in 0..panels(n) {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            let panel = &self.data[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                b[kk * n + j0..kk * n + j0 + w]
                    .copy_from_slice(&panel[kk * NR..kk * NR + w]);
            }
        }
        b
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Resident bytes of the packed payload.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[inline]
fn panels(n: usize) -> usize {
    n.div_ceil(NR)
}

/// Pack `B[k,n]` into `out` (reusing its capacity; zero tail padding).
fn pack_into(b: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(b.len(), k * n);
    let np = panels(n);
    out.resize(np * k * NR, 0.0);
    for p in 0..np {
        let j0 = p * NR;
        let w = NR.min(n - j0);
        let panel = &mut out[p * k * NR..(p + 1) * k * NR];
        for kk in 0..k {
            panel[kk * NR..kk * NR + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
            if w < NR {
                panel[kk * NR + w..(kk + 1) * NR].iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }
}

thread_local! {
    /// Per-thread pack scratch for [`matmul`]'s ad-hoc `B` operands
    /// (engine workers reuse it; zero steady-state allocation).
    static PACK_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// `C[m,n] = A[m,k] · B[k,n]`, overwriting `c`. Packs `B` into
/// thread-local scratch, then runs the packed kernel on the shared pool.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    PACK_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        pack_into(b, k, n, &mut buf);
        gemm(a, &buf, m, k, n, c, default_workers());
    });
}

/// `C[m,n] = A[m,k] · B` for a pre-packed `B`, overwriting `c`, on the
/// shared pool ([`default_workers`] participants).
pub fn matmul_packed(a: &[f32], b: &PackedB, m: usize, c: &mut [f32]) {
    matmul_packed_with(a, b, m, c, default_workers());
}

/// [`matmul_packed`] with an explicit participant count — bit-identical
/// to `threads == 1` at every count (benches and the exactness property
/// test sweep this).
pub fn matmul_packed_with(a: &[f32], b: &PackedB, m: usize, c: &mut [f32], threads: usize) {
    debug_assert_eq!(a.len(), m * b.k);
    gemm(a, &b.data, m, b.k, b.n, c, threads);
}

/// Driver: split the output across participants (never `k`).
fn gemm(a: &[f32], packed: &[f32], m: usize, k: usize, n: usize, c: &mut [f32], threads: usize) {
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let np = panels(n);
    let c_ptr = SyncPtr::new(c.as_mut_ptr());
    let c_ref = &c_ptr;
    // Captures the operand *slices* (Sync) and the output via `SyncPtr`,
    // so the closure can cross to pool workers.
    let run_rows = |i0: usize, i1: usize| {
        for p in 0..np {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            // SAFETY: pointers stay in-bounds (checked dims above); row
            // ranges/panels are disjoint across work items.
            unsafe {
                let panel = packed.as_ptr().add(p * k * NR);
                panel_rows(a.as_ptr(), panel, k, n, w, j0, i0, i1, c_ref.get());
            }
        }
    };
    if threads <= 1 || m * k * n < PAR_MIN_WORK {
        run_rows(0, m);
    } else if m >= threads * 2 * ROW_BLOCK {
        // Prefill-shaped m: parallelize over output row blocks.
        let blocks = m.div_ceil(ROW_BLOCK);
        parallel_for(blocks, threads, |ib| {
            let i0 = ib * ROW_BLOCK;
            run_rows(i0, (i0 + ROW_BLOCK).min(m));
        });
    } else {
        // Decode-shaped m (few rows, wide n): parallelize over column
        // panels — still disjoint C writes, still the same per-element
        // k-order fold.
        parallel_for(np, threads, |p| {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            // SAFETY: as above; each p owns its column strip of C.
            unsafe {
                let panel = packed.as_ptr().add(p * k * NR);
                panel_rows(a.as_ptr(), panel, k, n, w, j0, 0, m, c_ref.get());
            }
        });
    }
}

/// Compute `C` rows `[i0, i1)` of one packed panel (columns
/// `[j0, j0+w)`), dispatching to AVX2 when available.
///
/// # Safety
/// `a` must cover `[i1*k]` floats, `panel` `[k*NR]`, `c` `[i1*n]`; the
/// `[i0, i1) × [j0, j0+w)` region of `c` must be exclusive to this call.
unsafe fn panel_rows(
    a: *const f32,
    panel: *const f32,
    k: usize,
    n: usize,
    w: usize,
    j0: usize,
    i0: usize,
    i1: usize,
    c: *mut f32,
) {
    #[cfg(target_arch = "x86_64")]
    if super::ops::avx2() {
        return x86::panel_rows(a, panel, k, n, w, j0, i0, i1, c);
    }
    panel_rows_scalar(a, panel, k, n, w, j0, i0, i1, c)
}

/// Portable micro-kernel: per output element one `acc += a*b` chain over
/// `k` in order — the reference lane structure the AVX2 path reproduces.
#[allow(clippy::too_many_arguments)]
unsafe fn panel_rows_scalar(
    a: *const f32,
    panel: *const f32,
    k: usize,
    n: usize,
    w: usize,
    j0: usize,
    i0: usize,
    i1: usize,
    c: *mut f32,
) {
    for i in i0..i1 {
        let arow = a.add(i * k);
        let mut acc = [0.0f32; NR];
        for kk in 0..k {
            let av = *arow.add(kk);
            let prow = panel.add(kk * NR);
            for (j, slot) in acc.iter_mut().enumerate() {
                *slot += av * *prow.add(j);
            }
        }
        let crow = c.add(i * n + j0);
        for (j, &v) in acc.iter().take(w).enumerate() {
            *crow.add(j) = v;
        }
    }
}

/// AVX2 micro-kernels. Per-lane identical to [`panel_rows_scalar`]: one
/// accumulator per output element, `add(acc, mul(broadcast(a), b))` per
/// `k` step — no FMA, so the two-rounding scalar result is reproduced
/// bit-exactly (the PR-6 convention).
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn panel_rows(
        a: *const f32,
        panel: *const f32,
        k: usize,
        n: usize,
        w: usize,
        j0: usize,
        i0: usize,
        i1: usize,
        c: *mut f32,
    ) {
        let mut i = i0;
        while i + MR <= i1 {
            block::<MR>(a, panel, k, n, w, j0, i, c);
            i += MR;
        }
        while i < i1 {
            block::<1>(a, panel, k, n, w, j0, i, c);
            i += 1;
        }
    }

    /// `R` rows × one 16-wide panel, `2R` YMM accumulators. Always
    /// inlined into the `target_feature` caller (a generic fn cannot
    /// carry the attribute itself on older toolchains).
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    unsafe fn block<const R: usize>(
        a: *const f32,
        panel: *const f32,
        k: usize,
        n: usize,
        w: usize,
        j0: usize,
        i: usize,
        c: *mut f32,
    ) {
        let mut lo = [_mm256_setzero_ps(); R];
        let mut hi = [_mm256_setzero_ps(); R];
        for kk in 0..k {
            let prow = panel.add(kk * NR);
            let b0 = _mm256_loadu_ps(prow);
            let b1 = _mm256_loadu_ps(prow.add(8));
            for r in 0..R {
                let av = _mm256_set1_ps(*a.add((i + r) * k + kk));
                lo[r] = _mm256_add_ps(lo[r], _mm256_mul_ps(av, b0));
                hi[r] = _mm256_add_ps(hi[r], _mm256_mul_ps(av, b1));
            }
        }
        for r in 0..R {
            let crow = c.add((i + r) * n + j0);
            if w == NR {
                _mm256_storeu_ps(crow, lo[r]);
                _mm256_storeu_ps(crow.add(8), hi[r]);
            } else {
                let mut tmp = [0f32; NR];
                _mm256_storeu_ps(tmp.as_mut_ptr(), lo[r]);
                _mm256_storeu_ps(tmp.as_mut_ptr().add(8), hi[r]);
                for (j, &v) in tmp.iter().take(w).enumerate() {
                    *crow.add(j) = v;
                }
            }
        }
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (each output is a row-row dot product).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Threaded [`matmul_bt`] splitting output rows across `threads`.
pub fn par_matmul_bt(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    threads: usize,
) {
    if threads <= 1 || m < 4 {
        return matmul_bt(a, b, m, k, n, c);
    }
    debug_assert_eq!(c.len(), m * n);
    // Rows are disjoint; hand each worker an independent &mut row via raw
    // pointer arithmetic wrapped in a Sync cell.
    let c_ptr = SyncPtr::new(c.as_mut_ptr());
    let c_ref = &c_ptr; // capture the Sync wrapper, not the raw pointer field
    parallel_for(m, threads, |i| {
        let arow = &a[i * k..(i + 1) * k];
        // SAFETY: each i writes exclusively to its own row slice.
        let crow = unsafe { std::slice::from_raw_parts_mut(c_ref.get().add(i * n), n) };
        for j in 0..n {
            crow[j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    });
}

/// Fused `argmax_j (A · Bᵀ)[i, j]` per row: for each of the `m` rows of
/// `A[m,k]`, the index of the largest dot product against the `n` rows of
/// `B[n,k]` — the greedy-decoding logits reduction without ever
/// materializing the `[m, n]` logits. Each dot is computed exactly as
/// [`matmul_bt`] computes it and ties break to the lower index, so the
/// result is bit-identical to `topk_indices(&matmul_bt_row, 1)[0]`.
/// Rows are split across the shared pool when the reduction is large
/// enough to amortize the fan-out wake (a lower bar than the old per-call
/// thread spawn — the persistent pool makes smaller logits heads worth
/// parallelizing).
pub fn matmul_bt_argmax(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [u32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m);
    debug_assert!(n > 0 && n <= u32::MAX as usize);
    let row_argmax = |arow: &[f32]| -> u32 {
        let mut best = f32::NEG_INFINITY;
        let mut best_j = 0u32;
        for j in 0..n {
            let v = dot(arow, &b[j * k..(j + 1) * k]);
            if v > best {
                best = v;
                best_j = j as u32;
            }
        }
        best_j
    };
    let threads = default_workers().min(m);
    if threads <= 1 || m * n * k < PAR_MIN_WORK {
        for (i, o) in out.iter_mut().enumerate() {
            *o = row_argmax(&a[i * k..(i + 1) * k]);
        }
        return;
    }
    let o_ptr = SyncPtr::new(out.as_mut_ptr());
    let o_ref = &o_ptr;
    parallel_for(m, threads, |i| {
        // SAFETY: each i writes exclusively to its own output slot.
        unsafe { *o_ref.get().add(i) = row_argmax(&a[i * k..(i + 1) * k]) };
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (7, 300, 9), (16, 64, 16)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut c = vec![1.0; m * n]; // nonzero: matmul must overwrite it
            matmul(&a, &b, m, k, n, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn packed_path_matches_adhoc_matmul_bitwise() {
        // Pre-packed weights and the pack-on-the-fly path must agree to
        // the bit (the transformer mixes both).
        let mut rng = Rng::new(14);
        for &(m, k, n) in &[(1usize, 7usize, 3usize), (5, 33, 16), (8, 64, 100), (64, 48, 31)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut c1 = vec![0.0; m * n];
            matmul(&a, &b, m, k, n, &mut c1);
            let packed = PackedB::pack(&b, k, n);
            let mut c2 = vec![0.0; m * n];
            matmul_packed(&a, &packed, m, &mut c2);
            assert_eq!(c1, c2, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_bt_matches_transposed_naive() {
        let mut rng = Rng::new(12);
        let (m, k, n) = (5usize, 33usize, 8usize);
        let a = rng.normal_vec(m * k, 1.0);
        let bt = rng.normal_vec(n * k, 1.0); // B stored as [n, k]
        // Build B as [k, n] for the naive reference.
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for kk in 0..k {
                b[kk * n + j] = bt[j * k + kk];
            }
        }
        let want = naive(&a, &b, m, k, n);
        let mut c = vec![0.0; m * n];
        matmul_bt(&a, &bt, m, k, n, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn fused_argmax_matches_materialized_logits() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(1usize, 8usize, 17usize), (3, 16, 64), (8, 32, 300)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(n * k, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_bt(&a, &b, m, k, n, &mut c);
            let mut got = vec![0u32; m];
            matmul_bt_argmax(&a, &b, m, k, n, &mut got);
            for i in 0..m {
                let want = crate::tensor::ops::topk_indices(&c[i * n..(i + 1) * n], 1)[0] as u32;
                assert_eq!(got[i], want, "row {i} of ({m},{k},{n})");
            }
        }
        // Deterministic tie-break: lower index wins.
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 3 * 4]; // all rows identical
        let mut got = vec![9u32; 1];
        matmul_bt_argmax(&a, &b, 1, 4, 3, &mut got);
        assert_eq!(got[0], 0);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (37usize, 64usize, 51usize);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(n * k, 1.0);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        matmul_bt(&a, &b, m, k, n, &mut c1);
        par_matmul_bt(&a, &b, m, k, n, &mut c2, 4);
        assert_eq!(c1, c2);
    }
}
