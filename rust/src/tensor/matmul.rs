//! Blocked matrix multiplication.
//!
//! Two entry points cover the engine's needs:
//! - [`matmul`]: `C[m,n] = A[m,k] · B[k,n]` — projection layers.
//! - [`matmul_bt`]: `C[m,n] = A[m,k] · Bᵀ` with `B[n,k]` — the `QKᵀ` score
//!   shape, where both operands are row-major token matrices.
//!
//! The kernels are cache-blocked and use unrolled inner loops that rustc
//! auto-vectorizes; `par_matmul*` variants split rows across threads for the
//! large dense-baseline attention at 32k context.

use super::ops::dot;
use crate::util::threadpool::parallel_for;

const BLOCK_K: usize = 256;

/// `C[m,n] = A[m,k] · B[k,n]`, accumulating into a zeroed `c`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.iter_mut().for_each(|v| *v = 0.0);
    // i-k-j loop order: unit-stride access on both B and C rows.
    for kb in (0..k).step_by(BLOCK_K) {
        let kend = (kb + BLOCK_K).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (each output is a row-row dot product).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Threaded [`matmul_bt`] splitting output rows across `threads`.
pub fn par_matmul_bt(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    threads: usize,
) {
    if threads <= 1 || m < 4 {
        return matmul_bt(a, b, m, k, n, c);
    }
    debug_assert_eq!(c.len(), m * n);
    // Rows are disjoint; hand each thread an independent &mut row via raw
    // pointer arithmetic wrapped in a Sync cell.
    let c_ptr = SyncPtr(c.as_mut_ptr());
    let c_ref = &c_ptr; // capture the Sync wrapper, not the raw pointer field
    parallel_for(m, threads, |i| {
        let arow = &a[i * k..(i + 1) * k];
        // SAFETY: each i writes exclusively to its own row slice.
        let crow = unsafe { std::slice::from_raw_parts_mut(c_ref.0.add(i * n), n) };
        for j in 0..n {
            crow[j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    });
}

struct SyncPtr<T>(*mut T);
unsafe impl<T> Sync for SyncPtr<T> {}
unsafe impl<T> Send for SyncPtr<T> {}

/// Fused `argmax_j (A · Bᵀ)[i, j]` per row: for each of the `m` rows of
/// `A[m,k]`, the index of the largest dot product against the `n` rows of
/// `B[n,k]` — the greedy-decoding logits reduction without ever
/// materializing the `[m, n]` logits. Each dot is computed exactly as
/// [`matmul_bt`] computes it and ties break to the lower index, so the
/// result is bit-identical to `topk_indices(&matmul_bt_row, 1)[0]`.
/// Rows are split across threads when the reduction is large enough to
/// amortize the fork-join.
pub fn matmul_bt_argmax(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [u32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m);
    debug_assert!(n > 0 && n <= u32::MAX as usize);
    let row_argmax = |arow: &[f32]| -> u32 {
        let mut best = f32::NEG_INFINITY;
        let mut best_j = 0u32;
        for j in 0..n {
            let v = dot(arow, &b[j * k..(j + 1) * k]);
            if v > best {
                best = v;
                best_j = j as u32;
            }
        }
        best_j
    };
    let threads = crate::util::threadpool::default_workers().min(m);
    if threads <= 1 || m * n * k < 1 << 20 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = row_argmax(&a[i * k..(i + 1) * k]);
        }
        return;
    }
    let o_ptr = SyncPtr(out.as_mut_ptr());
    let o_ref = &o_ptr;
    parallel_for(m, threads, |i| {
        // SAFETY: each i writes exclusively to its own output slot.
        unsafe { *o_ref.0.add(i) = row_argmax(&a[i * k..(i + 1) * k]) };
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (7, 300, 9), (16, 64, 16)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut c = vec![1.0; m * n]; // nonzero: matmul must zero it
            matmul(&a, &b, m, k, n, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matmul_bt_matches_transposed_naive() {
        let mut rng = Rng::new(12);
        let (m, k, n) = (5usize, 33usize, 8usize);
        let a = rng.normal_vec(m * k, 1.0);
        let bt = rng.normal_vec(n * k, 1.0); // B stored as [n, k]
        // Build B as [k, n] for the naive reference.
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for kk in 0..k {
                b[kk * n + j] = bt[j * k + kk];
            }
        }
        let want = naive(&a, &b, m, k, n);
        let mut c = vec![0.0; m * n];
        matmul_bt(&a, &bt, m, k, n, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn fused_argmax_matches_materialized_logits() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(1usize, 8usize, 17usize), (3, 16, 64), (8, 32, 300)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(n * k, 1.0);
            let mut c = vec![0.0; m * n];
            matmul_bt(&a, &b, m, k, n, &mut c);
            let mut got = vec![0u32; m];
            matmul_bt_argmax(&a, &b, m, k, n, &mut got);
            for i in 0..m {
                let want = crate::tensor::ops::topk_indices(&c[i * n..(i + 1) * n], 1)[0] as u32;
                assert_eq!(got[i], want, "row {i} of ({m},{k},{n})");
            }
        }
        // Deterministic tie-break: lower index wins.
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 3 * 4]; // all rows identical
        let mut got = vec![9u32; 1];
        matmul_bt_argmax(&a, &b, 1, 4, 3, &mut got);
        assert_eq!(got[0], 0);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (37usize, 64usize, 51usize);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(n * k, 1.0);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        matmul_bt(&a, &b, m, k, n, &mut c1);
        par_matmul_bt(&a, &b, m, k, n, &mut c2, 4);
        assert_eq!(c1, c2);
    }
}
