//! Slice-level numeric kernels used across the engine hot path.
//!
//! All functions operate on raw `&[f32]` so the coordinator can run them on
//! reused scratch buffers with zero allocation in the steady state.

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 8 independent accumulators: strict-FP addition order otherwise
    // blocks autovectorization; 8 lanes map onto one AVX2 register (two
    // on AVX-512) and LLVM unrolls further on its own.
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for i in 0..chunks {
        let j = i * 8;
        let (av, bv) = (&a[j..j + 8], &b[j..j + 8]);
        for l in 0..8 {
            acc[l] += av[l] * bv[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for j in chunks * 8..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize `a` to unit length in place; returns the original norm.
/// Zero vectors are left untouched (norm 0 returned).
#[inline]
pub fn normalize(a: &mut [f32]) -> f32 {
    let n = l2_norm(a);
    if n > 0.0 {
        let inv = 1.0 / n;
        for v in a.iter_mut() {
            *v *= inv;
        }
    }
    n
}

/// Cosine similarity, defined as 0 when either vector is zero.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

/// In-place numerically stable softmax over a row.
pub fn softmax(row: &mut [f32]) {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        // All -inf (fully masked): define as uniform zeros.
        for v in row.iter_mut() {
            *v = 0.0;
        }
        return;
    }
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm: `x * w / sqrt(mean(x^2) + eps)`, written to `out`.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(x.len(), out.len());
    let ms = dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Rotary position embedding applied in place to a head vector of even
/// dimension `d`, rotating pairs `(x[2i], x[2i+1])` by `pos * theta^(-2i/d)`.
pub fn rope(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    debug_assert!(d % 2 == 0);
    let half = d / 2;
    for i in 0..half {
        let freq = theta.powf(-2.0 * i as f32 / d as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = x[2 * i];
        let b = x[2 * i + 1];
        x[2 * i] = a * cos - b * sin;
        x[2 * i + 1] = a * sin + b * cos;
    }
}

/// SiLU (x * sigmoid(x)).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Mean over rows of an `[n, d]` matrix into `out[d]`.
pub fn mean_rows(mat: &[f32], n: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(mat.len(), n * d);
    out.iter_mut().for_each(|v| *v = 0.0);
    for r in 0..n {
        axpy(1.0, &mat[r * d..(r + 1) * d], out);
    }
    let inv = 1.0 / n as f32;
    for v in out.iter_mut() {
        *v *= inv;
    }
}

/// Indices of the `k` largest values (descending by value). Deterministic
/// tie-break: lower index wins. O(n + k log k) via partial selection.
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return vec![];
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    let cmp = |&a: &usize, &b: &usize| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    };
    if k < scores.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx
}

/// `topk_indices` then sorted ascending — the gather-friendly order used by
/// the KV cache (preserves positional order of retained tokens).
pub fn topk_indices_sorted(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx = topk_indices(scores, k);
    idx.sort_unstable();
    idx
}

/// Argsort descending.
pub fn argsort_desc(scores: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Gather rows `idx` of an `[n, d]` matrix into `out[idx.len(), d]`.
pub fn gather_rows(mat: &[f32], d: usize, idx: &[usize], out: &mut [f32]) {
    debug_assert_eq!(out.len(), idx.len() * d);
    for (o, &i) in idx.iter().enumerate() {
        out[o * d..(o + 1) * d].copy_from_slice(&mat[i * d..(i + 1) * d]);
    }
}

/// Relative L2 error ‖a−b‖/max(‖a‖, tiny).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut num = 0f64;
    let mut den = 0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) * (x - y)) as f64;
        den += (x * x) as f64;
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f32::INFINITY };
    }
    (num / den).sqrt() as f32
}

/// Pearson correlation of two samples.
pub fn pearson(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let my = y.iter().map(|&v| v as f64).sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0f64, 0f64, 0f64);
    for (&a, &b) in x.iter().zip(y) {
        let dx = a as f64 - mx;
        let dy = b as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    (sxy / (sxx.sqrt() * syy.sqrt())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut row = vec![1000.0, 1001.0, 999.0];
        softmax(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(row[1] > row[0] && row[0] > row[2]);
    }

    #[test]
    fn softmax_all_masked() {
        let mut row = vec![f32::NEG_INFINITY; 4];
        softmax(&mut row);
        assert!(row.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn normalize_unit_length() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine(&[1.0, 1.0], &[2.0, 2.0]) - 1.0).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn rmsnorm_matches_definition() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [1.0f32; 4];
        let mut out = [0.0f32; 4];
        rmsnorm(&x, &w, 1e-6, &mut out);
        let ms = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        for i in 0..4 {
            assert!((out[i] - x[i] / (ms + 1e-6).sqrt()).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm_and_is_positional() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0];
        let before = l2_norm(&a);
        rope(&mut a, 7, 10000.0);
        assert!((l2_norm(&a) - before).abs() < 1e-4);
        // pos 0 is the identity
        let mut b = vec![1.0, 2.0, 3.0, 4.0];
        rope(&mut b, 0, 10000.0);
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rope_relative_property() {
        // <rope(q,m), rope(k,n)> depends only on m-n for the same vectors.
        let q0 = vec![0.3, -1.2, 0.7, 0.5];
        let k0 = vec![1.0, 0.2, -0.4, 0.9];
        let dots: Vec<f32> = [(3usize, 1usize), (10, 8), (22, 20)]
            .iter()
            .map(|&(m, n)| {
                let mut q = q0.clone();
                let mut k = k0.clone();
                rope(&mut q, m, 10000.0);
                rope(&mut k, n, 10000.0);
                dot(&q, &k)
            })
            .collect();
        assert!((dots[0] - dots[1]).abs() < 1e-4);
        assert!((dots[1] - dots[2]).abs() < 1e-4);
    }

    #[test]
    fn topk_matches_argsort() {
        let scores = vec![0.1, 5.0, -2.0, 5.0, 3.3, 0.0];
        assert_eq!(topk_indices(&scores, 3), argsort_desc(&scores)[..3].to_vec());
        assert_eq!(topk_indices(&scores, 3), vec![1, 3, 4]);
        assert_eq!(topk_indices_sorted(&scores, 3), vec![1, 3, 4]);
        assert_eq!(topk_indices(&scores, 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&scores, 99).len(), 6);
    }

    #[test]
    fn gather_and_mean() {
        let mat = vec![1., 2., 3., 4., 5., 6.];
        let mut out = vec![0.0; 4];
        gather_rows(&mat, 2, &[2, 0], &mut out);
        assert_eq!(out, vec![5., 6., 1., 2.]);
        let mut m = vec![0.0; 2];
        mean_rows(&mat, 3, 2, &mut m);
        assert_eq!(m, vec![3.0, 4.0]);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-6);
        let z = vec![-1.0, -2.0, -3.0, -4.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn rel_l2_zero_and_nonzero() {
        assert_eq!(rel_l2(&[0.0; 3], &[0.0; 3]), 0.0);
        assert!(rel_l2(&[1.0, 0.0], &[0.0, 0.0]) > 0.9);
    }
}
