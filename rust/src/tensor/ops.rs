//! Slice-level numeric kernels used across the engine hot path.
//!
//! All functions operate on raw `&[f32]` so the coordinator can run them on
//! reused scratch buffers with zero allocation in the steady state.
//!
//! ## Register-blocked micro-kernels
//!
//! The attention and key-scan hot paths are built from a small set of
//! blocked primitives rather than repeated scalar [`dot`] calls:
//!
//! - [`dot4`] — one query row against four key rows, eight accumulator
//!   lanes per key so the additions stay association-free and LLVM can map
//!   each accumulator onto one SIMD register. Query loads are amortized
//!   over the four keys (the scalar loop reloads `q` for every key).
//! - [`qk_dots`] — one query against a *contiguous* `[n, d]` key tile
//!   (multi-key GEMV), the unit of work after a selection gather.
//! - [`qk_block`] — an `m×n` QKᵀ block over contiguous query and key
//!   tiles, register-blocked 2 queries × 4 keys ([`dot2x4`]); this is what
//!   the tiled attention kernel and the QUOKA key scan run per tile.
//! - [`av_accum`] — probability-weighted accumulation of a contiguous V
//!   tile into an output row (the streaming half of the online softmax).
//!
//! Keys are gathered into contiguous tiles *before* these kernels run, so
//! every inner loop walks sequential memory — the Double-Sparsity-style
//! layout that unlocks hardware bandwidth on sparse KV subsets.
//!
//! ## Explicit SIMD + int8 KV kernels
//!
//! The f32 micro-kernels ([`dot`], [`dot4`]) and their int8 counterparts
//! ([`qk_dots_q8`], [`qk_block_q8`], [`av_accum_q8`]) carry explicit AVX2
//! paths (`target_feature` intrinsics behind a runtime
//! `is_x86_feature_detected!` check, cached once) with the scalar
//! register-blocked loops as the portable fallback. The AVX2 f32 paths
//! reproduce the scalar lane structure exactly — same 8 independent
//! mul-then-add lanes (no FMA), same horizontal-sum tree — so dispatch
//! never changes results: fp32 numerics are bit-identical with and
//! without AVX2.
//!
//! Int8 KV rows are quantized per row ([`quantize_row_q8`]): symmetric
//! `scale = amax / 127`, codes `round(x / scale)`. The q8 kernels
//! dequantize *in registers* — `q · (c · s) = s · (q · c)` — so the cache
//! streams at 1 byte/element and no fp32 copy of a tile is ever
//! materialized.

/// Runtime AVX2 capability, probed once. Shared with the packed GEMM in
/// [`super::matmul`], which follows the same dispatch convention.
#[inline]
pub(crate) fn avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static HAS: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *HAS.get_or_init(|| std::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// AVX2 implementations. Every function mirrors its scalar sibling's lane
/// structure bit-exactly for f32 inputs: one vector register per scalar
/// 8-lane accumulator block, plain mul-then-add (no FMA — FMA's single
/// rounding would diverge from the scalar two-rounding result), and the
/// identical horizontal-sum tree via [`hsum8`].
#[cfg(target_arch = "x86_64")]
mod x86 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    #[inline]
    unsafe fn store8(v: __m256) -> [f32; 8] {
        let mut out = [0f32; 8];
        _mm256_storeu_ps(out.as_mut_ptr(), v);
        out
    }

    /// Sign-extend 8 i8 codes to an 8-lane f32 vector (exact conversion).
    #[inline]
    unsafe fn load8_i8(p: *const i8) -> __m256 {
        let raw = _mm_loadl_epi64(p as *const __m128i);
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(raw))
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let j = i * 8;
            let av = _mm256_loadu_ps(a.as_ptr().add(j));
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let mut s = super::hsum8(store8(acc));
        for j in chunks * 8..n {
            s += a[j] * b[j];
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4(q: &[f32], k0: &[f32], k1: &[f32], k2: &[f32], k3: &[f32]) -> [f32; 4] {
        let n = q.len();
        let chunks = n / 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        for c in 0..chunks {
            let j = c * 8;
            let qv = _mm256_loadu_ps(q.as_ptr().add(j));
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(qv, _mm256_loadu_ps(k0.as_ptr().add(j))));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(qv, _mm256_loadu_ps(k1.as_ptr().add(j))));
            a2 = _mm256_add_ps(a2, _mm256_mul_ps(qv, _mm256_loadu_ps(k2.as_ptr().add(j))));
            a3 = _mm256_add_ps(a3, _mm256_mul_ps(qv, _mm256_loadu_ps(k3.as_ptr().add(j))));
        }
        let mut out = [
            super::hsum8(store8(a0)),
            super::hsum8(store8(a1)),
            super::hsum8(store8(a2)),
            super::hsum8(store8(a3)),
        ];
        for j in chunks * 8..n {
            out[0] += q[j] * k0[j];
            out[1] += q[j] * k1[j];
            out[2] += q[j] * k2[j];
            out[3] += q[j] * k3[j];
        }
        out
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn dot2x4(
        q0: &[f32],
        q1: &[f32],
        k0: &[f32],
        k1: &[f32],
        k2: &[f32],
        k3: &[f32],
    ) -> [f32; 8] {
        let n = q0.len();
        let chunks = n / 4;
        let mut acc = [_mm_setzero_ps(); 8];
        for c in 0..chunks {
            let j = c * 4;
            let q0v = _mm_loadu_ps(q0.as_ptr().add(j));
            let q1v = _mm_loadu_ps(q1.as_ptr().add(j));
            let ks = [
                k0.as_ptr().add(j),
                k1.as_ptr().add(j),
                k2.as_ptr().add(j),
                k3.as_ptr().add(j),
            ];
            for (ki, &kp) in ks.iter().enumerate() {
                let kv = _mm_loadu_ps(kp);
                acc[ki] = _mm_add_ps(acc[ki], _mm_mul_ps(q0v, kv));
                acc[4 + ki] = _mm_add_ps(acc[4 + ki], _mm_mul_ps(q1v, kv));
            }
        }
        let mut out = [0f32; 8];
        for (o, a) in out.iter_mut().zip(acc.iter()) {
            let mut t = [0f32; 4];
            _mm_storeu_ps(t.as_mut_ptr(), *a);
            *o = (t[0] + t[1]) + (t[2] + t[3]);
        }
        for j in chunks * 4..n {
            let ks = [k0, k1, k2, k3];
            for (ki, kk) in ks.iter().enumerate() {
                out[ki] += q0[j] * kk[j];
                out[4 + ki] += q1[j] * kk[j];
            }
        }
        out
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_q8(q: &[f32], c: &[i8]) -> f32 {
        let n = q.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let j = i * 8;
            let qv = _mm256_loadu_ps(q.as_ptr().add(j));
            let cv = load8_i8(c.as_ptr().add(j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(qv, cv));
        }
        let mut s = super::hsum8(store8(acc));
        for j in chunks * 8..n {
            s += q[j] * c[j] as f32;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4_q8(q: &[f32], c0: &[i8], c1: &[i8], c2: &[i8], c3: &[i8]) -> [f32; 4] {
        let n = q.len();
        let chunks = n / 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        for c in 0..chunks {
            let j = c * 8;
            let qv = _mm256_loadu_ps(q.as_ptr().add(j));
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(qv, load8_i8(c0.as_ptr().add(j))));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(qv, load8_i8(c1.as_ptr().add(j))));
            a2 = _mm256_add_ps(a2, _mm256_mul_ps(qv, load8_i8(c2.as_ptr().add(j))));
            a3 = _mm256_add_ps(a3, _mm256_mul_ps(qv, load8_i8(c3.as_ptr().add(j))));
        }
        let mut out = [
            super::hsum8(store8(a0)),
            super::hsum8(store8(a1)),
            super::hsum8(store8(a2)),
            super::hsum8(store8(a3)),
        ];
        for j in chunks * 8..n {
            out[0] += q[j] * c0[j] as f32;
            out[1] += q[j] * c1[j] as f32;
            out[2] += q[j] * c2[j] as f32;
            out[3] += q[j] * c3[j] as f32;
        }
        out
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_q8(alpha: f32, x: &[i8], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 8;
        let av = _mm256_set1_ps(alpha);
        for i in 0..chunks {
            let j = i * 8;
            let xv = load8_i8(x.as_ptr().add(j));
            let yv = _mm256_loadu_ps(y.as_ptr().add(j));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
        }
        for j in chunks * 8..n {
            y[j] += alpha * x[j] as f32;
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: AVX2 presence checked; bit-identical to the scalar loop.
        return unsafe { x86::dot(a, b) };
    }
    // 8 independent accumulators: strict-FP addition order otherwise
    // blocks autovectorization; 8 lanes map onto one AVX2 register (two
    // on AVX-512) and LLVM unrolls further on its own.
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for i in 0..chunks {
        let j = i * 8;
        let (av, bv) = (&a[j..j + 8], &b[j..j + 8]);
        for l in 0..8 {
            acc[l] += av[l] * bv[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for j in chunks * 8..n {
        s += a[j] * b[j];
    }
    s
}

#[inline]
fn hsum8(a: [f32; 8]) -> f32 {
    (a[0] + a[1]) + (a[2] + a[3]) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// Dot products of one query row against four key rows (multi-key
/// micro-kernel). Eight accumulator lanes per key keep the reduction
/// association-free for autovectorization; the query chunk is loaded once
/// per four keys instead of once per key.
#[inline]
pub fn dot4(q: &[f32], k0: &[f32], k1: &[f32], k2: &[f32], k3: &[f32]) -> [f32; 4] {
    let n = q.len();
    debug_assert!(k0.len() >= n && k1.len() >= n && k2.len() >= n && k3.len() >= n);
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: AVX2 presence checked; bit-identical to the scalar loop.
        return unsafe { x86::dot4(q, k0, k1, k2, k3) };
    }
    let chunks = n / 8;
    let mut a0 = [0f32; 8];
    let mut a1 = [0f32; 8];
    let mut a2 = [0f32; 8];
    let mut a3 = [0f32; 8];
    for c in 0..chunks {
        let j = c * 8;
        let qv = &q[j..j + 8];
        let k0v = &k0[j..j + 8];
        let k1v = &k1[j..j + 8];
        let k2v = &k2[j..j + 8];
        let k3v = &k3[j..j + 8];
        for l in 0..8 {
            a0[l] += qv[l] * k0v[l];
            a1[l] += qv[l] * k1v[l];
            a2[l] += qv[l] * k2v[l];
            a3[l] += qv[l] * k3v[l];
        }
    }
    let mut out = [hsum8(a0), hsum8(a1), hsum8(a2), hsum8(a3)];
    for j in chunks * 8..n {
        out[0] += q[j] * k0[j];
        out[1] += q[j] * k1[j];
        out[2] += q[j] * k2[j];
        out[3] += q[j] * k3[j];
    }
    out
}

/// 2-query × 4-key register-blocked micro-kernel (multi-query): returns
/// `[q0·k0, q0·k1, q0·k2, q0·k3, q1·k0, q1·k1, q1·k2, q1·k3]`. Four
/// accumulator lanes per product keep register pressure at eight vector
/// accumulators while amortizing every key load over two queries.
#[inline]
#[allow(clippy::too_many_arguments)]
fn dot2x4(q0: &[f32], q1: &[f32], k0: &[f32], k1: &[f32], k2: &[f32], k3: &[f32]) -> [f32; 8] {
    let n = q0.len();
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: AVX2 presence checked; bit-identical to the scalar loop.
        return unsafe { x86::dot2x4(q0, q1, k0, k1, k2, k3) };
    }
    let chunks = n / 4;
    let mut acc = [[0f32; 4]; 8];
    for c in 0..chunks {
        let j = c * 4;
        let q0v = &q0[j..j + 4];
        let q1v = &q1[j..j + 4];
        let ks = [&k0[j..j + 4], &k1[j..j + 4], &k2[j..j + 4], &k3[j..j + 4]];
        for (ki, kv) in ks.iter().enumerate() {
            for l in 0..4 {
                acc[ki][l] += q0v[l] * kv[l];
                acc[4 + ki][l] += q1v[l] * kv[l];
            }
        }
    }
    let mut out = [0f32; 8];
    for (o, a) in out.iter_mut().zip(acc.iter()) {
        *o = (a[0] + a[1]) + (a[2] + a[3]);
    }
    for j in chunks * 4..n {
        let ks = [k0, k1, k2, k3];
        for (ki, kk) in ks.iter().enumerate() {
            out[ki] += q0[j] * kk[j];
            out[4 + ki] += q1[j] * kk[j];
        }
    }
    out
}

/// One query against a contiguous `[n, d]` key tile: `out[j] = q · keys_j`.
/// Blocked four keys at a time via [`dot4`], scalar tail via [`dot`].
pub fn qk_dots(q: &[f32], keys: &[f32], n: usize, d: usize, out: &mut [f32]) {
    debug_assert!(keys.len() >= n * d);
    debug_assert!(out.len() >= n);
    let mut j = 0;
    while j + 4 <= n {
        let b = j * d;
        let r = dot4(
            q,
            &keys[b..b + d],
            &keys[b + d..b + 2 * d],
            &keys[b + 2 * d..b + 3 * d],
            &keys[b + 3 * d..b + 4 * d],
        );
        out[j..j + 4].copy_from_slice(&r);
        j += 4;
    }
    while j < n {
        out[j] = dot(q, &keys[j * d..(j + 1) * d]);
        j += 1;
    }
}

/// `m×n` QKᵀ block over contiguous `[m, d]` query rows and `[n, d]` key
/// rows: `out[i*n + j] = qs_i · keys_j`. Register-blocked 2×4 with
/// [`dot2x4`]; row/column tails fall back to [`qk_dots`] / [`dot`].
pub fn qk_block(qs: &[f32], m: usize, keys: &[f32], n: usize, d: usize, out: &mut [f32]) {
    debug_assert!(qs.len() >= m * d);
    debug_assert!(keys.len() >= n * d);
    debug_assert!(out.len() >= m * n);
    let mut i = 0;
    while i + 2 <= m {
        let q0 = &qs[i * d..(i + 1) * d];
        let q1 = &qs[(i + 1) * d..(i + 2) * d];
        let mut j = 0;
        while j + 4 <= n {
            let b = j * d;
            let r = dot2x4(
                q0,
                q1,
                &keys[b..b + d],
                &keys[b + d..b + 2 * d],
                &keys[b + 2 * d..b + 3 * d],
                &keys[b + 3 * d..b + 4 * d],
            );
            out[i * n + j..i * n + j + 4].copy_from_slice(&r[..4]);
            out[(i + 1) * n + j..(i + 1) * n + j + 4].copy_from_slice(&r[4..]);
            j += 4;
        }
        while j < n {
            let key = &keys[j * d..(j + 1) * d];
            out[i * n + j] = dot(q0, key);
            out[(i + 1) * n + j] = dot(q1, key);
            j += 1;
        }
        i += 2;
    }
    if i < m {
        qk_dots(&qs[i * d..(i + 1) * d], keys, n, d, &mut out[i * n..i * n + n]);
    }
}

/// `acc += Σ_j w[j] · vs[j·d..]` — probability-weighted accumulation of a
/// contiguous `[n, d]` V tile into one output row. Streams the tile
/// sequentially; zero weights (fully masked or underflowed entries) are
/// skipped.
pub fn av_accum(w: &[f32], vs: &[f32], n: usize, d: usize, acc: &mut [f32]) {
    debug_assert!(w.len() >= n);
    debug_assert!(vs.len() >= n * d);
    debug_assert_eq!(acc.len(), d);
    for j in 0..n {
        let wj = w[j];
        if wj != 0.0 {
            axpy(wj, &vs[j * d..(j + 1) * d], acc);
        }
    }
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Quantize one f32 row to symmetric int8: `scale = amax / 127`,
/// `codes[i] = round(src[i] / scale)` clamped to `[-127, 127]`. A zero row
/// yields scale 0 and all-zero codes. Returns the scale; dequantization is
/// `codes[i] as f32 * scale` ([`dequant_row_q8`]). Deterministic and
/// order-independent per row, so re-quantizing the same row always yields
/// the same codes — the property the pool's bit-exact rollback relies on.
pub fn quantize_row_q8(src: &[f32], codes: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), codes.len());
    let mut amax = 0f32;
    for &v in src {
        amax = amax.max(v.abs());
    }
    if amax == 0.0 {
        codes.fill(0);
        return 0.0;
    }
    let inv = 127.0 / amax;
    for (c, &v) in codes.iter_mut().zip(src) {
        *c = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    amax / 127.0
}

/// Dequantize one int8 row: `out[i] = codes[i] as f32 * scale`.
#[inline]
pub fn dequant_row_q8(codes: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32 * scale;
    }
}

/// `q · codes` with the int8 codes sign-extended to f32 in registers; the
/// caller applies the row's dequant scale to the result
/// (`q · (c·s) = s · (q · c)`). Same 8-lane accumulator structure as
/// [`dot`], so the scalar and AVX2 paths agree bit-exactly.
#[inline]
pub fn dot_q8(q: &[f32], c: &[i8]) -> f32 {
    debug_assert_eq!(q.len(), c.len());
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: AVX2 presence checked; bit-identical to the scalar loop.
        return unsafe { x86::dot_q8(q, c) };
    }
    let n = q.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for i in 0..chunks {
        let j = i * 8;
        let (qv, cv) = (&q[j..j + 8], &c[j..j + 8]);
        for l in 0..8 {
            acc[l] += qv[l] * cv[l] as f32;
        }
    }
    let mut s = hsum8(acc);
    for j in chunks * 8..n {
        s += q[j] * c[j] as f32;
    }
    s
}

/// Int8 sibling of [`dot4`]: one query row against four int8 key rows,
/// widened to f32 lane-by-lane in registers.
#[inline]
fn dot4_q8(q: &[f32], c0: &[i8], c1: &[i8], c2: &[i8], c3: &[i8]) -> [f32; 4] {
    let n = q.len();
    debug_assert!(c0.len() >= n && c1.len() >= n && c2.len() >= n && c3.len() >= n);
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: AVX2 presence checked; bit-identical to the scalar loop.
        return unsafe { x86::dot4_q8(q, c0, c1, c2, c3) };
    }
    let chunks = n / 8;
    let mut a0 = [0f32; 8];
    let mut a1 = [0f32; 8];
    let mut a2 = [0f32; 8];
    let mut a3 = [0f32; 8];
    for c in 0..chunks {
        let j = c * 8;
        let qv = &q[j..j + 8];
        let c0v = &c0[j..j + 8];
        let c1v = &c1[j..j + 8];
        let c2v = &c2[j..j + 8];
        let c3v = &c3[j..j + 8];
        for l in 0..8 {
            a0[l] += qv[l] * c0v[l] as f32;
            a1[l] += qv[l] * c1v[l] as f32;
            a2[l] += qv[l] * c2v[l] as f32;
            a3[l] += qv[l] * c3v[l] as f32;
        }
    }
    let mut out = [hsum8(a0), hsum8(a1), hsum8(a2), hsum8(a3)];
    for j in chunks * 8..n {
        out[0] += q[j] * c0[j] as f32;
        out[1] += q[j] * c1[j] as f32;
        out[2] += q[j] * c2[j] as f32;
        out[3] += q[j] * c3[j] as f32;
    }
    out
}

/// One query against a contiguous int8 `[n, d]` key tile with per-row
/// dequant scales: `out[j] = scales[j] · (q · codes_j)`. The dequant
/// happens in registers — no fp32 copy of the tile is ever materialized,
/// so the tile streams at one byte per element.
pub fn qk_dots_q8(q: &[f32], codes: &[i8], scales: &[f32], n: usize, d: usize, out: &mut [f32]) {
    debug_assert!(codes.len() >= n * d);
    debug_assert!(scales.len() >= n);
    debug_assert!(out.len() >= n);
    let mut j = 0;
    while j + 4 <= n {
        let b = j * d;
        let r = dot4_q8(
            q,
            &codes[b..b + d],
            &codes[b + d..b + 2 * d],
            &codes[b + 2 * d..b + 3 * d],
            &codes[b + 3 * d..b + 4 * d],
        );
        for l in 0..4 {
            out[j + l] = r[l] * scales[j + l];
        }
        j += 4;
    }
    while j < n {
        out[j] = dot_q8(q, &codes[j * d..(j + 1) * d]) * scales[j];
        j += 1;
    }
}

/// `m×n` QKᵀ block over contiguous f32 query rows and an int8 `[n, d]`
/// key tile with per-row dequant scales. Row-at-a-time over
/// [`qk_dots_q8`]: the widening i8→f32 conversion of the key tile
/// dominates the kernel, so the extra query-amortization of the f32 2×4
/// blocking buys nothing here — and the bandwidth-bound int8 consumer
/// (decode) runs `m = 1` anyway.
pub fn qk_block_q8(
    qs: &[f32],
    m: usize,
    codes: &[i8],
    scales: &[f32],
    n: usize,
    d: usize,
    out: &mut [f32],
) {
    debug_assert!(qs.len() >= m * d);
    debug_assert!(out.len() >= m * n);
    for i in 0..m {
        qk_dots_q8(&qs[i * d..(i + 1) * d], codes, scales, n, d, &mut out[i * n..i * n + n]);
    }
}

/// `acc += Σ_j (w[j] · scales[j]) · codes[j·d..]` — probability-weighted
/// accumulation of an int8 `[n, d]` V tile into one output row, folding
/// each row's dequant scale into its softmax weight. Zero weights (masked
/// or underflowed) and zero scales (zero rows) are skipped.
pub fn av_accum_q8(w: &[f32], codes: &[i8], scales: &[f32], n: usize, d: usize, acc: &mut [f32]) {
    debug_assert!(w.len() >= n);
    debug_assert!(codes.len() >= n * d);
    debug_assert!(scales.len() >= n);
    debug_assert_eq!(acc.len(), d);
    for j in 0..n {
        let wj = w[j] * scales[j];
        if wj != 0.0 {
            axpy_q8(wj, &codes[j * d..(j + 1) * d], acc);
        }
    }
}

/// `y += alpha * (x as f32)` over an int8 row. Element-wise independent,
/// so the scalar and AVX2 paths agree bit-exactly.
#[inline]
pub fn axpy_q8(alpha: f32, x: &[i8], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2() {
        // SAFETY: AVX2 presence checked; bit-identical to the scalar loop.
        return unsafe { x86::axpy_q8(alpha, x, y) };
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * *xi as f32;
    }
}

/// Euclidean norm.
#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize `a` to unit length in place; returns the original norm.
/// Zero vectors are left untouched (norm 0 returned).
#[inline]
pub fn normalize(a: &mut [f32]) -> f32 {
    let n = l2_norm(a);
    if n > 0.0 {
        let inv = 1.0 / n;
        for v in a.iter_mut() {
            *v *= inv;
        }
    }
    n
}

/// Cosine similarity, defined as 0 when either vector is zero.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

/// In-place numerically stable softmax over a row.
pub fn softmax(row: &mut [f32]) {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        // All -inf (fully masked): define as uniform zeros.
        for v in row.iter_mut() {
            *v = 0.0;
        }
        return;
    }
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm: `x * w / sqrt(mean(x^2) + eps)`, written to `out`.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(x.len(), out.len());
    let ms = dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Precomputed RoPE frequency table for a fixed head dimension and base.
///
/// `theta.powf(-2i/d)` costs an `exp`+`log` per pair per token when
/// recomputed inline; the table hoists it to construction time so the
/// per-token work is one `sin_cos` + rotate per pair. Build once per
/// (head-dim, base) — e.g. per model — and reuse for every token.
#[derive(Clone, Debug)]
pub struct RopeTable {
    /// `freqs[i] = theta^(-2i/d)` for pair `i < d/2`.
    freqs: Vec<f32>,
}

impl RopeTable {
    pub fn new(d: usize, theta: f32) -> RopeTable {
        debug_assert!(d % 2 == 0);
        let half = d / 2;
        RopeTable {
            freqs: (0..half).map(|i| theta.powf(-2.0 * i as f32 / d as f32)).collect(),
        }
    }

    /// Head dimension this table was built for.
    pub fn dim(&self) -> usize {
        self.freqs.len() * 2
    }

    /// Rotate pairs `(x[2i], x[2i+1])` by `pos * freqs[i]` in place.
    #[inline]
    pub fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.freqs.len() * 2);
        for (i, &freq) in self.freqs.iter().enumerate() {
            let (sin, cos) = (pos as f32 * freq).sin_cos();
            let a = x[2 * i];
            let b = x[2 * i + 1];
            x[2 * i] = a * cos - b * sin;
            x[2 * i + 1] = a * sin + b * cos;
        }
    }
}

/// Rotary position embedding applied in place to a head vector of even
/// dimension `d`, rotating pairs `(x[2i], x[2i+1])` by `pos * theta^(-2i/d)`.
///
/// One-shot convenience that rebuilds the frequency table per call; hot
/// paths should hold a [`RopeTable`] instead.
pub fn rope(x: &mut [f32], pos: usize, theta: f32) {
    RopeTable::new(x.len(), theta).apply(x, pos);
}

/// SiLU (x * sigmoid(x)).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Mean over rows of an `[n, d]` matrix into `out[d]`.
pub fn mean_rows(mat: &[f32], n: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(mat.len(), n * d);
    out.iter_mut().for_each(|v| *v = 0.0);
    for r in 0..n {
        axpy(1.0, &mat[r * d..(r + 1) * d], out);
    }
    let inv = 1.0 / n as f32;
    for v in out.iter_mut() {
        *v *= inv;
    }
}

/// [`topk_indices`] into a caller-owned buffer: `idx` is cleared and left
/// holding the result, reusing its capacity so steady-state selection
/// loops perform no per-call allocation. The transient `(0..n)` index fill
/// lives in the same buffer.
pub fn topk_indices_into(scores: &[f32], k: usize, idx: &mut Vec<usize>) {
    idx.clear();
    let k = k.min(scores.len());
    if k == 0 {
        return;
    }
    idx.extend(0..scores.len());
    let cmp = |&a: &usize, &b: &usize| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    };
    if k < scores.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
}

/// Indices of the `k` largest values (descending by value). Deterministic
/// tie-break: lower index wins. O(n + k log k) via partial selection.
/// Allocates the result; hot paths should use [`topk_indices_into`].
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx = Vec::new();
    topk_indices_into(scores, k, &mut idx);
    idx
}

/// Argsort descending.
pub fn argsort_desc(scores: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Gather rows `idx` of an `[n, d]` matrix into `out[idx.len(), d]`.
pub fn gather_rows(mat: &[f32], d: usize, idx: &[usize], out: &mut [f32]) {
    debug_assert_eq!(out.len(), idx.len() * d);
    for (o, &i) in idx.iter().enumerate() {
        out[o * d..(o + 1) * d].copy_from_slice(&mat[i * d..(i + 1) * d]);
    }
}

/// Relative L2 error ‖a−b‖/max(‖a‖, tiny).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut num = 0f64;
    let mut den = 0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) * (x - y)) as f64;
        den += (x * x) as f64;
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f32::INFINITY };
    }
    (num / den).sqrt() as f32
}

/// Pearson correlation of two samples.
pub fn pearson(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let my = y.iter().map(|&v| v as f64).sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0f64, 0f64, 0f64);
    for (&a, &b) in x.iter().zip(y) {
        let dx = a as f64 - mx;
        let dy = b as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    (sxy / (sxx.sqrt() * syy.sqrt())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn blocked_kernels_match_naive_dots() {
        // Odd d exercises every tail path (8-lane in dot4, 4-lane in
        // dot2x4); n not divisible by 4 exercises the key-tail; odd m the
        // query-tail of qk_block.
        for &(m, n, d) in &[(1usize, 1usize, 3usize), (2, 4, 8), (3, 7, 13), (5, 9, 16), (4, 12, 31)] {
            let qs: Vec<f32> = (0..m * d).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.13).collect();
            let ks: Vec<f32> = (0..n * d).map(|i| ((i * 53 % 23) as f32 - 11.0) * 0.07).collect();
            let mut blk = vec![0.0f32; m * n];
            qk_block(&qs, m, &ks, n, d, &mut blk);
            let mut row = vec![0.0f32; n];
            for i in 0..m {
                let q = &qs[i * d..(i + 1) * d];
                qk_dots(q, &ks, n, d, &mut row);
                for j in 0..n {
                    let want = dot(q, &ks[j * d..(j + 1) * d]);
                    assert!((blk[i * n + j] - want).abs() < 1e-4, "block ({i},{j})");
                    assert!((row[j] - want).abs() < 1e-4, "dots ({i},{j})");
                }
            }
        }
    }

    /// Scalar 8-lane reference replicas of the dispatched kernels. The
    /// public kernels may route through AVX2; these never do. Bit-equality
    /// between the two proves dispatch does not change fp32 numerics.
    fn scalar_dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = [0f32; 8];
        for i in 0..chunks {
            let j = i * 8;
            for l in 0..8 {
                acc[l] += a[j + l] * b[j + l];
            }
        }
        let mut s = hsum8(acc);
        for j in chunks * 8..n {
            s += a[j] * b[j];
        }
        s
    }

    fn scalar_dot_q8(q: &[f32], c: &[i8]) -> f32 {
        let n = q.len();
        let chunks = n / 8;
        let mut acc = [0f32; 8];
        for i in 0..chunks {
            let j = i * 8;
            for l in 0..8 {
                acc[l] += q[j + l] * c[j + l] as f32;
            }
        }
        let mut s = hsum8(acc);
        for j in chunks * 8..n {
            s += q[j] * c[j] as f32;
        }
        s
    }

    fn scalar_dot2x4_entry(q: &[f32], k: &[f32]) -> f32 {
        // dot2x4's per-product structure: 4 lanes, tree (a0+a1)+(a2+a3).
        let n = q.len();
        let chunks = n / 4;
        let mut acc = [0f32; 4];
        for i in 0..chunks {
            let j = i * 4;
            for l in 0..4 {
                acc[l] += q[j + l] * k[j + l];
            }
        }
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for j in chunks * 4..n {
            s += q[j] * k[j];
        }
        s
    }

    fn test_rows(m: usize, n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        let qs: Vec<f32> = (0..m * d).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.13).collect();
        let ks: Vec<f32> = (0..n * d).map(|i| ((i * 53 % 23) as f32 - 11.0) * 0.07).collect();
        (qs, ks)
    }

    #[test]
    fn simd_dispatch_is_bit_identical_to_scalar_lanes() {
        for &(m, n, d) in &[(1usize, 1usize, 3usize), (2, 4, 8), (3, 7, 13), (5, 9, 16), (4, 12, 31)] {
            let (qs, ks) = test_rows(m, n, d);
            let mut codes = vec![0i8; n * d];
            let mut scales = vec![0f32; n];
            for j in 0..n {
                scales[j] = quantize_row_q8(&ks[j * d..(j + 1) * d], &mut codes[j * d..(j + 1) * d]);
            }
            // dot / dot4 (via qk_dots) against the 8-lane scalar replica.
            let mut row = vec![0f32; n];
            let mut row_q = vec![0f32; n];
            let mut blk = vec![0f32; m * n];
            let mut blk_q = vec![0f32; m * n];
            qk_block(&qs, m, &ks, n, d, &mut blk);
            qk_block_q8(&qs, m, &codes, &scales, n, d, &mut blk_q);
            for i in 0..m {
                let q = &qs[i * d..(i + 1) * d];
                qk_dots(q, &ks, n, d, &mut row);
                qk_dots_q8(q, &codes, &scales, n, d, &mut row_q);
                for j in 0..n {
                    let k = &ks[j * d..(j + 1) * d];
                    let c = &codes[j * d..(j + 1) * d];
                    assert_eq!(dot(q, k), scalar_dot(q, k), "dot ({i},{j})");
                    assert_eq!(row[j], scalar_dot(q, k), "qk_dots ({i},{j})");
                    assert_eq!(dot_q8(q, c), scalar_dot_q8(q, c), "dot_q8 ({i},{j})");
                    assert_eq!(row_q[j], scalar_dot_q8(q, c) * scales[j], "qk_dots_q8 ({i},{j})");
                    assert_eq!(blk_q[i * n + j], row_q[j], "qk_block_q8 ({i},{j})");
                    // qk_block interior entries flow through dot2x4 (4-lane
                    // structure); tails through dot/qk_dots (8-lane).
                    let paired = i + 1 < m || m % 2 == 0;
                    let want = if paired && j < n / 4 * 4 {
                        scalar_dot2x4_entry(q, k)
                    } else {
                        scalar_dot(q, k)
                    };
                    assert_eq!(blk[i * n + j], want, "qk_block ({i},{j})");
                }
            }
            // axpy_q8: element-wise, bit-identical to the scalar loop.
            let mut acc = vec![0.5f32; d];
            let mut acc_ref = acc.clone();
            axpy_q8(0.37, &codes[..d], &mut acc);
            for (y, &x) in acc_ref.iter_mut().zip(&codes[..d]) {
                *y += 0.37 * x as f32;
            }
            assert_eq!(acc, acc_ref, "axpy_q8 ({m},{n},{d})");
        }
    }

    #[test]
    fn quantize_roundtrip_error_is_half_step_bounded() {
        let src: Vec<f32> = (0..64).map(|i| ((i * 73 % 41) as f32 - 20.0) * 0.31).collect();
        let mut codes = vec![0i8; 64];
        let scale = quantize_row_q8(&src, &mut codes);
        let amax = src.iter().fold(0f32, |a, &v| a.max(v.abs()));
        assert!((scale - amax / 127.0).abs() < 1e-7);
        let mut back = vec![0f32; 64];
        dequant_row_q8(&codes, scale, &mut back);
        for (x, y) in src.iter().zip(&back) {
            // round-to-nearest: error ≤ half a quantization step
            assert!((x - y).abs() <= scale * 0.5 + 1e-6, "{x} vs {y}");
        }
        // extremes hit ±127 exactly; zero rows quantize to scale 0
        let idx = src.iter().position(|&v| v.abs() == amax).unwrap();
        assert_eq!(codes[idx].unsigned_abs(), 127);
        let mut zc = vec![1i8; 8];
        assert_eq!(quantize_row_q8(&[0.0; 8], &mut zc), 0.0);
        assert!(zc.iter().all(|&c| c == 0));
    }

    #[test]
    fn q8_kernels_match_dequantized_reference() {
        for &(m, n, d) in &[(1usize, 1usize, 3usize), (2, 4, 8), (3, 7, 13), (5, 9, 16), (4, 12, 31)] {
            let (qs, ks) = test_rows(m, n, d);
            let mut codes = vec![0i8; n * d];
            let mut scales = vec![0f32; n];
            let mut deq = vec![0f32; n * d];
            for j in 0..n {
                scales[j] = quantize_row_q8(&ks[j * d..(j + 1) * d], &mut codes[j * d..(j + 1) * d]);
                dequant_row_q8(&codes[j * d..(j + 1) * d], scales[j], &mut deq[j * d..(j + 1) * d]);
            }
            let mut got = vec![0f32; m * n];
            let mut want = vec![0f32; m * n];
            qk_block_q8(&qs, m, &codes, &scales, n, d, &mut got);
            qk_block(&qs, m, &deq, n, d, &mut want);
            for (g, w) in got.iter().zip(&want) {
                // same products up to fp32 associativity: s·(q·c) vs q·(c·s)
                assert!((g - w).abs() < 1e-3, "qk ({m},{n},{d}): {g} vs {w}");
            }
            let w: Vec<f32> = (0..n).map(|j| if j == 1 { 0.0 } else { j as f32 * 0.09 }).collect();
            let mut a = vec![0.25f32; d];
            let mut b = a.clone();
            av_accum_q8(&w, &codes, &scales, n, d, &mut a);
            av_accum(&w, &deq, n, d, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "av ({m},{n},{d}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn av_accum_matches_axpy_loop() {
        let (n, d) = (7usize, 5usize);
        let w: Vec<f32> = (0..n).map(|i| if i == 3 { 0.0 } else { i as f32 * 0.1 }).collect();
        let vs: Vec<f32> = (0..n * d).map(|i| (i as f32).sin()).collect();
        let mut a = vec![0.5f32; d];
        let mut b = a.clone();
        av_accum(&w, &vs, n, d, &mut a);
        for j in 0..n {
            axpy(w[j], &vs[j * d..(j + 1) * d], &mut b);
        }
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn topk_into_reuses_capacity() {
        let scores: Vec<f32> = (0..256).map(|i| ((i * 97) % 251) as f32).collect();
        let mut idx = Vec::new();
        topk_indices_into(&scores, 16, &mut idx);
        assert_eq!(idx, topk_indices(&scores, 16));
        let cap = idx.capacity();
        let p = idx.as_ptr();
        for k in [1usize, 8, 16] {
            topk_indices_into(&scores, k, &mut idx);
            assert_eq!(idx.len(), k);
        }
        assert_eq!(cap, idx.capacity());
        assert_eq!(p, idx.as_ptr());
    }

    #[test]
    fn rope_table_matches_rope() {
        let table = RopeTable::new(8, 10000.0);
        assert_eq!(table.dim(), 8);
        for pos in [0usize, 1, 17, 900] {
            let mut a: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).cos()).collect();
            let mut b = a.clone();
            rope(&mut a, pos, 10000.0);
            table.apply(&mut b, pos);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut row = vec![1000.0, 1001.0, 999.0];
        softmax(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(row[1] > row[0] && row[0] > row[2]);
    }

    #[test]
    fn softmax_all_masked() {
        let mut row = vec![f32::NEG_INFINITY; 4];
        softmax(&mut row);
        assert!(row.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn normalize_unit_length() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine(&[1.0, 1.0], &[2.0, 2.0]) - 1.0).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn rmsnorm_matches_definition() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [1.0f32; 4];
        let mut out = [0.0f32; 4];
        rmsnorm(&x, &w, 1e-6, &mut out);
        let ms = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        for i in 0..4 {
            assert!((out[i] - x[i] / (ms + 1e-6).sqrt()).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm_and_is_positional() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0];
        let before = l2_norm(&a);
        rope(&mut a, 7, 10000.0);
        assert!((l2_norm(&a) - before).abs() < 1e-4);
        // pos 0 is the identity
        let mut b = vec![1.0, 2.0, 3.0, 4.0];
        rope(&mut b, 0, 10000.0);
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rope_relative_property() {
        // <rope(q,m), rope(k,n)> depends only on m-n for the same vectors.
        let q0 = vec![0.3, -1.2, 0.7, 0.5];
        let k0 = vec![1.0, 0.2, -0.4, 0.9];
        let dots: Vec<f32> = [(3usize, 1usize), (10, 8), (22, 20)]
            .iter()
            .map(|&(m, n)| {
                let mut q = q0.clone();
                let mut k = k0.clone();
                rope(&mut q, m, 10000.0);
                rope(&mut k, n, 10000.0);
                dot(&q, &k)
            })
            .collect();
        assert!((dots[0] - dots[1]).abs() < 1e-4);
        assert!((dots[1] - dots[2]).abs() < 1e-4);
    }

    #[test]
    fn topk_matches_argsort() {
        let scores = vec![0.1, 5.0, -2.0, 5.0, 3.3, 0.0];
        assert_eq!(topk_indices(&scores, 3), argsort_desc(&scores)[..3].to_vec());
        assert_eq!(topk_indices(&scores, 3), vec![1, 3, 4]);
        assert_eq!(topk_indices(&scores, 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&scores, 99).len(), 6);
    }

    #[test]
    fn gather_and_mean() {
        let mat = vec![1., 2., 3., 4., 5., 6.];
        let mut out = vec![0.0; 4];
        gather_rows(&mat, 2, &[2, 0], &mut out);
        assert_eq!(out, vec![5., 6., 1., 2.]);
        let mut m = vec![0.0; 2];
        mean_rows(&mat, 3, 2, &mut m);
        assert_eq!(m, vec![3.0, 4.0]);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-6);
        let z = vec![-1.0, -2.0, -3.0, -4.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn rel_l2_zero_and_nonzero() {
        assert_eq!(rel_l2(&[0.0; 3], &[0.0; 3]), 0.0);
        assert!(rel_l2(&[1.0, 0.0], &[0.0, 0.0]) > 0.9);
    }
}
