//! From-scratch dense f32 tensor substrate.
//!
//! The host execution backend (and every selection policy) runs on this
//! module; it is deliberately small: contiguous row-major `f32` storage, a
//! shape vector, and the handful of kernels an attention stack needs
//! (blocked matmul, softmax, rmsnorm, RoPE, top-k, gathers, norms).
//!
//! Hot-path functions operate directly on slices so the engine can reuse
//! scratch buffers without allocation; [`Tensor`] is the convenience owner
//! used at module boundaries and in tests.

pub mod ops;
pub mod matmul;
pub mod linalg;

pub use matmul::{matmul, matmul_bt};

/// A contiguous row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor with the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Wrap existing data (len must match the shape product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Standard-normal random tensor.
    pub fn randn(shape: &[usize], rng: &mut crate::util::Rng, sigma: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, sigma);
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Dimension `i` (panics if out of range).
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Reinterpret the shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    /// Sub-slab `[i]` of a rank-3 tensor, viewed as rank-2 data.
    pub fn slab(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 3);
        let n = self.shape[1] * self.shape[2];
        &self.data[i * n..(i + 1) * n]
    }

    pub fn slab_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 3);
        let n = self.shape[1] * self.shape[2];
        &mut self.data[i * n..(i + 1) * n]
    }

    /// Element at a full index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &d)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(ix < d, "index {ix} out of bounds for dim {i} ({d})");
            off = off * d + ix;
        }
        off
    }

    /// Max |a - b| between tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖/‖a‖ (0 when both are 0).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        ops::rel_l2(&self.data, &other.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn shape_and_index() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.at(&[1, 2, 3]), 7.0);
        assert_eq!(t.data()[1 * 12 + 2 * 4 + 3], 7.0);
        assert_eq!(t.rank(), 3);
        assert_eq!(t.dim(2), 4);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_panics() {
        let t = Tensor::zeros(&[2, 2]);
        t.at(&[2, 0]);
    }

    #[test]
    fn rows_and_slabs() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let t3 = t.clone().reshape(&[1, 2, 3]);
        assert_eq!(t3.slab(0), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[100, 100], &mut rng, 2.0);
        let mean: f32 = t.data().iter().sum::<f32>() / t.len() as f32;
        let var: f32 = t.data().iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.1);
        assert!((var - 4.0).abs() < 0.3);
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0, 2.5]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
        assert!(a.rel_l2(&a) < 1e-9);
    }
}
