//! Higher-level linear algebra used by the geometry analyses (Fig. 2) and
//! the Loki baseline: row normalization, cosine-similarity matrices, and a
//! small power-iteration PCA (top-2 principal components, enough for the
//! paper's 2-D query/key geometry projection).

use super::ops::{axpy, dot, normalize};
use crate::util::Rng;

/// Normalize every row of an `[n, d]` matrix in place.
pub fn normalize_rows(mat: &mut [f32], d: usize) {
    debug_assert_eq!(mat.len() % d, 0);
    for row in mat.chunks_mut(d) {
        normalize(row);
    }
}

/// Cosine similarity of every row of `a[m,d]` against vector `v[d]`.
pub fn cosine_to_vec(a: &[f32], d: usize, v: &[f32]) -> Vec<f32> {
    let nv = dot(v, v).sqrt();
    a.chunks(d)
        .map(|row| {
            let nr = dot(row, row).sqrt();
            if nr == 0.0 || nv == 0.0 {
                0.0
            } else {
                dot(row, v) / (nr * nv)
            }
        })
        .collect()
}

/// Mean-center the rows of `mat[n,d]`, returning the mean.
pub fn center_rows(mat: &mut [f32], d: usize) -> Vec<f32> {
    let n = mat.len() / d;
    let mut mean = vec![0.0; d];
    for row in mat.chunks(d) {
        axpy(1.0, row, &mut mean);
    }
    for v in mean.iter_mut() {
        *v /= n as f32;
    }
    for row in mat.chunks_mut(d) {
        for (x, m) in row.iter_mut().zip(&mean) {
            *x -= m;
        }
    }
    mean
}

/// Top-`k` principal directions of the rows of `mat[n,d]` via power
/// iteration with deflation. Returns `k` unit vectors of length `d`.
///
/// Used for Fig. 2b (2-D PCA of queries and keys) and as the offline basis
/// builder for the Loki baseline's low-rank key projection.
pub fn principal_components(mat: &[f32], d: usize, k: usize, iters: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    let n = mat.len() / d;
    let mut comps: Vec<Vec<f32>> = Vec::with_capacity(k);
    let mut centered = mat.to_vec();
    center_rows(&mut centered, d);
    for _ in 0..k {
        let mut v = rng.normal_vec(d, 1.0);
        normalize(&mut v);
        for _ in 0..iters {
            // w = Cov·v computed as Xᵀ(X v) without forming Cov.
            let mut w = vec![0.0; d];
            for row in centered.chunks(d) {
                let p = dot(row, &v);
                axpy(p, row, &mut w);
            }
            // Deflate previously found components.
            for c in &comps {
                let p = dot(&w, c);
                axpy(-p, c, &mut w);
            }
            if normalize(&mut w) == 0.0 {
                break;
            }
            v = w;
        }
        comps.push(v);
    }
    let _ = n;
    comps
}

/// Project rows of `mat[n,d]` onto `comps` → `[n, comps.len()]`.
pub fn project(mat: &[f32], d: usize, comps: &[Vec<f32>]) -> Vec<f32> {
    let n = mat.len() / d;
    let k = comps.len();
    let mut out = vec![0.0; n * k];
    for (i, row) in mat.chunks(d).enumerate() {
        for (j, c) in comps.iter().enumerate() {
            out[i * k + j] = dot(row, c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::l2_norm;

    #[test]
    fn normalize_rows_unit() {
        let mut m = vec![3.0, 4.0, 0.0, 5.0];
        normalize_rows(&mut m, 2);
        assert!((l2_norm(&m[0..2]) - 1.0).abs() < 1e-6);
        assert!((l2_norm(&m[2..4]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_to_vec_matches_scalar() {
        let a = vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0];
        let sims = cosine_to_vec(&a, 2, &[1.0, 0.0]);
        assert!((sims[0] - 1.0).abs() < 1e-6);
        assert!(sims[1].abs() < 1e-6);
        assert!((sims[2] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn pca_finds_dominant_direction() {
        let mut rng = Rng::new(42);
        // Points stretched along (1,1)/sqrt(2) with small noise.
        let dir = [std::f32::consts::FRAC_1_SQRT_2, std::f32::consts::FRAC_1_SQRT_2];
        let mut mat = Vec::new();
        for _ in 0..200 {
            let t = rng.normal() * 5.0;
            let noise = (rng.normal() * 0.1, rng.normal() * 0.1);
            mat.push(t * dir[0] + noise.0);
            mat.push(t * dir[1] + noise.1);
        }
        let comps = principal_components(&mat, 2, 1, 30, &mut rng);
        let c = &comps[0];
        let align = (c[0] * dir[0] + c[1] * dir[1]).abs();
        assert!(align > 0.99, "align {align}");
    }

    #[test]
    fn pca_components_orthogonal() {
        let mut rng = Rng::new(43);
        let mat = rng.normal_vec(100 * 8, 1.0);
        let comps = principal_components(&mat, 8, 2, 40, &mut rng);
        let d = dot(&comps[0], &comps[1]).abs();
        assert!(d < 0.05, "dot {d}");
    }

    #[test]
    fn center_rows_zero_mean() {
        let mut m = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        center_rows(&mut m, 2);
        let s0: f32 = m.iter().step_by(2).sum();
        assert!(s0.abs() < 1e-5);
    }

    #[test]
    fn project_shapes() {
        let mat = vec![1.0, 0.0, 0.0, 2.0];
        let comps = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let p = project(&mat, 2, &comps);
        assert_eq!(p, vec![1.0, 0.0, 0.0, 2.0]);
    }
}
