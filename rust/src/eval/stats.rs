//! Statistics for the paper's empirical-observation figures.
//!
//! - Fig. 2b: 2-D PCA projection of queries and keys;
//! - Fig. 2c: correlation between `S_q = −CosSim(M_Q, q)` and
//!   `max_k A[q, k]` (excluding the sink token);
//! - Fig. 3: distribution of the max-vs-mean deviation of attention scores
//!   along the query and head axes.

use crate::tensor::linalg::{principal_components, project};
use crate::tensor::ops::{dot, mean_rows, pearson, softmax};
use crate::util::Rng;

/// Per-query `S_q` values: negative cosine similarity to the mean query.
pub fn sq_scores(q: &[f32], s: usize, d: usize) -> Vec<f32> {
    let mut mean = vec![0.0; d];
    mean_rows(q, s, d, &mut mean);
    crate::tensor::linalg::cosine_to_vec(q, d, &mean)
        .into_iter()
        .map(|c| -c)
        .collect()
}

/// Per-query max post-softmax attention weight over keys, excluding the
/// sink (index 0) when `skip_sink`.
pub fn max_attention(q: &[f32], k: &[f32], s: usize, t: usize, d: usize, skip_sink: bool) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut row = vec![0.0f32; t];
    (0..s)
        .map(|i| {
            let qrow = &q[i * d..(i + 1) * d];
            for ti in 0..t {
                row[ti] = dot(qrow, &k[ti * d..(ti + 1) * d]) * scale;
            }
            softmax(&mut row);
            let start = if skip_sink { 1 } else { 0 };
            row[start..].iter().copied().fold(0.0, f32::max)
        })
        .collect()
}

/// Fig. 2c: Pearson correlation of `S_q` with `max_k(A)`.
pub fn sq_attention_correlation(q: &[f32], k: &[f32], s: usize, t: usize, d: usize) -> f32 {
    let sq = sq_scores(q, s, d);
    let ma = max_attention(q, k, s, t, d, true);
    pearson(&sq, &ma)
}

/// Fig. 2b: project queries and keys onto the keys' top-2 PCA plane.
/// Returns (q_proj `[s,2]`, k_proj `[t,2]`).
pub fn pca_projection(q: &[f32], k: &[f32], s: usize, t: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut joint = Vec::with_capacity((s + t) * d);
    joint.extend_from_slice(q);
    joint.extend_from_slice(k);
    let comps = principal_components(&joint, d, 2, 30, &mut rng);
    (project(q, d, &comps), project(k, d, &comps))
}

/// Fig. 3: deviations `max(x) − mean(x)` of per-key score columns along an
/// axis. `scores` is `[rows, cols]`; deviation is computed per column over
/// rows (rows = queries or heads).
pub fn max_mean_deviation(scores: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0; cols];
    for c in 0..cols {
        let mut m = f32::NEG_INFINITY;
        let mut sum = 0.0;
        for r in 0..rows {
            let v = scores[r * cols + c];
            sum += v;
            if v > m {
                m = v;
            }
        }
        out[c] = m - sum / rows as f32;
    }
    out
}

/// Histogram of values into `bins` equal-width buckets over [lo, hi].
pub fn histogram(vals: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &v in vals {
        let b = (((v - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::geometry::{GeometryConfig, GeometryTask, Needle};

    fn probe() -> (Vec<f32>, Vec<f32>, usize, usize, usize) {
        let cfg = GeometryConfig { t: 1024, seed: 7, ..Default::default() };
        let task = GeometryTask::generate(
            cfg,
            vec![Needle { key_pos: 256, width: 4, query_chunk: 7, dir: 0 }],
        );
        let q = task.q_chunk(7);
        let d = task.cfg.d;
        // Head 0 only.
        let s = q.len() / (task.cfg.n_q_heads * d);
        let qh = q[..s * d].to_vec();
        let kh = task.k[..896 * d].to_vec();
        (qh, kh, s, 896, d)
    }

    #[test]
    fn sq_correlates_with_max_attention() {
        // The paper's core empirical claim (Fig. 2c): queries dissimilar
        // from the mean query interact more strongly with keys.
        let (q, k, s, t, d) = probe();
        let r = sq_attention_correlation(&q, &k, s, t, d);
        assert!(r > 0.5, "expected strong positive correlation, got {r}");
    }

    #[test]
    fn pca_separates_queries_from_keys() {
        let (q, k, s, t, d) = probe();
        let (qp, kp) = pca_projection(&q, &k, s, t, d, 1);
        // Cluster centroids in the 2-D plane should be well separated
        // relative to within-cluster spread (Fig. 2b's visual).
        let cq = [
            qp.iter().step_by(2).sum::<f32>() / s as f32,
            qp.iter().skip(1).step_by(2).sum::<f32>() / s as f32,
        ];
        let ck = [
            kp.iter().step_by(2).sum::<f32>() / t as f32,
            kp.iter().skip(1).step_by(2).sum::<f32>() / t as f32,
        ];
        let dist = ((cq[0] - ck[0]).powi(2) + (cq[1] - ck[1]).powi(2)).sqrt();
        assert!(dist > 1.0, "centroid distance {dist}");
    }

    #[test]
    fn deviation_and_histogram() {
        let scores = vec![0.0, 1.0, 0.5, 0.5, 1.0, 0.0];
        let dev = max_mean_deviation(&scores, 2, 3);
        assert!((dev[0] - 0.25).abs() < 1e-6);
        assert!(dev[1].abs() < 1e-6);
        let h = histogram(&dev, 0.0, 1.0, 4);
        assert_eq!(h.iter().sum::<usize>(), 3);
    }
}
