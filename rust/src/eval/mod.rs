//! Evaluation: the scoring harness over geometry tasks plus the statistics
//! behind the paper's observation figures.

pub mod harness;
pub mod stats;

pub use harness::{eval_policy, EvalOpts, TaskScore};
