//! Evaluation harness: run a selection policy over a geometry task's
//! chunked prefill and score it.
//!
//! Two proxy metrics (DESIGN.md §6):
//! - **recall** — at each needle's query chunk, the fraction of the
//!   needle's ground-truth cache indices the policy retained (averaged
//!   over KV heads). This is what NIAH/RULER-style retrieval measures.
//! - **fidelity** — `1 − relL2(sparse attention output, dense attention
//!   output)` on the probe chunk's retrieval rows plus a sample of
//!   ordinary rows. This is what perplexity-style scores (LongBench
//!   summarization etc.) measure.
//!
//! Selection at a chunk is independent of earlier selections (QUOKA never
//! evicts — the cache always holds every token), so probing only the
//! chunks that matter is exact, not an approximation, and keeps 32k-token
//! sweeps tractable on CPU.

use crate::select::{KCache, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{axpy, dot, rel_l2, softmax};
use crate::workload::geometry::GeometryTask;

/// Score for one (task, policy, budget) run.
#[derive(Clone, Debug, Default)]
pub struct TaskScore {
    /// Per-needle recall in [0,1].
    pub needle_recall: Vec<f32>,
    /// Attention-output fidelity in [0,1] averaged over probes.
    pub fidelity: f32,
    /// Mean fraction of the cache retained.
    pub kv_frac: f32,
    /// Selection FLOPs tallied.
    pub select_flops: u64,
}

impl TaskScore {
    /// Mean recall (1.0 when no needles).
    pub fn recall(&self) -> f32 {
        if self.needle_recall.is_empty() {
            1.0
        } else {
            self.needle_recall.iter().sum::<f32>() / self.needle_recall.len() as f32
        }
    }

    /// Recall-gated fidelity: the headline task score in [0,1].
    pub fn score(&self) -> f32 {
        self.recall() * self.fidelity
    }

    /// Product of needle recalls (multi-hop scoring: every hop must land).
    pub fn chained_recall(&self) -> f32 {
        self.needle_recall.iter().product()
    }
}

/// Evaluation options.
#[derive(Clone, Copy, Debug)]
pub struct EvalOpts {
    /// Ordinary query rows sampled for fidelity (plus all retrieval rows).
    pub fidelity_rows: usize,
    /// Skip the fidelity computation (recall-only sweeps are much faster).
    pub skip_fidelity: bool,
    pub seed: u64,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts { fidelity_rows: 4, skip_fidelity: false, seed: 0 }
    }
}

/// Evaluate `policy` on `task` at `budget`.
pub fn eval_policy(
    task: &GeometryTask,
    policy: &dyn SelectionPolicy,
    budget: usize,
    opts: &EvalOpts,
) -> TaskScore {
    let cfg = &task.cfg;
    let (d, nq, nkv) = (cfg.d, cfg.n_q_heads, cfg.n_kv_heads);
    let mut ctx = SelectCtx::new(opts.seed);
    let mut score = TaskScore { needle_recall: vec![0.0; task.needles.len()], ..Default::default() };
    let mut fid_sum = 0.0;
    let mut fid_n = 0usize;
    let mut kv_sum = 0.0;
    let mut kv_n = 0usize;

    for &c in &task.probe_chunks() {
        let t_past = c * cfg.b_cp;
        if t_past == 0 {
            continue;
        }
        let qd = task.q_chunk(c);
        let s = qd.len() / (nq * d);
        let q = QChunk::new(&qd, nq, s, d);
        // The cache view: K rows [n_kv, t_past, d] — stored stride is the
        // full task length, so build a per-probe contiguous copy per head.
        let mut kc = vec![0.0f32; nkv * t_past * d];
        let mut vc = vec![0.0f32; nkv * t_past * d];
        for h in 0..nkv {
            let src = h * cfg.t * d;
            kc[h * t_past * d..(h + 1) * t_past * d]
                .copy_from_slice(&task.k[src..src + t_past * d]);
            vc[h * t_past * d..(h + 1) * t_past * d]
                .copy_from_slice(&task.v[src..src + t_past * d]);
        }
        let k = KCache::new(&kc, nkv, t_past, t_past, d);

        ctx.begin_step();
        // Probe at a representative mid-stack layer: layer-dependent
        // policies (TidalDecode's dense early layers, LessIsMore's
        // selection stride) must exhibit their *selection* behaviour, not
        // their layer-0 special case.
        ctx.layer = 2;
        let sel = policy.select(&q, &k, budget, &mut ctx);

        // ---- recall ----
        for &(_, ni) in task.retrieval_rows(c) {
            let truth = task.needles[ni].truth();
            let mut hit = 0usize;
            let mut total = 0usize;
            for h in 0..nkv {
                let hs = sel.head(h, t_past);
                for want in truth.clone() {
                    total += 1;
                    if hs.contains(want as u32) {
                        hit += 1;
                    }
                }
            }
            // A needle may be queried from several retrieval rows; the
            // selection is per-chunk so recall is identical — keep max.
            let r = hit as f32 / total.max(1) as f32;
            if r > score.needle_recall[ni] {
                score.needle_recall[ni] = r;
            }
        }

        kv_sum += sel.total(nkv, t_past) as f32 / (nkv * t_past) as f32;
        kv_n += 1;

        // ---- fidelity ----
        if !opts.skip_fidelity {
            let mut rows: Vec<usize> = task.retrieval_rows(c).iter().map(|&(r, _)| r).collect();
            let mut rr = crate::util::Rng::new(opts.seed ^ 0xF1D ^ c as u64);
            for _ in 0..opts.fidelity_rows {
                rows.push(rr.below(s));
            }
            rows.sort_unstable();
            rows.dedup();
            fid_sum += fidelity(&q, &k, &vc, &sel, &rows) as f64 as f32;
            fid_n += 1;
        }
    }

    score.fidelity = if opts.skip_fidelity || fid_n == 0 { 1.0 } else { fid_sum / fid_n as f32 };
    score.kv_frac = if kv_n == 0 { 1.0 } else { kv_sum / kv_n as f32 };
    score.select_flops = ctx.cost.flops();
    score
}

/// `1 − relL2` between sparse and dense attention outputs on `rows`.
fn fidelity(q: &QChunk, k: &KCache, v: &[f32], sel: &Selection, rows: &[usize]) -> f32 {
    let (d, t) = (q.d, k.t);
    let nkv = k.n_heads;
    let g = q.n_heads / nkv;
    let scale = 1.0 / (d as f32).sqrt();
    let mut dense_out = Vec::new();
    let mut sparse_out = Vec::new();
    let mut logits = vec![0.0f32; t];
    for h in 0..q.n_heads {
        let kv_h = h / g;
        let khead = k.head(kv_h);
        let vhead = &v[kv_h * t * d..(kv_h + 1) * t * d];
        // Borrowed selection view — no per-(head, probe) index clone.
        let hs = sel.head(kv_h, t);
        for &r in rows {
            let qrow = q.query(h, r);
            // Dense.
            for ti in 0..t {
                logits[ti] = dot(qrow, &khead[ti * d..(ti + 1) * d]) * scale;
            }
            softmax(&mut logits);
            let mut od = vec![0.0f32; d];
            for ti in 0..t {
                if logits[ti] > 1e-8 {
                    axpy(logits[ti], &vhead[ti * d..(ti + 1) * d], &mut od);
                }
            }
            // Sparse (same computation restricted to the selection).
            let mut slog: Vec<f32> = hs
                .iter()
                .map(|ti| dot(qrow, &khead[ti * d..(ti + 1) * d]) * scale)
                .collect();
            softmax(&mut slog);
            let mut os = vec![0.0f32; d];
            for (j, ti) in hs.iter().enumerate() {
                if slog[j] > 1e-8 {
                    axpy(slog[j], &vhead[ti * d..(ti + 1) * d], &mut os);
                }
            }
            dense_out.extend_from_slice(&od);
            sparse_out.extend_from_slice(&os);
        }
    }
    (1.0 - rel_l2(&dense_out, &sparse_out)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::policy_by_name;
    use crate::workload::geometry::{GeometryConfig, GeometryTask, Needle};

    fn task(t: usize, seed: u64) -> GeometryTask {
        let cfg = GeometryConfig { t, seed, ..Default::default() };
        let needles = vec![
            Needle { key_pos: t / 4, width: 4, query_chunk: t / 128 - 1, dir: 0 },
            Needle { key_pos: t / 2, width: 4, query_chunk: t / 128 - 1, dir: 1 },
        ];
        GeometryTask::generate(cfg, needles)
    }

    #[test]
    fn dense_scores_perfectly() {
        let t = task(2048, 1);
        let dense = policy_by_name("dense").unwrap();
        let s = eval_policy(&t, dense.as_ref(), usize::MAX, &EvalOpts::default());
        assert_eq!(s.recall(), 1.0);
        assert!(s.fidelity > 0.999);
        assert_eq!(s.kv_frac, 1.0);
    }

    #[test]
    fn quoka_beats_keydiff_on_retrieval() {
        let t = task(2048, 2);
        let opts = EvalOpts { skip_fidelity: true, ..Default::default() };
        let quoka = policy_by_name("quoka").unwrap();
        let keydiff = policy_by_name("keydiff").unwrap();
        let sq = eval_policy(&t, quoka.as_ref(), 128, &opts);
        let sk = eval_policy(&t, keydiff.as_ref(), 128, &opts);
        assert!(sq.recall() >= sk.recall(), "{} vs {}", sq.recall(), sk.recall());
        assert!(sq.recall() > 0.9, "quoka recall {}", sq.recall());
    }

    #[test]
    fn budget_fraction_respected() {
        let t = task(2048, 3);
        let quoka = policy_by_name("quoka").unwrap();
        let s = eval_policy(
            &t,
            quoka.as_ref(),
            128,
            &EvalOpts { skip_fidelity: true, ..Default::default() },
        );
        // Probe at chunk 15: cache = 1920 entries; 128/1920 ≈ 6.7%.
        assert!(s.kv_frac < 0.10, "kv_frac {}", s.kv_frac);
        assert!(s.select_flops > 0);
    }

    #[test]
    fn fidelity_penalizes_missing_needle() {
        // KeyDiff is query-agnostic; at a small budget it should lose
        // fidelity on retrieval rows relative to QUOKA.
        let t = task(2048, 4);
        let quoka = policy_by_name("quoka").unwrap();
        let keydiff = policy_by_name("keydiff").unwrap();
        let sq = eval_policy(&t, quoka.as_ref(), 96, &EvalOpts::default());
        let sk = eval_policy(&t, keydiff.as_ref(), 96, &EvalOpts::default());
        assert!(sq.score() > sk.score(), "{} vs {}", sq.score(), sk.score());
    }
}
