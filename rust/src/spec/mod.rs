//! # Speculative decode subsystem
//!
//! Decode advances one token per engine step even after the fused batched
//! path (PR 3): every step streams the full weight set through the caches
//! to emit a single token per sequence. Speculative decoding breaks that
//! bound by *drafting* `gamma` cheap candidate tokens and *verifying* them
//! all in one multi-token forward — the same weight stream scores
//! `gamma + 1` positions, and greedy acceptance keeps every drafted token
//! up to the first disagreement plus the model's own correction token.
//!
//! The subsystem is three orthogonal pieces:
//!
//! * **Drafting** — a [`DraftSource`] proposes continuation tokens. The
//!   built-in drafter is training-free *prompt lookup*
//!   ([`PromptLookup`]): suffix-match the last few generated tokens
//!   against the prompt + generation history and propose the continuation
//!   of the most recent match. Zero model cost, hardware-agnostic, and
//!   strongest exactly on the long-context workloads this repo targets
//!   (NIAH / RULER / LongBench answers are dominated by verbatim copying
//!   from the prompt).
//! * **Verification** — `HostModel::forward_verify` runs the draft as a
//!   tiny causal chunk through the existing tile pipeline with a fused
//!   per-position row-argmax, producing the model's greedy target at
//!   every draft position in one forward. Selection runs **per position**
//!   with that position's query over exactly the cache a serial decode
//!   would have seen, so accepted tokens are *bit-identical* to
//!   non-speculative greedy decode under every selection policy and KV
//!   layout — speculation is lossless, never approximate.
//! * **Rollback** — rejected draft tokens are unwound from the KV store
//!   (`KvBuffers::truncate` / `KvPool::truncate_seq`), keeping the
//!   incremental norm cache, per-(layer, page) fill counters and per-page
//!   key-sum metadata exactly as if the rejected tokens were never
//!   appended. Rollback only ever touches exclusively-owned pages — a
//!   page shared through the radix prefix cache is copy-on-write-guarded
//!   *before* the verify forward writes into it, so shared KV is never
//!   mutated.
//!
//! The engine schedules one [`WorkItem::Verify`] per speculating decode
//! sequence (charging `gamma + 1` tokens of step budget — the width of
//! the verified chunk), and [`Metrics`] reports drafted/accepted token
//! counts, the acceptance rate and speculative decode tokens/sec.
//!
//! [`WorkItem::Verify`]: crate::coordinator::scheduler::WorkItem::Verify
//! [`Metrics`]: crate::coordinator::Metrics

pub mod prompt_lookup;

pub use prompt_lookup::PromptLookup;

/// Which drafter a speculating request uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DraftPolicy {
    /// No drafting: every decode step emits exactly one token.
    Off,
    /// Training-free n-gram prompt lookup over the prompt + generation
    /// history (see [`PromptLookup`]).
    PromptLookup,
}

/// Draft depth used when a client opts into speculation by policy alone
/// (e.g. a wire request carrying `spec_policy: "pld"` with no
/// `spec_gamma`, against a server whose own default is off).
pub const DEFAULT_GAMMA: usize = 4;

/// Per-request speculative-decode configuration. Rides the CLI
/// (`--spec-gamma` / `--spec-policy`) and the wire protocol
/// (`spec_gamma` / `spec_policy` request fields).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecCfg {
    /// Maximum draft tokens verified per decode step. 0 disables
    /// speculation regardless of `policy`.
    pub gamma: usize,
    pub policy: DraftPolicy,
}

impl Default for SpecCfg {
    fn default() -> Self {
        SpecCfg::off()
    }
}

impl SpecCfg {
    /// Speculation disabled: plain one-token decode steps.
    pub fn off() -> SpecCfg {
        SpecCfg { gamma: 0, policy: DraftPolicy::Off }
    }

    /// Prompt-lookup drafting with up to `gamma` draft tokens per step.
    pub fn prompt_lookup(gamma: usize) -> SpecCfg {
        SpecCfg { gamma, policy: DraftPolicy::PromptLookup }
    }

    /// True when decode steps should draft + verify.
    pub fn enabled(&self) -> bool {
        self.gamma > 0 && self.policy != DraftPolicy::Off
    }

    /// Parse a CLI / wire `(policy, gamma)` pair. `"off"` (or gamma 0)
    /// disables speculation; `"pld"` / `"prompt-lookup"` /
    /// `"prompt_lookup"` selects the prompt-lookup drafter.
    pub fn parse(policy: &str, gamma: usize) -> anyhow::Result<SpecCfg> {
        let cfg = match policy {
            "off" | "none" => SpecCfg::off(),
            "pld" | "prompt-lookup" | "prompt_lookup" => SpecCfg::prompt_lookup(gamma),
            other => anyhow::bail!(
                "unknown speculative-decode policy '{other}' (known: off, pld)"
            ),
        };
        Ok(cfg)
    }

    /// Stable policy name for the wire protocol / summaries.
    pub fn policy_name(&self) -> &'static str {
        match self.policy {
            DraftPolicy::Off => "off",
            DraftPolicy::PromptLookup => "pld",
        }
    }
}

/// A source of draft tokens for one sequence.
///
/// Drafters are per-sequence (the engine keeps one per speculating
/// request) so stateful implementations — adaptive gamma, learned n-gram
/// tables — have a place to live; [`PromptLookup`] itself is stateless
/// apart from acceptance feedback.
pub trait DraftSource: Send {
    /// Stable identifier for metrics / debugging.
    fn name(&self) -> &'static str;

    /// Propose up to `gamma` tokens continuing `prompt ++ generated`
    /// (`generated` is never empty during decode — its last element is
    /// the token the next forward will consume). An empty draft makes the
    /// engine fall back to a plain one-token decode step for this
    /// sequence — drafting is advisory, never required.
    fn draft(&mut self, prompt: &[u32], generated: &[u32], gamma: usize) -> Vec<u32>;

    /// Acceptance feedback after a verify step: `drafted` tokens were
    /// proposed, `accepted` survived greedy verification. Default: ignore.
    fn observe(&mut self, drafted: usize, accepted: usize) {
        let _ = (drafted, accepted);
    }
}

/// Construct the drafter for a spec config; `None` when speculation is
/// disabled.
pub fn drafter_for(cfg: &SpecCfg) -> Option<Box<dyn DraftSource>> {
    if !cfg.enabled() {
        return None;
    }
    match cfg.policy {
        DraftPolicy::Off => None,
        DraftPolicy::PromptLookup => Some(Box::new(PromptLookup::default())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_parse_and_enable() {
        assert!(!SpecCfg::off().enabled());
        assert!(!SpecCfg::prompt_lookup(0).enabled());
        assert!(SpecCfg::prompt_lookup(4).enabled());
        assert_eq!(SpecCfg::parse("off", 8).unwrap(), SpecCfg::off());
        let p = SpecCfg::parse("pld", 6).unwrap();
        assert_eq!(p, SpecCfg::prompt_lookup(6));
        assert_eq!(p.policy_name(), "pld");
        assert!(SpecCfg::parse("oracle", 4).is_err());
        assert!(drafter_for(&SpecCfg::off()).is_none());
        assert_eq!(drafter_for(&p).unwrap().name(), "prompt-lookup");
    }
}
