//! Prompt-lookup drafting: training-free n-gram speculation.
//!
//! The drafter suffix-matches the last `n` context tokens (for `n` from
//! `max_ngram` down to `min_ngram`) against the earlier context — prompt
//! *and* generation history — and proposes the tokens that followed the
//! most recent match. On copy-dominated workloads (NIAH / RULER answer
//! spans, quoting, structured repetition) the model's greedy continuation
//! often *is* a verbatim span of the prompt, so a pure string-matching
//! drafter reaches useful acceptance rates at zero model cost (Saxena,
//! 2023 — "prompt lookup decoding"; also arXiv:2304.04487's n-gram
//! drafting). The drafter never sees logits and never runs the model: it
//! is pure token arithmetic, hardware-agnostic by construction.
//!
//! Matching is a backward linear scan — O(context · max_ngram) worst case
//! per draft, which is noise next to one transformer forward (a 16k-token
//! scan is ~48k u32 compares; one decode forward is tens of millions of
//! FLOPs). A rolling-hash index would make it O(1) amortized; not worth
//! the state until contexts grow far beyond the bench geometries.

use super::DraftSource;

/// Consecutive fully-rejected drafts before the drafter backs off.
const BACKOFF_AFTER: u32 = 3;
/// Steps the drafter abstains per backoff episode (abstaining sequences
/// ride the step's fused decode batch, so a backoff costs nothing).
const BACKOFF_STEPS: u32 = 8;

/// The prompt-lookup drafter. `max_ngram`-first matching: longer suffix
/// matches are more specific, so they win over shorter ones; within one
/// length, the **most recent** occurrence wins (recent context dominates
/// long-range repetition in generation dynamics).
///
/// Acceptance feedback drives a cheap backoff: after [`BACKOFF_AFTER`]
/// consecutive drafts with zero accepted tokens, the drafter abstains for
/// [`BACKOFF_STEPS`] steps before probing again. A sequence whose context
/// merely *looks* repetitive (n-grams match but the model diverges) then
/// spends most steps in the fused decode batch instead of paying a
/// private verify forward per token — speculation degrades toward the
/// plain batched path on incompressible generations instead of falling
/// off a cliff.
#[derive(Clone, Debug)]
pub struct PromptLookup {
    /// Longest suffix length to try first.
    pub max_ngram: usize,
    /// Shortest suffix length worth matching (1 = plain bigram chains).
    pub min_ngram: usize,
    /// Consecutive zero-acceptance drafts observed.
    reject_streak: u32,
    /// Remaining steps of the current backoff episode.
    cooldown: u32,
}

impl Default for PromptLookup {
    fn default() -> Self {
        // max 3 / min 1 maximizes drafted-tokens-per-step on the repo's
        // synthetic workloads (swept offline): short-suffix fallback keeps
        // the drafter active inside loops and alternations, and wrong
        // short-match drafts cost only rejected verify positions, which
        // ride a weight stream the step pays for anyway.
        PromptLookup::new(3, 1)
    }
}

impl PromptLookup {
    pub fn new(max_ngram: usize, min_ngram: usize) -> PromptLookup {
        assert!(min_ngram >= 1 && max_ngram >= min_ngram);
        PromptLookup { max_ngram, min_ngram, reject_streak: 0, cooldown: 0 }
    }

    /// Core lookup over one flat context slice: the continuation of the
    /// most recent earlier occurrence of the longest matching suffix.
    /// Callers guarantee `gamma >= 1`, and any earlier occurrence has at
    /// least one token after it (the matched span ends at `len - n - 1 +
    /// n < len`), so a match always yields a non-empty draft.
    fn lookup(&self, ctx: &[u32], gamma: usize) -> Vec<u32> {
        debug_assert!(gamma >= 1);
        let len = ctx.len();
        for n in (self.min_ngram..=self.max_ngram).rev() {
            if len <= n {
                continue;
            }
            let pat = &ctx[len - n..];
            // Most recent earlier occurrence: scan candidate start
            // positions backward. The suffix occurrence at `len - n`
            // itself is excluded (its continuation is what we are trying
            // to predict).
            for start in (0..len - n).rev() {
                if &ctx[start..start + n] == pat {
                    return ctx[start + n..(start + n + gamma).min(len)].to_vec();
                }
            }
        }
        Vec::new()
    }
}

impl DraftSource for PromptLookup {
    fn name(&self) -> &'static str {
        "prompt-lookup"
    }

    fn draft(&mut self, prompt: &[u32], generated: &[u32], gamma: usize) -> Vec<u32> {
        if gamma == 0 || generated.is_empty() {
            return Vec::new();
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return Vec::new();
        }
        // One concat covers cross-boundary patterns (answer spans quoting
        // the prompt); the Vec is dwarfed by the verify forward it feeds.
        let mut ctx = Vec::with_capacity(prompt.len() + generated.len() + gamma);
        ctx.extend_from_slice(prompt);
        ctx.extend_from_slice(generated);
        // Chained lookup: a match near the context end yields a short
        // continuation (it runs off the edge), but appending it re-arms
        // the suffix — inside a repetition loop the chain fills the whole
        // gamma window instead of stalling at the period boundary.
        let mut out = Vec::new();
        while out.len() < gamma {
            let got = self.lookup(&ctx, gamma - out.len());
            if got.is_empty() {
                break;
            }
            ctx.extend_from_slice(&got);
            out.extend_from_slice(&got);
        }
        out
    }

    fn observe(&mut self, drafted: usize, accepted: usize) {
        if drafted == 0 {
            return;
        }
        if accepted == 0 {
            self.reject_streak += 1;
            if self.reject_streak >= BACKOFF_AFTER {
                self.cooldown = BACKOFF_STEPS;
                self.reject_streak = 0;
            }
        } else {
            self.reject_streak = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draft(ctx: &[u32], gamma: usize) -> Vec<u32> {
        PromptLookup::default().draft(&[], &ctx.to_vec(), gamma)
    }

    #[test]
    fn copies_the_continuation_of_the_latest_match() {
        // ... 7 8 9 | 1 2 3 4 5 | ... | 1 2 3  →  draft 4 5
        let ctx = [7, 8, 9, 1, 2, 3, 4, 5, 9, 9, 1, 2, 3];
        assert_eq!(draft(&ctx, 2), vec![4, 5]);
        // Gamma past the context end: the chained lookup re-matches the
        // extended suffix and keeps copying.
        assert_eq!(draft(&ctx, 8), vec![4, 5, 9, 9, 1, 2, 3, 4]);
    }

    #[test]
    fn most_recent_occurrence_wins() {
        // Suffix [1, 2] occurs twice with different continuations; the
        // later one (→ 8) must win over the earlier (→ 4).
        let ctx = [1, 2, 4, 0, 1, 2, 8, 6, 1, 2];
        assert_eq!(draft(&ctx, 1), vec![8]);
    }

    #[test]
    fn longer_ngram_beats_shorter() {
        // [5, 1, 2] (n=3) matches with continuation 7; the more recent
        // bigram [1, 2] → 9 must lose to the longer, more specific match.
        let ctx = [5, 1, 2, 7, 0, 1, 2, 9, 3, 5, 1, 2];
        assert_eq!(draft(&ctx, 1), vec![7]);
    }

    #[test]
    fn spans_the_prompt_generation_boundary() {
        let mut d = PromptLookup::default();
        // Pattern tail in prompt, head of continuation crosses into it.
        let prompt = vec![4, 5, 6, 7, 8];
        let generated = vec![4, 5, 6];
        assert_eq!(d.draft(&prompt, &generated, 4), vec![7, 8, 4, 5]);
    }

    #[test]
    fn no_match_or_degenerate_inputs_mean_no_draft() {
        let mut d = PromptLookup::default();
        assert!(d.draft(&[], &[], 4).is_empty());
        assert!(d.draft(&[1, 2, 3], &[9], 0).is_empty());
        // All-distinct context: nothing to look up.
        assert!(draft(&[1, 2, 3, 4, 5], 4).is_empty());
    }

    #[test]
    fn repetition_loop_is_fully_drafted() {
        // A period-2 generation loop: the drafter should propose the whole
        // gamma window correctly.
        let ctx = [3, 9, 3, 9, 3, 9, 3, 9];
        assert_eq!(draft(&ctx, 4), vec![3, 9, 3, 9]);
        // Constant runs likewise.
        let ctx = [5, 5, 5, 5, 5];
        assert_eq!(draft(&ctx, 3), vec![5, 5, 5]);
    }

    #[test]
    fn sustained_rejection_backs_off_then_recovers() {
        let mut d = PromptLookup::default();
        let ctx = vec![3, 9, 3, 9, 3, 9]; // always matchable
        for _ in 0..BACKOFF_AFTER {
            let n = d.draft(&[], &ctx, 4).len();
            assert!(n > 0, "drafting continues while the streak builds");
            d.observe(n, 0); // the model rejects every draft
        }
        for step in 0..BACKOFF_STEPS {
            assert!(d.draft(&[], &ctx, 4).is_empty(), "cooldown step {step} must abstain");
        }
        // The cooldown expires and drafting probes again; one accepted
        // token clears the streak.
        let n = d.draft(&[], &ctx, 4).len();
        assert!(n > 0, "drafting resumes after the cooldown");
        d.observe(n, 1);
        let n = d.draft(&[], &ctx, 4).len();
        assert!(n > 0);
        // Abstained steps (drafted == 0) never advance the streak.
        d.observe(0, 0);
        assert!(!d.draft(&[], &ctx, 4).is_empty());
    }

    #[test]
    fn min_ngram_floor_disables_short_matches() {
        let mut strict = PromptLookup::new(3, 3);
        // Only a bigram repeats: below the floor, no draft.
        assert!(strict.draft(&[], &[1, 2, 8, 1, 2], 4).is_empty());
        let mut loose = PromptLookup::new(3, 2);
        // 3 tokens from the match, 1 more from the chained re-lookup.
        assert_eq!(loose.draft(&[], &[1, 2, 8, 1, 2], 4), vec![8, 1, 2, 8]);
    }
}
