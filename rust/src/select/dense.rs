//! Dense baseline: no selection — every query attends to the full cache.

use super::{KCache, QChunk, SelectCtx, Selection, SelectionPolicy};

/// Full attention (the paper's dense baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Dense;

impl SelectionPolicy for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn select(&self, _q: &QChunk, _k: &KCache, _budget: usize, _ctx: &mut SelectCtx) -> Selection {
        Selection::All
    }

    fn is_dense(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn always_selects_everything() {
        let mut rng = Rng::new(1);
        let qd = rng.normal_vec(2 * 4 * 8, 1.0);
        let kd = rng.normal_vec(1 * 32 * 8, 1.0);
        let q = QChunk::new(&qd, 2, 4, 8);
        let k = KCache::new(&kd, 1, 32, 32, 8);
        let sel = Dense.select(&q, &k, 4, &mut SelectCtx::new(0));
        assert_eq!(sel, Selection::All);
        assert_eq!(sel.head_len(0, k.t), 32);
    }
}
