//! SampleAttention baseline (Zhu et al., 2024).
//!
//! Targets prefill but treats the chunk's queries *homogeneously*: it
//! uniformly samples `N_Q` queries per head, computes real softmax attention
//! logits against the cache, then **averages** the resulting weights across
//! queries and across the KV group's heads before the top-k. Because the
//! logits are computed per Q head (before aggregation), both its runtime and
//! memory carry the full `n_Q` factor — the contrast QUOKA's pre-aggregation
//! removes (paper Table 4).

use super::{group_size, topk_ascending, KCache, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{dot, softmax};

/// Uniform-query-sampling selection.
#[derive(Clone, Copy, Debug)]
pub struct SampleAttention {
    /// Queries sampled per head; paper default 16.
    pub n_q: usize,
}

impl Default for SampleAttention {
    fn default() -> Self {
        SampleAttention { n_q: 16 }
    }
}

impl SelectionPolicy for SampleAttention {
    fn name(&self) -> &'static str {
        "sample"
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let d = q.d;
        let scale = 1.0 / (d as f32).sqrt();
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);
        let n_q_eff = self.n_q.min(q.s);

        // ONE uniform sample of query positions, shared across all heads —
        // the "treats queries homogeneously" design the paper contrasts
        // with QUOKA's per-head geometric ranking.
        let sample = ctx.rng.sample_indices(q.s, n_q_eff);

        let mut per_head = Vec::with_capacity(n_kv);
        for kv in 0..n_kv {
            let khead = k.head(kv);
            let agg = ctx.scratch.buf_a(t);
            agg.iter_mut().for_each(|v| *v = 0.0);
            let mut row = vec![0.0f32; t];
            for gq in 0..g {
                let h = kv * g + gq;
                for &qi in &sample {
                    let qrow = q.query(h, qi);
                    for ti in 0..t {
                        row[ti] = dot(qrow, &khead[ti * d..(ti + 1) * d]) * scale;
                    }
                    softmax(&mut row);
                    for ti in 0..t {
                        agg[ti] += row[ti];
                    }
                }
                ctx.cost.add_flops((n_q_eff * t * (2 * d + 4)) as u64);
                // Memory: per-Q-head logits materialized (the n_Q factor).
                ctx.cost.add_bytes((n_q_eff * t * 4) as u64);
            }
            per_head.push(topk_ascending(agg, budget));
        }
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn selects_budget_many_valid_indices() {
        let mut rng = Rng::new(2);
        let (nh, nkv, s, t, d) = (4usize, 2usize, 32usize, 200usize, 8usize);
        let qd = rng.normal_vec(nh * s * d, 1.0);
        let kd = rng.normal_vec(nkv * t * d, 1.0);
        let q = QChunk::new(&qd, nh, s, d);
        let k = KCache::new(&kd, nkv, t, t, d);
        let sel = SampleAttention::default().select(&q, &k, 24, &mut SelectCtx::new(3));
        for h in 0..nkv {
            let idx = sel.head_indices(h, t);
            assert_eq!(idx.len(), 24);
            for w in idx.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn finds_key_all_queries_want() {
        // A key aligned with the *mean* query direction is found easily by
        // mean aggregation (it is the outlier-needle case where this
        // baseline breaks; see quoka tests).
        let (s, t, d, hot) = (16usize, 128usize, 8usize, 77usize);
        let mut rng = Rng::new(4);
        let mut qd = vec![0.0; s * d];
        for i in 0..s {
            qd[i * d] = 1.0;
            for j in 0..d {
                qd[i * d + j] += rng.normal() * 0.05;
            }
        }
        let mut kd = rng.normal_vec(t * d, 0.05);
        kd[hot * d] = 5.0; // aligned with every query
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let sel = SampleAttention::default().select(&q, &k, 8, &mut SelectCtx::new(5));
        assert!(sel.head_indices(0, t).contains(&(hot as u32)));
    }

    #[test]
    fn deterministic_given_ctx_seed() {
        let mut rng = Rng::new(6);
        let qd = rng.normal_vec(2 * 32 * 8, 1.0);
        let kd = rng.normal_vec(1 * 100 * 8, 1.0);
        let q = QChunk::new(&qd, 2, 32, 8);
        let k = KCache::new(&kd, 1, 100, 100, 8);
        let a = SampleAttention::default().select(&q, &k, 10, &mut SelectCtx::new(42));
        let b = SampleAttention::default().select(&q, &k, 10, &mut SelectCtx::new(42));
        assert_eq!(a, b);
    }
}
