//! KeyDiff baseline (Park et al., 2025 — the paper's own prior work).
//!
//! Query-*agnostic* eviction scoring: keys are ranked by their cosine
//! *dissimilarity* to the mean key — distinctive keys are retained, keys in
//! the redundant cluster are dropped. Cheap (one pass over K, no Q at all)
//! but blind to what the current queries actually need, which is why the
//! paper reports it trailing query-aware methods on RULER.

use super::{topk_ascending_into, KCache, QChunk, Scratch, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{dot, l2_norm, mean_rows};

/// Key-geometry-only selection.
#[derive(Clone, Copy, Debug, Default)]
pub struct KeyDiff;

impl SelectionPolicy for KeyDiff {
    fn name(&self) -> &'static str {
        "keydiff"
    }

    fn select(&self, _q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let d = k.d;
        let mut per_head = Vec::with_capacity(k.n_heads);
        for kv in 0..k.n_heads {
            let khead = k.head(kv);
            let cost = &mut ctx.cost;
            let Scratch { a, c, idx, .. } = &mut ctx.scratch;
            let (scores, mean) = (super::fit(a, t), super::fit(c, d));
            mean_rows(&khead[..t * d], t, d, mean);
            let mn = l2_norm(&*mean);
            let inv_mn = if mn > 0.0 { 1.0 / mn } else { 0.0 };
            for ti in 0..t {
                let key = &khead[ti * d..(ti + 1) * d];
                // Key norms come from the incremental norm cache when the
                // view carries one (computed once at append time).
                let kinv = k.inv_norm(kv, ti);
                scores[ti] = -dot(key, mean) * kinv * inv_mn; // dissimilarity
            }
            // One dot per key; the norm pass is cached when available.
            let norm_flops = if k.inv_norms.is_some() { 0 } else { 2 * d };
            cost.add_flops((t * (2 * d + norm_flops)) as u64);
            cost.add_bytes((t * d * 4) as u64);
            per_head.push(topk_ascending_into(scores, budget, idx));
        }
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn keeps_distinctive_keys() {
        let (t, d) = (100usize, 8usize);
        let mut rng = Rng::new(61);
        let mut kd = vec![0.0; t * d];
        for i in 0..t {
            kd[i * d] = 1.0; // redundant cluster on e0
            for j in 0..d {
                kd[i * d + j] += rng.normal() * 0.02;
            }
        }
        kd[42 * d] = 0.0;
        kd[42 * d + 3] = 1.0; // distinctive key
        let qd = rng.normal_vec(4 * d, 1.0);
        let q = QChunk::new(&qd, 1, 4, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let sel = KeyDiff.select(&q, &k, 5, &mut SelectCtx::new(0));
        assert!(sel.head_indices(0, t).contains(&42));
    }

    #[test]
    fn ignores_queries_entirely() {
        let mut rng = Rng::new(62);
        let (t, d) = (64usize, 8usize);
        let kd = rng.normal_vec(t * d, 1.0);
        let qa = rng.normal_vec(4 * d, 1.0);
        let qb = rng.normal_vec(4 * d, 1.0);
        let k = KCache::new(&kd, 1, t, t, d);
        let sa = KeyDiff.select(&QChunk::new(&qa, 1, 4, d), &k, 8, &mut SelectCtx::new(0));
        let sb = KeyDiff.select(&QChunk::new(&qb, 1, 4, d), &k, 8, &mut SelectCtx::new(0));
        assert_eq!(sa, sb);
    }
}
