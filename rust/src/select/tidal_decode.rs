//! TidalDecode baseline (Yang et al., 2024b) — position-persistent sparse
//! attention, as used in the paper's LongBench comparison (Table 6).
//!
//! A few early *full* layers, then one re-selection layer computes token
//! positions from real attention scores; every later layer reuses those
//! positions verbatim (the "position persistent" idea — selection cost is
//! paid once per step, not per layer). Designed for decode; under chunked
//! prefill the persistent positions inherit the re-selection layer's
//! homogeneous query treatment.

use super::{group_size, topk_ascending, KCache, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{dot, softmax};

/// Position-persistent selection.
#[derive(Clone, Copy, Debug)]
pub struct TidalDecode {
    /// Layers `< full_layers` run dense.
    pub full_layers: usize,
    /// The layer that computes the persistent positions.
    pub select_layer: usize,
    /// Queries scored at the selection layer (last-window, like decode).
    pub obs_window: usize,
}

impl Default for TidalDecode {
    fn default() -> Self {
        TidalDecode { full_layers: 2, select_layer: 2, obs_window: 16 }
    }
}

impl SelectionPolicy for TidalDecode {
    fn name(&self) -> &'static str {
        "tidaldecode"
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        if ctx.layer < self.full_layers {
            return Selection::All;
        }
        if ctx.layer != self.select_layer {
            if let Some(shared) = &ctx.shared_indices {
                if shared.len() == k.n_heads {
                    let reused: Vec<Vec<u32>> = shared
                        .iter()
                        .map(|v| v.iter().copied().filter(|&i| (i as usize) < t).collect())
                        .collect();
                    return Selection::PerHead(reused);
                }
            }
            // Shared state missing (e.g. probed in isolation): fall through
            // and compute, as the re-selection layer would.
        }

        let d = q.d;
        let scale = 1.0 / (d as f32).sqrt();
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);
        let w_start = q.s.saturating_sub(self.obs_window);

        let mut per_head = Vec::with_capacity(n_kv);
        let mut row = vec![0.0f32; t];
        for kv in 0..n_kv {
            let khead = k.head(kv);
            let agg = ctx.scratch.buf_a(t);
            agg.iter_mut().for_each(|v| *v = 0.0);
            for gq in 0..g {
                let h = kv * g + gq;
                for i in w_start..q.s {
                    let qrow = q.query(h, i);
                    for ti in 0..t {
                        row[ti] = dot(qrow, &khead[ti * d..(ti + 1) * d]) * scale;
                    }
                    softmax(&mut row);
                    for ti in 0..t {
                        agg[ti] += row[ti];
                    }
                }
                ctx.cost.add_flops(((q.s - w_start) * t * (2 * d + 4)) as u64);
                ctx.cost.add_bytes(((q.s - w_start) * t * 4) as u64);
            }
            per_head.push(topk_ascending(agg, budget));
        }
        ctx.shared_indices = Some(per_head.clone());
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(rng: &mut Rng, t: usize) -> (Vec<f32>, Vec<f32>) {
        (rng.normal_vec(2 * 8 * 8, 1.0), rng.normal_vec(1 * t * 8, 1.0))
    }

    #[test]
    fn early_layers_are_dense() {
        let mut rng = Rng::new(71);
        let (qd, kd) = mk(&mut rng, 100);
        let q = QChunk::new(&qd, 2, 8, 8);
        let k = KCache::new(&kd, 1, 100, 100, 8);
        let mut ctx = SelectCtx::new(0);
        ctx.layer = 0;
        assert_eq!(TidalDecode::default().select(&q, &k, 16, &mut ctx), Selection::All);
        ctx.layer = 1;
        assert_eq!(TidalDecode::default().select(&q, &k, 16, &mut ctx), Selection::All);
    }

    #[test]
    fn positions_persist_across_later_layers() {
        let mut rng = Rng::new(72);
        let (qd, kd) = mk(&mut rng, 120);
        let q = QChunk::new(&qd, 2, 8, 8);
        let k = KCache::new(&kd, 1, 120, 120, 8);
        let mut ctx = SelectCtx::new(0);
        ctx.layer = 2;
        let sel2 = TidalDecode::default().select(&q, &k, 16, &mut ctx);
        assert!(ctx.shared_indices.is_some());
        // Later layers with *different* queries reuse the same positions.
        let qd2 = rng.normal_vec(2 * 8 * 8, 1.0);
        let q2 = QChunk::new(&qd2, 2, 8, 8);
        ctx.layer = 5;
        let sel5 = TidalDecode::default().select(&q2, &k, 16, &mut ctx);
        assert_eq!(sel2, sel5);
    }

    #[test]
    fn isolated_probe_still_selects() {
        // Without shared state at a late layer, it recomputes (contract
        // safety for single-layer eval probes).
        let mut rng = Rng::new(73);
        let (qd, kd) = mk(&mut rng, 90);
        let q = QChunk::new(&qd, 2, 8, 8);
        let k = KCache::new(&kd, 1, 90, 90, 8);
        let mut ctx = SelectCtx::new(0);
        ctx.layer = 7;
        let sel = TidalDecode::default().select(&q, &k, 12, &mut ctx);
        assert_eq!(sel.head_indices(0, 90).len(), 12);
    }
}
