//! SnapKV baseline (Li et al., 2024).
//!
//! Built for generation-time cache *eviction*: score each cached key by the
//! softmax attention mass it receives from an **observation window** (the
//! last `window` queries of the chunk), pool the scores over a small kernel
//! along the key axis (cluster retention), and keep the top `B_SA`. Queries
//! outside the window are ignored — the homogeneous-query assumption QUOKA
//! drops.

use super::{group_size, topk_ascending, KCache, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{dot, softmax};

/// Observation-window attention-mass selection.
#[derive(Clone, Copy, Debug)]
pub struct SnapKv {
    /// Observation window (queries at the chunk tail).
    pub window: usize,
    /// Max-pool kernel width along the key axis.
    pub pool: usize,
}

impl Default for SnapKv {
    fn default() -> Self {
        SnapKv { window: 16, pool: 7 }
    }
}

impl SelectionPolicy for SnapKv {
    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let d = q.d;
        let scale = 1.0 / (d as f32).sqrt();
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);
        let w = self.window.min(q.s);
        let w_start = q.s - w;

        let mut per_head = Vec::with_capacity(n_kv);
        let mut row = vec![0.0f32; t];
        for kv in 0..n_kv {
            let khead = k.head(kv);
            let (agg, pooled) = ctx.scratch.bufs_ab(t, t);
            agg.iter_mut().for_each(|v| *v = 0.0);
            for gq in 0..g {
                let h = kv * g + gq;
                for i in w_start..q.s {
                    let qrow = q.query(h, i);
                    for ti in 0..t {
                        row[ti] = dot(qrow, &khead[ti * d..(ti + 1) * d]) * scale;
                    }
                    softmax(&mut row);
                    for ti in 0..t {
                        agg[ti] += row[ti];
                    }
                }
                ctx.cost.add_flops((w * t * (2 * d + 4)) as u64);
                ctx.cost.add_bytes((w * t * 4) as u64);
            }
            // Max-pool along the key axis: a strong key promotes its
            // neighbourhood (SnapKV's clustering trick).
            let half = self.pool / 2;
            for ti in 0..t {
                let lo = ti.saturating_sub(half);
                let hi = (ti + half + 1).min(t);
                let mut m = f32::NEG_INFINITY;
                for tj in lo..hi {
                    if agg[tj] > m {
                        m = agg[tj];
                    }
                }
                pooled[ti] = m;
            }
            per_head.push(topk_ascending(pooled, budget));
        }
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn window_queries_drive_selection() {
        // A key matched only by EARLY queries (outside the window) should
        // lose to a key matched by the LAST query.
        let (s, t, d) = (32usize, 128usize, 8usize);
        let mut rng = Rng::new(51);
        let mut qd = rng.normal_vec(s * d, 0.05);
        // early query 0 points at e0; last query points at e1
        qd[0] = 3.0;
        qd[(s - 1) * d + 1] = 3.0;
        let mut kd = rng.normal_vec(t * d, 0.05);
        kd[30 * d] = 4.0; // matches early query only
        kd[90 * d + 1] = 4.0; // matches window query
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let snap = SnapKv { window: 4, pool: 1 };
        let sel = snap.select(&q, &k, 4, &mut SelectCtx::new(0));
        let idx = sel.head_indices(0, t);
        assert!(idx.contains(&90), "window-matched key missing: {idx:?}");
        assert!(!idx.contains(&30), "out-of-window key should be missed by SnapKV");
    }

    #[test]
    fn pooling_promotes_neighbourhood() {
        let (s, t, d) = (8usize, 64usize, 8usize);
        let mut rng = Rng::new(52);
        let mut qd = rng.normal_vec(s * d, 0.02);
        for i in 0..s {
            qd[i * d] = 1.0;
        }
        let mut kd = rng.normal_vec(t * d, 0.02);
        kd[40 * d] = 5.0;
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let sel = SnapKv { window: 8, pool: 7 }.select(&q, &k, 7, &mut SelectCtx::new(0));
        let idx = sel.head_indices(0, t);
        // The hot key and its pooled neighbours should be present.
        assert!(idx.contains(&40));
        assert!(idx.contains(&39) || idx.contains(&41), "{idx:?}");
    }

    #[test]
    fn contract_holds() {
        let mut rng = Rng::new(53);
        let (nh, nkv, s, t, d) = (4usize, 2usize, 16usize, 100usize, 8usize);
        let qd = rng.normal_vec(nh * s * d, 1.0);
        let kd = rng.normal_vec(nkv * t * d, 1.0);
        let q = QChunk::new(&qd, nh, s, d);
        let k = KCache::new(&kd, nkv, t, t, d);
        let sel = SnapKv::default().select(&q, &k, 12, &mut SelectCtx::new(0));
        for h in 0..nkv {
            let idx = sel.head_indices(h, t);
            assert_eq!(idx.len(), 12);
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
