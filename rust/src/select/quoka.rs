//! QUOKA: Query-oriented KV selection (paper Algorithm 1).
//!
//! Three stages per chunk:
//! 1. **Query subselection** — when the chunk holds more than `N_Q` queries,
//!    rank each query `q` by `S_q = -CosSim(M_Q, q)` (angular distance from
//!    the per-head mean query `M_Q`) and keep the top `N_Q`. Theorem 1 shows
//!    these are exactly the queries that can attend strongly to keys the
//!    mean query ignores.
//! 2. **Cosine-similarity scoring with GQA pre-aggregation** — normalize the
//!    retained queries and the keys; *average the normalized queries across
//!    each KV group first* (valid because the mean commutes with `Q̄Kᵀ`),
//!    then score `S = Q̄Kᵀ ∈ [N_Q, T]` per KV head. Pre-aggregation cuts
//!    both compute and memory by the group size versus aggregating scores.
//! 3. **Max aggregation + top-k** — `Ŝ = max over queries` (preserving rare
//!    but strong query–key interactions; Table 10), then keep the top
//!    `B_SA` keys per KV head.
//!
//! The ablation switches ([`Scoring::Dot`], [`QueryAgg::Mean`]) reproduce
//! Tables 9 and 10.

use super::{
    fit, group_size, topk_ascending_into, KCache, Pages, QChunk, Scratch, SelectCtx, Selection,
    SelectionPolicy,
};
use crate::tensor::ops::{dot, l2_norm, mean_rows, qk_block, qk_block_q8, topk_indices_into};
use crate::util::threadpool::SyncPtr;

/// Key rows per scan tile: the `[n_q_eff, SCAN_TILE]` score block stays
/// cache-resident (16 × 512 × 4 B = 32 KiB) while tiles remain large
/// enough to amortize the fork-join handoff.
const SCAN_TILE: usize = 512;

/// Key-relevance scoring function (Table 9 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scoring {
    /// Cosine similarity (the QUOKA default): bounded, scale-free, stable
    /// under aggregation.
    Cosine,
    /// Raw dot product `QKᵀ` (what most prior query-dependent methods use).
    Dot,
}

/// Aggregation across the (subselected) query axis (Table 10 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryAgg {
    /// Maximum over queries (the QUOKA default) — keeps heavy-tailed
    /// outlier interactions visible.
    Max,
    /// Mean over queries — obscures rare but important interactions.
    Mean,
}

/// QUOKA hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct QuokaConfig {
    /// Max queries retained per head (`N_Q`); paper default 16.
    pub n_q: usize,
    pub scoring: Scoring,
    pub query_agg: QueryAgg,
}

impl Default for QuokaConfig {
    fn default() -> Self {
        QuokaConfig { n_q: 16, scoring: Scoring::Cosine, query_agg: QueryAgg::Max }
    }
}

/// The QUOKA selection policy.
#[derive(Clone, Debug, Default)]
pub struct Quoka {
    pub cfg: QuokaConfig,
}

impl Quoka {
    pub fn new(cfg: QuokaConfig) -> Quoka {
        Quoka { cfg }
    }

    /// Stage 1: indices of the `n_q` queries of head `h` with the *lowest*
    /// cosine similarity to the head's mean query, left in
    /// `ctx.scratch.idx` (rank order — most dissimilar first, NOT index
    /// order: Alg. 1's group pre-aggregation pairs retained queries across
    /// the KV group's heads by this rank, which keeps the pairing
    /// invariant to query order within the chunk). Allocation-free: mean,
    /// similarity and index buffers all come from the scratch arena.
    fn subselect_into(&self, q: &QChunk, h: usize, ctx: &mut SelectCtx) {
        let (s, d) = (q.s, q.d);
        if s <= self.cfg.n_q {
            let idx = &mut ctx.scratch.idx;
            idx.clear();
            idx.extend(0..s);
            return;
        }
        let head = q.head(h);
        let cost = &mut ctx.cost;
        let Scratch { a, c, idx, .. } = &mut ctx.scratch;
        let mean = fit(c, d);
        mean_rows(head, s, d, mean);
        let mean_norm = l2_norm(mean);
        cost.add_flops((2 * s * d) as u64); // mean + norms
        // S_q = -CosSim(M_Q, q_i); rank descending by S_q == ascending CosSim.
        let neg_sims = fit(a, s);
        for i in 0..s {
            let qi = &head[i * d..(i + 1) * d];
            let n = l2_norm(qi);
            neg_sims[i] = if n == 0.0 || mean_norm == 0.0 {
                0.0
            } else {
                -dot(qi, mean) / (n * mean_norm)
            };
        }
        cost.add_flops((2 * s * d) as u64);
        topk_indices_into(neg_sims, self.cfg.n_q, idx);
    }

    /// Test-visible wrapper around [`Quoka::subselect_into`].
    #[cfg(test)]
    fn subselect_queries(&self, q: &QChunk, h: usize, ctx: &mut SelectCtx) -> Vec<usize> {
        self.subselect_into(q, h, ctx);
        ctx.scratch.idx.clone()
    }

    /// Stages 2b + 3 over a **paged** cache: block-metadata-first scan.
    ///
    /// 1. Score every page by its mean-key cosine against the
    ///    pre-aggregated queries (`cos(q̄_row, Σk) == cos(q̄_row, mean k)`
    ///    — cosine is scale-free, so the incrementally maintained key sum
    ///    stands in for the mean with no fill count). `Scoring::Dot` uses
    ///    the true mean (sum / filled rows).
    /// 2. Descend into the top `⌈2·budget/block_tokens⌉ + 1` pages — at
    ///    least `budget` candidate keys with 2× overscan headroom — and run
    ///    the exact per-key scan only on their (page-contiguous) head rows.
    /// 3. Top-`budget` over the exact scores; skipped pages keep `-∞` and
    ///    can never be selected because the descended set always holds
    ///    `>= budget` scored keys.
    ///
    /// This is the Double-Sparsity / CompactAttention move: O(T/block)
    /// metadata reads gate the O(T·d) key scan, so whole pages of
    /// irrelevant context are never touched. Expects `ctx.scratch.b` to
    /// hold the `[n_q_eff, d]` pre-aggregated queries from stage 2a.
    fn scan_paged(
        &self,
        k: &KCache,
        pg: Pages,
        kv: usize,
        n_q_eff: usize,
        budget: usize,
        ctx: &mut SelectCtx,
    ) -> Vec<u32> {
        let (t, d, n_kv) = (k.t, k.d, k.n_heads);
        let bt = pg.block_tokens;
        let n_blocks = t.div_ceil(bt);
        let cost = &mut ctx.cost;
        let Scratch { a, b, c, idx, workers, .. } = &mut ctx.scratch;
        let qbar: &[f32] = &b[..n_q_eff * d];

        // ---- metadata pass: one score per page ----
        let bscores = fit(c, n_blocks);
        for j in 0..n_blocks {
            let filled = (t - j * bt).min(bt);
            let page = pg.blocks[j] as usize;
            let sums = &pg.key_sums[(page * n_kv + kv) * d..(page * n_kv + kv + 1) * d];
            let scale = match self.cfg.scoring {
                Scoring::Cosine => {
                    let n = l2_norm(sums);
                    if n > 0.0 {
                        1.0 / n
                    } else {
                        0.0
                    }
                }
                Scoring::Dot => 1.0 / filled as f32,
            };
            let mut best = f32::NEG_INFINITY;
            for nq in 0..n_q_eff {
                let v = dot(&qbar[nq * d..(nq + 1) * d], sums);
                if v > best {
                    best = v;
                }
            }
            bscores[j] = best * scale;
        }
        cost.add_flops((n_blocks * n_q_eff * 2 * d) as u64);
        cost.add_bytes((n_blocks * d * 4) as u64);

        // ---- descend set ----
        let n_desc = ((2 * budget).div_ceil(bt) + 1).min(n_blocks);
        let descend = topk_ascending_into(&bscores[..n_blocks], n_desc, idx);

        // ---- exact scan within surviving pages ----
        let scores = fit(a, t);
        scores.fill(f32::NEG_INFINITY);
        if workers.is_empty() {
            workers.push(Vec::new());
        }
        let blk_arena = &mut workers[0];
        if blk_arena.len() < n_q_eff * bt {
            blk_arena.resize(n_q_eff * bt, 0.0);
        }
        let mut scanned = 0usize;
        for &jb in &descend {
            let j = jb as usize;
            let lo = j * bt;
            let tn = (t - lo).min(bt);
            let page = pg.blocks[j] as usize;
            // Per-page head rows are contiguous: tile the micro-kernel
            // straight over the page, no gather. Quantized pages are scored
            // through the int8 kernel — codes dequantize in registers, the
            // page streams at one byte per element.
            let base = (page * n_kv + kv) * bt * d;
            let blk = &mut blk_arena[..n_q_eff * tn];
            match k.quant {
                None => {
                    qk_block(qbar, n_q_eff, &k.data[base..base + tn * d], tn, d, blk);
                }
                Some(qk) => {
                    let mb = (page * n_kv + kv) * bt;
                    qk_block_q8(
                        qbar,
                        n_q_eff,
                        &qk.codes[base..base + tn * d],
                        &qk.scales[mb..mb + tn],
                        tn,
                        d,
                        blk,
                    );
                }
            }
            for jj in 0..tn {
                // kinv >= 0, so scaling commutes with max/mean.
                let kinv = match self.cfg.scoring {
                    Scoring::Cosine => k.inv_norm(kv, lo + jj),
                    Scoring::Dot => 1.0,
                };
                scores[lo + jj] = match self.cfg.query_agg {
                    QueryAgg::Max => {
                        let mut best = f32::NEG_INFINITY;
                        for nq in 0..n_q_eff {
                            let v = blk[nq * tn + jj];
                            if v > best {
                                best = v;
                            }
                        }
                        best * kinv
                    }
                    QueryAgg::Mean => {
                        let mut acc = 0.0;
                        for nq in 0..n_q_eff {
                            acc += blk[nq * tn + jj];
                        }
                        acc * kinv / n_q_eff as f32
                    }
                };
            }
            scanned += tn;
        }
        debug_assert!(scanned >= budget.min(t), "descend set must cover the budget");
        let key_bytes = if k.quant.is_some() { d + 4 } else { d * 4 };
        cost.add_flops((scanned * n_q_eff * 2 * d) as u64);
        cost.add_bytes((scanned * key_bytes) as u64);
        cost.add_skipped_keys((t - scanned) as u64);

        topk_ascending_into(&scores[..t], budget, idx)
    }
}

impl SelectionPolicy for Quoka {
    fn name(&self) -> &'static str {
        match (self.cfg.scoring, self.cfg.query_agg) {
            (Scoring::Cosine, QueryAgg::Max) => "quoka",
            (Scoring::Dot, _) => "quoka-dot",
            (Scoring::Cosine, QueryAgg::Mean) => "quoka-mean",
        }
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let d = q.d;
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);
        let n_q_eff = self.cfg.n_q.min(q.s);

        let mut per_head = Vec::with_capacity(n_kv);
        for kv in 0..n_kv {
            // ---- Stage 1 + 2a: per Q-head subselection, normalization and
            // pre-aggregation of normalized queries over the KV group.
            // qbar layout: [n_q_eff, d], held in scratch `b` across the
            // group loop (subselection itself uses `a`/`c`/`idx`).
            {
                let b = &mut ctx.scratch.b;
                if b.len() < n_q_eff * d {
                    b.resize(n_q_eff * d, 0.0);
                }
                b[..n_q_eff * d].fill(0.0);
            }
            for gq in 0..g {
                let h = kv * g + gq;
                self.subselect_into(q, h, ctx); // keep list (rank order) in scratch.idx
                let head = q.head(h);
                let Scratch { b, idx, .. } = &mut ctx.scratch;
                debug_assert_eq!(idx.len(), n_q_eff);
                for (slot, &qi) in idx.iter().enumerate() {
                    let row = &head[qi * d..(qi + 1) * d];
                    match self.cfg.scoring {
                        Scoring::Cosine => {
                            // Normalize before averaging: the group mean of
                            // unit queries, dotted with unit keys, equals the
                            // group-mean cosine score (pre-aggregation).
                            let n = l2_norm(row);
                            let inv = if n > 0.0 { 1.0 / (n * g as f32) } else { 0.0 };
                            for (o, &v) in b[slot * d..(slot + 1) * d].iter_mut().zip(row) {
                                *o += v * inv;
                            }
                        }
                        Scoring::Dot => {
                            let inv = 1.0 / g as f32;
                            for (o, &v) in b[slot * d..(slot + 1) * d].iter_mut().zip(row) {
                                *o += v * inv;
                            }
                        }
                    }
                }
            }
            ctx.cost.add_flops((g * n_q_eff * 2 * d) as u64);
            ctx.cost.add_bytes((n_q_eff * d * 4) as u64);

            // ---- Stage 2b/3, block-table-aware path: over a paged cache
            // the scan goes metadata-first — score each page's mean key,
            // descend only into surviving pages (see `scan_paged`).
            if let Some(pg) = k.pages {
                per_head.push(self.scan_paged(k, pg, kv, n_q_eff, budget, ctx));
                continue;
            }

            // ---- Stage 2b: S = Q̄ Kᵀ over the valid cache rows, with keys
            // normalized for cosine scoring via the *incremental norm
            // cache* (computed once at append time — no O(T·d) rescan).
            // ---- Stage 3: aggregate over the query axis into score[t].
            //
            // The scan walks the (contiguous) key slab in SCAN_TILE blocks
            // through the register-blocked `qk_block` micro-kernel; workers
            // own disjoint tile ranges plus a per-worker score block from
            // the scratch arena (§Perf: the scan is the selection's only
            // O(T) term).
            let capacity = k.capacity;
            let (khead, kq) = match k.quant {
                None => (k.head(kv), None),
                // Quantized cache: scan the int8 code slab of this head with
                // its per-row scales — there is no f32 slab to walk.
                Some(qk) => (
                    &[][..],
                    Some((
                        &qk.codes[kv * capacity * d..(kv + 1) * capacity * d],
                        &qk.scales[kv * capacity..(kv + 1) * capacity],
                    )),
                ),
            };
            let cost = &mut ctx.cost;
            let Scratch { a, b, idx, workers, .. } = &mut ctx.scratch;
            let scores = fit(a, t);
            let qbar: &[f32] = &b[..n_q_eff * d];
            let n_tiles = t.div_ceil(SCAN_TILE);
            let threads = if t * n_q_eff * d > 1 << 21 {
                crate::util::threadpool::default_workers().min(n_tiles).max(1)
            } else {
                1
            };
            if workers.len() < threads {
                workers.resize_with(threads, Vec::new);
            }
            for w in workers[..threads].iter_mut() {
                if w.len() < n_q_eff * SCAN_TILE {
                    w.resize(n_q_eff * SCAN_TILE, 0.0);
                }
            }
            let sp = SyncPtr::new(scores.as_mut_ptr());
            let wp = SyncPtr::new(workers.as_mut_ptr());
            let scoring = self.cfg.scoring;
            let agg = self.cfg.query_agg;
            crate::util::threadpool::parallel_for(threads, threads, |w| {
                // SAFETY: worker `w` owns scratch slot `w` and writes only
                // the disjoint score ranges of its strided tiles. Striding
                // (w, w+threads, …) keeps the near-uniform tiles balanced
                // even when n_tiles is not a multiple of threads.
                let blk_arena = unsafe { &mut *wp.get().add(w) };
                for tile in (w..n_tiles).step_by(threads) {
                    let lo = tile * SCAN_TILE;
                    let hi = (lo + SCAN_TILE).min(t);
                    let tn = hi - lo;
                    let blk = &mut blk_arena[..n_q_eff * tn];
                    match kq {
                        None => qk_block(qbar, n_q_eff, &khead[lo * d..hi * d], tn, d, blk),
                        Some((codes, scales)) => qk_block_q8(
                            qbar,
                            n_q_eff,
                            &codes[lo * d..hi * d],
                            &scales[lo..hi],
                            tn,
                            d,
                            blk,
                        ),
                    }
                    let out = unsafe { std::slice::from_raw_parts_mut(sp.get().add(lo), tn) };
                    for (o, j) in out.iter_mut().zip(0..tn) {
                        // kinv >= 0, so scaling commutes with max/mean.
                        let kinv = match scoring {
                            Scoring::Cosine => k.inv_norm(kv, lo + j),
                            Scoring::Dot => 1.0,
                        };
                        *o = match agg {
                            QueryAgg::Max => {
                                let mut best = f32::NEG_INFINITY;
                                for nq in 0..n_q_eff {
                                    let v = blk[nq * tn + j];
                                    if v > best {
                                        best = v;
                                    }
                                }
                                best * kinv
                            }
                            QueryAgg::Mean => {
                                let mut acc = 0.0;
                                for nq in 0..n_q_eff {
                                    acc += blk[nq * tn + j];
                                }
                                acc * kinv / n_q_eff as f32
                            }
                        };
                    }
                }
            });
            let key_bytes = if k.quant.is_some() { d + 4 } else { d * 4 };
            cost.add_flops((t * n_q_eff * 2 * d) as u64);
            cost.add_bytes((t * key_bytes) as u64);

            per_head.push(topk_ascending_into(&scores[..t], budget, idx));
        }
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Build a toy geometry where the ground truth is known:
    /// - most queries cluster around +e0 (near the mean),
    /// - one "retrieval" query points at +e1 (dissimilar from the mean),
    /// - most keys cluster at -e0 (ignored by everyone),
    /// - one "needle" key points at +e1 (only the retrieval query wants it).
    fn toy(d: usize, s: usize, t: usize, needle: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(99);
        let mut q = vec![0.0; s * d];
        for i in 0..s {
            q[i * d] = 1.0; // cluster on e0
            for j in 0..d {
                q[i * d + j] += rng.normal() * 0.05;
            }
        }
        // Last query is the retrieval query on e1.
        let last = s - 1;
        q[last * d] = 0.0;
        q[last * d + 1] = 1.0;
        let mut k = vec![0.0; t * d];
        for i in 0..t {
            k[i * d] = -1.0; // anti-aligned cluster
            for j in 0..d {
                k[i * d + j] += rng.normal() * 0.05;
            }
        }
        k[needle * d] = 0.0;
        k[needle * d + 1] = 1.0; // the needle aligns with the retrieval query
        (q, k)
    }

    #[test]
    fn finds_planted_needle() {
        let (d, s, t, needle) = (16usize, 32usize, 256usize, 137usize);
        let (qd, kd) = toy(d, s, t, needle);
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let mut ctx = SelectCtx::new(0);
        let quoka = Quoka::default();
        let sel = quoka.select(&q, &k, 16, &mut ctx);
        let idx = sel.head_indices(0, t);
        assert!(idx.contains(&(needle as u32)), "needle {needle} not in {idx:?}");
    }

    #[test]
    fn mean_aggregation_misses_needle_when_max_finds_it() {
        // With many near-mean queries and one retrieval query, the mean
        // over *all* scores dilutes the needle; max keeps it. This is the
        // paper's Table 10 mechanism in miniature.
        let (d, s, t, needle) = (16usize, 64usize, 512usize, 300usize);
        let (qd, mut kd) = toy(d, s, t, needle);
        // Distractor keys partially aligned with the query cluster: every
        // near-mean query gives them cos ≈ 0.89, so their MEAN score beats
        // the needle's (≈ 1/64) while their MAX (0.89) stays below the
        // needle's (≈ 0.99 from the retrieval query).
        for i in 0..20 {
            for j in 0..d {
                kd[i * d + j] = 0.0;
            }
            kd[i * d] = 1.0;
            kd[i * d + 2] = 0.5;
        }
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);

        // Disable query subselection (n_q = s) to isolate the aggregation
        // axis: with subselection on, even the mean variant can win.
        let mut ctx = SelectCtx::new(0);
        let maxv = Quoka::new(QuokaConfig { n_q: s, ..QuokaConfig::default() });
        let sel_max = maxv.select(&q, &k, 8, &mut ctx);
        assert!(sel_max.head_indices(0, t).contains(&(needle as u32)));

        let meanv = Quoka::new(QuokaConfig { n_q: s, query_agg: QueryAgg::Mean, ..QuokaConfig::default() });
        let sel_mean = meanv.select(&q, &k, 8, &mut ctx);
        assert!(
            !sel_mean.head_indices(0, t).contains(&(needle as u32)),
            "mean aggregation over 64 near-mean queries should dilute a single needle"
        );
    }

    #[test]
    fn query_subselection_keeps_dissimilar_query() {
        let (d, s, _t, _n) = (16usize, 32usize, 64usize, 0usize);
        let (qd, _) = toy(d, s, 64, 0);
        let q = QChunk::new(&qd, 1, s, d);
        let quoka = Quoka::new(QuokaConfig { n_q: 4, ..QuokaConfig::default() });
        let mut ctx = SelectCtx::new(0);
        let keep = quoka.subselect_queries(&q, 0, &mut ctx);
        assert_eq!(keep.len(), 4);
        assert!(keep.contains(&(s - 1)), "the e1 retrieval query must rank most dissimilar");
    }

    #[test]
    fn returns_all_under_budget() {
        let mut rng = Rng::new(5);
        let (d, s, t) = (8usize, 4usize, 10usize);
        let qd = rng.normal_vec(s * d, 1.0);
        let kd = rng.normal_vec(t * d, 1.0);
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let sel = Quoka::default().select(&q, &k, 32, &mut SelectCtx::new(0));
        assert_eq!(sel, Selection::All);
    }

    #[test]
    fn respects_budget_and_order() {
        let mut rng = Rng::new(6);
        let (d, s, t, nh, nkv) = (8usize, 16usize, 128usize, 4usize, 2usize);
        let qd = rng.normal_vec(nh * s * d, 1.0);
        let kd = rng.normal_vec(nkv * t * d, 1.0);
        let q = QChunk::new(&qd, nh, s, d);
        let k = KCache::new(&kd, nkv, t, t, d);
        let sel = Quoka::default().select(&q, &k, 16, &mut SelectCtx::new(0));
        if let Selection::PerHead(v) = sel {
            assert_eq!(v.len(), nkv);
            for head in v {
                assert_eq!(head.len(), 16);
                for w in head.windows(2) {
                    assert!(w[0] < w[1]);
                }
                assert!(head.iter().all(|&i| (i as usize) < t));
            }
        } else {
            panic!("expected PerHead");
        }
    }

    #[test]
    fn gqa_preaggregation_equals_postaggregation() {
        // The paper's pre-aggregation claim: averaging normalized queries
        // across the KV group before QKᵀ equals averaging the per-head
        // cosine score matrices. Verify numerically on random data by
        // comparing selections with group size 2 vs an explicit
        // post-aggregated construction.
        let mut rng = Rng::new(7);
        let (d, s, t, g) = (8usize, 4usize, 96usize, 2usize);
        let qd = rng.normal_vec(g * s * d, 1.0);
        let kd = rng.normal_vec(t * d, 1.0);
        let q = QChunk::new(&qd, g, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let quoka = Quoka::new(QuokaConfig { n_q: s, ..QuokaConfig::default() });
        let sel = quoka.select(&q, &k, 8, &mut SelectCtx::new(0));

        // Explicit post-aggregation oracle.
        let mut scores = vec![f32::NEG_INFINITY; t];
        for ti in 0..t {
            for qi in 0..s {
                let mut acc = 0.0;
                for h in 0..g {
                    acc += crate::tensor::ops::cosine(q.query(h, qi), k.key(0, ti));
                }
                let v = acc / g as f32;
                if v > scores[ti] {
                    scores[ti] = v;
                }
            }
        }
        let want = crate::select::topk_ascending(&scores, 8);
        assert_eq!(sel.head_indices(0, t), want);
    }

    /// Identity-mapped paged view over contiguous `[t, d]` single-head
    /// data: with `blocks[j] == j` the pool layout `[page, 1, bt, d]`
    /// coincides with the contiguous layout, so the same buffer serves
    /// both views and any divergence is the scan's, not the data's.
    fn paged_fixture(kd: &[f32], t: usize, d: usize, bt: usize) -> (Vec<f32>, Vec<f32>, Vec<u32>) {
        assert_eq!(t % bt, 0);
        let n_blocks = t / bt;
        let mut norms = vec![0.0f32; t];
        for (i, n) in norms.iter_mut().enumerate() {
            let l = crate::tensor::ops::l2_norm(&kd[i * d..(i + 1) * d]);
            *n = if l > 0.0 { 1.0 / l } else { 0.0 };
        }
        let mut sums = vec![0.0f32; n_blocks * d];
        for i in 0..t {
            for j in 0..d {
                sums[(i / bt) * d + j] += kd[i * d + j];
            }
        }
        (norms, sums, (0..n_blocks as u32).collect())
    }

    #[test]
    fn paged_scan_equals_contiguous_when_descending_everywhere() {
        // With the descend set covering every page, the block-table-aware
        // scan computes the exact same per-key scores as the contiguous
        // tiled scan — selections must agree bitwise.
        let mut rng = Rng::new(11);
        let (d, s, t, bt) = (8usize, 16usize, 96usize, 16usize);
        let qd = rng.normal_vec(s * d, 1.0);
        let kd = rng.normal_vec(t * d, 1.0);
        let (norms, sums, blocks) = paged_fixture(&kd, t, d, bt);
        let q = QChunk::new(&qd, 1, s, d);
        let contig = KCache::with_norms(&kd, 1, t, t, d, &norms);
        let paged = KCache::paged(
            &kd,
            1,
            t,
            d,
            &norms,
            Pages { blocks: &blocks, block_tokens: bt, key_sums: &sums },
        );
        // budget 40 → descend ⌈80/16⌉+1 = 6 = all pages.
        for quoka in [
            Quoka::default(),
            Quoka::new(QuokaConfig { scoring: Scoring::Dot, ..QuokaConfig::default() }),
            Quoka::new(QuokaConfig { query_agg: QueryAgg::Mean, ..QuokaConfig::default() }),
        ] {
            let a = quoka.select(&q, &contig, 40, &mut SelectCtx::new(0));
            let b = quoka.select(&q, &paged, 40, &mut SelectCtx::new(0));
            assert_eq!(
                a.head_indices(0, t),
                b.head_indices(0, t),
                "{}",
                quoka.name()
            );
        }
    }

    #[test]
    fn paged_scan_skips_blocks_and_still_finds_needle_page() {
        // One page full of needle-aligned keys among many anti-aligned
        // pages: the metadata pass must rank it into the descend set, the
        // exact scan must select its keys, and whole pages must be skipped.
        let (d, s, t, bt) = (8usize, 4usize, 256usize, 16usize);
        let needle_block = 5usize;
        let mut rng = Rng::new(12);
        let mut qd = vec![0.0f32; s * d];
        for i in 0..s {
            qd[i * d + 1] = 1.0;
            for j in 0..d {
                qd[i * d + j] += rng.normal() * 0.01;
            }
        }
        let mut kd = vec![0.0f32; t * d];
        for i in 0..t {
            kd[i * d] = -1.0;
            for j in 0..d {
                kd[i * d + j] += rng.normal() * 0.01;
            }
        }
        for i in needle_block * bt..(needle_block + 1) * bt {
            kd[i * d] = 0.0;
            kd[i * d + 1] = 1.0;
        }
        let (norms, sums, blocks) = paged_fixture(&kd, t, d, bt);
        let q = QChunk::new(&qd, 1, s, d);
        let paged = KCache::paged(
            &kd,
            1,
            t,
            d,
            &norms,
            Pages { blocks: &blocks, block_tokens: bt, key_sums: &sums },
        );
        let mut ctx = SelectCtx::new(0);
        let sel = Quoka::default().select(&q, &paged, bt, &mut ctx);
        let idx = sel.head_indices(0, t);
        assert_eq!(idx.len(), bt);
        assert!(
            idx.iter().all(|&i| (i as usize) / bt == needle_block),
            "selection must come from the needle page, got {idx:?}"
        );
        // budget 16 → descend 3 of 16 pages: 13 pages (208 keys) skipped.
        assert_eq!(ctx.cost.skipped_keys(), (t - 3 * bt) as u64);
    }

    #[test]
    fn decode_shaped_paged_scan_prunes_pages_and_matches_contiguous() {
        // The decode hot path calls select with a single query (s = 1).
        // The paged scan must still go metadata-first — score page mean
        // keys, descend only into survivors — and, when the descend set
        // covers every page, agree exactly with the contiguous scan.
        let mut rng = Rng::new(44);
        let (d, t, bt) = (8usize, 128usize, 16usize);
        let qd = rng.normal_vec(d, 1.0);
        let kd = rng.normal_vec(t * d, 1.0);
        let (norms, sums, blocks) = paged_fixture(&kd, t, d, bt);
        let q = QChunk::new(&qd, 1, 1, d);
        let contig = KCache::with_norms(&kd, 1, t, t, d, &norms);
        let paged = KCache::paged(
            &kd,
            1,
            t,
            d,
            &norms,
            Pages { blocks: &blocks, block_tokens: bt, key_sums: &sums },
        );
        // budget 60 → descend ⌈120/16⌉+1 = 9 > 8 pages: full coverage.
        let a = Quoka::default().select(&q, &contig, 60, &mut SelectCtx::new(0));
        let b = Quoka::default().select(&q, &paged, 60, &mut SelectCtx::new(0));
        assert_eq!(a.head_indices(0, t), b.head_indices(0, t));
        // budget 8 → descend 2 of 8 pages: 6 pages (96 keys) never read.
        let mut ctx = SelectCtx::new(0);
        let sel = Quoka::default().select(&q, &paged, 8, &mut ctx);
        assert_eq!(sel.head_indices(0, t).len(), 8);
        assert_eq!(ctx.cost.skipped_keys(), (t - 2 * bt) as u64);
    }

    #[test]
    fn cosine_beats_dot_under_key_norm_attack() {
        // Plant a needle with a *small-norm* key while an irrelevant key has
        // a huge norm: dot scoring chases the big norm, cosine does not.
        let (d, s, t, needle, loud) = (8usize, 4usize, 64usize, 20usize, 40usize);
        let mut rng = Rng::new(8);
        let mut qd = vec![0.0; s * d];
        for i in 0..s {
            qd[i * d + 1] = 1.0;
            for j in 0..d {
                qd[i * d + j] += rng.normal() * 0.01;
            }
        }
        let mut kd = vec![0.0; t * d];
        for i in 0..t {
            kd[i * d] = -1.0;
            for j in 0..d {
                kd[i * d + j] += rng.normal() * 0.01;
            }
        }
        kd[needle * d] = 0.0;
        kd[needle * d + 1] = 0.2; // perfectly aligned but small norm
        kd[loud * d] = -40.0; // huge norm, partial alignment: cos≈0.6 but
        kd[loud * d + 1] = 30.0; // dot ≈ 30 ≫ the needle's 0.2
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let cos_sel = Quoka::default().select(&q, &k, 4, &mut SelectCtx::new(0));
        assert!(cos_sel.head_indices(0, t).contains(&(needle as u32)));
        let dot_sel = Quoka::new(QuokaConfig { scoring: Scoring::Dot, ..QuokaConfig::default() })
            .select(&q, &k, 1, &mut SelectCtx::new(0));
        // Under dot scoring, the needle cannot be the single top key
        // because |needle| is tiny; cosine keeps it on top.
        let cos_top = Quoka::default().select(&q, &k, 1, &mut SelectCtx::new(0));
        assert_eq!(cos_top.head_indices(0, t), vec![needle as u32]);
        assert_ne!(dot_sel.head_indices(0, t), vec![needle as u32]);
    }
}
