//! KV-cache selection policies.
//!
//! The heart of the reproduction: QUOKA (Algorithm 1 of the paper) plus the
//! baselines it is evaluated against — SampleAttention, SparQ, Loki,
//! LessIsMore, SnapKV, KeyDiff and the dense no-op. Every policy implements
//! [`SelectionPolicy`]: given the chunk's queries and the KV cache for one
//! layer, return (per KV head) the indices of at most `budget` cache
//! entries the attention kernel should see.
//!
//! All policies run on the host tensor substrate (standard linear algebra —
//! the paper's portability claim) and tally FLOP/byte counters so Table 4's
//! complexity comparison can be *measured*, not just asserted.

pub mod quoka;
pub mod dense;
pub mod sample_attention;
pub mod sparq;
pub mod loki;
pub mod less_is_more;
pub mod snapkv;
pub mod keydiff;
pub mod tidal_decode;
pub mod cost;

pub use cost::CostCounter;
pub use quoka::{Quoka, QuokaConfig, Scoring, QueryAgg};

use crate::util::Rng;

/// Query chunk view, layout `[n_heads, s, d]` row-major.
#[derive(Clone, Copy)]
pub struct QChunk<'a> {
    pub data: &'a [f32],
    pub n_heads: usize,
    pub s: usize,
    pub d: usize,
}

impl<'a> QChunk<'a> {
    pub fn new(data: &'a [f32], n_heads: usize, s: usize, d: usize) -> Self {
        debug_assert_eq!(data.len(), n_heads * s * d);
        QChunk { data, n_heads, s, d }
    }

    /// Head `h` as an `[s, d]` slice.
    #[inline]
    pub fn head(&self, h: usize) -> &'a [f32] {
        let n = self.s * self.d;
        &self.data[h * n..(h + 1) * n]
    }

    /// Query row `(h, i)`.
    #[inline]
    pub fn query(&self, h: usize, i: usize) -> &'a [f32] {
        let base = (h * self.s + i) * self.d;
        &self.data[base..base + self.d]
    }
}

/// Block-table indirection for a [`KCache`] over the shared paged KV pool
/// (`kvpool::KvPool`): logical token `i` lives in page `blocks[i /
/// block_tokens]`, and every page carries a per-head key-sum row
/// (≡ unnormalized mean key) that block-granular policies score *before*
/// touching individual keys.
#[derive(Clone, Copy)]
pub struct Pages<'a> {
    /// Logical block → pool page id.
    pub blocks: &'a [u32],
    /// Tokens per page.
    pub block_tokens: usize,
    /// Per-page key sums, layout `[page, n_heads, d]` over the pool slab.
    pub key_sums: &'a [f32],
}

/// Quantized key rows riding a [`KCache`]: int8 codes in the same layout
/// as the cache's f32 `data` slab, with per-row fp32 dequant scales laid
/// out like the inverse norms (`[n_heads, capacity]` contiguous,
/// `[page, n_heads, block_tokens]` paged). When present, the cache's f32
/// `data` slab is empty — scans must consume the codes directly
/// (`qk_block_q8` and friends) instead of calling [`KCache::key`].
#[derive(Clone, Copy)]
pub struct QuantKeys<'a> {
    pub codes: &'a [i8],
    pub scales: &'a [f32],
}

/// Key-cache view for one layer.
///
/// Contiguous form (`pages == None`): layout `[n_heads, capacity, d]` with
/// the first `t` rows of each head valid. Paged form (`pages == Some`):
/// `data` is the pool's whole layer slab `[page, n_heads, block_tokens,
/// d]` and rows are resolved through the block table; `head()` has no
/// contiguous slab in this form and must not be called (the engine only
/// routes block-table-aware policies at paged caches).
///
/// Quantized form (`quant == Some`): the key payload is int8 with per-row
/// scales and `data` is empty; only policies with quantization-aware scans
/// (dense, QUOKA) are routed at such caches — the engine gates the rest at
/// submit time.
#[derive(Clone, Copy)]
pub struct KCache<'a> {
    pub data: &'a [f32],
    pub n_heads: usize,
    /// Valid (filled) length.
    pub t: usize,
    /// Row capacity of each head slab (`>= t`; `block_tokens` when paged).
    pub capacity: usize,
    pub d: usize,
    /// Cached per-key inverse L2 norms, layout `[n_heads, capacity]`
    /// (contiguous) or `[page, n_heads, block_tokens]` (paged), maintained
    /// incrementally at append time. `None` — e.g. for ad-hoc views built
    /// from raw slices — falls back to recomputing norms on demand.
    ///
    /// Always computed from the *original* fp32 key row, so norm-based
    /// scoring stays exact even when the stored rows are quantized.
    pub inv_norms: Option<&'a [f32]>,
    /// Block-table indirection; `None` for contiguous caches.
    pub pages: Option<Pages<'a>>,
    /// Int8 key codes + per-row scales; `None` for f32 caches.
    pub quant: Option<QuantKeys<'a>>,
}

impl<'a> KCache<'a> {
    pub fn new(data: &'a [f32], n_heads: usize, t: usize, capacity: usize, d: usize) -> Self {
        debug_assert!(t <= capacity);
        debug_assert!(
            data.len() == n_heads * capacity * d || data.is_empty(),
            "KCache data slab must match the geometry (or be empty for a quantized cache)"
        );
        KCache { data, n_heads, t, capacity, d, inv_norms: None, pages: None, quant: None }
    }

    /// View with an incremental norm cache (layout `[n_heads, capacity]`).
    pub fn with_norms(
        data: &'a [f32],
        n_heads: usize,
        t: usize,
        capacity: usize,
        d: usize,
        inv_norms: &'a [f32],
    ) -> Self {
        debug_assert_eq!(inv_norms.len(), n_heads * capacity);
        KCache { inv_norms: Some(inv_norms), ..KCache::new(data, n_heads, t, capacity, d) }
    }

    /// Block-table-aware view over a pool layer slab (always carries the
    /// pooled norm cache and per-page key sums).
    pub fn paged(
        data: &'a [f32],
        n_heads: usize,
        t: usize,
        d: usize,
        inv_norms: &'a [f32],
        pages: Pages<'a>,
    ) -> Self {
        debug_assert!(pages.blocks.len() * pages.block_tokens >= t);
        KCache {
            data,
            n_heads,
            t,
            capacity: pages.block_tokens,
            d,
            inv_norms: Some(inv_norms),
            pages: Some(pages),
            quant: None,
        }
    }

    /// Attach int8 key codes + per-row dequant scales (layouts mirroring
    /// `data` / `inv_norms`). The f32 `data` slab of a quantized cache is
    /// empty by construction — no fp32 copy of the cache exists.
    pub fn with_quant(self, codes: &'a [i8], scales: &'a [f32]) -> Self {
        KCache { quant: Some(QuantKeys { codes, scales }), ..self }
    }

    /// `1 / ‖key(h, i)‖` (0 for a zero key): one load when the cache view
    /// carries incremental norms, an O(d) reduction otherwise.
    #[inline]
    pub fn inv_norm(&self, h: usize, i: usize) -> f32 {
        if let (Some(p), Some(norms)) = (self.pages, self.inv_norms) {
            let bt = p.block_tokens;
            return norms[(p.blocks[i / bt] as usize * self.n_heads + h) * bt + i % bt];
        }
        match self.inv_norms {
            Some(norms) => norms[h * self.capacity + i],
            None => {
                let n = crate::tensor::ops::l2_norm(self.key(h, i));
                if n > 0.0 {
                    1.0 / n
                } else {
                    0.0
                }
            }
        }
    }

    /// Head `h` as a `[capacity, d]` slice (only `..t` rows valid).
    /// Contiguous caches only — paged caches have no per-head slab.
    #[inline]
    pub fn head(&self, h: usize) -> &'a [f32] {
        assert!(
            self.pages.is_none(),
            "KCache::head: paged cache has no contiguous head slab \
             (route block-table-aware policies instead)"
        );
        assert!(
            self.quant.is_none(),
            "KCache::head: quantized cache has no f32 key slab \
             (use the int8 codes + scales via `quant`)"
        );
        let n = self.capacity * self.d;
        &self.data[h * n..(h + 1) * n]
    }

    /// Key row `(h, i)`. F32 caches only — a quantized cache's f32 slab is
    /// empty (the engine routes only quantization-aware policies there).
    #[inline]
    pub fn key(&self, h: usize, i: usize) -> &'a [f32] {
        debug_assert!(self.quant.is_none(), "KCache::key: f32 key row of a quantized cache");
        let base = match self.pages {
            None => h * self.capacity * self.d + i * self.d,
            Some(p) => {
                let bt = p.block_tokens;
                ((p.blocks[i / bt] as usize * self.n_heads + h) * bt + i % bt) * self.d
            }
        };
        &self.data[base..base + self.d]
    }
}

/// Result of a selection: per-KV-head ascending index lists into the cache.
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    /// Keep everything (dense attention, or `t <= budget`).
    All,
    /// `indices[kv_head]` — ascending, unique, each `< t`, `len <= budget`.
    PerHead(Vec<Vec<u32>>),
}

/// Borrowed, allocation-free view of one head's selection — what the
/// attention kernel and eval paths iterate instead of materializing index
/// vectors per call (`All` stays implicit as `0..t`).
#[derive(Clone, Copy, Debug)]
pub enum HeadSel<'a> {
    /// All `t` past entries.
    All(usize),
    /// Explicit ascending, unique indices.
    Idx(&'a [u32]),
}

impl<'a> HeadSel<'a> {
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            HeadSel::All(t) => *t,
            HeadSel::Idx(v) => v.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache index of the `j`-th selected entry.
    #[inline]
    pub fn get(&self, j: usize) -> usize {
        match self {
            HeadSel::All(_) => j,
            HeadSel::Idx(v) => v[j] as usize,
        }
    }

    /// Membership test (O(1) for `All`, binary search otherwise — the
    /// index lists are ascending by contract).
    #[inline]
    pub fn contains(&self, i: u32) -> bool {
        match self {
            HeadSel::All(t) => (i as usize) < *t,
            HeadSel::Idx(v) => v.binary_search(&i).is_ok(),
        }
    }

    /// Iterate the selected cache indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + 'a {
        let this = *self;
        (0..this.len()).map(move |j| this.get(j))
    }
}

impl Selection {
    /// Borrowed per-head view — no allocation, `All` stays implicit.
    #[inline]
    pub fn head(&self, h: usize, t: usize) -> HeadSel<'_> {
        match self {
            Selection::All => HeadSel::All(t),
            Selection::PerHead(v) => HeadSel::Idx(&v[h]),
        }
    }

    /// Indices for a head, materializing `All` as `0..t`. Allocates; hot
    /// paths should use the borrowed [`Selection::head`] view instead.
    pub fn head_indices(&self, h: usize, t: usize) -> Vec<u32> {
        match self {
            Selection::All => (0..t as u32).collect(),
            Selection::PerHead(v) => v[h].clone(),
        }
    }

    /// Number of retained entries for head `h`.
    pub fn head_len(&self, h: usize, t: usize) -> usize {
        match self {
            Selection::All => t,
            Selection::PerHead(v) => v[h].len(),
        }
    }

    /// Total retained entries across heads.
    pub fn total(&self, n_heads: usize, t: usize) -> usize {
        match self {
            Selection::All => n_heads * t,
            Selection::PerHead(v) => v.iter().map(|x| x.len()).sum(),
        }
    }
}

/// Mutable per-call context: scratch space, cost counters, cross-layer
/// state (LessIsMore index reuse) and a deterministic RNG (SampleAttention).
pub struct SelectCtx {
    pub rng: Rng,
    pub cost: CostCounter,
    /// Current layer index (0-based) — layer-dependent policies read this.
    pub layer: usize,
    /// Total number of layers.
    pub n_layers: usize,
    /// Indices shared across layers within the current engine step
    /// (LessIsMore writes at its selection layers, reads elsewhere).
    /// **Per sequence**: the batched decode forward swaps each sequence's
    /// slot in around its select call, so sequences decoding in one batch
    /// never observe each other's cross-layer state.
    pub shared_indices: Option<Vec<Vec<u32>>>,
    /// Scratch buffers reused across calls to avoid steady-state allocation.
    pub scratch: Scratch,
}

impl SelectCtx {
    pub fn new(seed: u64) -> SelectCtx {
        SelectCtx {
            rng: Rng::new(seed),
            cost: CostCounter::default(),
            layer: 0,
            n_layers: 1,
            shared_indices: None,
            scratch: Scratch::default(),
        }
    }

    /// Reset per-step state (layer counter + shared indices), keeping
    /// scratch capacity and cumulative cost counters.
    pub fn begin_step(&mut self) {
        self.layer = 0;
        self.shared_indices = None;
    }
}

/// Reusable scratch buffers.
///
/// `a`/`b`/`c` are general float arenas (policies assign roles per phase),
/// `idx` is the shared top-k / keep-list index arena, and `workers` holds
/// one score-block arena per fork-join worker for parallel key scans —
/// all reused across chunks so steady-state selection allocates nothing.
#[derive(Default)]
pub struct Scratch {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub idx: Vec<usize>,
    /// Per-worker tile buffers for parallelized key scans (disjoint slots,
    /// one per worker task).
    pub workers: Vec<Vec<f32>>,
}

/// Grow-and-borrow helper for raw scratch vectors (contents undefined).
#[inline]
pub(crate) fn fit(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

impl Scratch {
    /// Borrow `a` resized to `n` (contents undefined).
    pub fn buf_a(&mut self, n: usize) -> &mut [f32] {
        fit(&mut self.a, n)
    }
    pub fn buf_b(&mut self, n: usize) -> &mut [f32] {
        fit(&mut self.b, n)
    }
    pub fn buf_c(&mut self, n: usize) -> &mut [f32] {
        fit(&mut self.c, n)
    }

    /// Split-borrow `a` and `b` simultaneously.
    pub fn bufs_ab(&mut self, na: usize, nb: usize) -> (&mut [f32], &mut [f32]) {
        let Scratch { a, b, .. } = self;
        (fit(a, na), fit(b, nb))
    }
}

/// A KV-cache selection policy.
pub trait SelectionPolicy: Send + Sync {
    /// Stable identifier used by CLI flags and bench tables.
    fn name(&self) -> &'static str;

    /// Select at most `budget` cache indices per KV head for this chunk.
    ///
    /// Contract (property-tested in `rust/tests/select_props.rs`):
    /// - returned indices are unique, ascending, `< k.t`;
    /// - each head's list has `len == min(budget, k.t)` unless the policy
    ///   is layer-skipping and reuses shared indices;
    /// - `Selection::All` may be returned when `k.t <= budget`.
    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection;

    /// True when this policy is a dense no-op.
    fn is_dense(&self) -> bool {
        false
    }
}

/// Number of query heads per KV head (GQA group size).
#[inline]
pub fn group_size(n_q_heads: usize, n_kv_heads: usize) -> usize {
    debug_assert_eq!(n_q_heads % n_kv_heads, 0);
    n_q_heads / n_kv_heads
}

/// Shared helper: top-`budget` indices of a score vector, returned
/// ascending (the gather-friendly order that preserves token positions).
pub fn topk_ascending(scores: &[f32], budget: usize) -> Vec<u32> {
    let mut idx = Vec::new();
    topk_ascending_into(scores, budget, &mut idx)
}

/// [`topk_ascending`] with the transient index arena supplied by the
/// caller (typically [`Scratch::idx`]) so only the returned `u32` list —
/// the selection itself — is allocated.
pub fn topk_ascending_into(scores: &[f32], budget: usize, idx: &mut Vec<usize>) -> Vec<u32> {
    crate::tensor::ops::topk_indices_into(scores, budget, idx);
    idx.sort_unstable();
    idx.iter().map(|&i| i as u32).collect()
}

/// Construct a policy by name with paper-default hyperparameters. Central
/// registry so the CLI, benches and tests agree on names.
pub fn policy_by_name(name: &str) -> anyhow::Result<Box<dyn SelectionPolicy>> {
    Ok(match name {
        "dense" | "full" => Box::new(dense::Dense),
        "quoka" => Box::new(Quoka::default()),
        "quoka-dot" => Box::new(Quoka::new(QuokaConfig { scoring: Scoring::Dot, ..QuokaConfig::default() })),
        "quoka-mean" => Box::new(Quoka::new(QuokaConfig { query_agg: QueryAgg::Mean, ..QuokaConfig::default() })),
        "sample" | "sample_attention" => Box::new(sample_attention::SampleAttention::default()),
        "sparq" => Box::new(sparq::SparQ::default()),
        "loki" => Box::new(loki::Loki::default()),
        "lessismore" | "less_is_more" => Box::new(less_is_more::LessIsMore::default()),
        "snapkv" => Box::new(snapkv::SnapKv::default()),
        "keydiff" => Box::new(keydiff::KeyDiff::default()),
        "tidaldecode" | "tidal_decode" => Box::new(tidal_decode::TidalDecode::default()),
        other => anyhow::bail!(
            "unknown selection policy '{other}' (known: dense, quoka, quoka-dot, quoka-mean, \
             sample, sparq, loki, lessismore, snapkv, keydiff, tidaldecode)"
        ),
    })
}

/// The method roster used by the paper's comparison tables (Table 1 order).
pub fn comparison_roster() -> Vec<&'static str> {
    vec!["snapkv", "keydiff", "lessismore", "loki", "sparq", "sample", "quoka"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_all_methods() {
        for name in comparison_roster() {
            assert!(policy_by_name(name).is_ok(), "{name}");
        }
        assert!(policy_by_name("dense").unwrap().is_dense());
        assert!(policy_by_name("nope").is_err());
    }

    #[test]
    fn selection_accessors() {
        let s = Selection::PerHead(vec![vec![0, 2], vec![1]]);
        assert_eq!(s.head_indices(0, 5), vec![0, 2]);
        assert_eq!(s.head_len(1, 5), 1);
        assert_eq!(s.total(2, 5), 3);
        let all = Selection::All;
        assert_eq!(all.head_indices(0, 3), vec![0, 1, 2]);
        assert_eq!(all.total(2, 3), 6);
    }

    #[test]
    fn head_sel_borrowed_view() {
        let s = Selection::PerHead(vec![vec![0, 2, 7], vec![1]]);
        let h0 = s.head(0, 9);
        assert_eq!(h0.len(), 3);
        assert!(h0.contains(2) && !h0.contains(3));
        assert_eq!(h0.iter().collect::<Vec<_>>(), vec![0, 2, 7]);
        assert_eq!(h0.get(2), 7);
        let sel_all = Selection::All;
        let all = sel_all.head(0, 4);
        assert_eq!(all.len(), 4);
        assert!(all.contains(3) && !all.contains(4));
        assert_eq!(all.iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(sel_all.head(0, 0).is_empty());
    }

    #[test]
    fn inv_norm_fallback_matches_definition() {
        let data = vec![3.0f32, 4.0, 0.0, 0.0, 1.0, 0.0];
        let k = KCache::new(&data, 1, 3, 3, 2);
        assert!((k.inv_norm(0, 0) - 0.2).abs() < 1e-6);
        assert_eq!(k.inv_norm(0, 1), 0.0);
        let norms = vec![0.25f32, 0.5, 1.0];
        let kn = KCache::with_norms(&data, 1, 3, 3, 2, &norms);
        assert_eq!(kn.inv_norm(0, 0), 0.25);
    }

    #[test]
    fn views_index_correctly() {
        let data: Vec<f32> = (0..2 * 3 * 4).map(|i| i as f32).collect();
        let q = QChunk::new(&data, 2, 3, 4);
        assert_eq!(q.query(1, 2)[0], (1 * 3 + 2) as f32 * 4.0);
        let k = KCache::new(&data, 2, 2, 3, 4);
        assert_eq!(k.key(1, 1)[0], (1 * 3 + 1) as f32 * 4.0);
    }

    #[test]
    fn scratch_reuses_capacity() {
        let mut s = Scratch::default();
        s.buf_a(100);
        let p1 = s.a.as_ptr();
        s.buf_a(50);
        assert_eq!(p1, s.a.as_ptr());
    }
}
