//! SparQ baseline (Ribar et al., 2024).
//!
//! Bandwidth-oriented: pick the `r` channels where the chunk's queries carry
//! the most mass (sum of |q| per channel), compute *approximate* attention
//! logits using only those channels of Q and K, softmax, and mean-aggregate
//! over queries and the KV group. Designed for single-query decode; under
//! multi-query prefill the channel ranking blends all queries together.

use super::{fit, group_size, topk_ascending_into, KCache, QChunk, Scratch, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{softmax, topk_indices_into};

/// Channel-subselecting approximate-score policy.
#[derive(Clone, Copy, Debug)]
pub struct SparQ {
    /// Channels retained (`d_l < d`). The paper keeps half the head dim
    /// (64 of 128); our heads are `d = 64`, so the default is 32.
    pub r: usize,
}

impl Default for SparQ {
    fn default() -> Self {
        SparQ { r: 32 }
    }
}

impl SelectionPolicy for SparQ {
    fn name(&self) -> &'static str {
        "sparq"
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let d = q.d;
        let r = self.r.min(d);
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);

        let mut per_head = Vec::with_capacity(n_kv);
        for kv in 0..n_kv {
            let khead = k.head(kv);
            let cost = &mut ctx.cost;
            let Scratch { a, b, c, idx, .. } = &mut ctx.scratch;
            let agg = fit(a, t);
            let row = fit(b, t);
            let chan = fit(c, d);
            agg.iter_mut().for_each(|v| *v = 0.0);
            for gq in 0..g {
                let h = kv * g + gq;
                // Channel importance: sum_i |q_i[c]| over the chunk.
                chan.iter_mut().for_each(|v| *v = 0.0);
                for i in 0..q.s {
                    let qrow = q.query(h, i);
                    for ci in 0..d {
                        chan[ci] += qrow[ci].abs();
                    }
                }
                topk_indices_into(chan, r, idx);
                cost.add_flops((q.s * d) as u64);
                // Approximate logits over the reduced channels. SparQ scales
                // by sqrt(d * mass_kept/mass_total) — we use sqrt(r) which
                // preserves ranking (softmax is monotone in scale per row).
                let scale = 1.0 / (r as f32).sqrt();
                for i in 0..q.s {
                    let qrow = q.query(h, i);
                    for ti in 0..t {
                        let key = &khead[ti * d..(ti + 1) * d];
                        let mut s = 0.0;
                        for &ci in idx.iter() {
                            s += qrow[ci] * key[ci];
                        }
                        row[ti] = s * scale;
                    }
                    softmax(row);
                    for ti in 0..t {
                        agg[ti] += row[ti];
                    }
                }
                cost.add_flops((q.s * t * (2 * r + 4)) as u64);
                cost.add_bytes((q.s * t * 4) as u64);
            }
            per_head.push(topk_ascending_into(agg, budget, idx));
        }
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn respects_contract() {
        let mut rng = Rng::new(21);
        let (nh, nkv, s, t, d) = (2usize, 1usize, 8usize, 120usize, 16usize);
        let qd = rng.normal_vec(nh * s * d, 1.0);
        let kd = rng.normal_vec(nkv * t * d, 1.0);
        let q = QChunk::new(&qd, nh, s, d);
        let k = KCache::new(&kd, nkv, t, t, d);
        let sel = SparQ { r: 4 }.select(&q, &k, 10, &mut SelectCtx::new(0));
        let idx = sel.head_indices(0, t);
        assert_eq!(idx.len(), 10);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn channel_pruning_finds_strong_key_on_kept_channel() {
        // Queries concentrate on channel 0; a key spikes there too — the
        // reduced-channel logits must still surface it.
        let (s, t, d, hot) = (8usize, 64usize, 16usize, 31usize);
        let mut rng = Rng::new(22);
        let mut qd = rng.normal_vec(s * d, 0.05);
        for i in 0..s {
            qd[i * d] = 2.0;
        }
        let mut kd = rng.normal_vec(t * d, 0.05);
        kd[hot * d] = 4.0;
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let sel = SparQ { r: 2 }.select(&q, &k, 6, &mut SelectCtx::new(0));
        assert!(sel.head_indices(0, t).contains(&(hot as u32)));
    }

    #[test]
    fn r_clamped_to_head_dim() {
        let mut rng = Rng::new(23);
        let qd = rng.normal_vec(1 * 4 * 8, 1.0);
        let kd = rng.normal_vec(1 * 50 * 8, 1.0);
        let q = QChunk::new(&qd, 1, 4, 8);
        let k = KCache::new(&kd, 1, 50, 50, 8);
        // r=64 > d=8 must not panic.
        let sel = SparQ::default().select(&q, &k, 5, &mut SelectCtx::new(0));
        assert_eq!(sel.head_indices(0, 50).len(), 5);
    }
}
