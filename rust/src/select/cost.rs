//! Cost accounting for Table 4 (runtime & memory complexity).
//!
//! Policies tally the FLOPs they execute and the score/projection bytes
//! they materialize; [`analytic`] evaluates the paper's closed-form
//! complexity expressions at the same parameters so the bench
//! `table4_complexity` can check measured-vs-formula scaling directly.

/// Accumulated measured cost.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostCounter {
    flops: u64,
    bytes: u64,
    calls: u64,
    skipped_keys: u64,
}

impl CostCounter {
    #[inline]
    pub fn add_flops(&mut self, f: u64) {
        self.flops += f;
    }
    #[inline]
    pub fn add_bytes(&mut self, b: u64) {
        self.bytes += b;
    }
    /// Keys a block-granular scan never touched (metadata pruned them).
    #[inline]
    pub fn add_skipped_keys(&mut self, k: u64) {
        self.skipped_keys += k;
    }
    pub fn bump_calls(&mut self) {
        self.calls += 1;
    }
    pub fn flops(&self) -> u64 {
        self.flops
    }
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
    pub fn calls(&self) -> u64 {
        self.calls
    }
    /// Keys skipped by metadata-first scans (paged QUOKA).
    pub fn skipped_keys(&self) -> u64 {
        self.skipped_keys
    }
    pub fn reset(&mut self) {
        *self = CostCounter::default();
    }
}

/// Parameters of the paper's complexity table.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Prefill chunk size `B_CP`.
    pub b_cp: usize,
    /// KV cache length `T`.
    pub t: usize,
    /// Query heads `n_Q`.
    pub n_q_heads: usize,
    /// KV heads `n_KV`.
    pub n_kv_heads: usize,
    /// Head dim `d`.
    pub d: usize,
    /// Subselected queries `N_Q`.
    pub n_q_sel: usize,
    /// Down-projection dim `d_l` (SparQ/Loki).
    pub d_l: usize,
    /// Layer count `L` (LessIsMore amortization).
    pub layers: usize,
}

/// The paper's Table 4 closed forms (up to constant factors), evaluated so
/// scaling ratios can be compared against measured counters.
pub fn analytic(method: &str, p: &CostParams) -> (f64, f64) {
    let (b_cp, t) = (p.b_cp as f64, p.t as f64);
    let (n_q, n_kv, d) = (p.n_q_heads as f64, p.n_kv_heads as f64, p.d as f64);
    let nq_sel = p.n_q_sel as f64;
    let d_l = p.d_l as f64;
    let layers = p.layers as f64;
    match method {
        // O(B_CP + N_Q(1 + d n_KV) T) runtime, O(n_KV N_Q T) memory
        "quoka" => (b_cp + nq_sel * (1.0 + d * n_kv) * t, n_kv * nq_sel * t),
        // O((d n_Q + n_Q/n_KV + n_KV) N_Q T), O(n_Q N_Q T)
        "sample" => ((d * n_q + n_q / n_kv + n_kv) * nq_sel * t, n_q * nq_sel * t),
        // O(B_CP T d_l n_Q), O(n_Q B_CP T)
        "sparq" => (b_cp * t * d_l * n_q, n_q * b_cp * t),
        // O(d_l n_Q (B_CP T + d(B_CP + T))), O(n_Q B_CP T)
        "loki" => (d_l * n_q * (b_cp * t + d * (b_cp + t)), n_q * b_cp * t),
        // O(d n_Q B_CP T / L), O(n_Q B_CP T / L)
        "lessismore" => (d * n_q * b_cp * t / layers, n_q * b_cp * t / layers),
        _ => (0.0, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(t: usize) -> CostParams {
        CostParams {
            b_cp: 128,
            t,
            n_q_heads: 8,
            n_kv_heads: 2,
            d: 64,
            n_q_sel: 16,
            d_l: 64,
            layers: 8,
        }
    }

    #[test]
    fn counter_accumulates() {
        let mut c = CostCounter::default();
        c.add_flops(10);
        c.add_flops(5);
        c.add_bytes(3);
        assert_eq!(c.flops(), 15);
        assert_eq!(c.bytes(), 3);
        c.reset();
        assert_eq!(c.flops(), 0);
    }

    #[test]
    fn quoka_scales_with_nkv_not_nq() {
        // The paper's asymptotic point: QUOKA's terms carry n_KV, sample
        // attention's carry n_Q (> n_KV).
        let (rq, mq) = analytic("quoka", &p(8192));
        let (rs, ms) = analytic("sample", &p(8192));
        assert!(rq < rs);
        assert!(mq < ms);
    }

    #[test]
    fn linear_in_t() {
        for m in ["quoka", "sample", "sparq", "loki", "lessismore"] {
            let (r1, _) = analytic(m, &p(4096));
            let (r2, _) = analytic(m, &p(8192));
            let ratio = r2 / r1;
            assert!((ratio - 2.0).abs() < 0.1, "{m}: {ratio}");
        }
    }
}
