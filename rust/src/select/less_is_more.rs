//! LessIsMore baseline (Yang et al., 2025b).
//!
//! Computes selection scores only at designated *selection layers* and
//! reuses those indices (with global locality) at every other layer,
//! amortizing the scoring cost by the layer count (paper Table 4 divides by
//! `L`). Within a selection layer it scores like an attention-based method:
//! softmax logits mean-aggregated across queries and the KV group, plus a
//! local recency window.

use super::{group_size, topk_ascending, KCache, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::ops::{dot, softmax};

/// Layer-skipping attention-score selection.
#[derive(Clone, Copy, Debug)]
pub struct LessIsMore {
    /// Run real selection every `stride` layers (layer 0 always selects).
    pub stride: usize,
    /// Recency window always retained (global locality component).
    pub local_window: usize,
    /// Scoring uses only the last `obs_window` queries of the chunk
    /// (global-locality assumption: recent queries represent the task).
    pub obs_window: usize,
}

impl Default for LessIsMore {
    fn default() -> Self {
        LessIsMore { stride: 4, local_window: 64, obs_window: 32 }
    }
}

impl SelectionPolicy for LessIsMore {
    fn name(&self) -> &'static str {
        "lessismore"
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let is_selection_layer = ctx.layer % self.stride == 0;
        if !is_selection_layer {
            if let Some(shared) = &ctx.shared_indices {
                // Reuse, clamping to the current cache length (the cache only
                // grows between layers of the same step, so indices are valid;
                // clamp defensively anyway).
                let reused: Vec<Vec<u32>> = shared
                    .iter()
                    .map(|v| v.iter().copied().filter(|&i| (i as usize) < t).collect())
                    .collect();
                if reused.len() == k.n_heads {
                    return Selection::PerHead(reused);
                }
            }
        }

        let d = q.d;
        let scale = 1.0 / (d as f32).sqrt();
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);
        let local_start = t.saturating_sub(self.local_window.min(budget / 2));
        let w_start = q.s.saturating_sub(self.obs_window);

        let mut per_head = Vec::with_capacity(n_kv);
        let mut row = vec![0.0f32; t];
        for kv in 0..n_kv {
            let khead = k.head(kv);
            let agg = ctx.scratch.buf_a(t);
            agg.iter_mut().for_each(|v| *v = 0.0);
            for gq in 0..g {
                let h = kv * g + gq;
                for i in w_start..q.s {
                    let qrow = q.query(h, i);
                    for ti in 0..t {
                        row[ti] = dot(qrow, &khead[ti * d..(ti + 1) * d]) * scale;
                    }
                    softmax(&mut row);
                    for ti in 0..t {
                        agg[ti] += row[ti];
                    }
                }
                ctx.cost.add_flops(((q.s - w_start) * t * (2 * d + 4)) as u64);
                ctx.cost.add_bytes(((q.s - w_start) * t * 4) as u64);
            }
            // Global locality: force the recency window into the set.
            for ti in local_start..t {
                agg[ti] = f32::INFINITY;
            }
            per_head.push(topk_ascending(agg, budget));
        }
        ctx.shared_indices = Some(per_head.clone());
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(rng: &mut Rng, nh: usize, nkv: usize, s: usize, t: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        (rng.normal_vec(nh * s * d, 1.0), rng.normal_vec(nkv * t * d, 1.0))
    }

    #[test]
    fn selection_layer_populates_shared_state() {
        let mut rng = Rng::new(41);
        let (qd, kd) = mk(&mut rng, 2, 1, 8, 100, 8);
        let q = QChunk::new(&qd, 2, 8, 8);
        let k = KCache::new(&kd, 1, 100, 100, 8);
        let mut ctx = SelectCtx::new(0);
        assert!(ctx.shared_indices.is_none());
        let sel0 = LessIsMore::default().select(&q, &k, 16, &mut ctx);
        assert!(ctx.shared_indices.is_some());
        // Non-selection layer reuses.
        ctx.layer = 1;
        let sel1 = LessIsMore::default().select(&q, &k, 16, &mut ctx);
        assert_eq!(sel0, sel1);
        // Next selection layer recomputes (may coincide, but must run: check
        // it still satisfies the contract).
        ctx.layer = 4;
        let sel4 = LessIsMore::default().select(&q, &k, 16, &mut ctx);
        assert_eq!(sel4.head_indices(0, 100).len(), 16);
    }

    #[test]
    fn local_window_always_present() {
        let mut rng = Rng::new(42);
        let (qd, kd) = mk(&mut rng, 1, 1, 4, 200, 8);
        let q = QChunk::new(&qd, 1, 4, 8);
        let k = KCache::new(&kd, 1, 200, 200, 8);
        let lim = LessIsMore { stride: 4, local_window: 8, ..Default::default() };
        let sel = lim.select(&q, &k, 16, &mut SelectCtx::new(0));
        let idx = sel.head_indices(0, 200);
        for want in 196u32..200 {
            assert!(idx.contains(&want), "recency token {want} missing");
        }
    }

    #[test]
    fn amortized_cost_is_lower_than_every_layer() {
        let mut rng = Rng::new(43);
        let (qd, kd) = mk(&mut rng, 1, 1, 8, 150, 8);
        let q = QChunk::new(&qd, 1, 8, 8);
        let k = KCache::new(&kd, 1, 150, 150, 8);
        let lim = LessIsMore::default();
        let mut ctx = SelectCtx::new(0);
        ctx.n_layers = 8;
        for layer in 0..8 {
            ctx.layer = layer;
            let _ = lim.select(&q, &k, 16, &mut ctx);
        }
        let amortized = ctx.cost.flops();
        let mut ctx2 = SelectCtx::new(0);
        for layer in 0..8 {
            ctx2.layer = layer;
            ctx2.shared_indices = None; // force rescore
            let lim_every = LessIsMore { stride: 1, local_window: 64, ..Default::default() };
            let _ = lim_every.select(&q, &k, 16, &mut ctx2);
        }
        assert!(amortized * 2 < ctx2.cost.flops(), "{amortized} vs {}", ctx2.cost.flops());
    }
}
