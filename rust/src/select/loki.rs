//! Loki baseline (Singhania et al., 2024).
//!
//! Low-rank keys: project queries and keys onto a `d_l`-dimensional PCA
//! basis of the keys, score in the reduced space, softmax and mean-aggregate
//! across queries and the KV group. The original uses an offline calibration
//! corpus for the basis; offline data does not exist in this harness, so the
//! basis is fit **lazily from the first `CALIB` cached keys of each head**
//! and then frozen — the same "basis learned from representative keys"
//! mechanism (documented substitution, DESIGN.md §3). Loki also pays
//! `O(d·d_l·n_Q)` per-layer basis storage, tallied in the cost counters.

use super::{fit, group_size, topk_ascending_into, KCache, QChunk, Scratch, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::linalg::principal_components;
use crate::tensor::ops::{dot, softmax};
use crate::util::Rng;
use std::sync::Mutex;

/// Keys used to fit each head's basis.
const CALIB: usize = 256;

/// Low-rank key projection policy.
#[derive(Debug)]
pub struct Loki {
    /// Reduced dimension (`d_l`). The paper projects to half the head dim
    /// (64 of 128); our heads are `d = 64`, so the default is 32.
    pub d_l: usize,
    /// Frozen per-(layer,head) bases, keyed by `(layer, kv_head)`.
    basis: Mutex<std::collections::HashMap<(usize, usize), Vec<Vec<f32>>>>,
}

impl Default for Loki {
    fn default() -> Self {
        Loki { d_l: 64, basis: Mutex::new(Default::default()) }
    }
}

impl Loki {
    pub fn new(d_l: usize) -> Loki {
        Loki { d_l, basis: Mutex::new(Default::default()) }
    }

    fn basis_for(&self, layer: usize, kv: usize, d: usize, d_l: usize) -> Vec<Vec<f32>> {
        let mut map = self.basis.lock().unwrap();
        map.entry((layer, kv))
            .or_insert_with(|| {
                // Offline calibration: the original fits the basis on keys
                // from a *calibration corpus*, not the live prompt. With no
                // corpus available offline, we draw calibration keys from a
                // generic distribution — reproducing the method's real
                // failure mode (basis/prompt distribution mismatch) rather
                // than granting it self-calibration the paper's Loki never
                // had (DESIGN.md §3).
                let mut rng = Rng::new(0x10C1 + (layer * 131 + kv) as u64);
                let calib = rng.normal_vec(CALIB * d, 1.0);
                principal_components(&calib, d, d_l, 12, &mut rng)
            })
            .clone()
    }
}

impl SelectionPolicy for Loki {
    fn name(&self) -> &'static str {
        "loki"
    }

    fn select(&self, q: &QChunk, k: &KCache, budget: usize, ctx: &mut SelectCtx) -> Selection {
        let t = k.t;
        if t <= budget {
            return Selection::All;
        }
        let d = q.d;
        let d_l = self.d_l.min(d);
        let n_kv = k.n_heads;
        let g = group_size(q.n_heads, n_kv);
        let scale = 1.0 / (d as f32).sqrt();

        let mut per_head = Vec::with_capacity(n_kv);
        for kv in 0..n_kv {
            let khead = k.head(kv);
            let basis = self.basis_for(ctx.layer, kv, d, d_l);
            let cost = &mut ctx.cost;
            cost.add_bytes((d * d_l * 4) as u64); // basis residency

            // All buffers from the scratch arena: kproj `[t, d_l]`, the
            // score aggregate, and a (row, qproj) pair carved from one
            // slab — zero per-call allocation.
            let Scratch { a, b, c, idx, .. } = &mut ctx.scratch;
            let kproj = fit(a, t * d_l);
            let agg = fit(b, t);
            let (row, qproj) = fit(c, t + d_l).split_at_mut(t);
            // Project keys once per call: kproj[t, d_l].
            for ti in 0..t {
                let key = &khead[ti * d..(ti + 1) * d];
                for (j, bv) in basis.iter().enumerate() {
                    kproj[ti * d_l + j] = dot(key, bv);
                }
            }
            cost.add_flops((t * d_l * 2 * d) as u64);
            agg.iter_mut().for_each(|v| *v = 0.0);
            for gq in 0..g {
                let h = kv * g + gq;
                for i in 0..q.s {
                    let qrow = q.query(h, i);
                    for (j, bv) in basis.iter().enumerate() {
                        qproj[j] = dot(qrow, bv);
                    }
                    for ti in 0..t {
                        row[ti] = dot(&*qproj, &kproj[ti * d_l..(ti + 1) * d_l]) * scale;
                    }
                    softmax(row);
                    for ti in 0..t {
                        agg[ti] += row[ti];
                    }
                }
                cost.add_flops((q.s * (d_l * 2 * d + t * (2 * d_l + 4))) as u64);
                cost.add_bytes((q.s * t * 4) as u64);
            }
            per_head.push(topk_ascending_into(agg, budget, idx));
        }
        Selection::PerHead(per_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_and_determinism() {
        let mut rng = Rng::new(31);
        let (nh, nkv, s, t, d) = (2usize, 1usize, 6usize, 90usize, 16usize);
        let qd = rng.normal_vec(nh * s * d, 1.0);
        let kd = rng.normal_vec(nkv * t * d, 1.0);
        let q = QChunk::new(&qd, nh, s, d);
        let k = KCache::new(&kd, nkv, t, t, d);
        let loki = Loki::new(4);
        let a = loki.select(&q, &k, 12, &mut SelectCtx::new(0));
        let b = loki.select(&q, &k, 12, &mut SelectCtx::new(0));
        assert_eq!(a, b);
        let idx = a.head_indices(0, t);
        assert_eq!(idx.len(), 12);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn low_rank_projection_finds_dominant_direction_key() {
        // Keys mostly live along e0; the needle is a large spike along e0
        // matched by the queries — a rank-1 basis captures it.
        let (s, t, d, hot) = (4usize, 80usize, 8usize, 55usize);
        let mut rng = Rng::new(32);
        let mut qd = rng.normal_vec(s * d, 0.02);
        for i in 0..s {
            qd[i * d] = 1.0;
        }
        let mut kd = rng.normal_vec(t * d, 0.02);
        for i in 0..t {
            kd[i * d] += rng.normal() * 0.5;
        }
        kd[hot * d] = 6.0;
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let sel = Loki::new(1).select(&q, &k, 8, &mut SelectCtx::new(0));
        assert!(sel.head_indices(0, t).contains(&(hot as u32)));
    }

    #[test]
    fn basis_is_frozen_after_first_fit() {
        let mut rng = Rng::new(33);
        let (s, t, d) = (4usize, 64usize, 8usize);
        let qd = rng.normal_vec(s * d, 1.0);
        let kd = rng.normal_vec(t * d, 1.0);
        let q = QChunk::new(&qd, 1, s, d);
        let k = KCache::new(&kd, 1, t, t, d);
        let loki = Loki::new(2);
        let _ = loki.select(&q, &k, 8, &mut SelectCtx::new(0));
        let n_bases = loki.basis.lock().unwrap().len();
        let _ = loki.select(&q, &k, 8, &mut SelectCtx::new(0));
        assert_eq!(loki.basis.lock().unwrap().len(), n_bases);
    }
}
