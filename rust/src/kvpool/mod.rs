//! # Shared paged KV pool with radix prefix caching
//!
//! The physical KV store behind the engine's paged mode. Where the private
//! per-sequence [`KvBuffers`](crate::model::attention::KvBuffers) path keeps
//! one growable slab per `(sequence, layer)`, this subsystem keeps **one
//! shared slab of fixed-size pages per layer** and gives each sequence a
//! *block table* — an ordered list of page ids — so identical prompt
//! prefixes are stored once and shared across requests.
//!
//! ## Architecture (engine → scheduler → pool → kernel)
//!
//! ```text
//!   submit(tokens)
//!      │   RadixCache::lookup — longest cached prefix, in whole pages;
//!      │   matched pages are retained (+1 ref) and become the head of the
//!      │   sequence's block table; the prefill cursor starts *after* them,
//!      │   so their chunks are never scheduled.
//!      ▼
//!   Scheduler::plan — admission by real residency: a sequence is charged
//!      │   blocks_for(prompt + max_new) MINUS the pages it already holds
//!      │   from the prefix cache. BlockAllocator stays the lease layer:
//!      │   it hands out page ids and enforces capacity; the pool adds
//!      │   refcounts and physical storage on top.
//!      ▼
//!   KvPool — per-layer page slabs `[page, n_kv, block_tokens, d]`, grown
//!      │   lazily as pages are first leased. Every append maintains page
//!      │   metadata incrementally: per-key `1/‖k‖` (the PR-1 norm cache,
//!      │   now pooled) and a per-(page, head) key sum (≡ unnormalized mean
//!      │   key). Shared pages are copy-on-write: a write into a page with
//!      │   refcount > 1 first clones it into a fresh page.
//!      ▼
//!   Kernels — `paged_chunk_attention` gathers K/V tiles through the block
//!          table (per-page head rows are contiguous, so full-selection
//!          tiles stream page runs); the QUOKA key scan scores the per-page
//!          mean-key metadata first and only descends into pages whose
//!          cosine bound survives (CompactAttention / Double-Sparsity
//!          style), skipping whole pages of the exact scan.
//! ```
//!
//! ## Prefix-cache semantics
//!
//! * Keys are **token ids at page granularity** plus a namespace hash of
//!   `(policy, budget, b_cp)` — with sparse selection the cached hidden
//!   states (hence KV) depend on the policy *and* on where prefill chunk
//!   boundaries fell, so prefixes are only reused within the same
//!   configuration (dense attention is exact under any chunking and
//!   shares one namespace). Under concurrent load the scheduler can still
//!   truncate a sparse policy's chunk below `b_cp`, shifting later
//!   boundaries; reused KV may then differ slightly from a cold
//!   recompute — an approximation of the same order the sparse policy
//!   already accepts (exact reuse is pinned by the serial-load e2e test).
//! * Only *full* pages of the **prompt** are inserted — **in flight**, as
//!   each prefill chunk completes them ([`RadixCache::publish_upto`]), so
//!   concurrent requests sharing a prefix park behind the producing
//!   sequence and adopt its pages instead of recomputing them (the
//!   engine's `Phase::WaitingOnPrefix`). A partially filled page is never
//!   published; generated tokens never enter the tree. An aborted
//!   publisher's unadopted tail is withdrawn
//!   ([`RadixCache::unpublish_tail`]); anything a follower adopted
//!   survives the abort, and the follower recomputes only what the tree
//!   no longer covers.
//! * A lookup never matches the entire prompt: at least one token is left
//!   to prefill so TTFT sampling always has a final hidden row.
//! * The tree holds its own +1 reference on every cached page. Eviction is
//!   LRU over *leaf* nodes whose page has no other owner — a page
//!   referenced by any live sequence is never freed (property-tested in
//!   `rust/tests/kvpool_props.rs`).
//!
//! ## Invariants
//!
//! * `free + leased == total` on the lease layer, always (the pool never
//!   bypasses the allocator).
//! * `refcount[p] > 0` ⇔ page `p` is leased; a page reaching refcount 0 is
//!   returned to the allocator immediately.
//! * Page metadata (`1/‖k‖`, key sums) is exact for every filled row after
//!   every append, COW copy and page reuse (reused pages have their sums
//!   zeroed on adoption).

pub mod pool;
pub mod radix;
pub mod spill;

pub use pool::{KvDtype, KvPool, PagedKv, PoolCfg};
pub use radix::{policy_ns, PageRef, RadixCache, RadixCursor, RadixStats};
pub use spill::{slot_stride, PromoteDone, Promoter, SpillFile};
