//! Radix prefix cache over token ids, at page granularity.
//!
//! Each tree edge spans exactly one page (`block_tokens` token ids); a node
//! owns the pool page holding that span's KV. Lookups walk whole pages and
//! return the longest cached prefix's pages; inserts add prompt pages as
//! they fill — [`RadixCache::publish_upto`] is the in-flight publish hook
//! (a page is publishable the moment its last token's KV is written, never
//! earlier), so concurrent requests sharing a prefix adopt pages while the
//! producing prefill is still running ([`RadixCache::extend_match`]).
//! Eviction is LRU over leaves whose page has no owner besides the tree
//! itself — a page referenced by a live sequence is never freed — and an
//! aborted in-flight publisher's unadopted tail can be withdrawn with
//! [`RadixCache::unpublish_tail`].
//!
//! With a spill tier attached ([`RadixCache::evict_until_spill`]), cold
//! pages are *demoted* instead of destroyed: the page image moves to the
//! mmapped spill file, the node keeps [`PageRef::Spilled`] (suffix-first —
//! a node demotes only once all its children are spilled), and a later
//! hit on the spilled prefix promotes pages back
//! ([`RadixCache::spilled_run`] → async read → [`RadixCache::promote_node`]).
//! Lookups and follower polls only ever return *resident* pages; a
//! spilled continuation is surfaced separately so the engine can park the
//! request on the promotion instead of retaining a page that is not
//! there.
//!
//! Trees are *namespaced* by a `(policy, budget, b_cp)` hash (see
//! [`policy_ns`]): under sparse selection the cached hidden states (hence
//! KV) depend on the selection configuration, so prefixes must not be
//! shared across it; exact (dense) attention shares one namespace.

use super::pool::KvPool;
use crate::coordinator::kv_blocks::BlockAllocator;
use std::collections::HashMap;

/// Namespace hash for prefix sharing (FNV-1a).
///
/// Cached KV depends on the selection configuration: sparse policies
/// change hidden states (hence KV), and their prefill chunk boundaries
/// (`b_cp`) change which keys each chunk's selection saw — so requests
/// only share cached KV when policy name, budget and chunk size all
/// agree. Dense attention is exact under any chunking, so every
/// dense/full request shares one namespace regardless of budget or
/// `b_cp`. (Under concurrent load the scheduler may still truncate a
/// sparse policy's chunk below `b_cp`, shifting later boundaries — reused
/// KV can then differ slightly from a cold recompute, bounded by the same
/// approximation the sparse policy already accepts; see ROADMAP.)
pub fn policy_ns(name: &str, budget: usize, b_cp: usize) -> u64 {
    let exact = name == "dense" || name == "full";
    let name = if exact { "dense" } else { name };
    let (budget, b_cp) = if exact { (0, 0) } else { (budget, b_cp) };
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for b in budget.to_le_bytes().into_iter().chain(b_cp.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

const PARENT_ROOT: usize = usize::MAX;
const PARENT_FREE: usize = usize::MAX - 1;

/// Where a cached page's KV currently lives: a RAM pool page, or a slot
/// of the mmapped spill file (`kvpool/spill.rs`). A spilled node's fp32
/// key-sum metadata stays resident in the spill tier's sidecar, so the
/// QUOKA scan can still score the prefix without touching disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageRef {
    Resident(u32),
    Spilled(u32),
}

struct Node {
    /// Child edges, keyed by their `block_tokens`-long token span.
    children: HashMap<Vec<u32>, usize>,
    /// Parent node index; `PARENT_ROOT` for roots, `PARENT_FREE` when the
    /// slot is on the free list.
    parent: usize,
    /// Token span of the edge from `parent` (empty for roots).
    edge: Vec<u32>,
    /// Pool page or spill slot holding this span's KV (unused for roots).
    block: PageRef,
    /// LRU clock value of the last lookup/insert touching this node.
    last_use: u64,
    /// Slot generation, bumped whenever the slot is freed — remembered
    /// [`RadixCursor`]s validate against it before trusting the index.
    gen: u64,
}

/// A remembered position in one prompt's radix chain: `node` is the tree
/// node whose depth (in whole pages) is `pages`. Callers that publish or
/// poll the same chain repeatedly hand the cursor back so each call walks
/// only the *new* pages instead of re-walking from the root — O(new)
/// instead of O(published) span hashes per call.
///
/// Validity: node indices are stable while the chain's pages stay
/// referenced (eviction and abort withdrawal never free a page with a
/// live owner), which covers a publisher's own chain and a follower's
/// adopted prefix. The one exception is a chain tail whose node holds
/// *another* request's page (duplicate publishes keep the existing node):
/// that page can be evicted once its owner retires, freeing the node
/// under the cursor. Cursors therefore carry the node's generation
/// counter — a stale or reused slot fails validation and the walk falls
/// back to the root, trading one O(published) re-walk for correctness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RadixCursor {
    node: usize,
    gen: u64,
    pages: usize,
}

/// Cache observability counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct RadixStats {
    pub lookups: u64,
    pub hits: u64,
    pub lookup_tokens: u64,
    pub hit_tokens: u64,
    pub inserted_blocks: u64,
    /// Pages removed by LRU pressure ([`RadixCache::evict_until`]).
    pub evicted_blocks: u64,
    /// Pages removed by abort withdrawal ([`RadixCache::unpublish_tail`])
    /// — kept separate from evictions so cancel-heavy traffic does not
    /// read as memory pressure.
    pub withdrawn_blocks: u64,
    /// Pages demoted to the spill tier instead of destroyed
    /// ([`RadixCache::evict_until_spill`]).
    pub spilled_blocks: u64,
    /// Pages promoted back from the spill tier
    /// ([`RadixCache::promote_node`]).
    pub promoted_blocks: u64,
}

/// The prefix tree.
pub struct RadixCache {
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    /// Namespace hash → root node index.
    roots: HashMap<u64, usize>,
    block_tokens: usize,
    tick: u64,
    pub stats: RadixStats,
    /// Spill slots whose owning node was removed or revived — the engine
    /// drains these into `SpillFile::free_slot` after any call that can
    /// drop a spilled node (removal cannot free the slot directly: the
    /// spill file is not threaded through every removal path, and a slot
    /// with an in-flight promotion read must go through the file's
    /// pin/defer protocol).
    freed_slots: Vec<u32>,
}

impl RadixCache {
    pub fn new(block_tokens: usize) -> RadixCache {
        assert!(block_tokens > 0);
        RadixCache {
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: HashMap::new(),
            block_tokens,
            tick: 0,
            stats: RadixStats::default(),
            freed_slots: Vec::new(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Drain the spill slots orphaned since the last call (see the field
    /// doc) — the engine feeds them to `SpillFile::free_slot`.
    pub fn take_freed_slots(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.freed_slots)
    }

    fn new_node(&mut self, parent: usize, edge: Vec<u32>, block: PageRef) -> usize {
        let node =
            Node { children: HashMap::new(), parent, edge, block, last_use: self.tick, gen: 0 };
        match self.free_nodes.pop() {
            Some(i) => {
                let gen = self.nodes[i].gen; // survives the slot overwrite
                self.nodes[i] = node;
                self.nodes[i].gen = gen;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    fn root(&mut self, ns: u64) -> usize {
        if let Some(&r) = self.roots.get(&ns) {
            return r;
        }
        let r = self.new_node(PARENT_ROOT, Vec::new(), PageRef::Resident(u32::MAX));
        self.roots.insert(ns, r);
        r
    }

    /// Longest *resident* cached prefix of `tokens` in namespace `ns`, as
    /// pool page ids (one per `block_tokens` tokens). Never matches the
    /// entire prompt: at least one token is left to prefill. The walk
    /// stops at the first spilled node — spilled pages cannot be retained;
    /// the caller discovers the spilled continuation with
    /// [`RadixCache::spilled_run`] and promotes it instead. The caller
    /// owns nothing yet — it must `KvPool::retain` every returned page.
    pub fn lookup(&mut self, ns: u64, tokens: &[u32]) -> Vec<u32> {
        self.tick += 1;
        self.stats.lookups += 1;
        self.stats.lookup_tokens += tokens.len() as u64;
        let bt = self.block_tokens;
        let max_blocks = tokens.len().saturating_sub(1) / bt;
        let Some(&root) = self.roots.get(&ns) else {
            return Vec::new();
        };
        let mut cur = root;
        let mut out = Vec::new();
        for j in 0..max_blocks {
            let span = &tokens[j * bt..(j + 1) * bt];
            match self.nodes[cur].children.get(span) {
                Some(&next) => {
                    let PageRef::Resident(b) = self.nodes[next].block else {
                        break;
                    };
                    cur = next;
                    self.nodes[cur].last_use = self.tick;
                    out.push(b);
                }
                None => break,
            }
        }
        if !out.is_empty() {
            self.stats.hits += 1;
            self.stats.hit_tokens += (out.len() * bt) as u64;
        }
        out
    }

    /// The contiguous spilled continuation of a prompt's match: spill
    /// slots for the pages of `tokens` starting at page `from_pages`
    /// (normally the resident match length a [`RadixCache::lookup`] just
    /// returned), each as `(node, generation, slot)` — the readahead
    /// target the engine hands to the promotion thread at `submit`. The
    /// run stops at the first resident or uncached page and never covers
    /// the whole prompt (same one-token floor as `lookup`). Touches the
    /// LRU clock: a hit on a spilled prefix is still a hit.
    pub fn spilled_run(
        &mut self,
        ns: u64,
        tokens: &[u32],
        from_pages: usize,
    ) -> Vec<(usize, u64, u32)> {
        self.tick += 1;
        let bt = self.block_tokens;
        let max_blocks = tokens.len().saturating_sub(1) / bt;
        let Some(&root) = self.roots.get(&ns) else {
            return Vec::new();
        };
        let mut cur = root;
        let mut out = Vec::new();
        for j in 0..max_blocks {
            let span = &tokens[j * bt..(j + 1) * bt];
            let Some(&next) = self.nodes[cur].children.get(span) else {
                break;
            };
            cur = next;
            if j >= from_pages {
                let PageRef::Spilled(slot) = self.nodes[cur].block else {
                    break;
                };
                self.nodes[cur].last_use = self.tick;
                out.push((cur, self.nodes[cur].gen, slot));
            }
        }
        out
    }

    /// Apply a finished promotion: the node (validated live via its
    /// generation and still holding `slot`) flips to
    /// `PageRef::Resident(page)`; the caller has restored the image into
    /// `page`, whose single reference (from `KvPool::adopt_new`) becomes
    /// the tree's own. Returns false when the node was removed or revived
    /// while the read was in flight — the caller keeps its page lease and
    /// releases it. Either way the slot is done: on success it is pushed
    /// to the orphan list for the engine to free.
    pub fn promote_node(&mut self, idx: usize, gen: u64, slot: u32, page: u32) -> bool {
        let live = idx < self.nodes.len()
            && self.nodes[idx].gen == gen
            && self.nodes[idx].parent != PARENT_FREE
            && self.nodes[idx].block == PageRef::Spilled(slot);
        if !live {
            return false;
        }
        self.nodes[idx].block = PageRef::Resident(page);
        self.freed_slots.push(slot);
        self.stats.promoted_blocks += 1;
        true
    }

    /// Drop a spilled node and its (necessarily all-spilled) subtree —
    /// the promotion failure path (torn slot, or no RAM page could be
    /// allocated): the chain is no longer recoverable, so waiters fall
    /// back to a cold prefill. No-op when the node is stale. Slots land
    /// on the orphan list.
    pub fn drop_spilled_subtree(&mut self, idx: usize, gen: u64) {
        let live = idx < self.nodes.len()
            && self.nodes[idx].gen == gen
            && self.nodes[idx].parent != PARENT_FREE
            && matches!(self.nodes[idx].block, PageRef::Spilled(_));
        if !live {
            return;
        }
        let mut stack = vec![idx];
        let mut order = Vec::new();
        while let Some(i) = stack.pop() {
            order.push(i);
            stack.extend(self.nodes[i].children.values().copied());
        }
        // Unlink from the surviving parent once, then free deepest-first.
        let parent = self.nodes[idx].parent;
        let edge = std::mem::take(&mut self.nodes[idx].edge);
        let removed = self.nodes[parent].children.remove(edge.as_slice());
        debug_assert_eq!(removed, Some(idx));
        for &i in order.iter().rev() {
            match self.nodes[i].block {
                PageRef::Spilled(s) => self.freed_slots.push(s),
                PageRef::Resident(_) => {
                    debug_assert!(false, "resident node {i} under a spilled subtree")
                }
            }
            self.nodes[i].children = HashMap::new();
            self.nodes[i].edge = Vec::new();
            self.nodes[i].parent = PARENT_FREE;
            self.nodes[i].gen += 1;
            self.free_nodes.push(i);
        }
    }

    /// Insert the full pages of `tokens` (a finished prefill's prompt) with
    /// their backing pool pages. New nodes retain their page (+1 ref, the
    /// tree's own); spans already cached keep their existing page and the
    /// duplicate stays solely owned by its sequence.
    pub fn insert(&mut self, ns: u64, tokens: &[u32], blocks: &[u32], pool: &mut KvPool) {
        self.tick += 1;
        let bt = self.block_tokens;
        let n = (tokens.len() / bt).min(blocks.len());
        let mut cur = self.root(ns);
        for j in 0..n {
            let span = &tokens[j * bt..(j + 1) * bt];
            if let Some(&next) = self.nodes[cur].children.get(span) {
                cur = next;
                self.nodes[cur].last_use = self.tick;
                self.revive(cur, blocks[j], pool);
            } else {
                let span = span.to_vec();
                let node = self.new_node(cur, span.clone(), PageRef::Resident(blocks[j]));
                self.nodes[cur].children.insert(span, node);
                pool.retain(blocks[j]);
                self.stats.inserted_blocks += 1;
                cur = node;
            }
        }
    }

    /// A publisher walked onto an existing *spilled* node for a span it
    /// just recomputed: adopt the fresh page as the node's resident copy
    /// (the spilled image is identical KV — same namespace, same span
    /// chain) and orphan the slot. Keeps demoted chains from shadowing
    /// re-publishes forever.
    fn revive(&mut self, idx: usize, block: u32, pool: &mut KvPool) {
        if let PageRef::Spilled(slot) = self.nodes[idx].block {
            self.nodes[idx].block = PageRef::Resident(block);
            pool.retain(block);
            self.freed_slots.push(slot);
            self.stats.inserted_blocks += 1;
        }
    }

    /// In-flight publish hook: insert every *completed* page of a prompt
    /// that is still prefilling. `filled_tokens` is how far the prompt's
    /// KV has been written; only whole pages below it are published — a
    /// partially filled page is never inserted (each published page's fill
    /// is checked against the pool in debug builds). Re-publishing already
    /// cached spans is a no-op (existing nodes keep their pages), so the
    /// caller only needs a monotone watermark, not exact bookkeeping.
    /// Returns the new watermark: pages of `tokens` now in the tree.
    ///
    /// Thin wrapper over [`RadixCache::publish_upto_at`] with no
    /// remembered cursor (one full root walk per call) — there is exactly
    /// one copy of the walk/insert/retain logic.
    pub fn publish_upto(
        &mut self,
        ns: u64,
        tokens: &[u32],
        blocks: &[u32],
        filled_tokens: usize,
        pool: &mut KvPool,
    ) -> usize {
        self.publish_upto_at(ns, tokens, blocks, filled_tokens, pool, &mut None)
    }

    /// Resolve a remembered cursor to `(node, depth)`, falling back to a
    /// fresh root walk when the cursor is absent, stale (its slot was
    /// freed or reused — generation mismatch), or deeper than the caller's
    /// confirmed coverage. Returns `None` when the namespace has no tree
    /// yet and `create_root` is false.
    fn resolve_cursor(
        &mut self,
        ns: u64,
        tokens: &[u32],
        cursor: &Option<RadixCursor>,
        max_depth: usize,
        create_root: bool,
    ) -> Option<(usize, usize)> {
        if let Some(c) = cursor {
            let live = c.node < self.nodes.len()
                && self.nodes[c.node].gen == c.gen
                && self.nodes[c.node].parent != PARENT_FREE;
            if live && c.pages <= max_depth {
                debug_assert!(
                    c.pages == 0
                        || (c.pages * self.block_tokens <= tokens.len()
                            && self.nodes[c.node].edge
                                == tokens[(c.pages - 1) * self.block_tokens
                                    ..c.pages * self.block_tokens]),
                    "live radix cursor off its chain"
                );
                return Some((c.node, c.pages));
            }
        }
        if create_root {
            Some((self.root(ns), 0))
        } else {
            self.roots.get(&ns).map(|&r| (r, 0))
        }
    }

    /// [`RadixCache::publish_upto`] with a remembered cursor: the walk
    /// resumes at `cursor` (or the namespace root when absent/stale) and
    /// only descends/creates nodes for pages past the cursor's depth, so
    /// a publisher inserting pages chunk by chunk pays O(new pages) per
    /// publish instead of re-hashing its whole published span. The cursor
    /// is advanced to the new watermark; semantics are otherwise identical
    /// (whole pages only, idempotent over already-cached spans).
    pub fn publish_upto_at(
        &mut self,
        ns: u64,
        tokens: &[u32],
        blocks: &[u32],
        filled_tokens: usize,
        pool: &mut KvPool,
        cursor: &mut Option<RadixCursor>,
    ) -> usize {
        self.tick += 1;
        let bt = self.block_tokens;
        let n = (filled_tokens / bt).min(tokens.len() / bt).min(blocks.len());
        if cfg!(debug_assertions) {
            for &b in &blocks[..n] {
                assert!(pool.page_filled(b), "publishing partially filled page {b} (fill < {bt})");
            }
        }
        let (mut cur, start) =
            self.resolve_cursor(ns, tokens, cursor, n, true).expect("root creation is infallible");
        for j in start..n {
            let span = &tokens[j * bt..(j + 1) * bt];
            if let Some(&next) = self.nodes[cur].children.get(span) {
                cur = next;
                self.nodes[cur].last_use = self.tick;
                self.revive(cur, blocks[j], pool);
            } else {
                let span = span.to_vec();
                let node = self.new_node(cur, span.clone(), PageRef::Resident(blocks[j]));
                self.nodes[cur].children.insert(span, node);
                pool.retain(blocks[j]);
                self.stats.inserted_blocks += 1;
                cur = node;
            }
        }
        *cursor =
            Some(RadixCursor { node: cur, gen: self.nodes[cur].gen, pages: n.max(start) });
        n
    }

    /// [`RadixCache::extend_match`] with a remembered cursor: the
    /// follower-adoption poll resumes its silent walk at `cursor` instead
    /// of the root (O(new pages) per poll). As in `extend_match`, returns
    /// the pages cached beyond `from_pages`, or nothing when the chain no
    /// longer reaches `from_pages`. The cursor is advanced only to
    /// `from_pages` — the depth the caller has *confirmed holdings* for
    /// (an adopter may take fewer pages than matched, and cursor safety
    /// leans on the owner referencing every page at or above the cursor);
    /// the caller bumps it implicitly by passing a larger `from_pages`
    /// next poll.
    pub fn extend_match_at(
        &mut self,
        ns: u64,
        tokens: &[u32],
        from_pages: usize,
        cursor: &mut Option<RadixCursor>,
    ) -> Vec<u32> {
        let bt = self.block_tokens;
        let max_blocks = tokens.len().saturating_sub(1) / bt;
        let Some((mut cur, start)) = self.resolve_cursor(ns, tokens, cursor, from_pages, false)
        else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut depth = start;
        let mut at_from = if start == from_pages { Some(cur) } else { None };
        for j in start..max_blocks {
            let span = &tokens[j * bt..(j + 1) * bt];
            match self.nodes[cur].children.get(span) {
                Some(&next) => {
                    if j >= from_pages {
                        // Only resident pages can be adopted (the caller
                        // retains them); a spilled continuation is the
                        // promotion machinery's job, not the poll's.
                        let PageRef::Resident(b) = self.nodes[next].block else {
                            break;
                        };
                        out.push(b);
                    }
                    cur = next;
                    depth = j + 1;
                    if depth == from_pages {
                        at_from = Some(cur);
                    }
                }
                None => break,
            }
        }
        if depth < from_pages {
            // The chain no longer reaches the caller's coverage
            // (unpublished or evicted underneath it): nothing to adopt.
            return Vec::new();
        }
        if let Some(node) = at_from {
            *cursor =
                Some(RadixCursor { node, gen: self.nodes[node].gen, pages: from_pages });
        }
        out
    }

    /// Pages cached for `tokens` beyond the first `from_pages`, in walk
    /// order — the follower-adoption poll: cheap, side-effect free (no LRU
    /// clock or stats update; adopters take their own page references,
    /// which protect the pages from eviction better than recency would).
    /// Returns an empty vector when even the first `from_pages` pages are
    /// no longer cached (the chain was unpublished or evicted).
    ///
    /// Thin wrapper over [`RadixCache::extend_match_at`] with no
    /// remembered cursor (one full root walk per call).
    pub fn extend_match(&mut self, ns: u64, tokens: &[u32], from_pages: usize) -> Vec<u32> {
        self.extend_match_at(ns, tokens, from_pages, &mut None)
    }

    /// Withdraw the unadopted tail of a published chain (leader abort):
    /// walk the chain for `tokens`, then remove nodes deepest-first down
    /// to `keep_pages`, stopping at the first node that has children
    /// (another prompt's chain hangs off it) or whose page any live
    /// sequence still references — adopted pages always outlive the
    /// aborted publisher. Returns the pages freed. The caller must have
    /// released the aborting sequence's own page references first, so
    /// "refcount 1" means "tree only".
    pub fn unpublish_tail(
        &mut self,
        ns: u64,
        tokens: &[u32],
        keep_pages: usize,
        pool: &mut KvPool,
        alloc: &mut BlockAllocator,
    ) -> usize {
        let bt = self.block_tokens;
        let Some(&root) = self.roots.get(&ns) else {
            return 0;
        };
        let mut chain = Vec::new();
        let mut cur = root;
        for j in 0..tokens.len() / bt {
            let span = &tokens[j * bt..(j + 1) * bt];
            match self.nodes[cur].children.get(span) {
                Some(&next) => {
                    cur = next;
                    chain.push(next);
                }
                None => break,
            }
        }
        let mut freed = 0;
        while chain.len() > keep_pages {
            let idx = chain.pop().unwrap();
            let sole_owner = match self.nodes[idx].block {
                PageRef::Resident(b) => pool.refcount(b) == 1,
                PageRef::Spilled(_) => true, // spill slots have no pool owner
            };
            if !self.nodes[idx].children.is_empty() || !sole_owner {
                break;
            }
            self.remove_leaf(idx, pool, alloc);
            self.stats.withdrawn_blocks += 1;
            freed += 1;
        }
        freed
    }

    /// Pool page ids of every *resident* cached node (test hook for
    /// publish invariants, e.g. "every cached page is fully filled").
    pub fn cached_pages(&self) -> Vec<u32> {
        self.nodes
            .iter()
            .filter(|n| n.parent != PARENT_FREE && n.parent != PARENT_ROOT)
            .filter_map(|n| match n.block {
                PageRef::Resident(b) => Some(b),
                PageRef::Spilled(_) => None,
            })
            .collect()
    }

    /// Number of RAM pages the tree currently holds a reference on
    /// (spilled nodes hold a spill slot, not a pool reference).
    pub fn cached_blocks(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| {
                n.parent != PARENT_FREE
                    && n.parent != PARENT_ROOT
                    && matches!(n.block, PageRef::Resident(_))
            })
            .count()
    }

    /// Number of cached pages currently demoted to the spill tier.
    pub fn spilled_nodes(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.parent != PARENT_FREE && matches!(n.block, PageRef::Spilled(_)))
            .count()
    }

    /// Evict LRU unreferenced leaves until the lease layer has at least
    /// `min_free` free pages (or nothing more can be evicted). Returns the
    /// number of pages freed. Pages with any owner besides the tree are
    /// never touched.
    ///
    /// Each pass scans the node slab once and evicts the whole eligible
    /// batch oldest-first; evicting a leaf can turn its parent into a
    /// leaf, so passes repeat until the target is met or a scan comes back
    /// empty — O(nodes · depth) worst case instead of O(nodes · freed).
    pub fn evict_until(
        &mut self,
        min_free: usize,
        pool: &mut KvPool,
        alloc: &mut BlockAllocator,
    ) -> usize {
        self.evict_until_traced(min_free, pool, alloc, &mut crate::obs::Tracer::disabled())
    }

    /// [`RadixCache::evict_until`] with lifecycle tracing: a non-empty
    /// eviction emits one engine-scope `Evict{pages}` event at the
    /// pressure site (the engine passes its tracer). No spill tier:
    /// every cold page is destroyed.
    pub fn evict_until_traced(
        &mut self,
        min_free: usize,
        pool: &mut KvPool,
        alloc: &mut BlockAllocator,
        tracer: &mut crate::obs::Tracer,
    ) -> usize {
        self.evict_until_spill(min_free, pool, alloc, None, tracer)
    }

    /// [`RadixCache::evict_until_traced`] over a tiered pool: cold pages
    /// are **demoted** to the spill file instead of destroyed — the page
    /// image (rows, scales, inverse norms, key sums, fill) moves to a
    /// checksummed slot, the node flips to [`PageRef::Spilled`], and the
    /// RAM page goes back to the allocator, so `kv_bytes_resident`
    /// (computed from leased blocks) counts only the RAM tier. Demotion
    /// is suffix-first: a node is eligible once every child is already
    /// spilled, so interior pages of a cold chain demote too, not just
    /// leaves. When the spill file is full (or absent) the pass falls
    /// back to hard eviction, dropping an exhausted node's spilled
    /// subtree first when one is in the way. Returns RAM pages freed
    /// (demoted + evicted); emits engine-scope `Spill{pages}` /
    /// `Evict{pages}` events for the non-empty kinds.
    pub fn evict_until_spill(
        &mut self,
        min_free: usize,
        pool: &mut KvPool,
        alloc: &mut BlockAllocator,
        mut spill: Option<&mut crate::kvpool::spill::SpillFile>,
        tracer: &mut crate::obs::Tracer,
    ) -> usize {
        let mut evicted = 0u32;
        let mut demoted = 0u32;
        let mut img = Vec::new();
        while alloc.free_blocks() < min_free {
            // Batch entries stay valid as the batch drains: an eligible
            // node's parent has a resident child (so is never in the same
            // batch), and no refcount or child set changes except by the
            // removals/demotions themselves.
            let mut batch: Vec<(u64, usize)> = self
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| {
                    n.parent != PARENT_FREE
                        && n.parent != PARENT_ROOT
                        && matches!(n.block, PageRef::Resident(b) if pool.refcount(b) == 1)
                        && n.children
                            .values()
                            .all(|&c| matches!(self.nodes[c].block, PageRef::Spilled(_)))
                })
                .map(|(i, n)| (n.last_use, i))
                .collect();
            if batch.is_empty() {
                break;
            }
            batch.sort_unstable();
            let mut progress = false;
            for (_, idx) in batch {
                if alloc.free_blocks() >= min_free {
                    break;
                }
                let PageRef::Resident(b) = self.nodes[idx].block else {
                    unreachable!("batch filter keeps resident nodes only")
                };
                if let Some(sp) = spill.as_deref_mut() {
                    pool.extract_page_image(b, &mut img);
                    let sums = pool.page_key_sums(b);
                    if let Some(slot) = sp.write(&img, sums) {
                        self.nodes[idx].block = PageRef::Spilled(slot);
                        pool.release_block(b, alloc);
                        self.stats.spilled_blocks += 1;
                        demoted += 1;
                        progress = true;
                        continue;
                    }
                }
                // Spill full or absent: destroy. A node with spilled
                // children cannot be unlinked until they are dropped —
                // the tier is exhausted, so the subtree is unrecoverable
                // pressure anyway.
                if !self.nodes[idx].children.is_empty() {
                    let children: Vec<usize> =
                        self.nodes[idx].children.values().copied().collect();
                    for c in children {
                        self.drop_spilled_subtree(c, self.nodes[c].gen);
                    }
                }
                self.remove_leaf(idx, pool, alloc);
                self.stats.evicted_blocks += 1;
                evicted += 1;
                progress = true;
            }
            if !progress {
                break;
            }
        }
        if demoted > 0 {
            tracer.record(0, crate::obs::TraceEventKind::Spill { pages: demoted });
        }
        if evicted > 0 {
            tracer.record(0, crate::obs::TraceEventKind::Evict { pages: evicted });
        }
        (evicted + demoted) as usize
    }

    fn remove_leaf(&mut self, idx: usize, pool: &mut KvPool, alloc: &mut BlockAllocator) {
        debug_assert!(self.nodes[idx].children.is_empty());
        let parent = self.nodes[idx].parent;
        let edge = std::mem::take(&mut self.nodes[idx].edge);
        let removed = self.nodes[parent].children.remove(edge.as_slice());
        debug_assert_eq!(removed, Some(idx));
        match self.nodes[idx].block {
            PageRef::Resident(b) => pool.release_block(b, alloc),
            PageRef::Spilled(slot) => self.freed_slots.push(slot),
        }
        self.nodes[idx].children = HashMap::new();
        self.nodes[idx].parent = PARENT_FREE;
        self.nodes[idx].gen += 1; // invalidate remembered cursors
        self.free_nodes.push(idx);
    }

    /// Structural invariant check (test hook): parent/child links are
    /// consistent, every edge spans one page, and every cached page is
    /// owned at least by the tree.
    pub fn validate(&self, pool: &KvPool) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.parent == PARENT_FREE {
                continue;
            }
            if n.parent == PARENT_ROOT {
                if !n.edge.is_empty() {
                    return Err(format!("root {i} has a non-empty edge"));
                }
            } else {
                if n.edge.len() != self.block_tokens {
                    return Err(format!("node {i}: edge length {}", n.edge.len()));
                }
                let p = &self.nodes[n.parent];
                if p.parent == PARENT_FREE {
                    return Err(format!("node {i}: freed parent"));
                }
                if p.children.get(n.edge.as_slice()) != Some(&i) {
                    return Err(format!("node {i}: parent link broken"));
                }
                match n.block {
                    PageRef::Resident(b) => {
                        if pool.refcount(b) == 0 {
                            return Err(format!("node {i}: cached page {b} unowned"));
                        }
                    }
                    PageRef::Spilled(_) => {
                        // Demotion is suffix-first, so a spilled node's
                        // children can never be resident.
                        for &c in n.children.values() {
                            if matches!(self.nodes[c].block, PageRef::Resident(_)) {
                                return Err(format!("node {i}: resident child {c} under spill"));
                            }
                        }
                    }
                }
            }
            for (edge, &c) in &n.children {
                let cn = &self.nodes[c];
                if cn.parent != i || &cn.edge != edge {
                    return Err(format!("node {i}: child {c} link broken"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::pool::PoolCfg;

    fn setup() -> (RadixCache, KvPool, BlockAllocator) {
        let cfg = PoolCfg { n_layers: 1, n_kv: 1, d: 2, block_tokens: 4, total_blocks: 32 };
        (RadixCache::new(4), KvPool::new(cfg), BlockAllocator::new(32, 4))
    }

    fn seq_tokens(n: usize, salt: u32) -> Vec<u32> {
        (0..n).map(|i| i as u32 * 3 + salt).collect()
    }

    /// Write KV rows for token positions `pos..pos+len` so those pages
    /// count as filled (publish_upto asserts fill in debug builds).
    fn fill(pool: &mut KvPool, blocks: &[u32], pos: usize, len: usize) {
        let (n_kv, d) = (pool.cfg.n_kv, pool.cfg.d);
        for l in 0..pool.cfg.n_layers {
            let k = vec![1.0f32; n_kv * len * d];
            let v = vec![0.5f32; n_kv * len * d];
            pool.append_chunk(blocks, l, pos, &k, &v, len);
        }
    }

    #[test]
    fn publish_upto_never_publishes_a_partial_page() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(12, 0); // 3 pages
        let blocks = alloc.alloc(3).unwrap();
        pool.adopt_new(&blocks);
        fill(&mut pool, &blocks, 0, 10); // 2.5 pages written
        let w = r.publish_upto(ns, &toks, &blocks, 10, &mut pool);
        assert_eq!(w, 2, "only the two completed pages are published");
        assert_eq!(r.cached_blocks(), 2);
        assert_eq!(pool.refcount(blocks[2]), 1, "partial page gained no tree ref");
        // Completing the page and republishing extends the chain; the
        // already-cached spans are untouched (idempotent watermark).
        fill(&mut pool, &blocks, 10, 2);
        let w = r.publish_upto(ns, &toks, &blocks, 12, &mut pool);
        assert_eq!(w, 3);
        assert_eq!(r.cached_blocks(), 3);
        assert_eq!(pool.refcount(blocks[0]), 2, "seq + tree, not re-retained");
        r.validate(&pool).unwrap();
    }

    #[test]
    fn extend_match_is_a_silent_suffix_walk() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(16, 1); // 4 pages
        let blocks = alloc.alloc(4).unwrap();
        pool.adopt_new(&blocks);
        fill(&mut pool, &blocks, 0, 8);
        r.publish_upto(ns, &toks, &blocks, 8, &mut pool);
        let lookups = r.stats.lookups;
        // Cursor at 1 page: only page 2 of the published prefix is new.
        assert_eq!(r.extend_match(ns, &toks, 1), vec![blocks[1]]);
        assert_eq!(r.extend_match(ns, &toks, 2), Vec::<u32>::new());
        fill(&mut pool, &blocks, 8, 8);
        r.publish_upto(ns, &toks, &blocks, 16, &mut pool);
        // The whole-prompt cap still applies: 16 tokens → at most 3 pages.
        assert_eq!(r.extend_match(ns, &toks, 1), blocks[1..3].to_vec());
        assert_eq!(r.stats.lookups, lookups, "extend_match must not count as a lookup");
        assert!(r.extend_match(policy_ns("dense", 0, 16), &toks, 0).is_empty());
    }

    #[test]
    fn unpublish_tail_spares_adopted_and_shared_pages() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(16, 2); // 4 pages, last never published
        let mut blocks = alloc.alloc(4).unwrap();
        pool.adopt_new(&blocks);
        fill(&mut pool, &blocks, 0, 12);
        r.publish_upto(ns, &toks, &blocks, 12, &mut pool);
        // A follower adopted the first page only.
        pool.retain(blocks[0]);
        let mut follower = vec![blocks[0]];
        // Leader aborts: releases its own refs, then withdraws its tail.
        let leader_pages = std::mem::take(&mut blocks);
        for b in &leader_pages {
            pool.release_block(*b, &mut alloc);
        }
        let freed = r.unpublish_tail(ns, &toks, 0, &mut pool, &mut alloc);
        assert_eq!(freed, 2, "pages 1..3 withdrawn; the adopted page survives");
        assert_eq!(r.stats.withdrawn_blocks, 2);
        assert_eq!(r.stats.evicted_blocks, 0, "withdrawals are not evictions");
        assert_eq!(r.cached_blocks(), 1);
        assert_eq!(pool.refcount(follower[0]), 2, "follower + tree");
        r.validate(&pool).unwrap();
        // The surviving page still answers lookups for the follower.
        assert_eq!(r.lookup(ns, &toks), vec![follower[0]]);
        pool.release_seq(&mut follower, &mut alloc);
        // keep_pages floor: nothing below it is withdrawn even when free.
        assert_eq!(r.unpublish_tail(ns, &toks, 1, &mut pool, &mut alloc), 0);
        assert_eq!(r.unpublish_tail(ns, &toks, 0, &mut pool, &mut alloc), 1);
        assert_eq!(alloc.free_blocks(), 32);
        r.validate(&pool).unwrap();
    }

    #[test]
    fn cursor_publish_walks_only_new_pages_and_matches_root_walks() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(16, 3); // 4 pages
        let blocks = alloc.alloc(4).unwrap();
        pool.adopt_new(&blocks);
        fill(&mut pool, &blocks, 0, 8);
        // Two cursor publishes must equal one big from-root publish.
        let mut cur = None;
        assert_eq!(r.publish_upto_at(ns, &toks, &blocks, 8, &mut pool, &mut cur), 2);
        let c1 = cur.expect("cursor set");
        fill(&mut pool, &blocks, 8, 8);
        assert_eq!(r.publish_upto_at(ns, &toks, &blocks, 16, &mut pool, &mut cur), 4);
        assert_ne!(cur.unwrap(), c1, "cursor advances with the watermark");
        assert_eq!(r.cached_blocks(), 4);
        for &b in &blocks {
            assert_eq!(pool.refcount(b), 2, "seq + tree, no double retain via cursor");
        }
        // Republish through the same cursor: idempotent, no new inserts.
        let inserted = r.stats.inserted_blocks;
        assert_eq!(r.publish_upto_at(ns, &toks, &blocks, 16, &mut pool, &mut cur), 4);
        assert_eq!(r.stats.inserted_blocks, inserted);
        r.validate(&pool).unwrap();

        // The cursor-aware follower poll equals the root-walk poll, and
        // its remembered position advances with confirmed coverage.
        let mut fc = None;
        assert_eq!(r.extend_match_at(ns, &toks, 1, &mut fc), r.extend_match(ns, &toks, 1));
        assert!(fc.is_some());
        assert_eq!(r.extend_match_at(ns, &toks, 2, &mut fc), r.extend_match(ns, &toks, 2));
        // Whole-prompt cap carries over: 16 tokens → 3 matchable pages.
        assert_eq!(r.extend_match_at(ns, &toks, 3, &mut fc), Vec::<u32>::new());
        // An unknown namespace stays empty through the cursor API too.
        let mut none = None;
        assert!(r.extend_match_at(policy_ns("dense", 0, 16), &toks, 0, &mut none).is_empty());
    }

    #[test]
    fn stale_cursor_falls_back_to_a_root_walk() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(12, 4); // 3 pages
        let mut blocks = alloc.alloc(3).unwrap();
        pool.adopt_new(&blocks);
        fill(&mut pool, &blocks, 0, 12);
        let mut cur = None;
        r.publish_upto_at(ns, &toks, &blocks, 12, &mut pool, &mut cur);
        // The publisher retires; its chain is evicted under the cursor.
        pool.release_seq(&mut blocks, &mut alloc);
        r.evict_until(alloc.total_blocks(), &mut pool, &mut alloc);
        assert_eq!(r.cached_blocks(), 0);
        // A new request republishes the same prompt while handing the
        // stale cursor back: generation validation must reject it and the
        // walk restarts at the root — fresh nodes, correct refcounts.
        let blocks2 = alloc.alloc(3).unwrap();
        pool.adopt_new(&blocks2);
        fill(&mut pool, &blocks2, 0, 12);
        assert_eq!(r.publish_upto_at(ns, &toks, &blocks2, 12, &mut pool, &mut cur), 3);
        assert_eq!(r.cached_blocks(), 3);
        assert_eq!(r.lookup(ns, &[toks.clone(), vec![0; 4]].concat()), blocks2);
        r.validate(&pool).unwrap();
        // Likewise for the follower poll: a stale cursor is equivalent to
        // no cursor, not a crash or a wrong chain.
        let mut stale = cur; // now valid again (points at the new chain)
        r.evict_until(alloc.total_blocks(), &mut pool, &mut alloc);
        assert_eq!(r.cached_blocks(), 3, "live pages are never evicted");
        let adopted = r.extend_match_at(ns, &toks, 0, &mut stale);
        assert_eq!(adopted, blocks2[..2].to_vec());
    }

    #[test]
    fn namespace_ignores_irrelevant_config_for_exact_attention() {
        // Dense KV is identical under any budget/chunking — one namespace.
        assert_eq!(policy_ns("dense", 0, 128), policy_ns("dense", 512, 256));
        assert_eq!(policy_ns("dense", 0, 128), policy_ns("full", 7, 64));
        // Sparse KV depends on budget AND chunk boundaries.
        assert_ne!(policy_ns("quoka", 64, 16), policy_ns("quoka", 64, 32));
        assert_ne!(policy_ns("quoka", 64, 16), policy_ns("quoka", 32, 16));
        assert_ne!(policy_ns("quoka", 64, 16), policy_ns("dense", 64, 16));
    }

    #[test]
    fn longest_match_walks_whole_pages() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(12, 0); // 3 pages
        let mut blocks = alloc.alloc(3).unwrap();
        pool.adopt_new(&blocks);
        r.insert(ns, &toks, &blocks, &mut pool);
        assert_eq!(r.cached_blocks(), 3);
        for b in &blocks {
            assert_eq!(pool.refcount(*b), 2); // seq + tree
        }
        // Full prompt never matches whole: 12 tokens → at most 2 pages.
        assert_eq!(r.lookup(ns, &toks), blocks[..2].to_vec());
        // Longer prompt sharing the prefix matches all 3 pages.
        let mut longer = toks.clone();
        longer.extend(seq_tokens(5, 99));
        assert_eq!(r.lookup(ns, &longer), blocks.clone());
        // Diverging second page stops the walk after one page.
        let mut div = toks.clone();
        div[5] = 1000;
        assert_eq!(r.lookup(ns, &div), blocks[..1].to_vec());
        // Other namespaces see nothing.
        assert!(r.lookup(policy_ns("dense", 0, 16), &longer).is_empty());
        r.validate(&pool).unwrap();
        pool.release_seq(&mut blocks, &mut alloc);
        r.validate(&pool).unwrap();
    }

    #[test]
    fn duplicate_insert_keeps_existing_pages() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        let toks = seq_tokens(8, 1);
        let mut b1 = alloc.alloc(2).unwrap();
        pool.adopt_new(&b1);
        r.insert(ns, &toks, &b1, &mut pool);
        let mut b2 = alloc.alloc(2).unwrap();
        pool.adopt_new(&b2);
        r.insert(ns, &toks, &b2, &mut pool);
        // The duplicate's pages gained no tree reference.
        assert_eq!(pool.refcount(b1[0]), 2);
        assert_eq!(pool.refcount(b2[0]), 1);
        assert_eq!(r.cached_blocks(), 2);
        pool.release_seq(&mut b1, &mut alloc);
        pool.release_seq(&mut b2, &mut alloc);
        r.validate(&pool).unwrap();
    }

    #[test]
    fn eviction_is_lru_leaf_only_and_respects_refs() {
        let (mut r, mut pool, mut alloc) = setup();
        let ns = policy_ns("quoka", 64, 16);
        // Two chains sharing the first page: [A B] and [A C].
        let ta = seq_tokens(8, 0);
        let mut tb = ta.clone();
        tb[6] = 500;
        let mut ba = alloc.alloc(2).unwrap();
        pool.adopt_new(&ba);
        r.insert(ns, &ta, &ba, &mut pool);
        let mut bb = vec![ba[0], alloc.alloc(1).unwrap()[0]];
        pool.retain(bb[0]);
        pool.adopt_new(&bb);
        r.insert(ns, &tb, &bb, &mut pool);
        // Touch chain B so chain A's leaf is LRU.
        let _ = r.lookup(ns, &[tb.clone(), vec![0; 4]].concat());
        // Drop the sequences' own refs; tree refs remain.
        pool.release_seq(&mut ba, &mut alloc);
        pool.release_seq(&mut bb, &mut alloc);
        r.validate(&pool).unwrap();
        let free0 = alloc.free_blocks();
        // Evict one page: must be chain A's *leaf* (LRU), not the shared root page.
        let freed = r.evict_until(free0 + 1, &mut pool, &mut alloc);
        assert_eq!(freed, 1);
        assert_eq!(r.cached_blocks(), 2);
        assert!(r.lookup(ns, &[tb.clone(), vec![0; 4]].concat()).len() == 2, "chain B intact");
        r.validate(&pool).unwrap();
        // A page referenced by a "live sequence" is never freed.
        let held = r.lookup(ns, &[tb.clone(), vec![0; 4]].concat());
        for &b in &held {
            pool.retain(b);
        }
        let freed = r.evict_until(alloc.total_blocks(), &mut pool, &mut alloc);
        assert_eq!(freed, 0, "all remaining pages are externally referenced");
        r.validate(&pool).unwrap();
    }
}
