//! Spill tier: an mmap-backed cold-page store under the paged KV pool.
//!
//! The radix prefix cache demotes cold pages here instead of destroying
//! them under pool pressure (`RadixCache::evict_until`): the page image —
//! rows (f32 or int8 codes), dequant scales, inverse norms, key sums and
//! fill counter — is serialized into a fixed-size *slot* of an mmapped
//! file, the radix node flips to `PageRef::Spilled(slot)`, and the RAM
//! page is released. A later radix hit on the spilled prefix promotes the
//! slots back into fresh pool pages on a background thread (`Promoter`),
//! while the requesting sequence parks in the engine's existing
//! `Phase::WaitingOnPrefix` machinery.
//!
//! Layout: the file is a flat array of slots, each
//!
//! ```text
//! [ magic u64 | payload_len u64 | fnv1a64(payload) u64 | payload … pad ]
//! ```
//!
//! written payload-first, header-last, so a crash mid-demote leaves a
//! torn slot whose checksum fails — `SpillFile::open` keeps only
//! checksum-valid slots and returns the rest to the free list (the
//! crash-safety property pinned in `rust/tests/kvpool_props.rs`). Freed
//! slots are reused. Alongside each occupied slot the file keeps a RAM
//! sidecar with the page's fp32 key sums (`slot_key_sums`), so the QUOKA
//! paged scan can score — and skip — a spilled prefix without touching
//! disk.
//!
//! Threading: the engine thread is the sole writer. The `Promoter`
//! worker only ever reads slots the engine has pinned for an in-flight
//! promotion; `free_slot` on a pinned slot defers until `unpin`, so a
//! slot is never recycled under a concurrent read.

use std::collections::{HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

const SLOT_MAGIC: u64 = 0x51554f4b41535031; // "QUOKASP1"
const HEADER_BYTES: usize = 24;

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Align slots to 64 bytes so payloads start cache-line aligned.
fn slot_bytes_for(payload_bytes: usize) -> usize {
    (HEADER_BYTES + payload_bytes + 63) & !63
}

/// Bytes one spilled page occupies on disk for a pool whose
/// `page_image_bytes()` is `payload_bytes` — the unit `--kv-spill-cap`
/// must be a whole multiple of.
pub fn slot_stride(payload_bytes: usize) -> usize {
    slot_bytes_for(payload_bytes)
}

// ------------------------------------------------------------- mmap FFI

#[cfg(unix)]
mod sys {
    use std::os::unix::io::RawFd;

    pub const PROT_READ: i32 = 0x1;
    pub const PROT_WRITE: i32 = 0x2;
    pub const MAP_SHARED: i32 = 0x01;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: RawFd,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

/// A shared-mapping region. Unmapped when the last handle drops, so the
/// promotion worker can outlive the `SpillFile` briefly during shutdown.
struct RegionInner {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the region is a plain byte range; the engine thread is the only
// writer and never writes a slot the worker is reading (pin protocol
// above), so there are no data races on live slots.
unsafe impl Send for RegionInner {}
unsafe impl Sync for RegionInner {}

impl Drop for RegionInner {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

#[derive(Clone)]
struct Region(Arc<RegionInner>);

impl Region {
    #[cfg(unix)]
    fn map(file: &File, len: usize) -> anyhow::Result<Region> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            anyhow::bail!("mmap(MAP_SHARED) failed — no write-back support on this filesystem?");
        }
        Ok(Region(Arc::new(RegionInner { ptr, len })))
    }

    fn bytes(&self, off: usize, len: usize) -> &[u8] {
        assert!(off + len <= self.0.len);
        unsafe { std::slice::from_raw_parts(self.0.ptr.add(off), len) }
    }

    /// SAFETY contract: caller is the sole writer (engine thread) and the
    /// range is not a slot pinned for a concurrent worker read.
    #[allow(clippy::mut_from_ref)]
    fn bytes_mut(&self, off: usize, len: usize) -> &mut [u8] {
        assert!(off + len <= self.0.len);
        unsafe { std::slice::from_raw_parts_mut(self.0.ptr.add(off), len) }
    }
}

// ----------------------------------------------------------- spill file

/// The engine-side handle to the spill tier: slot allocation, demote
/// writes, checksummed reads, and the resident key-sum sidecar.
pub struct SpillFile {
    _file: File,
    path: PathBuf,
    region: Region,
    payload_bytes: usize,
    slot_bytes: usize,
    n_slots: usize,
    free: Vec<u32>,
    /// fp32 key sums per occupied slot — the scan metadata that stays in
    /// RAM when the page itself is cold.
    key_sums: HashMap<u32, Vec<f32>>,
    /// Slots with an in-flight worker read; `free_slot` defers for these.
    pinned: HashSet<u32>,
    zombie: HashSet<u32>,
}

impl SpillFile {
    /// Open (creating if absent) a spill file of exactly `cap_bytes`,
    /// slotted for pages of `payload_bytes`. `cap_bytes` must be a whole
    /// number of slots (`slot_stride(payload_bytes)`) — the engine
    /// validates this up front and reports the stride in its error.
    /// Reopening an existing file keeps every checksum-valid slot
    /// occupied (their key-sum sidecars are rebuilt lazily by the pool on
    /// promotion) and drops torn or stale slots to the free list.
    #[cfg(unix)]
    pub fn open(path: &Path, cap_bytes: usize, payload_bytes: usize) -> anyhow::Result<SpillFile> {
        let slot_bytes = slot_bytes_for(payload_bytes);
        anyhow::ensure!(cap_bytes > 0, "spill cap is zero");
        anyhow::ensure!(
            cap_bytes % slot_bytes == 0,
            "spill cap {} is not a whole number of {}-byte page slots",
            cap_bytes,
            slot_bytes
        );
        let n_slots = cap_bytes / slot_bytes;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)?;
        let existing = file.metadata()?.len();
        file.set_len(cap_bytes as u64)?;
        let region = Region::map(&file, cap_bytes)?;
        let mut sf = SpillFile {
            _file: file,
            path: path.to_path_buf(),
            region,
            payload_bytes,
            slot_bytes,
            n_slots,
            free: Vec::with_capacity(n_slots),
            key_sums: HashMap::new(),
            pinned: HashSet::new(),
            zombie: HashSet::new(),
        };
        // Scan headers oldest-slot-first; a torn tail (crash mid-demote)
        // fails its checksum and lands on the free list.
        let scan_slots = ((existing as usize) / slot_bytes).min(n_slots);
        let mut occupied = 0usize;
        for s in (0..n_slots).rev() {
            if s < scan_slots && sf.slot_valid(s as u32) {
                occupied += 1;
            } else {
                sf.free.push(s as u32);
            }
        }
        let _ = occupied;
        Ok(sf)
    }

    #[cfg(not(unix))]
    pub fn open(_path: &Path, _cap: usize, _payload: usize) -> anyhow::Result<SpillFile> {
        anyhow::bail!("KV spill requires a unix mmap; tier disabled on this platform")
    }

    fn slot_off(&self, slot: u32) -> usize {
        slot as usize * self.slot_bytes
    }

    fn slot_valid(&self, slot: u32) -> bool {
        let off = self.slot_off(slot);
        let hdr = self.region.bytes(off, HEADER_BYTES);
        let magic = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
        magic == SLOT_MAGIC
            && len == self.payload_bytes
            && fnv1a64(self.region.bytes(off + HEADER_BYTES, len)) == sum
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
    pub fn capacity_slots(&self) -> usize {
        self.n_slots
    }
    pub fn free_slots(&self) -> usize {
        self.free.len()
    }
    pub fn used_slots(&self) -> usize {
        self.n_slots - self.free.len()
    }
    pub fn payload_bytes(&self) -> usize {
        self.payload_bytes
    }
    /// Bytes of page payload currently parked in the spill tier.
    pub fn used_bytes(&self) -> usize {
        self.used_slots() * self.payload_bytes
    }

    /// Demote: write one page image (and keep its fp32 key sums resident).
    /// Returns the slot, or `None` when the file is full — the caller
    /// falls back to a hard evict.
    pub fn write(&mut self, img: &[u8], key_sums: Vec<f32>) -> Option<u32> {
        assert_eq!(img.len(), self.payload_bytes, "page image size mismatch");
        let slot = self.free.pop()?;
        let off = self.slot_off(slot);
        // Payload first, header (with checksum) last: a torn write is
        // dropped on reopen instead of restoring garbage.
        self.region
            .bytes_mut(off + HEADER_BYTES, img.len())
            .copy_from_slice(img);
        let hdr = self.region.bytes_mut(off, HEADER_BYTES);
        hdr[0..8].copy_from_slice(&SLOT_MAGIC.to_le_bytes());
        hdr[8..16].copy_from_slice(&(img.len() as u64).to_le_bytes());
        hdr[16..24].copy_from_slice(&fnv1a64(img).to_le_bytes());
        self.key_sums.insert(slot, key_sums);
        Some(slot)
    }

    /// Checksum-verified read of one slot's page image (engine-thread
    /// synchronous path; the promotion worker uses `SpillReader`).
    pub fn read(&self, slot: u32, out: &mut Vec<u8>) -> anyhow::Result<()> {
        anyhow::ensure!((slot as usize) < self.n_slots, "slot {slot} out of range");
        anyhow::ensure!(self.slot_valid(slot), "spill slot {slot} failed checksum");
        out.clear();
        out.extend_from_slice(
            self.region
                .bytes(self.slot_off(slot) + HEADER_BYTES, self.payload_bytes),
        );
        Ok(())
    }

    /// The resident fp32 key sums for an occupied slot (None after a
    /// reopen, until the slot is promoted once).
    pub fn slot_key_sums(&self, slot: u32) -> Option<&[f32]> {
        self.key_sums.get(&slot).map(|v| v.as_slice())
    }

    /// Pin a slot for an in-flight worker read; `free_slot` defers until
    /// `unpin`.
    pub fn pin(&mut self, slot: u32) {
        self.pinned.insert(slot);
    }

    /// Drop a pin; if the slot was freed while pinned, release it now.
    pub fn unpin(&mut self, slot: u32) {
        self.pinned.remove(&slot);
        if self.zombie.remove(&slot) {
            self.release(slot);
        }
    }

    /// Return a slot to the free list (promotion applied, or the owning
    /// radix node was removed). Deferred while the slot is pinned.
    pub fn free_slot(&mut self, slot: u32) {
        if self.pinned.contains(&slot) {
            self.zombie.insert(slot);
            return;
        }
        self.release(slot);
    }

    fn release(&mut self, slot: u32) {
        // Invalidate the header so a reopen does not resurrect the slot.
        let off = self.slot_off(slot);
        self.region.bytes_mut(off, 8).copy_from_slice(&0u64.to_le_bytes());
        self.key_sums.remove(&slot);
        debug_assert!(!self.free.contains(&slot), "double free of spill slot {slot}");
        self.free.push(slot);
    }

    /// A read-only view the promotion worker can take to another thread.
    pub fn reader(&self) -> SpillReader {
        SpillReader {
            region: self.region.clone(),
            payload_bytes: self.payload_bytes,
            slot_bytes: self.slot_bytes,
            n_slots: self.n_slots,
        }
    }
}

/// Read-only slot access for the promotion worker thread.
pub struct SpillReader {
    region: Region,
    payload_bytes: usize,
    slot_bytes: usize,
    n_slots: usize,
}

impl SpillReader {
    fn read(&self, slot: u32) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!((slot as usize) < self.n_slots, "slot {slot} out of range");
        let off = slot as usize * self.slot_bytes;
        let hdr = self.region.bytes(off, HEADER_BYTES);
        let magic = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
        anyhow::ensure!(
            magic == SLOT_MAGIC && len == self.payload_bytes,
            "spill slot {slot} header invalid"
        );
        let payload = self.region.bytes(off + HEADER_BYTES, len);
        anyhow::ensure!(fnv1a64(payload) == sum, "spill slot {slot} failed checksum");
        Ok(payload.to_vec())
    }
}

// ------------------------------------------------------------ promoter

/// One staged promotion: the slot's verified page image (or the checksum
/// error), ready for the engine thread to apply.
pub struct PromoteDone {
    pub slot: u32,
    pub bytes: anyhow::Result<Vec<u8>>,
}

/// Background promotion thread: the engine enqueues slots at `submit`
/// (readahead on a spilled radix hit); the worker reads + checksum-
/// verifies each slot off the critical path and stages the bytes back.
/// All pool/radix mutation stays on the engine thread.
pub struct Promoter {
    tx: Option<mpsc::Sender<u32>>,
    rx: mpsc::Receiver<PromoteDone>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Promoter {
    pub fn spawn(reader: SpillReader) -> Promoter {
        let (tx, req_rx) = mpsc::channel::<u32>();
        let (done_tx, rx) = mpsc::channel::<PromoteDone>();
        let handle = std::thread::Builder::new()
            .name("quoka-promote".into())
            .spawn(move || {
                while let Ok(slot) = req_rx.recv() {
                    let bytes = reader.read(slot);
                    if done_tx.send(PromoteDone { slot, bytes }).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn promotion thread");
        Promoter {
            tx: Some(tx),
            rx,
            handle: Some(handle),
        }
    }

    /// Kick an async read of `slot`. The caller must pin the slot first.
    pub fn request(&self, slot: u32) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(slot);
        }
    }

    /// Non-blocking drain of staged promotions.
    pub fn try_recv(&self) -> Option<PromoteDone> {
        self.rx.try_recv().ok()
    }

    /// Short blocking wait — used when a step has nothing to do but wait
    /// for promotions, so the engine does not busy-spin.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<PromoteDone> {
        self.rx.recv_timeout(timeout).ok()
    }
}

impl Drop for Promoter {
    fn drop(&mut self) {
        self.tx.take(); // close the request channel → worker exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
