//! The shared page pool: per-layer K/V slabs, refcounted pages,
//! copy-on-write, and incrementally maintained per-page metadata.
//!
//! Page ids come from the engine's [`BlockAllocator`] — the pool never
//! allocates ids itself, it only attaches physical storage, refcounts and
//! metadata to ids the lease layer hands out. Slabs grow lazily (geometric
//! doubling up to `total_blocks`) so a big admission-capacity pool costs no
//! memory until pages are actually leased.

use crate::coordinator::kv_blocks::BlockAllocator;
use crate::select::{KCache, Pages};
use crate::tensor::ops::{l2_norm, quantize_row_q8};

/// Pool geometry.
#[derive(Clone, Copy, Debug)]
pub struct PoolCfg {
    pub n_layers: usize,
    /// KV heads per layer.
    pub n_kv: usize,
    /// Head dim.
    pub d: usize,
    /// Tokens per page.
    pub block_tokens: usize,
    /// Admission capacity in pages (mirrors `BlockAllocator::total_blocks`).
    pub total_blocks: usize,
}

/// Element type of the bulk K/V rows held by [`KvPool`] and the contiguous
/// per-sequence caches. Page metadata — inverse norms, per-page key sums
/// and (under int8) the per-row dequant scales — is always fp32 and exact;
/// only the K/V row payload changes representation.
///
/// Int8 rows are quantized at append time with a symmetric per-row scale
/// (`quantize_row_q8`) and dequantized *inside* the attention / scan tile
/// kernels; an fp32 copy of the cache is never materialized. Quantization
/// is deterministic per row, so copy-on-write clones and speculative
/// rollback keep their bit-exactness guarantees.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    Int8,
}

impl KvDtype {
    /// Bytes per cached K/V element.
    #[inline]
    pub fn bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::Int8 => 1,
        }
    }

    /// Parse a `--kv-dtype` value.
    pub fn parse(s: &str) -> anyhow::Result<KvDtype> {
        match s {
            "f32" => Ok(KvDtype::F32),
            "int8" => Ok(KvDtype::Int8),
            other => anyhow::bail!("unknown kv dtype {other:?} (expected f32 | int8)"),
        }
    }

    /// Engine-default dtype: `QUOKA_KV_DTYPE=int8` flips the default so the
    /// CI matrix can run the whole suite on quantized pages without
    /// threading a flag through every constructor; anything else means f32.
    pub fn env_default() -> KvDtype {
        match std::env::var("QUOKA_KV_DTYPE").ok().as_deref() {
            Some("int8") => KvDtype::Int8,
            _ => KvDtype::F32,
        }
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
        })
    }
}

/// One layer's physical storage, laid out per page:
/// `k`/`v` (f32 pages) or `kq`/`vq` (int8 pages): `[page, n_kv, block_tokens, d]`,
/// `inv_norm`: `[page, n_kv, block_tokens]`,
/// `k_scale`/`v_scale` (int8 pages only): `[page, n_kv, block_tokens]`
/// per-row dequant scales riding the same metadata layout as `inv_norm`,
/// `key_sums`: `[page, n_kv, d]` (sum of filled key rows — cosine against
/// it equals cosine against the mean key),
/// `fill`: `[page]` filled slots, so overwriting a slot (COW rewrite)
/// subtracts the old row from the sums and metadata stays exact.
///
/// Exactly one of the f32 / int8 row representations is populated per
/// pool (by [`KvDtype`]); the other's slabs stay empty. Under int8 the
/// key sums accumulate the *dequantized stored* rows, not the raw input
/// rows, so the metadata pass of the QUOKA scan scores exactly what the
/// exact scan sees, and [`KvPool::truncate_seq`]'s rebuild-from-stored-rows
/// stays bit-identical to an append-only history. Inverse norms are always
/// computed from the original fp32 input row (written once at append),
/// keeping them exact in both representations.
struct LayerPages {
    k: Vec<f32>,
    v: Vec<f32>,
    kq: Vec<i8>,
    vq: Vec<i8>,
    k_scale: Vec<f32>,
    v_scale: Vec<f32>,
    inv_norm: Vec<f32>,
    key_sums: Vec<f32>,
    fill: Vec<u16>,
}

impl LayerPages {
    /// Write one `(head, slot)` K/V row of a page and maintain its
    /// metadata: retire the old row from the page key sum when
    /// overwriting a filled slot (COW rewrite), refresh the inverse norm,
    /// and accumulate the new row into the key sum. The single write path
    /// shared by chunked and batched-decode appends — metadata and
    /// quantization rules live here exactly once.
    #[allow(clippy::too_many_arguments)]
    fn write_row(
        &mut self,
        cfg: &PoolCfg,
        dtype: KvDtype,
        page: usize,
        slot: usize,
        h: usize,
        k_row: &[f32],
        v_row: &[f32],
        was_filled: bool,
    ) {
        let (n_kv, d, bt) = (cfg.n_kv, cfg.d, cfg.block_tokens);
        let dst = ((page * n_kv + h) * bt + slot) * d;
        let nb = (page * n_kv + h) * bt + slot;
        let sb = (page * n_kv + h) * d;
        match dtype {
            KvDtype::F32 => {
                if was_filled {
                    for jj in 0..d {
                        self.key_sums[sb + jj] -= self.k[dst + jj];
                    }
                }
                self.k[dst..dst + d].copy_from_slice(k_row);
                self.v[dst..dst + d].copy_from_slice(v_row);
                for (o, &x) in self.key_sums[sb..sb + d].iter_mut().zip(k_row) {
                    *o += x;
                }
            }
            KvDtype::Int8 => {
                if was_filled {
                    let s_old = self.k_scale[nb];
                    for jj in 0..d {
                        self.key_sums[sb + jj] -= self.kq[dst + jj] as f32 * s_old;
                    }
                }
                let ks = quantize_row_q8(k_row, &mut self.kq[dst..dst + d]);
                let vs = quantize_row_q8(v_row, &mut self.vq[dst..dst + d]);
                self.k_scale[nb] = ks;
                self.v_scale[nb] = vs;
                // Sum the dequantized *stored* row so metadata scoring and
                // rollback rebuilds see the same keys the kernels see.
                for jj in 0..d {
                    self.key_sums[sb + jj] += self.kq[dst + jj] as f32 * ks;
                }
            }
        }
        let norm = l2_norm(k_row);
        self.inv_norm[nb] = if norm > 0.0 { 1.0 / norm } else { 0.0 };
    }
}

/// The shared paged KV pool.
pub struct KvPool {
    pub cfg: PoolCfg,
    /// Element type of the bulk K/V rows (metadata stays fp32).
    dtype: KvDtype,
    layers: Vec<LayerPages>,
    /// Owners per page id (0 = free as far as the pool is concerned).
    refcount: Vec<u32>,
    /// Pages with physical storage behind them (`<= cfg.total_blocks`).
    capacity_pages: usize,
    /// Copy-on-write page clones performed (observability).
    pub cow_copies: u64,
}

/// Borrowed view of one sequence × one layer: what the paged attention
/// kernel walks. Per-page rows of a single head are contiguous, so
/// full-selection tiles stream page runs without a gather.
///
/// Exactly one row representation is live, per [`PagedKv::dtype`]: the f32
/// `k`/`v` slabs, or the int8 `kq`/`vq` slabs with per-row `k_scale`/
/// `v_scale` (indexed like `inv_norm`). The dormant representation's
/// slices are empty.
#[derive(Clone, Copy)]
pub struct PagedKv<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub kq: &'a [i8],
    pub vq: &'a [i8],
    pub k_scale: &'a [f32],
    pub v_scale: &'a [f32],
    pub inv_norm: &'a [f32],
    pub dtype: KvDtype,
    /// The sequence's block table: logical block `j` lives in page
    /// `blocks[j]`.
    pub blocks: &'a [u32],
    pub block_tokens: usize,
    pub n_kv: usize,
    pub d: usize,
    /// Valid (filled) tokens.
    pub t: usize,
}

impl PagedKv<'_> {
    /// Flat element offset of row `(h, i)` in the K/V slabs (f32 or int8 —
    /// both share the `[page, n_kv, block_tokens, d]` layout).
    #[inline]
    pub fn row_base(&self, h: usize, i: usize) -> usize {
        let bt = self.block_tokens;
        let page = self.blocks[i / bt] as usize;
        ((page * self.n_kv + h) * bt + (i % bt)) * self.d
    }

    /// Flat offset of row `(h, i)` in the per-row metadata slabs
    /// (`inv_norm`, `k_scale`, `v_scale`).
    #[inline]
    pub fn meta_base(&self, h: usize, i: usize) -> usize {
        let bt = self.block_tokens;
        let page = self.blocks[i / bt] as usize;
        (page * self.n_kv + h) * bt + (i % bt)
    }

    #[inline]
    pub fn key(&self, h: usize, i: usize) -> &[f32] {
        debug_assert_eq!(self.dtype, KvDtype::F32, "f32 key row of an int8 paged cache");
        let b = self.row_base(h, i);
        &self.k[b..b + self.d]
    }

    #[inline]
    pub fn value(&self, h: usize, i: usize) -> &[f32] {
        debug_assert_eq!(self.dtype, KvDtype::F32, "f32 value row of an int8 paged cache");
        let b = self.row_base(h, i);
        &self.v[b..b + self.d]
    }
}

impl KvPool {
    pub fn new(cfg: PoolCfg) -> KvPool {
        KvPool::new_with_dtype(cfg, KvDtype::F32)
    }

    pub fn new_with_dtype(cfg: PoolCfg, dtype: KvDtype) -> KvPool {
        assert!(cfg.n_layers > 0 && cfg.n_kv > 0 && cfg.d > 0);
        assert!(cfg.block_tokens > 0 && cfg.total_blocks > 0);
        assert!(cfg.block_tokens <= u16::MAX as usize, "fill counters are u16");
        KvPool {
            layers: (0..cfg.n_layers)
                .map(|_| LayerPages {
                    k: Vec::new(),
                    v: Vec::new(),
                    kq: Vec::new(),
                    vq: Vec::new(),
                    k_scale: Vec::new(),
                    v_scale: Vec::new(),
                    inv_norm: Vec::new(),
                    key_sums: Vec::new(),
                    fill: Vec::new(),
                })
                .collect(),
            refcount: vec![0; cfg.total_blocks],
            capacity_pages: 0,
            cow_copies: 0,
            dtype,
            cfg,
        }
    }

    /// Element type of the bulk K/V rows.
    #[inline]
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Floats of K (or V) per page per layer.
    #[inline]
    fn page_floats(&self) -> usize {
        self.cfg.n_kv * self.cfg.block_tokens * self.cfg.d
    }

    /// Grow the slabs so `page` has storage behind it.
    fn ensure_page(&mut self, page: usize) {
        if page < self.capacity_pages {
            return;
        }
        let new_cap = (self.capacity_pages.max(1) * 2)
            .max(page + 1)
            .min(self.cfg.total_blocks);
        let pf = self.page_floats();
        let nf = self.cfg.n_kv * self.cfg.block_tokens;
        let sf = self.cfg.n_kv * self.cfg.d;
        let dtype = self.dtype;
        for lp in &mut self.layers {
            // Only the live representation's row slabs get storage; the
            // dormant one stays empty so int8 pools never pay fp32 bytes.
            match dtype {
                KvDtype::F32 => {
                    lp.k.resize(new_cap * pf, 0.0);
                    lp.v.resize(new_cap * pf, 0.0);
                }
                KvDtype::Int8 => {
                    lp.kq.resize(new_cap * pf, 0);
                    lp.vq.resize(new_cap * pf, 0);
                    lp.k_scale.resize(new_cap * nf, 0.0);
                    lp.v_scale.resize(new_cap * nf, 0.0);
                }
            }
            lp.inv_norm.resize(new_cap * nf, 0.0);
            lp.key_sums.resize(new_cap * sf, 0.0);
            lp.fill.resize(new_cap, 0);
        }
        self.capacity_pages = new_cap;
    }

    pub fn refcount(&self, b: u32) -> u32 {
        self.refcount[b as usize]
    }

    /// Filled slots of page `b` in `layer` (0 for never-ensured pages) —
    /// metadata observability for tests and debugging.
    pub fn page_fill(&self, layer: usize, b: u32) -> usize {
        let bi = b as usize;
        if bi < self.capacity_pages {
            self.layers[layer].fill[bi] as usize
        } else {
            0
        }
    }

    /// True when every layer of page `b` has all `block_tokens` slots
    /// written — the publishability condition for the radix cache's
    /// in-flight inserts (a partially filled page must never be shared:
    /// its empty slots would read as garbage KV to an adopter).
    pub fn page_filled(&self, b: u32) -> bool {
        let bi = b as usize;
        bi < self.capacity_pages
            && self.layers.iter().all(|lp| lp.fill[bi] as usize == self.cfg.block_tokens)
    }

    /// Add an owner to an already-owned page (prefix sharing).
    pub fn retain(&mut self, b: u32) {
        let rc = &mut self.refcount[b as usize];
        assert!(*rc > 0, "retain of unowned page {b}");
        *rc += 1;
    }

    /// Take ownership of pages freshly leased from the allocator: any id
    /// with refcount 0 becomes owned (refcount 1) with zeroed metadata
    /// sums. Ids already owned (e.g. radix-matched prefix pages) are left
    /// untouched, so this is safe to call on a whole block table.
    pub fn adopt_new(&mut self, blocks: &[u32]) {
        for &b in blocks {
            let bi = b as usize;
            if self.refcount[bi] != 0 {
                continue;
            }
            self.refcount[bi] = 1;
            self.ensure_page(bi);
            let sf = self.cfg.n_kv * self.cfg.d;
            for lp in &mut self.layers {
                lp.key_sums[bi * sf..(bi + 1) * sf].fill(0.0);
                lp.fill[bi] = 0;
            }
        }
    }

    /// Drop one owner of page `b`; the last owner returns it to the lease
    /// layer.
    pub fn release_block(&mut self, b: u32, alloc: &mut BlockAllocator) {
        let bi = b as usize;
        assert!(self.refcount[bi] > 0, "release of unowned page {b}");
        self.refcount[bi] -= 1;
        if self.refcount[bi] == 0 {
            alloc.release_one(b);
        }
    }

    /// Release a whole block table (sequence retirement).
    pub fn release_seq(&mut self, blocks: &mut Vec<u32>, alloc: &mut BlockAllocator) {
        for b in blocks.drain(..) {
            self.release_block(b, alloc);
        }
    }

    /// Copy-on-write guard: make the pages covering token positions
    /// `[first, first + n)` exclusively owned, cloning any shared page
    /// (all layers + metadata) into a freshly leased one.
    pub fn make_writable(
        &mut self,
        blocks: &mut [u32],
        first: usize,
        n: usize,
        alloc: &mut BlockAllocator,
    ) -> anyhow::Result<()> {
        if n == 0 {
            return Ok(());
        }
        let bt = self.cfg.block_tokens;
        let (b0, b1) = (first / bt, (first + n - 1) / bt);
        anyhow::ensure!(
            b1 < blocks.len(),
            "block table too short for write at tokens {}..{}",
            first,
            first + n
        );
        for j in b0..=b1 {
            let old = blocks[j] as usize;
            if self.refcount[old] <= 1 {
                continue;
            }
            let Some(lease) = alloc.alloc(1) else {
                anyhow::bail!("KV pool exhausted during copy-on-write");
            };
            let new = lease[0] as usize;
            self.refcount[new] = 1;
            self.ensure_page(new);
            self.copy_page(old, new);
            self.cow_copies += 1;
            // Drop this table's share of the original (refcount >= 2, so
            // it stays owned by the other holders).
            self.refcount[old] -= 1;
            blocks[j] = new as u32;
        }
        Ok(())
    }

    fn copy_page(&mut self, src: usize, dst: usize) {
        let pf = self.page_floats();
        let nf = self.cfg.n_kv * self.cfg.block_tokens;
        let sf = self.cfg.n_kv * self.cfg.d;
        let dtype = self.dtype;
        for lp in &mut self.layers {
            match dtype {
                KvDtype::F32 => {
                    lp.k.copy_within(src * pf..(src + 1) * pf, dst * pf);
                    lp.v.copy_within(src * pf..(src + 1) * pf, dst * pf);
                }
                KvDtype::Int8 => {
                    lp.kq.copy_within(src * pf..(src + 1) * pf, dst * pf);
                    lp.vq.copy_within(src * pf..(src + 1) * pf, dst * pf);
                    lp.k_scale.copy_within(src * nf..(src + 1) * nf, dst * nf);
                    lp.v_scale.copy_within(src * nf..(src + 1) * nf, dst * nf);
                }
            }
            lp.inv_norm.copy_within(src * nf..(src + 1) * nf, dst * nf);
            lp.key_sums.copy_within(src * sf..(src + 1) * sf, dst * sf);
            lp.fill[dst] = lp.fill[src];
        }
    }

    /// Write `s` tokens of one layer's per-head K/V (layout `[n_kv, s, d]`)
    /// at token positions `pos..pos+s`, maintaining the per-key inverse
    /// norms and per-page key sums incrementally. The caller must have
    /// ensured capacity ([`BlockAllocator::ensure`] + [`KvPool::adopt_new`])
    /// and exclusivity ([`KvPool::make_writable`]).
    pub fn append_chunk(
        &mut self,
        blocks: &[u32],
        layer: usize,
        pos: usize,
        k_new: &[f32],
        v_new: &[f32],
        s: usize,
    ) {
        let PoolCfg { n_kv, d, block_tokens: bt, .. } = self.cfg;
        debug_assert_eq!(k_new.len(), n_kv * s * d);
        debug_assert_eq!(v_new.len(), n_kv * s * d);
        assert!(blocks.len() * bt >= pos + s, "block table too short for append");
        for j in pos / bt..=(pos + s - 1) / bt {
            let page = blocks[j] as usize;
            debug_assert!(self.refcount[page] == 1, "append into shared/unowned page {page}");
            self.ensure_page(page);
        }
        let cfg = self.cfg;
        let dtype = self.dtype;
        let lp = &mut self.layers[layer];
        for i in 0..s {
            let tok = pos + i;
            let page = blocks[tok / bt] as usize;
            let slot = tok % bt;
            let was_filled = slot < lp.fill[page] as usize;
            for h in 0..n_kv {
                let src = (h * s + i) * d;
                lp.write_row(
                    &cfg,
                    dtype,
                    page,
                    slot,
                    h,
                    &k_new[src..src + d],
                    &v_new[src..src + d],
                    was_filled,
                );
            }
            if lp.fill[page] as usize <= slot {
                lp.fill[page] = (slot + 1) as u16;
            }
        }
    }

    /// Write one token's per-head K/V at position `pos`, reading head rows
    /// out of a **batch-layout** slab `[n_kv, batch, d]` (head `h` of
    /// sequence `seq` at row `h * batch + seq`) — the layout the batched
    /// decode forward produces — without staging a contiguous copy.
    /// Metadata maintenance (inverse norms, per-page key sums, fill
    /// counters) is identical to [`KvPool::append_chunk`]; so are the
    /// capacity/exclusivity preconditions.
    #[allow(clippy::too_many_arguments)]
    pub fn append_token_strided(
        &mut self,
        blocks: &[u32],
        layer: usize,
        pos: usize,
        k_batch: &[f32],
        v_batch: &[f32],
        seq: usize,
        batch: usize,
    ) {
        let PoolCfg { n_kv, d, block_tokens: bt, .. } = self.cfg;
        debug_assert_eq!(k_batch.len(), n_kv * batch * d);
        debug_assert_eq!(v_batch.len(), n_kv * batch * d);
        debug_assert!(seq < batch);
        assert!(blocks.len() * bt >= pos + 1, "block table too short for append");
        let page = blocks[pos / bt] as usize;
        debug_assert!(self.refcount[page] == 1, "append into shared/unowned page {page}");
        self.ensure_page(page);
        let slot = pos % bt;
        let cfg = self.cfg;
        let dtype = self.dtype;
        let lp = &mut self.layers[layer];
        let was_filled = slot < lp.fill[page] as usize;
        for h in 0..n_kv {
            let src = (h * batch + seq) * d;
            lp.write_row(
                &cfg,
                dtype,
                page,
                slot,
                h,
                &k_batch[src..src + d],
                &v_batch[src..src + d],
                was_filled,
            );
        }
        if lp.fill[page] as usize <= slot {
            lp.fill[page] = (slot + 1) as u16;
        }
    }

    /// Roll a sequence's cache back from `old_t` to `new_t` resident
    /// tokens (speculative-decode rollback of rejected draft tokens),
    /// keeping every page's metadata exactly as if tokens `new_t..old_t`
    /// were never appended: fill counters drop to the kept slot count,
    /// dropped slots' inverse norms are zeroed, and per-(page, head) key
    /// sums are rebuilt by re-accumulating the surviving rows in append
    /// order — bit-identical to the incremental sums an append-only
    /// history would have produced (f32 addition is order-sensitive, so a
    /// subtract-the-rejected-rows shortcut would drift).
    ///
    /// COW-aware by precondition: every touched page must be exclusively
    /// owned (`refcount == 1`). Rollback only ever covers positions the
    /// same step's verify forward just wrote, and those pages were
    /// `make_writable`-guarded before the write — a page shared through
    /// the radix cache is cloned *before* any draft KV lands in it, so
    /// rollback can never mutate shared KV.
    pub fn truncate_seq(&mut self, blocks: &[u32], new_t: usize, old_t: usize) {
        if new_t >= old_t {
            return;
        }
        let PoolCfg { n_kv, d, block_tokens: bt, .. } = self.cfg;
        assert!(blocks.len() * bt >= old_t, "block table too short for truncate");
        for j in new_t / bt..=(old_t - 1) / bt {
            let page = blocks[j] as usize;
            assert!(
                self.refcount[page] == 1,
                "speculative rollback into shared/unowned page {page}"
            );
            let keep = new_t.saturating_sub(j * bt).min(bt);
            let dtype = self.dtype;
            for lp in &mut self.layers {
                let filled = lp.fill[page] as usize;
                if filled <= keep {
                    continue; // page never held rejected rows in this layer
                }
                for h in 0..n_kv {
                    let nb = (page * n_kv + h) * bt;
                    lp.inv_norm[nb + keep..nb + filled].fill(0.0);
                    if dtype == KvDtype::Int8 {
                        // Dropped rows' scales go back to the never-written
                        // state, like the inverse norms (the codes, like
                        // dropped f32 rows, are dead until overwritten).
                        lp.k_scale[nb + keep..nb + filled].fill(0.0);
                        lp.v_scale[nb + keep..nb + filled].fill(0.0);
                    }
                    let sb = (page * n_kv + h) * d;
                    lp.key_sums[sb..sb + d].fill(0.0);
                    // Re-accumulate surviving rows in append order — under
                    // int8, the dequantized stored rows, exactly what the
                    // incremental append path summed.
                    for slot in 0..keep {
                        let kb = ((page * n_kv + h) * bt + slot) * d;
                        match dtype {
                            KvDtype::F32 => {
                                for jj in 0..d {
                                    lp.key_sums[sb + jj] += lp.k[kb + jj];
                                }
                            }
                            KvDtype::Int8 => {
                                let s = lp.k_scale[nb + slot];
                                for jj in 0..d {
                                    lp.key_sums[sb + jj] += lp.kq[kb + jj] as f32 * s;
                                }
                            }
                        }
                    }
                }
                lp.fill[page] = keep as u16;
            }
        }
    }

    /// Selection-policy view of layer `layer` through a block table: a
    /// block-table-aware [`KCache`] carrying the pooled norm cache and the
    /// per-page mean-key metadata.
    pub fn k_cache<'a>(&'a self, blocks: &'a [u32], t: usize, layer: usize) -> KCache<'a> {
        let lp = &self.layers[layer];
        let kc = KCache::paged(
            &lp.k,
            self.cfg.n_kv,
            t,
            self.cfg.d,
            &lp.inv_norm,
            Pages {
                blocks,
                block_tokens: self.cfg.block_tokens,
                key_sums: &lp.key_sums,
            },
        );
        match self.dtype {
            KvDtype::F32 => kc,
            KvDtype::Int8 => kc.with_quant(&lp.kq, &lp.k_scale),
        }
    }

    /// Attention-kernel view of layer `layer` through a block table.
    pub fn kv_view<'a>(&'a self, blocks: &'a [u32], t: usize, layer: usize) -> PagedKv<'a> {
        let lp = &self.layers[layer];
        PagedKv {
            k: &lp.k,
            v: &lp.v,
            kq: &lp.kq,
            vq: &lp.vq,
            k_scale: &lp.k_scale,
            v_scale: &lp.v_scale,
            inv_norm: &lp.inv_norm,
            dtype: self.dtype,
            blocks,
            block_tokens: self.cfg.block_tokens,
            n_kv: self.cfg.n_kv,
            d: self.cfg.d,
            t,
        }
    }

    /// KV + metadata bytes of one cached token across all layers, derived
    /// from the pool's actual element width (int8 rows ride 1-byte
    /// elements plus two fp32 dequant scales per (layer, head) token).
    pub fn token_bytes(&self) -> usize {
        // K + V rows (2d elements) + one inv-norm float per (layer, head),
        // + per-row K/V scales when quantized.
        let row = 2 * self.cfg.d * self.dtype.bytes();
        let meta = match self.dtype {
            KvDtype::F32 => 4,
            KvDtype::Int8 => 3 * 4, // inv_norm + k_scale + v_scale
        };
        self.cfg.n_layers * self.cfg.n_kv * (row + meta)
    }

    /// Bytes of one page across all layers, metadata included.
    pub fn page_bytes(&self) -> usize {
        let c = &self.cfg;
        // Per (layer, head): K + V rows, per-slot metadata floats
        // (inv_norm, plus the two scale slabs when quantized) and the
        // per-page key-sum vector.
        let rows = 2 * c.block_tokens * c.d * self.dtype.bytes();
        let slot_meta = match self.dtype {
            KvDtype::F32 => c.block_tokens * 4,
            KvDtype::Int8 => 3 * c.block_tokens * 4,
        };
        c.n_layers * c.n_kv * (rows + slot_meta + c.d * 4)
    }

    /// Physical bytes accounted to `leased_pages` pages (K, V, norm cache
    /// and per-page key sums).
    pub fn resident_bytes(&self, leased_pages: usize) -> usize {
        leased_pages * self.page_bytes()
    }

    // ------------------------------------------------------- page images
    //
    // The spill tier (`kvpool/spill.rs`) demotes cold prefix pages to an
    // mmapped file and promotes them back later, possibly into a different
    // page id. The unit of exchange is a *page image*: every byte of
    // per-page state across all layers, serialized in a fixed order so a
    // demote → promote round trip is bit-identical for both row
    // representations (codes + scales under int8, raw rows under f32,
    // inverse norms, key sums and fill counters in either).

    /// Serialized size of one page image (fixed for a given pool config).
    pub fn page_image_bytes(&self) -> usize {
        let c = &self.cfg;
        let pf = self.page_floats();
        let nf = c.n_kv * c.block_tokens;
        let sf = c.n_kv * c.d;
        let rows = 2 * pf * self.dtype.bytes();
        let scales = match self.dtype {
            KvDtype::F32 => 0,
            KvDtype::Int8 => 2 * nf * 4,
        };
        c.n_layers * (rows + scales + nf * 4 + sf * 4 + 2)
    }

    /// Serialize page `b` into `out` (cleared first). Layout per layer:
    /// K rows, V rows (f32 or int8 per the pool dtype), K/V dequant scales
    /// (int8 only), inverse norms, key sums, fill counter — all
    /// little-endian.
    pub fn extract_page_image(&self, b: u32, out: &mut Vec<u8>) {
        let bi = b as usize;
        assert!(bi < self.capacity_pages, "image of never-ensured page {b}");
        let pf = self.page_floats();
        let nf = self.cfg.n_kv * self.cfg.block_tokens;
        let sf = self.cfg.n_kv * self.cfg.d;
        out.clear();
        out.reserve(self.page_image_bytes());
        let push_f32 = |out: &mut Vec<u8>, s: &[f32]| {
            for &x in s {
                out.extend_from_slice(&x.to_le_bytes());
            }
        };
        for lp in &self.layers {
            match self.dtype {
                KvDtype::F32 => {
                    push_f32(out, &lp.k[bi * pf..(bi + 1) * pf]);
                    push_f32(out, &lp.v[bi * pf..(bi + 1) * pf]);
                }
                KvDtype::Int8 => {
                    out.extend(lp.kq[bi * pf..(bi + 1) * pf].iter().map(|&x| x as u8));
                    out.extend(lp.vq[bi * pf..(bi + 1) * pf].iter().map(|&x| x as u8));
                    push_f32(out, &lp.k_scale[bi * nf..(bi + 1) * nf]);
                    push_f32(out, &lp.v_scale[bi * nf..(bi + 1) * nf]);
                }
            }
            push_f32(out, &lp.inv_norm[bi * nf..(bi + 1) * nf]);
            push_f32(out, &lp.key_sums[bi * sf..(bi + 1) * sf]);
            out.extend_from_slice(&lp.fill[bi].to_le_bytes());
        }
        debug_assert_eq!(out.len(), self.page_image_bytes());
    }

    /// The fp32 per-(layer, head) key-sum vectors of page `b`, concatenated
    /// — the QUOKA scan's page mean-key metadata. The spill tier keeps this
    /// slice resident in RAM when the page itself is demoted, so scoring a
    /// spilled prefix never touches disk.
    pub fn page_key_sums(&self, b: u32) -> Vec<f32> {
        let bi = b as usize;
        let sf = self.cfg.n_kv * self.cfg.d;
        let mut out = Vec::with_capacity(self.cfg.n_layers * sf);
        for lp in &self.layers {
            out.extend_from_slice(&lp.key_sums[bi * sf..(bi + 1) * sf]);
        }
        out
    }

    /// Restore page `b` from an image produced by
    /// [`KvPool::extract_page_image`] (possibly under a different page id).
    /// The page must be exclusively owned and freshly adopted
    /// ([`KvPool::adopt_new`] — which also ensures storage). Errors on a
    /// size mismatch (an image from a different pool geometry or dtype).
    pub fn restore_page_image(&mut self, b: u32, img: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            img.len() == self.page_image_bytes(),
            "page image is {} bytes, pool expects {}",
            img.len(),
            self.page_image_bytes()
        );
        let bi = b as usize;
        assert!(self.refcount[bi] == 1, "restore into shared/unowned page {b}");
        self.ensure_page(bi);
        let pf = self.page_floats();
        let nf = self.cfg.n_kv * self.cfg.block_tokens;
        let sf = self.cfg.n_kv * self.cfg.d;
        let dtype = self.dtype;
        let mut off = 0usize;
        let take_f32 = |img: &[u8], off: &mut usize, dst: &mut [f32]| {
            for x in dst.iter_mut() {
                *x = f32::from_le_bytes(img[*off..*off + 4].try_into().unwrap());
                *off += 4;
            }
        };
        for lp in &mut self.layers {
            match dtype {
                KvDtype::F32 => {
                    take_f32(img, &mut off, &mut lp.k[bi * pf..(bi + 1) * pf]);
                    take_f32(img, &mut off, &mut lp.v[bi * pf..(bi + 1) * pf]);
                }
                KvDtype::Int8 => {
                    for x in lp.kq[bi * pf..(bi + 1) * pf].iter_mut() {
                        *x = img[off] as i8;
                        off += 1;
                    }
                    for x in lp.vq[bi * pf..(bi + 1) * pf].iter_mut() {
                        *x = img[off] as i8;
                        off += 1;
                    }
                    take_f32(img, &mut off, &mut lp.k_scale[bi * nf..(bi + 1) * nf]);
                    take_f32(img, &mut off, &mut lp.v_scale[bi * nf..(bi + 1) * nf]);
                }
            }
            take_f32(img, &mut off, &mut lp.inv_norm[bi * nf..(bi + 1) * nf]);
            take_f32(img, &mut off, &mut lp.key_sums[bi * sf..(bi + 1) * sf]);
            lp.fill[bi] = u16::from_le_bytes(img[off..off + 2].try_into().unwrap());
            off += 2;
        }
        debug_assert_eq!(off, img.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> PoolCfg {
        PoolCfg { n_layers: 2, n_kv: 2, d: 4, block_tokens: 4, total_blocks: 16 }
    }

    fn lease_for(alloc: &mut BlockAllocator, pool: &mut KvPool, tokens: usize) -> Vec<u32> {
        let mut blocks = Vec::new();
        assert!(alloc.ensure(&mut blocks, tokens));
        pool.adopt_new(&blocks);
        blocks
    }

    #[test]
    fn append_and_views_roundtrip() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new(c);
        let mut rng = Rng::new(3);
        let blocks = lease_for(&mut alloc, &mut pool, 10);
        let mut pos = 0;
        for s in [3usize, 4, 3] {
            for l in 0..c.n_layers {
                let k = rng.normal_vec(c.n_kv * s * c.d, 1.0);
                let v = rng.normal_vec(c.n_kv * s * c.d, 1.0);
                pool.append_chunk(&blocks, l, pos, &k, &v, s);
            }
            pos += s;
        }
        let view = pool.kv_view(&blocks, pos, 1);
        assert_eq!(view.t, 10);
        // Norm metadata matches a recompute for every filled row.
        for h in 0..c.n_kv {
            for i in 0..pos {
                let n = l2_norm(view.key(h, i));
                let want = if n > 0.0 { 1.0 / n } else { 0.0 };
                let got = view.inv_norm[(view.blocks[i / c.block_tokens] as usize * c.n_kv + h)
                    * c.block_tokens
                    + i % c.block_tokens];
                assert!((got - want).abs() < 1e-6);
            }
        }
        // Key sums equal the sum of filled rows per page.
        let kc = pool.k_cache(&blocks, pos, 1);
        let pg = kc.pages.unwrap();
        for (j, &page) in blocks.iter().enumerate() {
            let lo = j * c.block_tokens;
            let hi = (lo + c.block_tokens).min(pos);
            for h in 0..c.n_kv {
                let mut want = vec![0.0f32; c.d];
                for i in lo..hi {
                    for (w, &x) in want.iter_mut().zip(kc.key(h, i)) {
                        *w += x;
                    }
                }
                let sb = (page as usize * c.n_kv + h) * c.d;
                for (a, b) in want.iter().zip(&pg.key_sums[sb..sb + c.d]) {
                    assert!((a - b).abs() < 1e-5, "{a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn cow_clones_shared_page_and_preserves_original() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new(c);
        let mut rng = Rng::new(9);
        let mut owner = lease_for(&mut alloc, &mut pool, c.block_tokens);
        for l in 0..c.n_layers {
            let k = rng.normal_vec(c.n_kv * c.block_tokens * c.d, 1.0);
            let v = rng.normal_vec(c.n_kv * c.block_tokens * c.d, 1.0);
            pool.append_chunk(&owner, l, 0, &k, &v, c.block_tokens);
        }
        let orig_row: Vec<f32> = pool.kv_view(&owner, c.block_tokens, 0).key(1, 2).to_vec();
        // Second table shares the page.
        let mut sharer = owner.clone();
        pool.retain(sharer[0]);
        assert_eq!(pool.refcount(owner[0]), 2);
        // Writing through the sharer triggers COW.
        pool.make_writable(&mut sharer, 0, 1, &mut alloc).unwrap();
        assert_ne!(sharer[0], owner[0]);
        assert_eq!(pool.refcount(owner[0]), 1);
        assert_eq!(pool.refcount(sharer[0]), 1);
        assert_eq!(pool.cow_copies, 1);
        // Clone carries the data; original is untouched by later writes.
        assert_eq!(pool.kv_view(&sharer, c.block_tokens, 0).key(1, 2), &orig_row[..]);
        let k2 = vec![7.0f32; c.n_kv * c.d];
        let v2 = vec![1.0f32; c.n_kv * c.d];
        // Overwrite slot 2 via a 1-token append at pos 2 on the sharer.
        pool.append_chunk(&sharer, 0, 2, &k2, &v2, 1);
        assert_eq!(pool.kv_view(&owner, c.block_tokens, 0).key(1, 2), &orig_row[..]);
        // Overwriting must keep the page's key-sum metadata exact: the old
        // row is retired from the sum before the new one is added.
        {
            let kc = pool.k_cache(&sharer, c.block_tokens, 0);
            for h in 0..c.n_kv {
                let mut want = vec![0.0f32; c.d];
                for i in 0..c.block_tokens {
                    for (w, &x) in want.iter_mut().zip(kc.key(h, i)) {
                        *w += x;
                    }
                }
                let sb = (sharer[0] as usize * c.n_kv + h) * c.d;
                for (a, b) in want.iter().zip(&kc.pages.unwrap().key_sums[sb..sb + c.d]) {
                    assert!((a - b).abs() < 1e-5, "sum drift after overwrite: {a} vs {b}");
                }
            }
        }
        // Exclusive pages are not cloned again.
        pool.make_writable(&mut sharer, 0, c.block_tokens, &mut alloc).unwrap();
        assert_eq!(pool.cow_copies, 1);
        // Releases return everything.
        pool.release_seq(&mut owner, &mut alloc);
        pool.release_seq(&mut sharer, &mut alloc);
        assert_eq!(alloc.free_blocks(), c.total_blocks);
    }

    #[test]
    fn append_token_strided_matches_append_chunk() {
        let c = cfg();
        let mut rng = Rng::new(31);
        let (bsz, seq) = (3usize, 2usize);
        let kb = rng.normal_vec(c.n_kv * bsz * c.d, 1.0);
        let vb = rng.normal_vec(c.n_kv * bsz * c.d, 1.0);
        // Strided write at pos 1 of a partially filled page...
        let mut alloc_a = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool_a = KvPool::new(c);
        let blocks_a = lease_for(&mut alloc_a, &mut pool_a, 4);
        let k0 = rng.normal_vec(c.n_kv * c.d, 1.0);
        let v0 = rng.normal_vec(c.n_kv * c.d, 1.0);
        pool_a.append_chunk(&blocks_a, 0, 0, &k0, &v0, 1);
        pool_a.append_token_strided(&blocks_a, 0, 1, &kb, &vb, seq, bsz);
        // ...must equal a contiguous append of the gathered rows.
        let pick = |slab: &[f32]| -> Vec<f32> {
            (0..c.n_kv)
                .flat_map(|h| slab[(h * bsz + seq) * c.d..(h * bsz + seq + 1) * c.d].to_vec())
                .collect()
        };
        let mut alloc_b = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool_b = KvPool::new(c);
        let blocks_b = lease_for(&mut alloc_b, &mut pool_b, 4);
        pool_b.append_chunk(&blocks_b, 0, 0, &k0, &v0, 1);
        pool_b.append_chunk(&blocks_b, 0, 1, &pick(&kb), &pick(&vb), 1);
        let va = pool_a.kv_view(&blocks_a, 2, 0);
        let vb_ = pool_b.kv_view(&blocks_b, 2, 0);
        for h in 0..c.n_kv {
            for i in 0..2 {
                assert_eq!(va.key(h, i), vb_.key(h, i));
                assert_eq!(va.value(h, i), vb_.value(h, i));
            }
        }
        let (ka, kb_) = (pool_a.k_cache(&blocks_a, 2, 0), pool_b.k_cache(&blocks_b, 2, 0));
        for h in 0..c.n_kv {
            let sb = (blocks_a[0] as usize * c.n_kv + h) * c.d;
            let sb2 = (blocks_b[0] as usize * c.n_kv + h) * c.d;
            assert_eq!(
                &ka.pages.unwrap().key_sums[sb..sb + c.d],
                &kb_.pages.unwrap().key_sums[sb2..sb2 + c.d]
            );
            assert_eq!(ka.inv_norm(h, 1), kb_.inv_norm(h, 1));
        }
    }

    #[test]
    fn page_filled_requires_every_layer_full() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new(c);
        let blocks = lease_for(&mut alloc, &mut pool, c.block_tokens);
        assert!(!pool.page_filled(blocks[0]), "fresh page is empty");
        assert!(!pool.page_filled(7), "never-ensured page is not filled");
        let k = vec![1.0f32; c.n_kv * c.block_tokens * c.d];
        let v = vec![0.0f32; c.n_kv * c.block_tokens * c.d];
        pool.append_chunk(&blocks, 0, 0, &k, &v, c.block_tokens);
        assert!(!pool.page_filled(blocks[0]), "layer 1 still unwritten");
        pool.append_chunk(&blocks, 1, 0, &k, &v, c.block_tokens - 1);
        assert!(!pool.page_filled(blocks[0]), "last slot of layer 1 missing");
        let k1 = vec![1.0f32; c.n_kv * c.d];
        let v1 = vec![0.0f32; c.n_kv * c.d];
        pool.append_chunk(&blocks, 1, c.block_tokens - 1, &k1, &v1, 1);
        assert!(pool.page_filled(blocks[0]));
    }

    #[test]
    fn truncate_seq_rewinds_fill_sums_and_norms() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new(c);
        let mut rng = Rng::new(41);
        let blocks = lease_for(&mut alloc, &mut pool, 3 * c.block_tokens);
        // 6 base tokens (1.5 pages), then a 5-token "draft" spanning into
        // page 2, rolled back to 6 + 2 accepted.
        let (base, draft, keep) = (6usize, 5usize, 2usize);
        let mk = |rng: &mut Rng, n: usize| {
            (rng.normal_vec(c.n_kv * n * c.d, 1.0), rng.normal_vec(c.n_kv * n * c.d, 1.0))
        };
        let mut drafts = Vec::new();
        for l in 0..c.n_layers {
            let (k, v) = mk(&mut rng, base);
            pool.append_chunk(&blocks, l, 0, &k, &v, base);
            drafts.push(mk(&mut rng, draft));
        }
        // Oracle state: what fill/sums look like with base + keep only.
        let mut oracle = KvPool::new(c);
        let mut alloc_o = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let blocks_o = lease_for(&mut alloc_o, &mut oracle, 3 * c.block_tokens);
        let mut rng_o = Rng::new(41);
        for l in 0..c.n_layers {
            let (k, v) = mk(&mut rng_o, base);
            oracle.append_chunk(&blocks_o, l, 0, &k, &v, base);
            let (dk, dv) = mk(&mut rng_o, draft);
            let head = |s: &[f32]| -> Vec<f32> {
                (0..c.n_kv)
                    .flat_map(|h| s[h * draft * c.d..(h * draft + keep) * c.d].to_vec())
                    .collect()
            };
            oracle.append_chunk(&blocks_o, l, base, &head(&dk), &head(&dv), keep);
        }
        for (l, (dk, dv)) in drafts.iter().enumerate() {
            pool.append_chunk(&blocks, l, base, dk, dv, draft);
        }
        assert_eq!(pool.page_fill(0, blocks[2]), 3, "draft reached page 2");
        pool.truncate_seq(&blocks, base + keep, base + draft);
        for l in 0..c.n_layers {
            for (j, (&b, &bo)) in blocks.iter().zip(&blocks_o).enumerate() {
                assert_eq!(
                    pool.page_fill(l, b),
                    oracle.page_fill(l, bo),
                    "fill of page {j} layer {l}"
                );
                let (ka, ko) = (pool.k_cache(&blocks, 0, l), oracle.k_cache(&blocks_o, 0, l));
                for h in 0..c.n_kv {
                    let sa = (b as usize * c.n_kv + h) * c.d;
                    let so = (bo as usize * c.n_kv + h) * c.d;
                    assert_eq!(
                        &ka.pages.unwrap().key_sums[sa..sa + c.d],
                        &ko.pages.unwrap().key_sums[so..so + c.d],
                        "key sums of page {j} layer {l} head {h} (must be bit-identical \
                         to never having appended the rejected tail)"
                    );
                    let na = (b as usize * c.n_kv + h) * c.block_tokens;
                    let no = (bo as usize * c.n_kv + h) * c.block_tokens;
                    assert_eq!(
                        &ka.inv_norms.unwrap()[na..na + c.block_tokens],
                        &ko.inv_norms.unwrap()[no..no + c.block_tokens],
                        "inv norms of page {j} layer {l} head {h}"
                    );
                }
            }
        }
        // Re-appending after rollback behaves like a first write.
        let (k2, v2) = mk(&mut rng, 1);
        pool.append_chunk(&blocks, 0, base + keep, &k2, &v2, 1);
        assert_eq!(pool.page_fill(0, blocks[(base + keep) / c.block_tokens]), {
            (base + keep) % c.block_tokens + 1
        });
    }

    #[test]
    #[should_panic(expected = "shared/unowned")]
    fn truncate_seq_refuses_shared_pages() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new(c);
        let blocks = lease_for(&mut alloc, &mut pool, c.block_tokens);
        let k = vec![1.0f32; c.n_kv * 2 * c.d];
        let v = vec![0.5f32; c.n_kv * 2 * c.d];
        pool.append_chunk(&blocks, 0, 0, &k, &v, 2);
        pool.retain(blocks[0]); // shared via the radix cache, say
        pool.truncate_seq(&blocks, 1, 2); // must panic, never mutate
    }

    #[test]
    fn int8_pool_append_views_and_bytes() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new_with_dtype(c, KvDtype::Int8);
        assert_eq!(pool.dtype(), KvDtype::Int8);
        let mut rng = Rng::new(5);
        let blocks = lease_for(&mut alloc, &mut pool, 6);
        let mut pos = 0;
        for s in [3usize, 3] {
            for l in 0..c.n_layers {
                let k = rng.normal_vec(c.n_kv * s * c.d, 1.0);
                let v = rng.normal_vec(c.n_kv * s * c.d, 1.0);
                pool.append_chunk(&blocks, l, pos, &k, &v, s);
            }
            pos += s;
        }
        let view = pool.kv_view(&blocks, pos, 0);
        assert_eq!(view.dtype, KvDtype::Int8);
        assert!(view.k.is_empty() && view.v.is_empty(), "no fp32 copy of the cache");
        // Key sums equal the sum of dequantized *stored* rows, bit-exactly.
        let kc = pool.k_cache(&blocks, pos, 0);
        let pg = kc.pages.unwrap();
        let q = kc.quant.unwrap();
        for (j, &page) in blocks.iter().enumerate() {
            let lo = j * c.block_tokens;
            let hi = (lo + c.block_tokens).min(pos);
            for h in 0..c.n_kv {
                let mut want = vec![0.0f32; c.d];
                for i in lo..hi {
                    let b = view.row_base(h, i);
                    let s = q.scales[view.meta_base(h, i)];
                    for (w, &cd) in want.iter_mut().zip(&q.codes[b..b + c.d]) {
                        *w += cd as f32 * s;
                    }
                }
                let sb = (page as usize * c.n_kv + h) * c.d;
                assert_eq!(&want[..], &pg.key_sums[sb..sb + c.d]);
            }
        }
        // int8 pages report true (smaller) byte footprints.
        let f32_pool = KvPool::new(c);
        assert!(pool.token_bytes() < f32_pool.token_bytes());
        assert!(pool.page_bytes() < f32_pool.page_bytes());
    }

    #[test]
    fn adopt_resets_sums_on_page_reuse() {
        let c = cfg();
        let mut alloc = BlockAllocator::new(c.total_blocks, c.block_tokens);
        let mut pool = KvPool::new(c);
        let mut blocks = lease_for(&mut alloc, &mut pool, c.block_tokens);
        let k = vec![1.0f32; c.n_kv * c.block_tokens * c.d];
        let v = vec![0.0f32; c.n_kv * c.block_tokens * c.d];
        pool.append_chunk(&blocks, 0, 0, &k, &v, c.block_tokens);
        let page = blocks[0];
        pool.release_seq(&mut blocks, &mut alloc);
        // Re-lease (ids are reused LIFO) and adopt: sums must be zeroed.
        let blocks2 = lease_for(&mut alloc, &mut pool, c.block_tokens);
        assert!(blocks2.contains(&page), "expected page reuse");
        let kc = pool.k_cache(&blocks2, 0, 0);
        let sb = (page as usize * c.n_kv) * c.d;
        assert!(kc.pages.unwrap().key_sums[sb..sb + c.d].iter().all(|&x| x == 0.0));
    }
}
