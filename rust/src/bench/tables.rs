//! Paper table/figure regeneration drivers.
//!
//! One function per evaluation item; `rust/benches/*` targets and the
//! `quoka bench <id>` CLI both dispatch here. Grids default to a reduced
//! "quick" sweep; set `QUOKA_BENCH_FULL=1` for the paper-scale grids
//! (minutes to tens of minutes on CPU — see EXPERIMENTS.md for recorded
//! full runs).
//!
//! Scores are the proxy metrics of DESIGN.md §6: dense ≡ 100 (RULER) or
//! 1.0 (LongBench-normalized). What must reproduce is the *shape*: method
//! ordering, degradation with length, robustness across the ablations.

use super::{banner, full_mode};
use crate::eval::harness::{eval_policy, EvalOpts};
use crate::eval::stats;
use crate::model::ModelConfig;
use crate::select::sample_attention::SampleAttention;
use crate::select::{comparison_roster, policy_by_name, Quoka, QuokaConfig, QueryAgg, Scoring};
use crate::select::{CostCounter, SelectCtx, SelectionPolicy};
use crate::util::timing::{heatmap, Table};
use crate::workload::geometry::{GeometryConfig, GeometryTask, Needle};
use crate::workload::{longbench, math500, niah, ruler};

/// Geometry prototype simulating a model preset's head configuration.
pub fn sim_proto(model: &str, t: usize, b_cp: usize, seed: u64) -> GeometryConfig {
    let mc = ModelConfig::preset(model).expect("preset");
    GeometryConfig {
        d: 32,
        n_q_heads: mc.n_q_heads,
        n_kv_heads: mc.n_kv_heads,
        t,
        b_cp,
        seed,
        ..Default::default()
    }
}

fn models() -> Vec<&'static str> {
    if full_mode() {
        crate::model::sim_roster()
    } else {
        vec!["llama32-3b-sim"]
    }
}

fn lengths() -> Vec<usize> {
    if full_mode() {
        vec![4096, 8192, 16384, 32768]
    } else {
        // The short end where budgets don't bind is uninformative; the
        // quick grid keeps one easy and one binding length.
        vec![4096, 16384]
    }
}

fn fast_opts() -> EvalOpts {
    EvalOpts { skip_fidelity: true, ..Default::default() }
}

// ------------------------------------------------------------------ Fig 2

/// Fig. 2: the geometric observations QUOKA is built on.
pub fn fig2_geometry() -> Table {
    banner(
        "fig2_geometry",
        "Figure 2 (a-c)",
        "S_q vs max_k(A) correlation + query/key PCA separation on GeometrySim \
         (observations the generator reproduces from trained-LLM geometry).",
    );
    let mut t = Table::new(&["seed", "corr(S_q, max A)", "pca centroid dist", "q spread", "k spread"]);
    for seed in 0..4u64 {
        let cfg = GeometryConfig { t: 2048, seed, ..Default::default() };
        let task = GeometryTask::generate(
            cfg,
            vec![Needle { key_pos: 600, width: 4, query_chunk: 15, dir: 0 }],
        );
        let d = task.cfg.d;
        let q = task.q_chunk(15);
        let s = q.len() / (task.cfg.n_q_heads * d);
        let qh = &q[..s * d];
        let t_past = 15 * 128;
        let kh = &task.k[..t_past * d];
        let corr = stats::sq_attention_correlation(qh, kh, s, t_past, d);
        let (qp, kp) = stats::pca_projection(qh, kh, s, t_past, d, seed);
        let centroid = |p: &[f32], n: usize| -> [f32; 2] {
            [
                p.iter().step_by(2).sum::<f32>() / n as f32,
                p.iter().skip(1).step_by(2).sum::<f32>() / n as f32,
            ]
        };
        let spread = |p: &[f32], c: [f32; 2], n: usize| -> f32 {
            (p.chunks(2).map(|xy| (xy[0] - c[0]).powi(2) + (xy[1] - c[1]).powi(2)).sum::<f32>()
                / n as f32)
                .sqrt()
        };
        let cq = centroid(&qp, s);
        let ck = centroid(&kp, t_past);
        let dist = ((cq[0] - ck[0]).powi(2) + (cq[1] - ck[1]).powi(2)).sqrt();
        t.row(vec![
            seed.to_string(),
            format!("{corr:.3}"),
            format!("{dist:.2}"),
            format!("{:.2}", spread(&qp, cq, s)),
            format!("{:.2}", spread(&kp, ck, t_past)),
        ]);
    }
    t.print();
    println!("expected shape: strongly positive correlation; centroid distance >> spreads\n");
    t
}

// ------------------------------------------------------------------ Fig 3

/// Fig. 3: max-vs-mean deviation distributions along query and head axes.
pub fn fig3_deviation() -> Table {
    banner(
        "fig3_deviation",
        "Figure 3",
        "Heavy-tailed max-mean deviation of scores along the query axis \
         (motivates max aggregation) vs the head axis (motivates mean).",
    );
    let cfg = GeometryConfig { t: 2048, seed: 1, ..Default::default() };
    let task = GeometryTask::generate(
        cfg,
        vec![Needle { key_pos: 512, width: 4, query_chunk: 15, dir: 0 }],
    );
    let d = task.cfg.d;
    let nq = task.cfg.n_q_heads;
    let q = task.q_chunk(15);
    let s = q.len() / (nq * d);
    let t_past = 15 * 128;

    // Cosine score matrices per head: [s, t_past].
    let mut per_head: Vec<Vec<f32>> = Vec::new();
    for h in 0..nq {
        let mut m = vec![0.0f32; s * t_past];
        for i in 0..s {
            let qrow = &q[(h * s + i) * d..(h * s + i + 1) * d];
            for k in 0..t_past {
                let kv_h = h / (nq / task.cfg.n_kv_heads);
                let krow = &task.k[(kv_h * task.cfg.t + k) * d..(kv_h * task.cfg.t + k + 1) * d];
                m[i * t_past + k] = crate::tensor::ops::cosine(qrow, krow);
            }
        }
        per_head.push(m);
    }
    // Query-axis deviation on head 0; head-axis deviation at query 0.
    let dev_q = stats::max_mean_deviation(&per_head[0], s, t_past);
    let mut head_scores = vec![0.0f32; nq * t_past];
    for h in 0..nq {
        head_scores[h * t_past..(h + 1) * t_past].copy_from_slice(&per_head[h][..t_past]);
    }
    let dev_h = stats::max_mean_deviation(&head_scores, nq, t_past);

    let bins = 10;
    let hq = stats::histogram(&dev_q, 0.0, 2.0, bins);
    let hh = stats::histogram(&dev_h, 0.0, 2.0, bins);
    let mut t = Table::new(&["deviation bin", "query axis", "head axis"]);
    for b in 0..bins {
        t.row(vec![
            format!("{:.1}-{:.1}", b as f32 * 0.2, (b + 1) as f32 * 0.2),
            hq[b].to_string(),
            hh[b].to_string(),
        ]);
    }
    let tail = |h: &[usize]| h[2..].iter().sum::<usize>() as f32 / h.iter().sum::<usize>() as f32;
    t.print();
    println!(
        "query-axis tail mass {:.3} vs head-axis {:.3} — the query axis is the \
         heavy-tailed one (max agg there, mean across heads)\n",
        tail(&hq),
        tail(&hh)
    );
    t
}

// ------------------------------------------------------------- Fig 4 / 7

/// Figs. 4 & 7: NIAH depth × length heatmaps per method.
pub fn fig4_niah() -> Vec<(String, f32)> {
    banner(
        "fig4_niah",
        "Figures 4 and 7",
        "Needle recall across depth x length, B_SA=2048, B_CP=128 (llama-sim geometry).",
    );
    let lengths: Vec<usize> = if full_mode() {
        vec![2048, 4096, 8192, 16384, 30720]
    } else {
        vec![2048, 4096, 8192]
    };
    // Paper setting: B_SA = 2048 with prompts to 30k (≈7% of cache). The
    // quick grid caps at 8k, so scale the budget to preserve the ratio.
    let budget = if full_mode() { 2048 } else { 512 };
    let n_depths = if full_mode() { 11 } else { 5 };
    let cells = niah::grid(&lengths, n_depths);
    let mut means = Vec::new();
    let mut methods = vec!["dense"];
    methods.extend(comparison_roster());
    for method in methods {
        let policy = policy_by_name(method).unwrap();
        let mut rows: Vec<Vec<f32>> = vec![vec![0.0; lengths.len()]; n_depths];
        for cell in &cells {
            let task = niah::build(cell, 128, 7);
            let score = eval_policy(&task, policy.as_ref(), budget, &fast_opts());
            let li = lengths.iter().position(|&l| l == cell.length).unwrap();
            let di = ((cell.depth * n_depths as f32) as usize).min(n_depths - 1);
            rows[di][li] = score.recall();
        }
        let row_labels: Vec<String> =
            (0..n_depths).map(|d| format!("{:.0}%", 100.0 * d as f32 / n_depths as f32)).collect();
        let col_labels: Vec<String> = lengths.iter().map(|l| format!("{l}")).collect();
        println!("{}", heatmap(&format!("[{method}]"), &row_labels, &col_labels, &rows));
        let mean: f32 =
            rows.iter().flatten().sum::<f32>() / (n_depths * lengths.len()) as f32;
        println!("  mean recall: {mean:.3}\n");
        means.push((method.to_string(), mean));
    }
    println!("expected shape: quoka ~= dense; baselines degrade with depth+length\n");
    means
}

// ------------------------------------------------------------------ T 1

/// Table 1: RULER across models and lengths at B_SA = 1024.
pub fn table1_ruler() -> Table {
    banner(
        "table1_ruler",
        "Table 1",
        "RULER proxy score (0-100) at B_SA=1024 across simulated model presets.",
    );
    let ls = lengths();
    let mut header = vec!["method".to_string()];
    for m in models() {
        for l in &ls {
            header.push(format!("{}/{}k", m.split('-').next().unwrap(), l / 1024));
        }
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for method in comparison_roster() {
        let policy = policy_by_name(method).unwrap();
        let mut row = vec![method.to_string()];
        for model in models() {
            for &l in &ls {
                let proto = sim_proto(model, l, 128, 11);
                let s = ruler::score_with(policy.as_ref(), 1024, proto, &fast_opts());
                row.push(format!("{s:.1}"));
            }
        }
        t.row(row);
    }
    t.print();
    println!("expected shape: quoka highest per column; gap grows with length\n");
    t
}

// ------------------------------------------------------------------ T 2/5

/// Tables 2 & 5: QUOKA budget sweep incl. the 25%-of-cache setting.
pub fn table2_ruler_budget() -> Table {
    banner(
        "table2_ruler_budget",
        "Tables 2 and 5",
        "QUOKA RULER score across budgets; '25%' tracks a quarter of the cache.",
    );
    let ls = lengths();
    let quoka = policy_by_name("quoka").unwrap();
    let dense = policy_by_name("dense").unwrap();
    let mut header = vec!["model".to_string(), "budget".to_string()];
    header.extend(ls.iter().map(|l| format!("{}k", l / 1024)));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for model in models() {
        for budget_name in ["full", "4096", "2048", "1024", "25%"] {
            let mut row = vec![model.to_string(), budget_name.to_string()];
            for &l in &ls {
                let proto = sim_proto(model, l, 128, 13);
                let s = match budget_name {
                    "full" => ruler::score_with(dense.as_ref(), usize::MAX, proto, &fast_opts()),
                    "25%" => ruler::score_with(quoka.as_ref(), l / 4, proto, &fast_opts()),
                    b => ruler::score_with(
                        quoka.as_ref(),
                        b.parse().unwrap(),
                        proto,
                        &fast_opts(),
                    ),
                };
                row.push(format!("{s:.1}"));
            }
            t.row(row);
        }
    }
    t.print();
    println!("expected shape: graceful degradation; 25% within a few points of full\n");
    t
}

// ------------------------------------------------------------------ T 3/6/7

/// Tables 3/6/7: LongBench normalized scores across budgets and methods.
pub fn table3_longbench() -> Table {
    banner(
        "table3_longbench",
        "Tables 3, 6, 7",
        "LongBench proxy normalized to dense=1.0 (recall-gated fidelity), t=16k.",
    );
    let budgets = [512usize, 1024, 2048];
    let t_len = 16384;
    let opts = EvalOpts::default();
    let mut header = vec!["model".to_string(), "method".to_string()];
    header.extend(budgets.iter().map(|b| b.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for model in models() {
        for method in ["lessismore", "tidaldecode", "sparq", "loki", "sample", "quoka"] {
            let policy = policy_by_name(method).unwrap();
            let mut row = vec![model.to_string(), method.to_string()];
            for &b in &budgets {
                let proto = sim_proto(model, t_len, 128, 17);
                let (_, mean) = longbench::scores_with(policy.as_ref(), b, proto, &opts);
                row.push(format!("{mean:.3}"));
            }
            t.row(row);
        }
    }
    t.print();
    println!("expected shape: quoka ≥0.9 at 512 and ~1.0 at 2048; baselines 10-30% lower\n");
    t
}

// ------------------------------------------------------------------ T 4

/// Table 4: measured runtime/memory counters vs the paper's closed forms.
pub fn table4_complexity() -> Table {
    banner(
        "table4_complexity",
        "Table 4",
        "Measured selection FLOPs/bytes scaling vs analytic complexity (ratio t->2t).",
    );
    use crate::select::cost::{analytic, CostParams};
    let (t1, t2) = (4096usize, 8192usize);
    let proto = |t: usize| GeometryConfig { t, seed: 23, ..Default::default() };
    let mut table = Table::new(&[
        "method", "flops@4k", "flops@8k", "meas ratio", "analytic ratio", "bytes@8k",
    ]);
    for method in ["quoka", "sample", "sparq", "loki", "lessismore"] {
        let policy = policy_by_name(method).unwrap();
        let cost = |t_len: usize| -> (u64, u64) {
            let task = GeometryTask::generate(
                proto(t_len),
                vec![Needle { key_pos: t_len / 3, width: 4, query_chunk: t_len / 128 - 1, dir: 0 }],
            );
            let s = eval_policy(&task, policy.as_ref(), 1024, &fast_opts());
            let _ = s;
            // Re-run raw for counters.
            let q = task.q_chunk(task.probe_chunks()[0]);
            let d = task.cfg.d;
            let sq = q.len() / (task.cfg.n_q_heads * d);
            let t_past = task.probe_chunks()[0] * 128;
            let mut kc = vec![0.0f32; task.cfg.n_kv_heads * t_past * d];
            for h in 0..task.cfg.n_kv_heads {
                kc[h * t_past * d..(h + 1) * t_past * d]
                    .copy_from_slice(&task.k[h * task.cfg.t * d..h * task.cfg.t * d + t_past * d]);
            }
            let kv = crate::select::KCache::new(&kc, task.cfg.n_kv_heads, t_past, t_past, d);
            let qv = crate::select::QChunk::new(&q, task.cfg.n_q_heads, sq, d);
            let mut ctx = SelectCtx::new(0);
            let _ = policy.select(&qv, &kv, 1024, &mut ctx);
            (ctx.cost.flops(), ctx.cost.bytes())
        };
        let (f1, _) = cost(t1);
        let (f2, b2) = cost(t2);
        let p = |t: usize| CostParams {
            b_cp: 128,
            t,
            n_q_heads: 8,
            n_kv_heads: 2,
            d: 64,
            n_q_sel: 16,
            d_l: 32,
            layers: 4,
        };
        let (a1, _) = analytic(method, &p(t1));
        let (a2, _) = analytic(method, &p(t2));
        table.row(vec![
            method.to_string(),
            f1.to_string(),
            f2.to_string(),
            format!("{:.2}", f2 as f64 / f1 as f64),
            format!("{:.2}", a2 / a1),
            b2.to_string(),
        ]);
    }
    table.print();
    println!("expected shape: measured ratios ≈ analytic (linear in T); quoka lowest flops\n");
    table
}

// ------------------------------------------------------------------ T 8

/// Table 8: Math500 decode-phase proxy.
pub fn table8_math500() -> Table {
    banner(
        "table8_math500",
        "Table 8",
        "Decode-phase retrieval: flex/exact match proxies + simulated gen length.",
    );
    let n_facts = 6;
    let t_len = if full_mode() { 4096 } else { 2048 };
    let mut t = Table::new(&["method", "budget", "flex", "exact", "avg gen len"]);
    let dense_row = |t_tbl: &mut Table| {
        let task = math500::build(t_len, n_facts, 128, 31);
        let dense = policy_by_name("dense").unwrap();
        let s = math500::run(&task, dense.as_ref(), usize::MAX, 128, 0);
        t_tbl.row(vec![
            "dense".into(),
            "full".into(),
            format!("{:.3}", s.flex),
            format!("{:.3}", s.exact),
            format!("{:.1}", s.gen_len),
        ]);
    };
    dense_row(&mut t);
    for method in ["sparq", "loki", "lessismore", "quoka"] {
        for budget in [128usize, 256] {
            let task = math500::build(t_len, n_facts, 128, 31);
            let policy = policy_by_name(method).unwrap();
            let s = math500::run(&task, policy.as_ref(), budget, 128, 0);
            t.row(vec![
                method.to_string(),
                budget.to_string(),
                format!("{:.3}", s.flex),
                format!("{:.3}", s.exact),
                format!("{:.1}", s.gen_len),
            ]);
        }
    }
    t.print();
    println!("expected shape: quoka ~= dense with short traces; weak methods retry (longer traces)\n");
    t
}

// ------------------------------------------------------------------ T 9/10

/// Table 9: cosine vs dot scoring.
pub fn table9_scoring() -> Table {
    banner("table9_scoring", "Table 9", "QUOKA scoring ablation on RULER (cosine vs dot).");
    ablation_rows(
        &["cosine", "dot"],
        |name| {
            Box::new(Quoka::new(QuokaConfig {
                scoring: if name == "dot" { Scoring::Dot } else { Scoring::Cosine },
                ..QuokaConfig::default()
            }))
        },
        "cosine strictly above dot at every length",
    )
}

/// Table 10: max vs mean query aggregation.
pub fn table10_aggregation() -> Table {
    banner("table10_aggregation", "Table 10", "QUOKA aggregation ablation on RULER (max vs mean).");
    ablation_rows(
        &["max", "mean"],
        |name| {
            Box::new(Quoka::new(QuokaConfig {
                query_agg: if name == "mean" { QueryAgg::Mean } else { QueryAgg::Max },
                ..QuokaConfig::default()
            }))
        },
        "max strictly above mean at every length",
    )
}

fn ablation_rows(
    variants: &[&str],
    make: impl Fn(&str) -> Box<dyn SelectionPolicy>,
    expect: &str,
) -> Table {
    let ls = lengths();
    let mut header = vec!["variant".to_string()];
    header.extend(ls.iter().map(|l| format!("{}k", l / 1024)));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for v in variants {
        let policy = make(v);
        let mut row = vec![v.to_string()];
        for &l in &ls {
            // Ablations run with an elevated large-norm-outlier fraction:
            // real checkpoints are full of high-norm keys (Fig. 3's heavy
            // tails), which is precisely the regime where unnormalized dot
            // scoring chases norms (Table 9's mechanism).
            let proto = GeometryConfig {
                t: l,
                b_cp: 128,
                seed: 37,
                distractor_frac: 0.05,
                ..Default::default()
            };
            let s = ruler::score_with(policy.as_ref(), 512, proto, &fast_opts());
            row.push(format!("{s:.1}"));
        }
        t.row(row);
    }
    t.print();
    println!("expected shape: {expect}\n");
    t
}

// ------------------------------------------------------------------ T 11

/// Table 11: robustness to the prefill chunk size.
pub fn table11_bcp() -> Table {
    banner("table11_bcp", "Table 11", "LongBench-normalized score across B_CP (N_Q = B_CP/4).");
    let t_len = if full_mode() { 16384 } else { 8192 };
    let mut t = Table::new(&["method", "B_CP=128", "B_CP=256", "B_CP=512"]);
    for method in ["quoka", "sample"] {
        let mut row = vec![method.to_string()];
        for b_cp in [128usize, 256, 512] {
            let policy: Box<dyn SelectionPolicy> = if method == "quoka" {
                Box::new(Quoka::new(QuokaConfig { n_q: b_cp / 4, ..QuokaConfig::default() }))
            } else {
                Box::new(SampleAttention { n_q: b_cp / 4 })
            };
            let proto =
                GeometryConfig { t: t_len, b_cp, seed: 41, ..Default::default() };
            let (_, mean) = longbench::scores_with(policy.as_ref(), 1024, proto, &EvalOpts::default());
            row.push(format!("{mean:.3}"));
        }
        t.row(row);
    }
    t.print();
    println!("expected shape: quoka flat (~constant) across B_CP and above sample\n");
    t
}

// ------------------------------------------------------------------ T 12

/// Table 12: robustness to N_Q (retained queries).
pub fn table12_nq() -> Table {
    banner("table12_nq", "Table 12", "LongBench-normalized score across N_Q at B_SA=1024, B_CP=128.");
    let t_len = if full_mode() { 16384 } else { 8192 };
    let nqs = [4usize, 8, 16, 32, 64, 128];
    let mut header = vec!["method".to_string()];
    header.extend(nqs.iter().map(|n| format!("N_Q={n}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for method in ["quoka", "sample"] {
        let mut row = vec![method.to_string()];
        for &nq in &nqs {
            let policy: Box<dyn SelectionPolicy> = if method == "quoka" {
                Box::new(Quoka::new(QuokaConfig { n_q: nq, ..QuokaConfig::default() }))
            } else {
                Box::new(SampleAttention { n_q: nq })
            };
            let proto = GeometryConfig { t: t_len, b_cp: 128, seed: 43, ..Default::default() };
            let (_, mean) = longbench::scores_with(policy.as_ref(), 1024, proto, &EvalOpts::default());
            row.push(format!("{mean:.3}"));
        }
        t.row(row);
    }
    t.print();
    println!("expected shape: quoka stays near its N_Q=128 score even at N_Q=4; sample drops\n");
    t
}

// ------------------------------------------------------------------ cost sanity

/// Shared by table4 tests: assert measured scaling is near-linear in T.
pub fn measured_flops(method: &str, t_len: usize) -> u64 {
    let policy = policy_by_name(method).unwrap();
    let proto = GeometryConfig { t: t_len, seed: 23, ..Default::default() };
    let task = GeometryTask::generate(
        proto,
        vec![Needle { key_pos: t_len / 3, width: 4, query_chunk: t_len / 128 - 1, dir: 0 }],
    );
    let s = eval_policy(&task, policy.as_ref(), 1024, &fast_opts());
    s.select_flops
}

#[allow(unused)]
fn unused(_: CostCounter) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_proto_matches_preset_heads() {
        let p = sim_proto("qwen25-3b-sim", 1024, 128, 0);
        assert_eq!(p.n_q_heads, 16);
        assert_eq!(p.n_kv_heads, 2);
    }

    #[test]
    fn measured_flops_scale_linearly() {
        let f1 = measured_flops("quoka", 2048);
        let f2 = measured_flops("quoka", 4096);
        let ratio = f2 as f64 / f1 as f64;
        assert!((1.6..2.4).contains(&ratio), "ratio {ratio}");
    }
}
