//! Tiered KV pool benchmark: the mmap-backed cold-page spill tier under
//! the workload it exists for — a long shared prefix whose pages were
//! evicted under pool pressure, then re-requested.
//!
//! Eight requests share a 12k-token prefix. Three arms, same prompts:
//!
//! * **warm-RAM** — ample pool, prefix cache resident: the upper bound
//!   (pages never leave RAM).
//! * **warm-spill** — tight pool + spill tier: filler traffic demotes the
//!   prefix to the spill file; the re-requested batch promotes it back
//!   through the async readahead instead of recomputing.
//! * **cold** — tight pool, no spill: the same pressure hard-evicts the
//!   prefix, so the batch recomputes the full prefill.
//!
//! Reports mean TTFT per arm, batch prefill tokens, promotion counts and
//! the promote-wait distribution, and writes `BENCH_tiered.json`
//! (override with `TIERED_OUT`) gated in CI by `scripts/check_bench.py`
//! (floor: warm-spill TTFT at least 2x better than cold).

use super::banner;
use crate::coordinator::{Engine, EngineCfg, KvLayout, PolicySpec, SchedCfg};
use crate::kvpool::{slot_stride, KvDtype, KvPool, PoolCfg};
use crate::model::ModelConfig;
use crate::util::Json;
use crate::util::Rng;

const PREFIX_TOKENS: usize = 12 * 1024;
const SUFFIX_TOKENS: usize = 96;
const N_REQUESTS: usize = 8;
const MAX_NEW: usize = 4;
const BLOCK_TOKENS: usize = 128;
/// Ample pool: the whole working set stays resident (warm-RAM arm).
const POOL_AMPLE: usize = 2048;
/// Tight pool: one request fits (97 pages), the cached prefix does not
/// survive the filler traffic.
const POOL_TIGHT: usize = 128;
const FILLERS: usize = 4;
const FILLER_TOKENS: usize = 4096;
/// Spill capacity in slots: the 96-page prefix plus every filler page
/// that demotes while promotions make room.
const SPILL_SLOTS: usize = 256;

fn spill_cap_bytes() -> usize {
    // One slot holds one checksummed page image of the bench model.
    let mc = ModelConfig::preset("tiny").expect("tiny preset");
    let probe = KvPool::new_with_dtype(
        PoolCfg {
            n_layers: mc.n_layers,
            n_kv: mc.n_kv_heads,
            d: mc.d_head,
            block_tokens: BLOCK_TOKENS,
            total_blocks: 1,
        },
        KvDtype::env_default(),
    );
    slot_stride(probe.page_image_bytes()) * SPILL_SLOTS
}

fn mk_engine(pool_blocks: usize, spill: Option<&std::path::Path>) -> Engine {
    Engine::new_host(
        "tiny",
        EngineCfg {
            sched: SchedCfg {
                b_cp: 256,
                step_tokens: 512,
                max_running: N_REQUESTS,
                ..SchedCfg::default()
            },
            pool_blocks,
            block_tokens: BLOCK_TOKENS,
            seed: 11,
            kv: KvLayout::Paged { prefix_cache: true },
            spill_path: spill.map(|p| p.to_path_buf()),
            spill_cap_bytes: spill.map(|_| spill_cap_bytes()).unwrap_or(0),
            ..EngineCfg::default()
        },
    )
    .expect("tiny host engine")
}

fn prompt(prefix: &[u32], i: usize) -> Vec<u32> {
    let mut rng = Rng::new(0x71E4ED + i as u64);
    let mut p = prefix.to_vec();
    p.extend((0..SUFFIX_TOKENS).map(|_| rng.below(240) as u32 + 1));
    p
}

fn filler(i: usize) -> Vec<u32> {
    let mut rng = Rng::new(0xF111E4 + i as u64 * 7919);
    (0..FILLER_TOKENS).map(|_| rng.below(240) as u32 + 1).collect()
}

fn spec() -> PolicySpec {
    PolicySpec { name: "quoka".into(), budget: 1024 }
}

/// One warmup request populates the prefix cache.
fn warm_cache(e: &mut Engine, prefix: &[u32]) {
    e.submit(prompt(prefix, 0), MAX_NEW, spec()).unwrap();
    e.run_to_completion().unwrap();
}

/// Unrelated filler traffic under the tight pool: each admission evicts
/// the cold prefix pages — demoting them when a spill tier is attached,
/// destroying them when not.
fn pressure(e: &mut Engine) {
    for f in 0..FILLERS {
        e.submit(filler(f), MAX_NEW, spec()).unwrap();
        e.run_to_completion().unwrap();
    }
}

/// The measured batch: every request re-uses the shared prefix. Returns
/// (mean TTFT seconds, per-request generations sorted by id).
fn run_batch(e: &mut Engine, prefix: &[u32]) -> (f64, Vec<Vec<u32>>) {
    for i in 0..N_REQUESTS {
        e.submit(prompt(prefix, i), MAX_NEW, spec()).unwrap();
    }
    let mut results = e.run_to_completion().unwrap();
    assert_eq!(results.len(), N_REQUESTS);
    results.sort_by_key(|r| r.id);
    let mean_ttft = results.iter().map(|r| r.ttft_s).sum::<f64>() / results.len() as f64;
    (mean_ttft, results.into_iter().map(|r| r.generated).collect())
}

/// The tiered-pool serving benchmark (see module docs).
pub fn tiered_serving() -> crate::util::timing::Table {
    banner(
        "tiered_serving",
        "serving §tiered-kv-pool",
        "8 requests re-using a 12k-token prefix after pool-pressure eviction: \
         resident / spill-promoted / recomputed.",
    );
    if !cfg!(unix) {
        println!("tiered_serving: the spill tier needs unix mmap — skipping\n");
        return crate::util::timing::Table::new(&["arm", "mean TTFT ms"]);
    }
    let mut rng = Rng::new(0x71E2ED);
    let prefix: Vec<u32> = (0..PREFIX_TOKENS).map(|_| rng.below(240) as u32 + 1).collect();
    let spill_path =
        std::env::temp_dir().join(format!("quoka-tiered-{}.spill", std::process::id()));
    let _ = std::fs::remove_file(&spill_path);

    // Warm-RAM: ample pool, no pressure — the prefix never leaves RAM.
    let mut ram = mk_engine(POOL_AMPLE, None);
    warm_cache(&mut ram, &prefix);
    let ram_warmup_prefill = ram.metrics.prefill_tokens;
    let (ttft_ram, gen_ram) = run_batch(&mut ram, &prefix);
    let ram_prefill = ram.metrics.prefill_tokens - ram_warmup_prefill;

    // Warm-spill: tight pool + spill file — pressure demotes the prefix,
    // the batch promotes it back off disk.
    let mut sp = mk_engine(POOL_TIGHT, Some(&spill_path));
    warm_cache(&mut sp, &prefix);
    pressure(&mut sp);
    assert!(
        sp.radix.as_ref().unwrap().spilled_nodes() > 0,
        "filler pressure must demote cached pages into the spill tier"
    );
    let sp_warmup_prefill = sp.metrics.prefill_tokens;
    let (ttft_spill, gen_spill) = run_batch(&mut sp, &prefix);
    let spill_prefill = sp.metrics.prefill_tokens - sp_warmup_prefill;
    assert!(sp.metrics.promotions > 0, "the batch must be served by promotions, not recompute");
    assert!(
        (spill_prefill as usize) < PREFIX_TOKENS,
        "spill-warm batch recomputed the prefix ({spill_prefill} prefill tokens) \
         instead of promoting it"
    );

    // Cold: the same pressure with no spill tier hard-evicts the prefix —
    // the batch pays the full prefill again.
    let mut cold = mk_engine(POOL_TIGHT, None);
    warm_cache(&mut cold, &prefix);
    pressure(&mut cold);
    let cold_warmup_prefill = cold.metrics.prefill_tokens;
    let (ttft_cold, gen_cold) = run_batch(&mut cold, &prefix);
    let cold_prefill = cold.metrics.prefill_tokens - cold_warmup_prefill;
    assert!(
        cold_prefill as usize >= PREFIX_TOKENS,
        "the cold arm must recompute the evicted prefix"
    );

    // Tier transitions must never change the numerics.
    assert_eq!(gen_ram, gen_spill, "spill-promoted generation differs from resident");
    assert_eq!(gen_ram, gen_cold, "cold recompute differs from resident");

    let speedup = if ttft_spill > 0.0 { ttft_cold / ttft_spill } else { 0.0 };
    let mut table = crate::util::timing::Table::new(&[
        "arm",
        "mean TTFT ms",
        "batch prefill tok",
        "promotions",
        "spilled pages",
    ]);
    table.row(vec![
        "warm-RAM".into(),
        format!("{:.1}", ttft_ram * 1e3),
        format!("{ram_prefill}"),
        "0".into(),
        "0".into(),
    ]);
    table.row(vec![
        "warm-spill".into(),
        format!("{:.1}", ttft_spill * 1e3),
        format!("{spill_prefill}"),
        format!("{}", sp.metrics.promotions),
        format!("{}", sp.metrics.spilled_pages),
    ]);
    table.row(vec![
        "cold".into(),
        format!("{:.1}", ttft_cold * 1e3),
        format!("{cold_prefill}"),
        "0".into(),
        "0".into(),
    ]);
    table.print();
    println!(
        "expected shape: warm-spill TTFT sits between warm-RAM and cold — promotion \
         reads {} page images off the mmap instead of recomputing {} prefill tokens\n",
        PREFIX_TOKENS / BLOCK_TOKENS,
        PREFIX_TOKENS
    );

    let out_path = std::env::var("TIERED_OUT").unwrap_or_else(|_| "BENCH_tiered.json".to_string());
    let config = format!(
        "prefix={PREFIX_TOKENS} suffix={SUFFIX_TOKENS} reqs={N_REQUESTS} \
         block_tokens={BLOCK_TOKENS} pool_tight={POOL_TIGHT} fillers={FILLERS}x{FILLER_TOKENS} \
         spill_slots={SPILL_SLOTS} policy=quoka budget=1024 preset=tiny"
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("tiered_serving")),
        ("config", Json::str(config)),
        ("ttft-ram-ms", Json::num(ttft_ram * 1e3)),
        ("ttft-spill-ms", Json::num(ttft_spill * 1e3)),
        ("ttft-cold-ms", Json::num(ttft_cold * 1e3)),
        ("spill-warm-speedup", Json::num(speedup)),
        (
            "ram-warm-speedup",
            Json::num(if ttft_ram > 0.0 { ttft_cold / ttft_ram } else { 0.0 }),
        ),
        ("prefill-tokens-ram-batch", Json::num(ram_prefill as f64)),
        ("prefill-tokens-spill-batch", Json::num(spill_prefill as f64)),
        ("prefill-tokens-cold-batch", Json::num(cold_prefill as f64)),
        ("promotions", Json::num(sp.metrics.promotions as f64)),
        ("spilled-pages", Json::num(sp.metrics.spilled_pages as f64)),
        ("spill-bytes", Json::num(sp.metrics.spill_bytes as f64)),
        (
            "promote-wait-p50-ms",
            Json::num(sp.metrics.promote_wait_hist.quantile_ms(0.50).unwrap_or(0.0)),
        ),
        (
            "promote-wait-p99-ms",
            Json::num(sp.metrics.promote_wait_hist.quantile_ms(0.99).unwrap_or(0.0)),
        ),
        ("ttft-spill-p50-ms", Json::num(sp.metrics.ttft_hist.quantile_ms(0.50).unwrap_or(0.0))),
        ("ttft-spill-p99-ms", Json::num(sp.metrics.ttft_hist.quantile_ms(0.99).unwrap_or(0.0))),
        ("ttft-cold-p50-ms", Json::num(cold.metrics.ttft_hist.quantile_ms(0.50).unwrap_or(0.0))),
        ("ttft-cold-p99-ms", Json::num(cold.metrics.ttft_hist.quantile_ms(0.99).unwrap_or(0.0))),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    drop(sp);
    let _ = std::fs::remove_file(&spill_path);
    table
}
