//! Quantized-KV benchmark: int8 pages + in-tile dequant against the fp32
//! cache they replace.
//!
//! Two arms, both at paper-shaped geometry:
//!
//! * **Decode** — eight `serve-small` sequences prefill a shared-length
//!   prompt and decode 64 steps through the fused batched forward, once
//!   with fp32 private KV and once with int8. Decode is KV-bandwidth
//!   bound, so streaming 1-byte codes (dequantized in registers inside
//!   `qk_dots_q8` / `av_accum_q8`) instead of 4-byte floats is the whole
//!   win; the reported `speedup` is fp32 wall time over int8 wall time.
//! * **Paged scan** — the QUOKA exact scan over a pooled layer's keys
//!   (`qk_block` vs `qk_block_q8` through the block table), timed per
//!   selection pass. The metadata pass is fp32 in both arms (key sums and
//!   norms stay exact), so this isolates the quantized key-stream.
//!
//! Writes `BENCH_quant.json` (override with `QUANT_OUT`);
//! `scripts/check_bench.py` floors the decode speedup at 1.5x.

use super::banner;
use crate::kvpool::{KvDtype, KvPool, PoolCfg};
use crate::model::{DecodeKv, DecodeSeq, HostModel, ModelConfig, SeqState, Weights};
use crate::select::{policy_by_name, QChunk, SelectCtx};
use crate::util::Json;

const N_SEQS: usize = 8;
const DECODE_STEPS: usize = 64;
const BUDGET: usize = 128;
const POLICY: &str = "quoka";

fn prompt(len: usize, vocab: usize, salt: u64) -> Vec<u32> {
    (0..len).map(|i| ((i as u64 * 131 + salt * 977) % (vocab as u64 - 1) + 1) as u32).collect()
}

/// Deterministic pseudo-random floats in roughly [-1, 1).
fn noise(n: usize, salt: u64) -> Vec<f32> {
    let mut s = salt.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 40) as f32 / (1u64 << 23) as f32 - 1.0
        })
        .collect()
}

/// Prefill `N_SEQS` private sequences of the given KV dtype; returns the
/// states plus each sequence's first decode input token.
fn prefilled(
    model: &HostModel,
    prompt_len: usize,
    dtype: KvDtype,
    ctx: &mut SelectCtx,
) -> (Vec<SeqState>, Vec<u32>) {
    let cfg = model.cfg();
    let policy = policy_by_name(POLICY).unwrap();
    let mut states = Vec::with_capacity(N_SEQS);
    let mut last = Vec::with_capacity(N_SEQS);
    for i in 0..N_SEQS {
        let toks = prompt(prompt_len, cfg.vocab, i as u64);
        let mut st = SeqState::new_with_dtype(cfg, dtype);
        let mut h = Vec::new();
        for chunk in toks.chunks(256) {
            h = model.forward_chunk(&mut st, chunk, policy.as_ref(), BUDGET, ctx);
        }
        last.push(model.greedy_next(&h));
        states.push(st);
    }
    (states, last)
}

/// One decode arm: wall seconds for `DECODE_STEPS` fused batched steps.
fn decode_arm(model: &HostModel, prompt_len: usize, dtype: KvDtype) -> f64 {
    let policy = policy_by_name(POLICY).unwrap();
    let mut ctx = SelectCtx::new(0);
    let (mut states, mut last) = prefilled(model, prompt_len, dtype, &mut ctx);
    let t0 = std::time::Instant::now();
    for _ in 0..DECODE_STEPS {
        ctx.begin_step();
        let mut batch: Vec<DecodeSeq> = states
            .iter_mut()
            .zip(&last)
            .map(|(st, &tok)| DecodeSeq {
                kv: DecodeKv::Private(st),
                token: tok,
                policy: policy.as_ref(),
                budget: BUDGET,
            })
            .collect();
        let next = model.forward_decode_batch(&mut batch, None, &mut ctx);
        drop(batch);
        last.copy_from_slice(&next);
    }
    t0.elapsed().as_secs_f64()
}

/// One paged-scan arm: seconds per QUOKA selection pass over a pooled
/// layer holding `t` tokens.
fn scan_arm(dtype: KvDtype, n_kv: usize, d: usize, bt: usize, t: usize, reps: usize) -> f64 {
    let n_pages = t.div_ceil(bt);
    let mut pool = KvPool::new_with_dtype(
        PoolCfg { n_layers: 1, n_kv, d, block_tokens: bt, total_blocks: n_pages },
        dtype,
    );
    let blocks: Vec<u32> = (0..n_pages as u32).collect();
    pool.adopt_new(&blocks);
    let k = noise(n_kv * t * d, 7);
    let v = noise(n_kv * t * d, 11);
    pool.append_chunk(&blocks, 0, 0, &k, &v, t);

    let policy = policy_by_name(POLICY).unwrap();
    let qdata = noise(n_kv * d, 23);
    let q = QChunk::new(&qdata, n_kv, 1, d);
    let budget = (t / 8).max(64);
    let mut ctx = SelectCtx::new(3);
    // One warm-up pass outside the timed loop (scratch allocation).
    let _ = policy.select(&q, &pool.k_cache(&blocks, t, 0), budget, &mut ctx);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        ctx.begin_step();
        let sel = policy.select(&q, &pool.k_cache(&blocks, t, 0), budget, &mut ctx);
        std::hint::black_box(&sel);
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// The quantized-KV benchmark (see module docs). Returns the fp32-vs-int8
/// decode speedup.
pub fn quant_serving() -> f64 {
    banner(
        "quant_serving",
        "§Quantized KV pages (int8 + in-tile dequant)",
        "8 sequences × 64 fused decode steps, fp32 vs int8 private KV; plus the \
         QUOKA paged key scan at pool geometry.",
    );
    let full = super::full_mode();
    let prompt_len = if full { 4096 } else { 768 };
    let cfg = ModelConfig::serve_small();
    let model = HostModel::new(Weights::generate(&cfg, 7));

    // ---- decode arms ----
    let f32_s = decode_arm(&model, prompt_len, KvDtype::F32);
    let i8_s = decode_arm(&model, prompt_len, KvDtype::Int8);
    let total_tokens = (N_SEQS * DECODE_STEPS) as f64;
    let f32_tps = total_tokens / f32_s;
    let i8_tps = total_tokens / i8_s;
    let speedup = f32_s / i8_s;

    // ---- paged-scan arms (paper-shaped pool geometry) ----
    let (n_kv, d, bt) = (8usize, 128usize, 128usize);
    let scan_t = if full { 32768 } else { 8192 };
    let scan_reps = if full { 50 } else { 20 };
    let f32_scan_s = scan_arm(KvDtype::F32, n_kv, d, bt, scan_t, scan_reps);
    let i8_scan_s = scan_arm(KvDtype::Int8, n_kv, d, bt, scan_t, scan_reps);
    let scan_speedup = f32_scan_s / i8_scan_s;
    let keys = (n_kv * scan_t) as f64;

    let mut table = crate::util::timing::Table::new(&["arm", "fp32", "int8", "speedup"]);
    table.row(vec![
        "decode tok/s".into(),
        format!("{f32_tps:.1}"),
        format!("{i8_tps:.1}"),
        format!("{speedup:.2}"),
    ]);
    table.row(vec![
        "paged scan keys/s".into(),
        format!("{:.2e}", keys / f32_scan_s),
        format!("{:.2e}", keys / i8_scan_s),
        format!("{scan_speedup:.2}"),
    ]);
    table.print();
    println!(
        "expected shape: decode is KV-bandwidth bound, so 1-byte codes + in-register \
         dequant should clear 1.5x over fp32 rows at this context length\n"
    );

    let out_path = std::env::var("QUANT_OUT").unwrap_or_else(|_| "BENCH_quant.json".to_string());
    let config = format!(
        "seqs={N_SEQS} decode_steps={DECODE_STEPS} prompt={prompt_len} policy={POLICY} \
         budget={BUDGET} preset={} scan_t={scan_t} scan_geom={n_kv}x{d}x{bt}",
        cfg.name
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("quant_serving")),
        ("config", Json::str(config)),
        ("f32-tok-s", Json::num(f32_tps)),
        ("int8-tok-s", Json::num(i8_tps)),
        ("speedup", Json::num(speedup)),
        ("f32-scan-s", Json::num(f32_scan_s)),
        ("int8-scan-s", Json::num(i8_scan_s)),
        ("scan-speedup", Json::num(scan_speedup)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    speedup
}
