//! Shared-prefix serving benchmark: the paged pool + radix prefix cache
//! under the workload they exist for — many requests over one long shared
//! context (system prompt / document), differing only in a short suffix.
//!
//! Eight requests share a 12k-token prefix. A cold engine (paged pool, no
//! prefix cache) pays the full prefill eight times; a warm engine serves
//! the prefix pages from the radix cache after the first request, so
//! requests 2..8 prefill only their suffixes; the *in-flight* arm takes
//! the whole burst on a cold cache — the first request leads and the
//! other seven park behind its mid-prefill page publishes, so the shared
//! prefix is prefilled exactly once across the batch (asserted). Reports
//! prefix-hit rate, TTFT for all three arms, prefill-token counts and KV
//! bytes saved, and writes `BENCH_prefix.json` (override with
//! `PREFIX_OUT`) so the serving trajectory is tracked PR over PR and
//! gated in CI by `scripts/check_bench.py`.

use super::banner;
use crate::coordinator::{Engine, EngineCfg, KvLayout, PolicySpec, SchedCfg};
use crate::util::Json;
use crate::util::Rng;

const PREFIX_TOKENS: usize = 12 * 1024;
const SUFFIX_TOKENS: usize = 96;
const N_REQUESTS: usize = 8;
const MAX_NEW: usize = 4;
const BLOCK_TOKENS: usize = 128;

fn mk_engine(prefix_cache: bool) -> Engine {
    Engine::new_host(
        "tiny",
        EngineCfg {
            sched: SchedCfg {
                b_cp: 256,
                step_tokens: 512,
                max_running: N_REQUESTS,
                ..SchedCfg::default()
            },
            pool_blocks: 2048,
            block_tokens: BLOCK_TOKENS,
            seed: 11,
            kv: KvLayout::Paged { prefix_cache },
            ..EngineCfg::default()
        },
    )
    .expect("tiny host engine")
}

fn prompt(prefix: &[u32], i: usize) -> Vec<u32> {
    let mut rng = Rng::new(0x5FF1C + i as u64);
    let mut p = prefix.to_vec();
    p.extend((0..SUFFIX_TOKENS).map(|_| rng.below(240) as u32 + 1));
    p
}

fn spec() -> PolicySpec {
    PolicySpec { name: "quoka".into(), budget: 1024 }
}

/// Run the 8-request shared-prefix workload; returns (mean TTFT seconds,
/// the engine for metric inspection).
fn run_batch(mut e: Engine, prefix: &[u32]) -> (f64, Engine) {
    for i in 0..N_REQUESTS {
        e.submit(prompt(prefix, i), MAX_NEW, spec()).unwrap();
    }
    let results = e.run_to_completion().unwrap();
    assert_eq!(results.len(), N_REQUESTS);
    let mean_ttft = results.iter().map(|r| r.ttft_s).sum::<f64>() / results.len() as f64;
    (mean_ttft, e)
}

/// In-flight arm: the whole burst hits a COLD cache at once — the first
/// request leads, the rest park behind its mid-prefill publishes, adopt
/// the shared pages as they land, and prefill only their own suffixes.
fn run_inflight(mut e: Engine, prefix: &[u32]) -> (f64, Engine) {
    e.submit(prompt(prefix, 0), MAX_NEW, spec()).unwrap();
    e.step().unwrap(); // the leader is mid-prefill when the burst arrives
    for i in 1..N_REQUESTS {
        e.submit(prompt(prefix, i), MAX_NEW, spec()).unwrap();
    }
    let results = e.run_to_completion().unwrap();
    assert_eq!(results.len(), N_REQUESTS);
    let mean_ttft = results.iter().map(|r| r.ttft_s).sum::<f64>() / results.len() as f64;
    (mean_ttft, e)
}

/// The shared-prefix serving benchmark (see module docs).
pub fn prefix_serving() -> crate::util::timing::Table {
    banner(
        "prefix_serving",
        "serving §prefix-cache",
        "8 requests sharing a 12k-token prefix: paged pool; radix cache off / warm / in-flight.",
    );
    let mut rng = Rng::new(0xD0C);
    let prefix: Vec<u32> = (0..PREFIX_TOKENS).map(|_| rng.below(240) as u32 + 1).collect();

    // Cold: paged pool, no prefix cache — every request prefills fully.
    let (ttft_cold, cold) = run_batch(mk_engine(false), &prefix);

    // Warm: one request populates the cache, then the measured batch
    // reuses the shared prefix pages.
    let mut warm = mk_engine(true);
    warm.submit(prompt(&prefix, 0), MAX_NEW, spec()).unwrap();
    warm.run_to_completion().unwrap();
    let warmup_prefill = warm.metrics.prefill_tokens;
    let (ttft_warm, warm) = run_batch(warm, &prefix);
    let batch_prefill = warm.metrics.prefill_tokens - warmup_prefill;

    // In-flight: a cold cache takes the whole burst at once; the seven
    // followers park behind the leader's mid-prefill publishes.
    let (ttft_inflight, inflight) = run_inflight(mk_engine(true), &prefix);
    let inflight_prefill = inflight.metrics.prefill_tokens;
    assert_eq!(
        inflight.metrics.inflight_followers as usize,
        N_REQUESTS - 1,
        "every request behind the leader must park, not recompute"
    );
    assert_eq!(
        inflight_prefill as usize,
        PREFIX_TOKENS + N_REQUESTS * SUFFIX_TOKENS,
        "in-flight burst must prefill the shared prefix exactly once"
    );

    let hit_rate = warm.metrics.prefix_hit_rate();
    let cached_per_req = (PREFIX_TOKENS / BLOCK_TOKENS) * BLOCK_TOKENS;
    let mut table = crate::util::timing::Table::new(&[
        "engine",
        "prefix-hit rate",
        "mean TTFT ms",
        "batch prefill tok",
        "kv bytes saved",
    ]);
    table.row(vec![
        "paged (no cache)".into(),
        "0.0%".into(),
        format!("{:.1}", ttft_cold * 1e3),
        format!("{}", cold.metrics.prefill_tokens),
        "0".into(),
    ]);
    table.row(vec![
        "paged + prefix cache".into(),
        format!("{:.1}%", hit_rate * 100.0),
        format!("{:.1}", ttft_warm * 1e3),
        format!("{batch_prefill}"),
        format!("{}", warm.metrics.prefix_bytes_saved),
    ]);
    table.row(vec![
        "paged + in-flight burst".into(),
        format!("{:.1}%", inflight.metrics.prefix_hit_rate() * 100.0),
        format!("{:.1}", ttft_inflight * 1e3),
        format!("{inflight_prefill}"),
        format!("{}", inflight.metrics.prefix_bytes_saved),
    ]);
    table.print();
    println!(
        "expected shape: warm batch prefills ≈ {} suffix tokens/request instead of {}; \
         TTFT speedup ≈ prompt/suffix ratio; the in-flight burst prefills the prefix \
         ONCE for all {} requests\n",
        SUFFIX_TOKENS,
        PREFIX_TOKENS + SUFFIX_TOKENS,
        N_REQUESTS
    );

    // Acceptance sanity: the warm batch must not have prefilled any cached
    // prefix token.
    assert_eq!(
        batch_prefill as usize,
        N_REQUESTS * (PREFIX_TOKENS + SUFFIX_TOKENS - cached_per_req),
        "warm batch prefilled cached-prefix tokens"
    );

    let out_path =
        std::env::var("PREFIX_OUT").unwrap_or_else(|_| "BENCH_prefix.json".to_string());
    let config = format!(
        "prefix={PREFIX_TOKENS} suffix={SUFFIX_TOKENS} reqs={N_REQUESTS} \
         block_tokens={BLOCK_TOKENS} policy=quoka budget=1024 preset=tiny"
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("prefix_serving")),
        ("config", Json::str(config)),
        ("prefix-hit-rate", Json::num(hit_rate)),
        ("ttft-cold-ms", Json::num(ttft_cold * 1e3)),
        ("ttft-warm-ms", Json::num(ttft_warm * 1e3)),
        ("ttft-speedup", Json::num(if ttft_warm > 0.0 { ttft_cold / ttft_warm } else { 0.0 })),
        ("ttft-inflight-ms", Json::num(ttft_inflight * 1e3)),
        (
            "inflight-speedup",
            Json::num(if ttft_inflight > 0.0 { ttft_cold / ttft_inflight } else { 0.0 }),
        ),
        ("prefill-tokens-cold", Json::num(cold.metrics.prefill_tokens as f64)),
        ("prefill-tokens-warm-batch", Json::num(batch_prefill as f64)),
        ("prefill-tokens-inflight", Json::num(inflight_prefill as f64)),
        (
            "inflight-adopted-tokens",
            Json::num(inflight.metrics.inflight_adopted_tokens as f64),
        ),
        ("kv-bytes-saved", Json::num(warm.metrics.prefix_bytes_saved as f64)),
        ("pool-resident-bytes", Json::num(warm.metrics.pool_resident_bytes as f64)),
        // Distribution tails from the engines' latency histograms
        // (schema-additive; check_bench.py ignores unknown keys). The warm
        // engine's histogram includes its one warmup request.
        ("ttft-cold-p50-ms", Json::num(cold.metrics.ttft_hist.quantile_ms(0.50).unwrap_or(0.0))),
        ("ttft-cold-p99-ms", Json::num(cold.metrics.ttft_hist.quantile_ms(0.99).unwrap_or(0.0))),
        ("ttft-warm-p50-ms", Json::num(warm.metrics.ttft_hist.quantile_ms(0.50).unwrap_or(0.0))),
        ("ttft-warm-p99-ms", Json::num(warm.metrics.ttft_hist.quantile_ms(0.99).unwrap_or(0.0))),
        (
            "ttft-inflight-p50-ms",
            Json::num(inflight.metrics.ttft_hist.quantile_ms(0.50).unwrap_or(0.0)),
        ),
        (
            "ttft-inflight-p99-ms",
            Json::num(inflight.metrics.ttft_hist.quantile_ms(0.99).unwrap_or(0.0)),
        ),
        (
            "itl-inflight-p50-ms",
            Json::num(inflight.metrics.itl_hist.quantile_ms(0.50).unwrap_or(0.0)),
        ),
        (
            "itl-inflight-p99-ms",
            Json::num(inflight.metrics.itl_hist.quantile_ms(0.99).unwrap_or(0.0)),
        ),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    table
}
