//! Speculative-decode benchmark: prompt-lookup drafting + batched
//! verification against the plain one-token decode loop.
//!
//! The workload is copy-heavy by construction — a short token block
//! repeated to prompt length — standing in for the verbatim-copy regime
//! of the long-context suites (NIAH / RULER answers quote the prompt),
//! which is where a training-free n-gram drafter earns its keep. Greedy
//! decode of the synthetic model settles into a repetition loop on most
//! such prompts, but *which* loop depends on the trajectory, so the bench
//! first probes a few candidate prompts with a short speculative run and
//! measures the most compressible one (both arms then use that same
//! prompt; the probe is reported alongside the result). One sequence,
//! B = 1: speculation's home turf is low-batch decode latency, where
//! nothing else amortizes the weight stream.
//!
//! Arms:
//! * **spec-off** — the engine's batched decode path at B = 1: one token
//!   per step, the full weight set streamed per token.
//! * **spec-on** — prompt-lookup drafting (`gamma` = 8) + one multi-token
//!   verify forward per step; accepted tokens ride a single weight
//!   stream. Generations are asserted bit-identical to the off arm
//!   (speculation is lossless), so the tokens/sec ratio is pure
//!   throughput.
//!
//! Writes `BENCH_spec.json` (override with `SPEC_OUT`): speculative
//! speedup, both arms' decode tokens/sec, and the acceptance-rate /
//! drafted / accepted counters — gated in CI by `scripts/check_bench.py`
//! (floor: >= 1.5x).

use super::banner;
use crate::coordinator::{Engine, EngineCfg, KvLayout, PolicySpec, SchedCfg};
use crate::spec::SpecCfg;
use crate::util::Json;
use crate::util::Rng;

const PROMPT_TOKENS: usize = 256;
const DECODE_TOKENS: usize = 192;
const GAMMA: usize = 8;
const BLOCK_PERIOD: usize = 8;
// Greedy trajectories of the synthetic model settle into a tight
// repetition loop on roughly a third of candidate prompts (offline sweep
// with the exact-weights mirror: salts 3, 6 and 7 lock at 89-100%
// acceptance for this seed); eight candidates make the probe's pick
// robust to trajectory perturbations.
const N_CANDIDATES: u64 = 8;
const PROBE_TOKENS: usize = 49; // short spec-on run per candidate prompt
const SEED: u64 = 7;

fn mk_engine(spec: SpecCfg) -> Engine {
    Engine::new_host(
        "serve-small",
        EngineCfg {
            sched: SchedCfg { b_cp: 256, step_tokens: 512, max_running: 2, ..SchedCfg::default() },
            pool_blocks: 64,
            block_tokens: 128,
            seed: SEED,
            kv: KvLayout::Private,
            spec,
            ..EngineCfg::default()
        },
    )
    .expect("serve-small host engine")
}

/// Copy-heavy candidate prompt `salt`: a `BLOCK_PERIOD`-token block
/// repeated to `PROMPT_TOKENS`.
fn prompt(salt: u64) -> Vec<u32> {
    let mut rng = Rng::new(0x5bec ^ (salt * 0x9E37));
    let block: Vec<u32> = (0..BLOCK_PERIOD).map(|_| rng.below(4000) as u32 + 1).collect();
    (0..PROMPT_TOKENS).map(|i| block[i % BLOCK_PERIOD]).collect()
}

fn policy() -> PolicySpec {
    PolicySpec { name: "quoka".into(), budget: 1024 }
}

/// Run one single-sequence episode; returns the engine for metrics plus
/// the generation.
fn run(spec: SpecCfg, toks: Vec<u32>, max_new: usize) -> (Engine, Vec<u32>) {
    let mut e = mk_engine(spec);
    e.submit(toks, max_new, policy()).unwrap();
    let r = e.run_to_completion().unwrap().remove(0);
    (e, r.generated)
}

/// The speculative-decode benchmark (see module docs). Returns the
/// spec-on vs spec-off decode-throughput speedup.
pub fn spec_serving() -> f64 {
    banner(
        "spec_serving",
        "§Speculative decode",
        "copy-heavy single-sequence decode: prompt-lookup drafting + batched verify \
         vs one token per weight stream.",
    );
    let decode_tokens = if super::full_mode() { 4 * DECODE_TOKENS } else { DECODE_TOKENS };

    // ---- probe: pick the most compressible candidate generation ----
    let mut best = (0u64, -1.0f64);
    for salt in 0..N_CANDIDATES {
        let (e, _) = run(SpecCfg::prompt_lookup(GAMMA), prompt(salt), PROBE_TOKENS);
        let m = &e.metrics;
        // Rank by emitted tokens per decode-phase step — verify steps
        // plus the plain fused steps the drafter abstained into (at B = 1
        // every histogram entry is one such step). Raw acceptance would
        // flatter a candidate that rarely drafts; dividing by verify
        // steps alone would flatter one that mostly abstains.
        let decode_steps = m.spec_steps + m.decode_batch_hist.iter().sum::<u64>();
        let score = m.decode_tokens as f64 / decode_steps.max(1) as f64;
        println!(
            "probe salt={salt}: accept={:.1}% tokens/step={score:.2}",
            100.0 * m.spec_acceptance()
        );
        if score > best.1 {
            best = (salt, score);
        }
    }
    let toks = prompt(best.0);
    println!("measuring candidate salt={} (tokens/step {:.2})\n", best.0, best.1);

    // ---- spec-off arm: one token per engine step ----
    let (e_off, gen_off) = run(SpecCfg::off(), toks.clone(), decode_tokens);
    let off_s = e_off.metrics.decode_s;
    let off_tok = e_off.metrics.decode_tokens as f64;

    // ---- spec-on arm: drafting + batched verification ----
    let (e_on, gen_on) = run(SpecCfg::prompt_lookup(GAMMA), toks, decode_tokens);
    let on_s = e_on.metrics.decode_s;
    let on_tok = e_on.metrics.decode_tokens as f64;

    assert_eq!(
        gen_off, gen_on,
        "speculative decode must generate exactly the non-speculative tokens"
    );
    assert_eq!(off_tok, on_tok);

    let tps_off = off_tok / off_s.max(1e-12);
    let tps_on = on_tok / on_s.max(1e-12);
    let speedup = tps_on / tps_off.max(1e-12);
    let accept = e_on.metrics.spec_acceptance();

    let mut table = crate::util::timing::Table::new(&[
        "decode path",
        "decode s",
        "tokens/s",
        "accept rate",
        "speedup",
    ]);
    table.row(vec![
        "spec-off (1 tok/step)".into(),
        format!("{off_s:.3}"),
        format!("{tps_off:.1}"),
        "—".into(),
        "1.00".into(),
    ]);
    table.row(vec![
        format!("spec-on (pld, gamma={GAMMA})"),
        format!("{on_s:.3}"),
        format!("{tps_on:.1}"),
        format!("{:.1}%", accept * 100.0),
        format!("{speedup:.2}"),
    ]);
    table.print();
    println!(
        "expected shape: >= 1.5x — accepted drafts ride one weight stream per verify \
         step instead of one per token; identical generations asserted\n"
    );

    let out_path = std::env::var("SPEC_OUT").unwrap_or_else(|_| "BENCH_spec.json".to_string());
    let config = format!(
        "prompt={PROMPT_TOKENS} decode={decode_tokens} gamma={GAMMA} period={BLOCK_PERIOD} \
         candidates={N_CANDIDATES} policy=quoka budget=1024 preset=serve-small seed={SEED}"
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("spec_serving")),
        ("config", Json::str(config)),
        ("speedup", Json::num(speedup)),
        ("accept-rate", Json::num(accept)),
        ("spec-tok-s", Json::num(tps_on)),
        ("base-tok-s", Json::num(tps_off)),
        ("drafted-tokens", Json::num(e_on.metrics.spec_drafted_tokens as f64)),
        ("accepted-tokens", Json::num(e_on.metrics.spec_accepted_tokens as f64)),
        ("verify-steps", Json::num(e_on.metrics.spec_steps as f64)),
        ("probe-salt", Json::num(best.0 as f64)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    speedup
}
