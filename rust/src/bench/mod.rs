//! Shared benchmark drivers.
//!
//! Every `rust/benches/*` target regenerates one paper table or figure by
//! dispatching into [`tables`] / [`latency`]; the `quoka bench <id>` CLI
//! uses the same functions, so numbers agree regardless of entry point.

pub mod tables;
pub mod latency;
pub mod prefix;
pub mod decode;
pub mod spec;
pub mod quant;
pub mod gemm;
pub mod serving;
pub mod tiered;

pub use crate::util::timing::{bench, heatmap, BenchCfg, Stats, Table};

/// `QUOKA_BENCH_FULL=1` enables the paper-scale grids; the default is a
/// reduced sweep suitable for CI (same code paths, smaller lengths).
pub fn full_mode() -> bool {
    std::env::var("QUOKA_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench header naming the reproduced paper item.
pub fn banner(id: &str, paper_item: &str, note: &str) {
    println!("=== {id} — reproduces {paper_item} ===");
    if !note.is_empty() {
        println!("{note}");
    }
    if !full_mode() {
        println!("(quick grid; QUOKA_BENCH_FULL=1 for the paper-scale sweep)");
    }
    println!();
}
