//! Latency benchmarks: Figures 5 (attention + TTFT speedup vs prompt
//! length) and 6 (decode speedup), plus the hot-path microbench used by
//! the §Perf optimization loop.
//!
//! Two testbeds stand in for the paper's A100/RTX2080/Xeon rows
//! (DESIGN.md §3): the **host** backend (pure Rust — the "CPU" story) and
//! the **pjrt** backend (XLA CPU — the "compiled kernel" story). As in the
//! paper, every number is reported as *speedup relative to dense attention
//! on the same backend*.

use super::{banner, full_mode};
use crate::model::attention::{chunk_attention, reference_chunk_attention, AttnScratch, KvBuffers};
use crate::model::{HostModel, ModelConfig, SeqState, Weights};
use crate::select::{policy_by_name, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::util::timing::{bench, BenchCfg, Stats, Table};
use crate::util::{Json, Rng};

fn grid() -> Vec<usize> {
    if full_mode() {
        vec![2048, 4096, 8192, 16384, 32768]
    } else {
        vec![2048, 4096, 8192]
    }
}

fn bench_cfg() -> BenchCfg {
    if full_mode() {
        BenchCfg { warmup_iters: 2, measure_iters: 8, max_seconds: 30.0 }
    } else {
        BenchCfg::quick()
    }
}

/// One standalone attention-module measurement: selection + (gathered)
/// attention for one chunk at cache depth `t`. Returns seconds.
fn attn_module_time(policy: &dyn SelectionPolicy, budget: usize, t: usize, cfg: &ModelConfig) -> f64 {
    let (nq, nkv, d) = (cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head);
    let s = 128usize;
    let mut rng = Rng::new(71);
    let q = rng.normal_vec(nq * s * d, 1.0);
    let k_self = rng.normal_vec(nkv * s * d, 1.0);
    let v_self = rng.normal_vec(nkv * s * d, 1.0);
    let mut cache = KvBuffers::new(nkv, d, t);
    let kk = rng.normal_vec(nkv * t * d, 1.0);
    let vv = rng.normal_vec(nkv * t * d, 1.0);
    cache.append(&kk, &vv, t);
    let mut ctx = SelectCtx::new(0);
    let mut out = vec![0.0f32; nq * s * d];
    let mut scratch = AttnScratch::new();
    let stats = bench(bench_cfg(), || {
        let sel = if policy.is_dense() {
            Selection::All
        } else {
            let qv = QChunk::new(&q, nq, s, d);
            policy.select(&qv, &cache.k_view(), budget, &mut ctx)
        };
        chunk_attention(&q, nq, s, d, &k_self, &v_self, &cache, &sel, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    stats.mean_ns / 1e9
}

/// Fig. 5a/5c: standalone attention speedup vs dense, host backend.
pub fn fig5_attention() -> Table {
    banner(
        "fig5_latency (attention)",
        "Figure 5a/5c",
        "Host-backend attention-module speedup over dense at B_SA=1024, B_CP=128.",
    );
    let cfg = ModelConfig::serve_small();
    let ts = grid();
    let mut header = vec!["method".to_string()];
    header.extend(ts.iter().map(|t| format!("T={t}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    let dense = policy_by_name("dense").unwrap();
    let dense_times: Vec<f64> =
        ts.iter().map(|&t| attn_module_time(dense.as_ref(), usize::MAX, t, &cfg)).collect();
    let mut row = vec!["dense (ms)".to_string()];
    row.extend(dense_times.iter().map(|s| format!("{:.1}", s * 1e3)));
    table.row(row);

    for method in ["quoka", "sample", "sparq", "loki", "keydiff"] {
        let policy = policy_by_name(method).unwrap();
        let mut row = vec![format!("{method} (x)")];
        for (i, &t) in ts.iter().enumerate() {
            let s = attn_module_time(policy.as_ref(), 1024, t, &cfg);
            row.push(format!("{:.2}", dense_times[i] / s));
        }
        table.row(row);
    }
    table.print();
    println!("expected shape: quoka speedup grows with T (crossover ≈ where T ≈ B_SA)\n");
    table
}

/// Fig. 5b/5d: TTFT speedup. Per-chunk full-layer step times measured at
/// sampled cache depths, integrated over the chunk schedule (estimator
/// validated against a real prefill at the smallest length).
pub fn fig5_ttft() -> Table {
    banner(
        "fig5_latency (TTFT)",
        "Figure 5b/5d",
        "End-to-end TTFT speedup (host backend, integrated per-chunk estimator).",
    );
    let cfg = ModelConfig::preset("serve-small").unwrap();
    let model = HostModel::new(Weights::generate(&cfg, 3));
    let ts = grid();
    let b_cp = 128usize;

    // Measure full chunk-step time (all layers) at sampled depths.
    let chunk_time = |policy: &dyn SelectionPolicy, budget: usize, depth: usize| -> f64 {
        let mut state = SeqState::new(&cfg);
        let mut rng = Rng::new(5);
        // Pre-fill caches directly (random rows stand in for context).
        for c in &mut state.caches {
            let kk = rng.normal_vec(cfg.n_kv_heads * depth * cfg.d_head, 0.5);
            let vv = rng.normal_vec(cfg.n_kv_heads * depth * cfg.d_head, 0.5);
            c.append(&kk, &vv, depth);
        }
        state.pos = depth;
        let tokens: Vec<u32> = (0..b_cp).map(|i| (i % cfg.vocab) as u32).collect();
        let mut ctx = SelectCtx::new(0);
        let st = bench(BenchCfg { warmup_iters: 1, measure_iters: 3, max_seconds: 20.0 }, || {
            let mut s2 = SeqState::new(&cfg);
            std::mem::swap(&mut s2.caches, &mut state.caches);
            s2.pos = depth;
            let h = model.forward_chunk(&mut s2, &tokens, policy, budget, &mut ctx);
            std::hint::black_box(&h);
            std::mem::swap(&mut s2.caches, &mut state.caches);
            // Trim the appended chunk back off so depth stays constant.
            for c in &mut state.caches {
                c.t = depth;
            }
        });
        st.mean_ns / 1e9
    };

    // Integrate chunk times over the prefill schedule with a coarse grid.
    let ttft = |policy: &dyn SelectionPolicy, budget: usize, total: usize| -> f64 {
        let samples = 5usize;
        let mut acc = 0.0;
        let n_chunks = total / b_cp;
        for i in 0..samples {
            let chunk_idx = i * n_chunks / samples;
            let depth = chunk_idx * b_cp;
            let w = n_chunks as f64 / samples as f64;
            acc += w * chunk_time(policy, budget, depth);
        }
        acc
    };

    let dense = policy_by_name("dense").unwrap();
    let quoka = policy_by_name("quoka").unwrap();
    let sample = policy_by_name("sample").unwrap();

    let mut header = vec!["method".to_string()];
    header.extend(ts.iter().map(|t| format!("T={t}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);
    let dense_ttfts: Vec<f64> = ts.iter().map(|&t| ttft(dense.as_ref(), usize::MAX, t)).collect();
    let mut row = vec!["dense TTFT (s)".to_string()];
    row.extend(dense_ttfts.iter().map(|s| format!("{s:.2}")));
    table.row(row);
    for (name, policy) in [("quoka", &quoka), ("sample", &sample)] {
        let mut row = vec![format!("{name} (x)")];
        for (i, &t) in ts.iter().enumerate() {
            let s = ttft(policy.as_ref(), 1024, t);
            row.push(format!("{:.2}", dense_ttfts[i] / s));
        }
        table.row(row);
    }
    table.print();
    println!("expected shape: ~1x at short prompts, ≥2-3x by 32k (attention share grows)\n");
    table
}

/// Fig. 6: decode-phase speedup vs number of decode steps.
pub fn fig6_decode() -> Table {
    banner(
        "fig6_decode",
        "Figure 6",
        "Decode attention speedup vs dense at context 8k (host backend).",
    );
    let cfg = ModelConfig::serve_small();
    let depth = if full_mode() { 16384 } else { 8192 };
    let steps = [16usize, 64, 128];
    let mut table = Table::new(&["method", "16 steps", "64 steps", "128 steps"]);
    let decode_time = |policy: &dyn SelectionPolicy, budget: usize, n: usize| -> f64 {
        let (nq, nkv, d) = (cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head);
        let mut rng = Rng::new(81);
        let mut cache = KvBuffers::new(nkv, d, depth + n + 1);
        let kk = rng.normal_vec(nkv * depth * d, 1.0);
        let vv = rng.normal_vec(nkv * depth * d, 1.0);
        cache.append(&kk, &vv, depth);
        let mut ctx = SelectCtx::new(0);
        let mut out = vec![0.0f32; nq * d];
        let mut scratch = AttnScratch::new();
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            let q = rng.normal_vec(nq * d, 1.0);
            let ks = rng.normal_vec(nkv * d, 1.0);
            let vs = rng.normal_vec(nkv * d, 1.0);
            let sel = if policy.is_dense() {
                Selection::All
            } else {
                let qv = QChunk::new(&q, nq, 1, d);
                policy.select(&qv, &cache.k_view(), budget, &mut ctx)
            };
            crate::model::attention::decode_attention(
                &q, nq, d, &ks, &vs, &cache, &sel, &mut scratch, &mut out,
            );
            cache.append(&ks, &vs, 1);
            std::hint::black_box(&out);
        }
        t0.elapsed().as_secs_f64()
    };
    let dense = policy_by_name("dense").unwrap();
    let base: Vec<f64> = steps.iter().map(|&n| decode_time(dense.as_ref(), usize::MAX, n)).collect();
    let mut row = vec!["dense (s)".to_string()];
    row.extend(base.iter().map(|s| format!("{s:.3}")));
    table.row(row);
    for method in ["quoka", "keydiff", "sparq"] {
        let policy = policy_by_name(method).unwrap();
        let mut row = vec![format!("{method} (x)")];
        for (i, &n) in steps.iter().enumerate() {
            let s = decode_time(policy.as_ref(), 1024, n);
            row.push(format!("{:.2}", base[i] / s));
        }
        table.row(row);
    }
    table.print();
    println!("expected shape: speedup roughly constant per step, > 1 once T >> B_SA\n");
    table
}

/// §Perf micro: the selection + gather + attention hot-path pieces.
///
/// Runs the acceptance configuration — 32 query / 8 KV heads, d=128,
/// s=128 chunk, QUOKA budget ≈ 12 % of T — and reports the tiled kernel
/// against the seed scalar kernel ([`reference_chunk_attention`]) on the
/// *same selection*, so the speedup isolates the kernel rewrite.
///
/// Results are also written as JSON (`BENCH_OUT` env var, default
/// `BENCH_hotpath.json` in the working directory; one entry per measured
/// piece with keys `config`, `wall-ns`, `GFLOP/s`) so the perf trajectory
/// is tracked PR over PR. `BENCH_SMOKE=1` selects the reduced
/// configuration used by `scripts/bench_smoke.sh`.
pub fn micro_hotpath() -> Table {
    banner(
        "micro_hotpath",
        "§Perf hot path",
        "Chunked-prefill hot path: QUOKA select + tiled attention vs the seed kernel.",
    );
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (nq, nkv, d) = (32usize, 8usize, 128usize);
    let s = 128usize;
    let ts: Vec<usize> = if smoke {
        vec![16384]
    } else if full_mode() {
        vec![4096, 16384, 65536]
    } else {
        vec![4096, 16384]
    };
    let cfg = if smoke {
        BenchCfg { warmup_iters: 1, measure_iters: 3, max_seconds: 30.0 }
    } else {
        bench_cfg()
    };
    let mut table = Table::new(&[
        "T",
        "budget",
        "select ms",
        "attn tiled ms",
        "attn seed ms",
        "speedup",
        "attn dense ms",
        "GFLOP/s tiled",
    ]);
    let mut entries: Vec<Json> = Vec::new();
    let mut entry = |config: String, st: &Stats, flops: f64| {
        entries.push(Json::obj(vec![
            ("config", Json::str(config)),
            ("wall-ns", Json::num(st.mean_ns)),
            ("GFLOP/s", Json::num(flops / st.mean_ns)),
        ]));
    };
    for &t in &ts {
        let budget = t * 12 / 100; // ≈ 12 % of the cache
        let shape = format!("T={t} GQA={nq}q/{nkv}kv d={d} s={s} budget={budget}");
        let mut rng = Rng::new(91);
        let q = rng.normal_vec(nq * s * d, 1.0);
        let k_self = rng.normal_vec(nkv * s * d, 1.0);
        let v_self = rng.normal_vec(nkv * s * d, 1.0);
        let mut cache = KvBuffers::new(nkv, d, t);
        let kk = rng.normal_vec(nkv * t * d, 1.0);
        let vv = rng.normal_vec(nkv * t * d, 1.0);
        cache.append(&kk, &vv, t);
        let quoka = policy_by_name("quoka").unwrap();
        let mut ctx = SelectCtx::new(0);
        let qv = QChunk::new(&q, nq, s, d);
        let sel_stats = bench(cfg, || {
            let sel = quoka.select(&qv, &cache.k_view(), budget, &mut ctx);
            std::hint::black_box(&sel);
        });
        // QUOKA scan flops: n_q_eff pre-aggregated queries × T keys × 2d
        // per KV head (n_q from the paper-default config, not hardcoded).
        let n_q_eff = crate::select::QuokaConfig::default().n_q.min(s) as f64;
        let scan_flops = nkv as f64 * t as f64 * n_q_eff * 2.0 * d as f64;
        entry(format!("select_quoka {shape}"), &sel_stats, scan_flops);

        let sel = quoka.select(&qv, &cache.k_view(), budget, &mut ctx);
        let n_sel: usize = (0..nkv).map(|h| sel.head_len(h, t)).sum::<usize>() / nkv;
        let mut out = vec![0.0f32; nq * s * d];
        let mut scratch = AttnScratch::new();
        let attn_tiled = bench(cfg, || {
            chunk_attention(&q, nq, s, d, &k_self, &v_self, &cache, &sel, &mut scratch, &mut out);
            std::hint::black_box(&out);
        });
        // QKᵀ + AV over (selected past + causal self): 4d flops per
        // (query, visible key).
        let attn_flops =
            (nq * s) as f64 * (n_sel as f64 + (s as f64 + 1.0) / 2.0) * (4 * d) as f64;
        entry(format!("attn_tiled {shape}"), &attn_tiled, attn_flops);

        let attn_seed = bench(cfg, || {
            reference_chunk_attention(&q, nq, s, d, &k_self, &v_self, &cache, &sel, &mut out);
            std::hint::black_box(&out);
        });
        entry(format!("attn_seed {shape}"), &attn_seed, attn_flops);

        let attn_dense = bench(cfg, || {
            chunk_attention(
                &q, nq, s, d, &k_self, &v_self, &cache, &Selection::All, &mut scratch, &mut out,
            );
            std::hint::black_box(&out);
        });
        let dense_flops = (nq * s) as f64 * (t as f64 + (s as f64 + 1.0) / 2.0) * (4 * d) as f64;
        entry(format!("attn_dense {shape}"), &attn_dense, dense_flops);

        table.row(vec![
            t.to_string(),
            budget.to_string(),
            format!("{:.2}", sel_stats.mean_ms()),
            format!("{:.2}", attn_tiled.mean_ms()),
            format!("{:.2}", attn_seed.mean_ms()),
            format!("{:.2}x", attn_seed.mean_ns / attn_tiled.mean_ns),
            format!("{:.2}", attn_dense.mean_ms()),
            format!("{:.2}", attn_flops / attn_tiled.mean_ns),
        ]);
    }
    table.print();
    println!("speedup = seed scalar kernel / tiled kernel on the same QUOKA selection\n");

    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let doc = Json::obj(vec![
        ("bench", Json::str("micro_hotpath")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
        ("entries", Json::arr(entries)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attn_module_quoka_faster_than_dense_at_depth() {
        let cfg = ModelConfig::tiny();
        let dense = policy_by_name("dense").unwrap();
        let quoka = policy_by_name("quoka").unwrap();
        let td = attn_module_time(dense.as_ref(), usize::MAX, 2048, &cfg);
        let tq = attn_module_time(quoka.as_ref(), 128, 2048, &cfg);
        assert!(tq < td, "quoka {tq} !< dense {td}");
    }
}
