//! Decode-serving benchmark: the GEMM-batched decode path against the
//! serial per-sequence loop it replaced.
//!
//! Eight sequences prefill a shared-length prompt, then decode 64 steps
//! each. The *serial* arm drives them one at a time (`B = 1` batches —
//! exactly the pre-batching engine behaviour: every weight matrix streams
//! through the caches once per sequence per token, plus a logits GEMV per
//! token). The *batched* arm runs all eight through one
//! `forward_decode_batch` per step, so weights stream once per step and
//! the logits head is a single `[B, d_model] × [d_model, vocab]` GEMM with
//! fused argmax. Both arms produce bit-identical tokens (asserted); the
//! difference is pure memory-bandwidth amortization. Writes
//! `BENCH_decode.json` (override with `DECODE_OUT`) so the decode
//! trajectory is tracked PR over PR.

use super::banner;
use crate::model::{DecodeKv, DecodeSeq, HostModel, ModelConfig, SeqState, Weights};
use crate::obs::LatencyHist;
use crate::select::{policy_by_name, SelectCtx};
use crate::util::Json;

const N_SEQS: usize = 8;
const DECODE_STEPS: usize = 64;
const BUDGET: usize = 128;
const POLICY: &str = "quoka";

fn prompt(len: usize, vocab: usize, salt: u64) -> Vec<u32> {
    (0..len).map(|i| ((i as u64 * 131 + salt * 977) % (vocab as u64 - 1) + 1) as u32).collect()
}

/// Prefill `N_SEQS` private sequences and return their states plus each
/// sequence's first decode input token.
fn prefilled(
    model: &HostModel,
    prompt_len: usize,
    ctx: &mut SelectCtx,
) -> (Vec<SeqState>, Vec<u32>) {
    let cfg = model.cfg();
    let policy = policy_by_name(POLICY).unwrap();
    let mut states = Vec::with_capacity(N_SEQS);
    let mut last = Vec::with_capacity(N_SEQS);
    for i in 0..N_SEQS {
        let toks = prompt(prompt_len, cfg.vocab, i as u64);
        let mut st = SeqState::new(cfg);
        let mut h = Vec::new();
        for chunk in toks.chunks(256) {
            h = model.forward_chunk(&mut st, chunk, policy.as_ref(), BUDGET, ctx);
        }
        last.push(model.greedy_next(&h));
        states.push(st);
    }
    (states, last)
}

/// The decode-throughput benchmark (see module docs). Returns the
/// serial-vs-batched speedup.
pub fn decode_serving() -> f64 {
    banner(
        "decode_serving",
        "§Serving decode phase",
        "8 concurrent sequences × 64 decode steps: serial (B=1) vs one fused batch per step.",
    );
    let prompt_len = if super::full_mode() { 4096 } else { 512 };
    let cfg = ModelConfig::serve_small();
    let model = HostModel::new(Weights::generate(&cfg, 7));
    let policy = policy_by_name(POLICY).unwrap();

    // ---- serial arm: one B=1 forward per sequence per step ----
    let mut ctx = SelectCtx::new(0);
    let (mut states, mut last) = prefilled(&model, prompt_len, &mut ctx);
    let t0 = std::time::Instant::now();
    let mut serial_tokens: Vec<Vec<u32>> = vec![Vec::new(); N_SEQS];
    // In the serial schedule a sequence waits a full round (all N_SEQS
    // B=1 forwards) between its tokens — that round IS its ITL.
    let mut serial_itl = LatencyHist::new();
    for _ in 0..DECODE_STEPS {
        let tr = std::time::Instant::now();
        for (i, st) in states.iter_mut().enumerate() {
            ctx.begin_step();
            let mut one = [DecodeSeq {
                kv: DecodeKv::Private(st),
                token: last[i],
                policy: policy.as_ref(),
                budget: BUDGET,
            }];
            let next = model.forward_decode_batch(&mut one, None, &mut ctx);
            last[i] = next[0];
            serial_tokens[i].push(next[0]);
        }
        serial_itl.record(tr.elapsed());
    }
    let serial_s = t0.elapsed().as_secs_f64();

    // ---- batched arm: one fused forward for all sequences per step ----
    let mut ctx = SelectCtx::new(0);
    let (mut states, mut last) = prefilled(&model, prompt_len, &mut ctx);
    let t0 = std::time::Instant::now();
    let mut batched_tokens: Vec<Vec<u32>> = vec![Vec::new(); N_SEQS];
    // One fused forward per step emits a token for every sequence, so the
    // step duration is each sequence's ITL.
    let mut batched_itl = LatencyHist::new();
    for _ in 0..DECODE_STEPS {
        let tr = std::time::Instant::now();
        ctx.begin_step();
        let mut batch: Vec<DecodeSeq> = states
            .iter_mut()
            .zip(&last)
            .map(|(st, &tok)| DecodeSeq {
                kv: DecodeKv::Private(st),
                token: tok,
                policy: policy.as_ref(),
                budget: BUDGET,
            })
            .collect();
        let next = model.forward_decode_batch(&mut batch, None, &mut ctx);
        drop(batch);
        for (i, &tok) in next.iter().enumerate() {
            last[i] = tok;
            batched_tokens[i].push(tok);
        }
        batched_itl.record(tr.elapsed());
    }
    let batched_s = t0.elapsed().as_secs_f64();

    assert_eq!(
        serial_tokens, batched_tokens,
        "batched decode must generate exactly the serial tokens"
    );

    let total_tokens = (N_SEQS * DECODE_STEPS) as f64;
    let serial_tps = total_tokens / serial_s;
    let batched_tps = total_tokens / batched_s;
    let speedup = serial_s / batched_s;

    let mut table = crate::util::timing::Table::new(&[
        "decode path",
        "wall s",
        "tokens/s",
        "speedup",
    ]);
    table.row(vec![
        "serial (B=1 loop)".into(),
        format!("{serial_s:.3}"),
        format!("{serial_tps:.1}"),
        "1.00".into(),
    ]);
    table.row(vec![
        "batched (1 fused fwd/step)".into(),
        format!("{batched_s:.3}"),
        format!("{batched_tps:.1}"),
        format!("{speedup:.2}"),
    ]);
    table.print();
    println!(
        "expected shape: >= 2x at {N_SEQS} sequences — weights stream once per step \
         instead of once per sequence, logits collapse to one GEMM\n"
    );

    let out_path =
        std::env::var("DECODE_OUT").unwrap_or_else(|_| "BENCH_decode.json".to_string());
    let config = format!(
        "seqs={N_SEQS} decode_steps={DECODE_STEPS} prompt={prompt_len} policy={POLICY} \
         budget={BUDGET} preset={}",
        cfg.name
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("decode_serving")),
        ("config", Json::str(config)),
        ("serial-tok-s", Json::num(serial_tps)),
        ("batched-tok-s", Json::num(batched_tps)),
        ("speedup", Json::num(speedup)),
        ("serial-wall-s", Json::num(serial_s)),
        ("batched-wall-s", Json::num(batched_s)),
        // ITL distribution tails (schema-additive; check_bench.py ignores
        // unknown keys).
        ("serial-itl-p50-ms", Json::num(serial_itl.quantile_ms(0.50).unwrap_or(0.0))),
        ("serial-itl-p99-ms", Json::num(serial_itl.quantile_ms(0.99).unwrap_or(0.0))),
        ("batched-itl-p50-ms", Json::num(batched_itl.quantile_ms(0.50).unwrap_or(0.0))),
        ("batched-itl-p99-ms", Json::num(batched_itl.quantile_ms(0.99).unwrap_or(0.0))),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    speedup
}
