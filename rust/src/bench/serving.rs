//! Open-loop serving benchmark: Poisson arrivals over the real TCP
//! server, with streaming, cancellation, tenants, and a shared-prefix
//! request mixture — the closest thing in-tree to production traffic.
//!
//! Unlike the closed-loop benches (submit a batch, run to completion),
//! this harness spawns one client thread per request and releases each at
//! its exponentially-distributed arrival time, so load does not adapt to
//! server slowness — queueing delay shows up in the tail instead of
//! hiding in the offered rate. Every request streams (`"stream": true`);
//! half share a common preamble (exercising the radix prefix cache),
//! half of those opt into speculative decode with a repetition-friendly
//! suffix (PLD accepts) while the rest carry corpus babble (PLD starves),
//! requests rotate across three tenants (one weighted), and every eighth
//! request cancels itself after its first delta frame.
//!
//! Reports client-observed TTFT plus the server's own PR-7 latency
//! histograms (TTFT / ITL / queue wait, p50/p99), goodput, and cancel
//! latency, and writes `BENCH_serving.json` (override with `SERVING_OUT`)
//! for the CI gate in `scripts/check_bench.py`: the `ttft-p50-over-p99`
//! ratio is floored so the tail cannot silently detach from the median.
//!
//! `SERVING_REQS` / `SERVING_RPS` override the request count and offered
//! rate; `QUOKA_BENCH_FULL=1` selects the larger grid.

use super::{banner, full_mode};
use crate::coordinator::{Engine, EngineCfg, KvLayout, SchedCfg};
use crate::server::{serve_with_opts, Client, ServeOpts, WireFrame, WireRequest, WireSpec};
use crate::util::timing::Table;
use crate::util::{Json, Rng};
use crate::workload::corpus::Corpus;
use std::time::{Duration, Instant};

/// Admission backpressure threshold for the benched server. Far above the
/// smoke-grid queue depth — the path is configured and exercised by tests;
/// the bench measures queueing, not rejection.
const MAX_QUEUE: usize = 512;
/// Every N-th request cancels itself after its first delta frame.
const CANCEL_EVERY: usize = 8;
/// Tenants requests rotate through ("" is the default pool).
const TENANTS: [&str; 3] = ["", "acme", "bravo"];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One exponential inter-arrival sample (Poisson process at `rps`).
fn exp_interval_s(rng: &mut Rng, rps: f64) -> f64 {
    let u = (1.0 - rng.f32() as f64).max(1e-9);
    -u.ln() / rps
}

/// Everything decided about a request before the clock starts.
struct ReqPlan {
    arrival_s: f64,
    wire: WireRequest,
    cancel: bool,
}

/// What one client thread observed.
struct Outcome {
    /// Final response frame; `None` for backpressured / errored requests.
    done: Option<crate::server::WireResponse>,
    /// Client-side delta concatenation (must equal `done.text`).
    assembled: String,
    ttft_ms: f64,
    /// Gaps between successive delta frames.
    itl_ms: Vec<f64>,
    /// Cancel-send → final-frame latency (designated cancels only).
    cancel_ms: Option<f64>,
    designated_cancel: bool,
    backpressured: bool,
    error: Option<String>,
}

fn build_plans(n_reqs: usize, rps: f64) -> Vec<ReqPlan> {
    let mut rng = Rng::new(0x5E21);
    let mut corpus = Corpus::new(0xBEEF);
    let preamble = corpus.text(480);
    let mut t = 0.0f64;
    (0..n_reqs)
        .map(|i| {
            t += exp_interval_s(&mut rng, rps);
            let cancel = i % CANCEL_EVERY == CANCEL_EVERY - 1;
            let shared = i % 2 == 0;
            let spec_friendly = i % 4 < 2;
            let body = if spec_friendly {
                "the quick brown fox jumps over the lazy dog. ".repeat(5)
            } else {
                corpus.text(160 + rng.below(160))
            };
            let prompt = if shared {
                format!("{preamble}{body} [req {i}]")
            } else {
                format!("{body} [req {i}]")
            };
            let tenant = TENANTS[i % TENANTS.len()];
            let wire = WireRequest {
                prompt,
                // Cancelled requests get slack so the cancel lands while
                // they are still decoding.
                max_new: if cancel { 48 } else { 8 },
                policy: "quoka".into(),
                budget: 256,
                spec: if spec_friendly {
                    Some(WireSpec { policy: "pld".into(), gamma: Some(4) })
                } else {
                    None
                },
                tenant: tenant.into(),
                tenant_weight: if tenant == "acme" { 2 } else { 1 },
                stream: true,
            };
            ReqPlan { arrival_s: t, wire, cancel }
        })
        .collect()
}

/// Drive one request through the server, open-loop: sleep to the arrival
/// time, stream, optionally cancel after the first delta frame.
fn run_one(addr: std::net::SocketAddr, plan: ReqPlan, t0: Instant) -> Outcome {
    let mut out = Outcome {
        done: None,
        assembled: String::new(),
        ttft_ms: 0.0,
        itl_ms: Vec::new(),
        cancel_ms: None,
        designated_cancel: plan.cancel,
        backpressured: false,
        error: None,
    };
    let target = Duration::from_secs_f64(plan.arrival_s);
    let elapsed = t0.elapsed();
    if target > elapsed {
        std::thread::sleep(target - elapsed);
    }
    let mut c = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            out.error = Some(format!("connect: {e}"));
            return out;
        }
    };
    let sent_at = Instant::now();
    if let Err(e) = c.send(&plan.wire) {
        out.error = Some(format!("send: {e}"));
        return out;
    }
    let mut last_frame: Option<Instant> = None;
    let mut cancel_sent: Option<Instant> = None;
    loop {
        match c.read_frame() {
            Ok(WireFrame::Token { id, delta, .. }) => {
                let now = Instant::now();
                match last_frame {
                    Some(prev) => out.itl_ms.push((now - prev).as_secs_f64() * 1e3),
                    None => out.ttft_ms = (now - sent_at).as_secs_f64() * 1e3,
                }
                last_frame = Some(now);
                out.assembled.push_str(&delta);
                if plan.cancel && cancel_sent.is_none() {
                    let _ = c.cancel(id);
                    cancel_sent = Some(Instant::now());
                }
            }
            Ok(WireFrame::Done(resp)) => {
                let now = Instant::now();
                if last_frame.is_none() {
                    out.ttft_ms = (now - sent_at).as_secs_f64() * 1e3;
                }
                out.cancel_ms = cancel_sent.map(|cs| (now - cs).as_secs_f64() * 1e3);
                out.done = Some(resp);
                return out;
            }
            Err(e) => {
                let msg = e.to_string();
                out.backpressured = msg.contains("server saturated");
                out.error = Some(msg);
                return out;
            }
        }
    }
}

fn pctl(xs: &mut [f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((xs.len() as f64 - 1.0) * q).round() as usize;
    xs[idx]
}

/// The open-loop serving benchmark (see module docs).
pub fn serving_load() -> Table {
    banner(
        "serving_load",
        "serving §open-loop load",
        "Poisson arrivals over the real TCP server: streaming, cancels, tenants, shared prefixes.",
    );
    let (def_reqs, def_rps) = if full_mode() { (256, 60.0) } else { (96, 40.0) };
    let n_reqs = env_usize("SERVING_REQS", def_reqs);
    let rps = env_f64("SERVING_RPS", def_rps);

    let handle = serve_with_opts(
        || {
            Engine::new_host(
                "tiny",
                EngineCfg {
                    sched: SchedCfg {
                        b_cp: 64,
                        step_tokens: 256,
                        max_running: 8,
                        ..SchedCfg::default()
                    },
                    pool_blocks: 1024,
                    block_tokens: 32,
                    seed: 7,
                    kv: KvLayout::Paged { prefix_cache: true },
                    ..EngineCfg::default()
                },
            )
        },
        "127.0.0.1:0",
        ServeOpts { max_queue: MAX_QUEUE, ..ServeOpts::default() },
    )
    .expect("serving_load server");
    let addr = handle.addr;

    let plans = build_plans(n_reqs, rps);
    let t0 = Instant::now();
    let threads: Vec<_> = plans
        .into_iter()
        .map(|p| std::thread::spawn(move || run_one(addr, p, t0)))
        .collect();
    let outcomes: Vec<Outcome> = threads.into_iter().map(|h| h.join().unwrap()).collect();
    let wall_s = t0.elapsed().as_secs_f64();

    // Every request must end in a terminal state the harness understands.
    for o in &outcomes {
        if o.done.is_none() && !o.backpressured {
            panic!("request died without a terminal frame: {:?}", o.error);
        }
        if let Some(d) = &o.done {
            assert_eq!(
                o.assembled, d.text,
                "delta concatenation must equal the done frame's text (id {})",
                d.id
            );
        }
    }
    let n_ok = outcomes.iter().filter(|o| o.done.as_ref().is_some_and(|d| !d.cancelled)).count();
    let n_cancelled =
        outcomes.iter().filter(|o| o.done.as_ref().is_some_and(|d| d.cancelled)).count();
    let n_bp = outcomes.iter().filter(|o| o.backpressured).count();
    let n_designated = outcomes.iter().filter(|o| o.designated_cancel).count();
    assert!(n_cancelled >= 1, "at least one mid-stream cancel must land");
    assert!(n_cancelled <= n_designated, "only designated requests may cancel");
    assert!(
        n_ok * 3 >= n_reqs * 2,
        "at least two thirds of the offered load must complete (got {n_ok}/{n_reqs})"
    );

    // Server-side view: counts must reconcile with the client's, and the
    // PR-7 histograms supply the latency distribution.
    let mut sc = Client::connect(addr).expect("stats client");
    let stats = sc.stats().expect("stats reply");
    let body = stats.get("stats").expect("stats body").clone();
    drop(sc);
    handle.shutdown();
    let hist = |h: &str, q: &str| {
        body.get(h).and_then(|o| o.get(q)).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    let count = |k: &str| body.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
    assert_eq!(count("requests_finished"), n_ok, "server finished-count reconciles");
    assert_eq!(count("requests_cancelled"), n_cancelled, "server cancel-count reconciles");

    let mut ttft_c: Vec<f64> =
        outcomes.iter().filter(|o| o.done.is_some()).map(|o| o.ttft_ms).collect();
    let mut itl_c: Vec<f64> = outcomes.iter().flat_map(|o| o.itl_ms.iter().copied()).collect();
    let mut cancel_lat: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.done.as_ref().is_some_and(|d| d.cancelled))
        .filter_map(|o| o.cancel_ms)
        .collect();
    let (ttft_p50, ttft_p99) = (hist("ttft", "p50_ms"), hist("ttft", "p99_ms"));
    let (itl_p50, itl_p99) = (hist("itl", "p50_ms"), hist("itl", "p99_ms"));
    let (qw_p50, qw_p99) = (hist("queue_wait", "p50_ms"), hist("queue_wait", "p99_ms"));
    let goodput = n_ok as f64 / wall_s;
    // CI gate: median-to-tail ratio (1.0 = perfectly flat distribution;
    // the floor in check_bench.py keeps p99 within a bounded multiple of
    // p50). Degenerate empty histograms read as perfectly flat.
    let tail_ratio = if ttft_p99 > 0.0 { ttft_p50 / ttft_p99 } else { 1.0 };

    let mut table = Table::new(&["metric", "value"]);
    table.row(vec!["requests (ok/cancel/bp)".into(), format!("{n_ok}/{n_cancelled}/{n_bp}")]);
    table.row(vec!["offered rps".into(), format!("{rps:.0}")]);
    table.row(vec!["goodput rps".into(), format!("{goodput:.1}")]);
    table.row(vec![
        "client ttft p50/p99 ms".into(),
        format!("{:.1}/{:.1}", pctl(&mut ttft_c, 0.50), pctl(&mut ttft_c, 0.99)),
    ]);
    table.row(vec!["server ttft p50/p99 ms".into(), format!("{ttft_p50:.1}/{ttft_p99:.1}")]);
    table.row(vec!["server itl p50/p99 ms".into(), format!("{itl_p50:.2}/{itl_p99:.2}")]);
    table.row(vec!["queue wait p50/p99 ms".into(), format!("{qw_p50:.1}/{qw_p99:.1}")]);
    table.row(vec![
        "cancel latency p50 ms".into(),
        format!("{:.1}", pctl(&mut cancel_lat, 0.50)),
    ]);
    table.print();
    println!(
        "expected shape: goodput tracks the offered rate until max_running saturates; \
         queue wait absorbs the excess; cancels land within one engine step\n"
    );

    let out_path =
        std::env::var("SERVING_OUT").unwrap_or_else(|_| "BENCH_serving.json".to_string());
    let config = format!(
        "reqs={n_reqs} rps={rps} max_running=8 b_cp=64 step_tokens=256 block_tokens=32 \
         prefix_cache=true max_queue={MAX_QUEUE} cancel_every={CANCEL_EVERY} preset=tiny"
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("serving_load")),
        ("config", Json::str(config)),
        ("requests", Json::num(n_reqs as f64)),
        ("completed", Json::num(n_ok as f64)),
        ("cancelled", Json::num(n_cancelled as f64)),
        ("backpressured", Json::num(n_bp as f64)),
        ("rps-offered", Json::num(rps)),
        ("goodput-rps", Json::num(goodput)),
        ("ttft-client-p50-ms", Json::num(pctl(&mut ttft_c, 0.50))),
        ("ttft-client-p99-ms", Json::num(pctl(&mut ttft_c, 0.99))),
        ("ttft-p50-ms", Json::num(ttft_p50)),
        ("ttft-p99-ms", Json::num(ttft_p99)),
        ("itl-p50-ms", Json::num(itl_p50)),
        ("itl-p99-ms", Json::num(itl_p99)),
        ("itl-client-p50-ms", Json::num(pctl(&mut itl_c, 0.50))),
        ("itl-client-p99-ms", Json::num(pctl(&mut itl_c, 0.99))),
        ("queue-wait-p50-ms", Json::num(qw_p50)),
        ("queue-wait-p99-ms", Json::num(qw_p99)),
        ("cancel-latency-p50-ms", Json::num(pctl(&mut cancel_lat, 0.50))),
        ("ttft-p50-over-p99", Json::num(tail_ratio)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    table
}
