//! Dense-GEMM benchmark: the pool-backed packed kernel against the seed
//! scalar loop it replaced, plus the end-to-end effect on prefill.
//!
//! Three sections:
//! 1. **Prefill-shaped** `[b_cp, d_model] × [d_model, d_ff]` — the FFN
//!    gate/up product that dominates chunked prefill. Arms: the seed
//!    serial i-k-j kernel, the packed kernel on one participant, and the
//!    packed kernel on the full pool (row-block parallel).
//! 2. **Decode-shaped** `[B, d_model] × [d_model, d_ff]` — a batched
//!    decode step's FFN row; too few rows for row blocks, so the packed
//!    kernel parallelizes over column panels.
//! 3. **Forward-pass phase share** — a real chunked prefill with the
//!    worker count pinned to 1 and then to the pool width, reporting
//!    TTFT and the `gemm` phase share from the PR-7 phase timers (the
//!    serial residue this PR removes).
//!
//! The packed serial and packed parallel arms are asserted bit-identical
//! (the kernel's determinism contract); seed-vs-packed is asserted to
//! 1e-3 (same k-order fold, so they agree far tighter in practice).
//! Writes `BENCH_gemm.json` (override with `GEMM_OUT`); the CI gate
//! floors `parallel-speedup` at 2x when the runner has >= 4 cores.

use super::banner;
use crate::model::{HostModel, ModelConfig, SeqState, Weights};
use crate::obs::phase::{self, Phase};
use crate::select::{policy_by_name, SelectCtx};
use crate::tensor::matmul::{matmul_packed_with, PackedB};
use crate::util::threadpool::set_workers;
use crate::util::{Json, Rng};
use std::time::Instant;

const SEED_BLOCK_K: usize = 256;

/// Verbatim copy of the pre-PR-8 serial kernel (blocked i-k-j with the
/// per-element zero skip) — the packed-vs-seed reference arm.
fn seed_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    c.iter_mut().for_each(|v| *v = 0.0);
    for kb in (0..k).step_by(SEED_BLOCK_K) {
        let kend = (kb + SEED_BLOCK_K).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

fn wall<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warm caches and the pool
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64()
}

struct ShapeResult {
    parallel_speedup: f64,
    packed_speedup: f64,
    serial_gflops: f64,
    parallel_gflops: f64,
}

/// Run the three kernel arms for one `[m,k] × [k,n]` shape.
fn shape_arms(
    label: &str,
    m: usize,
    k: usize,
    n: usize,
    iters: usize,
    workers: usize,
    table: &mut crate::util::timing::Table,
) -> ShapeResult {
    let mut rng = Rng::new(0x6E44 ^ m as u64);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let packed = PackedB::pack(&b, k, n);
    let mut c_seed = vec![0.0f32; m * n];
    let mut c_ser = vec![0.0f32; m * n];
    let mut c_par = vec![0.0f32; m * n];

    let seed_s = wall(iters, || seed_matmul(&a, &b, m, k, n, &mut c_seed));
    let ser_s = wall(iters, || matmul_packed_with(&a, &packed, m, &mut c_ser, 1));
    let par_s = wall(iters, || matmul_packed_with(&a, &packed, m, &mut c_par, workers));

    assert_eq!(
        c_ser, c_par,
        "packed GEMM must be bit-identical serial vs {workers} participants ({label})"
    );
    for (x, y) in c_seed.iter().zip(&c_ser) {
        assert!((x - y).abs() < 1e-3, "packed kernel diverged from seed: {x} vs {y} ({label})");
    }

    let flops = (2 * m * k * n * iters) as f64;
    let gf = |s: f64| flops / s / 1e9;
    for (arm, s) in [("seed serial", seed_s), ("packed serial", ser_s), ("packed pool", par_s)] {
        table.row(vec![
            format!("{label} {arm}"),
            format!("{:.4}", s),
            format!("{:.2}", gf(s)),
            format!("{:.2}", seed_s / s),
        ]);
    }
    ShapeResult {
        parallel_speedup: ser_s / par_s,
        packed_speedup: seed_s / ser_s,
        serial_gflops: gf(ser_s),
        parallel_gflops: gf(par_s),
    }
}

fn prompt(len: usize, vocab: usize) -> Vec<u32> {
    (0..len).map(|i| ((i as u64 * 131 + 17) % (vocab as u64 - 1) + 1) as u32).collect()
}

/// One cold prefill of `toks` in `b_cp`-token chunks; returns
/// (wall seconds, gemm phase share of the accounted phase time).
fn prefill_once(model: &HostModel, toks: &[u32], b_cp: usize) -> (f64, f64) {
    let mut st = SeqState::new(model.cfg());
    let mut ctx = SelectCtx::new(0);
    let policy = policy_by_name("quoka").unwrap();
    let _ = phase::take();
    let t0 = Instant::now();
    for chunk in toks.chunks(b_cp) {
        let _ = model.forward_chunk(&mut st, chunk, policy.as_ref(), 128, &mut ctx);
    }
    let s = t0.elapsed().as_secs_f64();
    let ph = phase::take();
    let total: u64 = ph.iter().sum();
    let share = if total > 0 { ph[Phase::Gemm as usize] as f64 / total as f64 } else { 0.0 };
    (s, share)
}

/// The dense-GEMM benchmark (see module docs). Returns the prefill-shaped
/// serial-vs-parallel speedup (the CI-gated headline).
pub fn gemm_serving() -> f64 {
    banner(
        "gemm_serving",
        "§System-level speedup: the dense substrate",
        "Packed pool-parallel GEMM vs the seed serial kernel, prefill- and decode-shaped, \
         plus the gemm phase share of a real chunked prefill.",
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // The bench owns the machine: use every core (sizes the shared pool
    // before its first fan-out).
    let workers = cores;
    set_workers(workers);

    let cfg = ModelConfig::serve_small();
    let (dm, dff) = (cfg.d_model, cfg.d_ff);
    let b_cp = 128;
    let batch = 8;
    let (pre_iters, dec_iters) = if super::full_mode() { (120, 1200) } else { (40, 400) };

    let mut table =
        crate::util::timing::Table::new(&["gemm arm", "wall s", "GFLOP/s", "speedup vs seed"]);
    let pre = shape_arms("prefill 128r", b_cp, dm, dff, pre_iters, workers, &mut table);
    let dec = shape_arms("decode 8r", batch, dm, dff, dec_iters, workers, &mut table);
    table.print();
    println!(
        "expected shape: packed >= 1x over seed serially (register tiling + panel reuse), \
         and ~{workers}x-bounded parallel scaling; serial == parallel bitwise is asserted.\n"
    );

    // ---- forward-pass arm: gemm phase share before/after threading ----
    let prompt_len = if super::full_mode() { 4096 } else { 1024 };
    let model = HostModel::new(Weights::generate(&cfg, 7));
    let toks = prompt(prompt_len, cfg.vocab);
    set_workers(1);
    let (ttft_serial, share_serial) = prefill_once(&model, &toks, b_cp);
    set_workers(workers);
    let (ttft_par, share_par) = prefill_once(&model, &toks, b_cp);

    let mut fwd = crate::util::timing::Table::new(&["prefill arm", "TTFT s", "gemm share"]);
    fwd.row(vec![
        "workers=1".into(),
        format!("{ttft_serial:.3}"),
        format!("{:.1}%", share_serial * 100.0),
    ]);
    fwd.row(vec![
        format!("workers={workers}"),
        format!("{ttft_par:.3}"),
        format!("{:.1}%", share_par * 100.0),
    ]);
    fwd.print();
    println!(
        "gemm phase share should drop with workers — the projections/FFN were the last \
         serial residue of prefill (TTFT speedup here folds in the attention fan-out too).\n"
    );

    let out_path = std::env::var("GEMM_OUT").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    let config = format!(
        "preset={} b_cp={b_cp} batch={batch} d_model={dm} d_ff={dff} prompt={prompt_len} \
         workers={workers}",
        cfg.name
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("gemm_serving")),
        ("config", Json::str(config)),
        ("cores", Json::num(cores as f64)),
        ("workers", Json::num(workers as f64)),
        // The CI-gated headline: prefill-shaped packed serial vs pool.
        ("parallel-speedup", Json::num(pre.parallel_speedup)),
        ("packed-vs-seed-speedup", Json::num(pre.packed_speedup)),
        ("prefill-serial-gflops", Json::num(pre.serial_gflops)),
        ("prefill-parallel-gflops", Json::num(pre.parallel_gflops)),
        ("decode-parallel-speedup", Json::num(dec.parallel_speedup)),
        ("decode-packed-vs-seed-speedup", Json::num(dec.packed_speedup)),
        ("ttft-serial-s", Json::num(ttft_serial)),
        ("ttft-parallel-s", Json::num(ttft_par)),
        ("gemm-share-serial", Json::num(share_serial)),
        ("gemm-share-parallel", Json::num(share_par)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    pre.parallel_speedup
}
