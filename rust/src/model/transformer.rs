//! Host (pure-Rust) transformer forward pass.
//!
//! This is the reference implementation the PJRT artifact path is checked
//! against, and the `--backend host` execution engine (the paper's "works
//! on CPUs / standard linear algebra" portability story). It implements
//! chunked prefill per Eq. (2) with a pluggable [`SelectionPolicy`] applied
//! to the KV cache of every layer, plus single-token decode.

use super::attention::{
    batched_decode_attention, chunk_attention, paged_chunk_attention, AttnScratch, KvBuffers,
    SeqKv,
};
use super::config::ModelConfig;
use super::weights::{LayerWeights, PackedLayer, Weights};
use crate::kvpool::{KvDtype, KvPool};
use crate::obs::phase::{scoped, Phase};
use crate::select::{fit, QChunk, SelectCtx, Selection, SelectionPolicy};
use crate::tensor::matmul::{matmul_bt_argmax, matmul_packed};
use crate::tensor::ops::{rmsnorm, silu, RopeTable};

/// Per-sequence inference state: one KV buffer per layer + token count.
pub struct SeqState {
    pub caches: Vec<KvBuffers>,
    /// Tokens processed so far (== caches[l].t).
    pub pos: usize,
}

impl SeqState {
    pub fn new(cfg: &ModelConfig) -> SeqState {
        SeqState::new_with_dtype(cfg, KvDtype::F32)
    }

    /// [`SeqState::new`] with an explicit KV element type (the engine
    /// passes its `--kv-dtype` here; int8 states store quantized pages).
    pub fn new_with_dtype(cfg: &ModelConfig, dtype: KvDtype) -> SeqState {
        SeqState {
            caches: (0..cfg.n_layers)
                .map(|_| KvBuffers::new_with_dtype(cfg.n_kv_heads, cfg.d_head, 256, dtype))
                .collect(),
            pos: 0,
        }
    }

    pub fn kv_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.resident_bytes()).sum()
    }

    /// Roll every layer's cache back to `new_pos` tokens (speculative
    /// rollback of rejected draft tokens).
    pub fn truncate(&mut self, new_pos: usize) {
        for c in &mut self.caches {
            c.truncate(new_pos);
        }
        self.pos = new_pos;
    }
}

/// Reusable forward-pass scratch (zero steady-state allocation).
#[derive(Default)]
struct FwdScratch {
    normed: Vec<f32>,
    q_proj: Vec<f32>,
    k_proj: Vec<f32>,
    v_proj: Vec<f32>,
    q_heads: Vec<f32>,
    k_heads: Vec<f32>,
    v_heads: Vec<f32>,
    attn_heads: Vec<f32>,
    attn_merged: Vec<f32>,
    attn_out: Vec<f32>,
    ffn_gate: Vec<f32>,
    ffn_up: Vec<f32>,
    ffn_out: Vec<f32>,
    attn: AttnScratch,
    /// One sequence's `[n_q, d_head]` query rows gathered out of the
    /// decode batch for its per-sequence selection call.
    q_seq: Vec<f32>,
    /// Final-norm row for the scratch-routed logits head.
    norm_row: Vec<f32>,
    /// Verify-path per-position gathers: one position's `[n_kv, d]` self
    /// K/V rows and its `[n_q, d]` attention output.
    k_pos: Vec<f32>,
    v_pos: Vec<f32>,
    attn_pos: Vec<f32>,
}

/// Absolute RoPE position of each row in a forward batch: a prefill chunk
/// is `Base(pos)` (row `i` sits at `pos + i`); a decode batch is `PerRow`
/// (row `i` is sequence `i`, at its own cursor).
enum RowPos<'a> {
    Base(usize),
    PerRow(&'a [usize]),
}

impl RowPos<'_> {
    #[inline]
    fn at(&self, i: usize) -> usize {
        match self {
            RowPos::Base(p) => p + i,
            RowPos::PerRow(v) => v[i],
        }
    }
}

/// One sequence's slot in a batched decode step (see
/// [`HostModel::forward_decode_batch`]).
pub struct DecodeSeq<'a> {
    /// Where this sequence's KV lives.
    pub kv: DecodeKv<'a>,
    /// The previously sampled token — this step's input.
    pub token: u32,
    pub policy: &'a dyn SelectionPolicy,
    /// Selection budget `B_SA`.
    pub budget: usize,
}

/// Physical KV location of one decode-batch sequence. One batch may mix
/// both variants (private sequences and pool-backed sequences decode
/// together).
pub enum DecodeKv<'a> {
    /// Private contiguous per-sequence state; its cursor and caches are
    /// advanced in place.
    Private(&'a mut SeqState),
    /// Shared-pool block table with `pos` tokens resident. The caller must
    /// have ensured page capacity and write exclusivity for position `pos`
    /// (lease layer + `KvPool::make_writable`) and advances its cursor by
    /// one afterwards.
    Paged { blocks: &'a [u32], pos: usize },
}

impl DecodeKv<'_> {
    /// Tokens already resident in this sequence's cache.
    #[inline]
    fn pos(&self) -> usize {
        match self {
            DecodeKv::Private(st) => st.pos,
            DecodeKv::Paged { pos, .. } => *pos,
        }
    }
}

/// The host model: weights + scratch + the precomputed RoPE frequency
/// table (one `theta^(-2i/d)` table per model instead of per token).
pub struct HostModel {
    pub w: Weights,
    /// Per-layer projection matrices in the packed-GEMM panel layout,
    /// built once here so the hot path never pays the pack.
    packed: Vec<PackedLayer>,
    rope: RopeTable,
    scratch: std::cell::RefCell<FwdScratch>,
}

impl HostModel {
    pub fn new(w: Weights) -> HostModel {
        let rope = RopeTable::new(w.cfg.d_head, w.cfg.rope_theta);
        let packed = w.layers.iter().map(|l| l.pack()).collect();
        HostModel { w, packed, rope, scratch: Default::default() }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.w.cfg
    }

    /// Embedding gather for one chunk.
    fn embed(&self, tokens: &[u32], s: usize) -> Vec<f32> {
        let cfg = &self.w.cfg;
        let dm = cfg.d_model;
        let mut hidden = vec![0.0f32; s * dm];
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize % cfg.vocab;
            hidden[i * dm..(i + 1) * dm].copy_from_slice(self.w.embedding.row(tok));
        }
        hidden
    }

    /// Pre-attention RMSNorm + QKV projection + `[s, H*dh] → [H, s, dh]`
    /// head split with RoPE at per-row absolute positions (a chunk's
    /// `pos..pos+s`, or one cursor per sequence for a decode batch).
    /// Leaves the batch's `[H, s, dh]` Q/K/V in `sc.{q,k,v}_heads`.
    fn layer_attn_inputs(
        &self,
        lw: &LayerWeights,
        pl: &PackedLayer,
        hidden: &[f32],
        s: usize,
        pos: RowPos,
        sc: &mut FwdScratch,
    ) {
        let _t = scoped(Phase::Gemm);
        let cfg = &self.w.cfg;
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        let (nq, nkv) = (cfg.n_q_heads, cfg.n_kv_heads);
        let (dq, dkv) = (nq * dh, nkv * dh);
        let normed = fit(&mut sc.normed, s * dm);
        for i in 0..s {
            rmsnorm(
                &hidden[i * dm..(i + 1) * dm],
                lw.attn_norm.data(),
                cfg.norm_eps,
                &mut normed[i * dm..(i + 1) * dm],
            );
        }
        let q_proj = fit(&mut sc.q_proj, s * dq);
        matmul_packed(normed, &pl.wq, s, q_proj);
        let k_proj = fit(&mut sc.k_proj, s * dkv);
        matmul_packed(normed, &pl.wk, s, k_proj);
        let v_proj = fit(&mut sc.v_proj, s * dkv);
        matmul_packed(normed, &pl.wv, s, v_proj);

        let q_heads = fit(&mut sc.q_heads, nq * s * dh);
        for h in 0..nq {
            for i in 0..s {
                let src = i * dq + h * dh;
                let dst = (h * s + i) * dh;
                q_heads[dst..dst + dh].copy_from_slice(&q_proj[src..src + dh]);
                if cfg.use_rope {
                    self.rope.apply(&mut q_heads[dst..dst + dh], pos.at(i));
                }
            }
        }
        let k_heads = fit(&mut sc.k_heads, nkv * s * dh);
        let v_heads = fit(&mut sc.v_heads, nkv * s * dh);
        for h in 0..nkv {
            for i in 0..s {
                let src = i * dkv + h * dh;
                let dst = (h * s + i) * dh;
                k_heads[dst..dst + dh].copy_from_slice(&k_proj[src..src + dh]);
                if cfg.use_rope {
                    self.rope.apply(&mut k_heads[dst..dst + dh], pos.at(i));
                }
                v_heads[dst..dst + dh].copy_from_slice(&v_proj[src..src + dh]);
            }
        }
    }

    /// `[H, s, dh] → [s, H*dh]` merge of `sc.attn_heads`, output
    /// projection, residual add into `hidden`.
    fn layer_attn_output(
        &self,
        pl: &PackedLayer,
        s: usize,
        hidden: &mut [f32],
        sc: &mut FwdScratch,
    ) {
        let _t = scoped(Phase::Gemm);
        let cfg = &self.w.cfg;
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        let nq = cfg.n_q_heads;
        let dq = nq * dh;
        let attn_merged = fit(&mut sc.attn_merged, s * dq);
        for h in 0..nq {
            for i in 0..s {
                let src = (h * s + i) * dh;
                let dst = i * dq + h * dh;
                attn_merged[dst..dst + dh].copy_from_slice(&sc.attn_heads[src..src + dh]);
            }
        }
        let attn_out = fit(&mut sc.attn_out, s * dm);
        matmul_packed(attn_merged, &pl.wo, s, attn_out);
        for (hv, ov) in hidden.iter_mut().zip(attn_out.iter()) {
            *hv += ov;
        }
    }

    /// FFN block (SwiGLU; optional top-1 MoE) with residual add.
    fn layer_ffn(
        &self,
        lw: &LayerWeights,
        pl: &PackedLayer,
        s: usize,
        hidden: &mut [f32],
        sc: &mut FwdScratch,
    ) {
        let _t = scoped(Phase::Gemm);
        let cfg = &self.w.cfg;
        let dm = cfg.d_model;
        let normed = fit(&mut sc.normed, s * dm);
        for i in 0..s {
            rmsnorm(
                &hidden[i * dm..(i + 1) * dm],
                lw.ffn_norm.data(),
                cfg.norm_eps,
                &mut normed[i * dm..(i + 1) * dm],
            );
        }
        let d_ff = cfg.d_ff;
        let ffn_out = fit(&mut sc.ffn_out, s * dm);
        if cfg.n_experts == 0 {
            let gate = fit(&mut sc.ffn_gate, s * d_ff);
            matmul_packed(normed, &pl.w_gate, s, gate);
            let up = fit(&mut sc.ffn_up, s * d_ff);
            matmul_packed(normed, &pl.w_up, s, up);
            for (gv, uv) in gate.iter_mut().zip(up.iter()) {
                *gv = silu(*gv) * uv;
            }
            matmul_packed(gate, &pl.w_down, s, ffn_out);
        } else {
            // Top-1 routing per token.
            for i in 0..s {
                let x = &normed[i * dm..(i + 1) * dm];
                let mut best = (0usize, f32::NEG_INFINITY);
                for e in 0..cfg.n_experts {
                    let mut score = 0.0;
                    for j in 0..dm {
                        score += x[j] * lw.router.data()[j * cfg.n_experts + e];
                    }
                    if score > best.1 {
                        best = (e, score);
                    }
                }
                let (wg, wu, wd) = if best.0 == 0 {
                    (&pl.w_gate, &pl.w_up, &pl.w_down)
                } else {
                    let ex = &pl.experts[best.0 - 1];
                    (&ex.0, &ex.1, &ex.2)
                };
                let gate = fit(&mut sc.ffn_gate, d_ff);
                matmul_packed(x, wg, 1, gate);
                let up = fit(&mut sc.ffn_up, d_ff);
                matmul_packed(x, wu, 1, up);
                for (gv, uv) in gate.iter_mut().zip(up.iter()) {
                    *gv = silu(*gv) * uv;
                }
                matmul_packed(gate, wd, 1, &mut ffn_out[i * dm..(i + 1) * dm]);
            }
        }
        for (hv, fv) in hidden.iter_mut().zip(ffn_out.iter()) {
            *hv += fv;
        }
    }

    /// Process one prefill chunk (or one decode token when `tokens.len()==1`
    /// after prefill). Applies `policy` to every layer's past cache,
    /// appends the chunk's KV, and returns the final hidden states
    /// `[s, d_model]`.
    pub fn forward_chunk(
        &self,
        state: &mut SeqState,
        tokens: &[u32],
        policy: &dyn SelectionPolicy,
        budget: usize,
        ctx: &mut SelectCtx,
    ) -> Vec<f32> {
        let cfg = &self.w.cfg;
        let (s, dh) = (tokens.len(), cfg.d_head);
        let (nq, nkv) = (cfg.n_q_heads, cfg.n_kv_heads);
        assert!(s > 0);

        let mut hidden = self.embed(tokens, s);
        let mut sc_guard = self.scratch.borrow_mut();
        let sc = &mut *sc_guard; // reborrow: allow disjoint field borrows
        ctx.n_layers = cfg.n_layers;
        for (l, lw) in self.w.layers.iter().enumerate() {
            ctx.layer = l;
            self.layer_attn_inputs(lw, &self.packed[l], &hidden, s, RowPos::Base(state.pos), sc);

            // ---- selection over the past cache + attention ----
            let cache = &state.caches[l];
            let sel = if cache.t == 0 || policy.is_dense() {
                Selection::All
            } else {
                let _t = scoped(Phase::Scan);
                let qv = QChunk::new(&sc.q_heads[..nq * s * dh], nq, s, dh);
                policy.select(&qv, &cache.k_view(), budget, ctx)
            };
            ctx.cost.bump_calls();
            chunk_attention(
                &sc.q_heads[..nq * s * dh],
                nq,
                s,
                dh,
                &sc.k_heads[..nkv * s * dh],
                &sc.v_heads[..nkv * s * dh],
                cache,
                &sel,
                &mut sc.attn,
                fit(&mut sc.attn_heads, nq * s * dh),
            );
            self.layer_attn_output(&self.packed[l], s, &mut hidden, sc);

            // Append the chunk's KV to the cache (full retention).
            {
                let _t = scoped(Phase::Append);
                state.caches[l].append(
                    &sc.k_heads[..nkv * s * dh],
                    &sc.v_heads[..nkv * s * dh],
                    s,
                );
            }

            self.layer_ffn(lw, &self.packed[l], s, &mut hidden, sc);
        }
        state.pos += s;
        hidden
    }

    /// [`HostModel::forward_chunk`] over the **shared paged KV pool**: the
    /// sequence's KV lives in `pool` pages addressed by its block table
    /// `blocks`, with `pos` tokens already resident — radix-cached prefix
    /// pages included, which is how a prefix hit skips prefill compute
    /// entirely. Appends the chunk's KV into the pages covering
    /// `pos..pos+s` (the caller must have ensured capacity via the lease
    /// layer and exclusivity via `KvPool::make_writable`) and returns the
    /// final hidden states `[s, d_model]`. The caller advances its token
    /// cursor by `s` afterwards.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_chunk_paged(
        &self,
        pool: &mut KvPool,
        blocks: &[u32],
        pos: usize,
        tokens: &[u32],
        policy: &dyn SelectionPolicy,
        budget: usize,
        ctx: &mut SelectCtx,
    ) -> Vec<f32> {
        let cfg = &self.w.cfg;
        let (s, dh) = (tokens.len(), cfg.d_head);
        let (nq, nkv) = (cfg.n_q_heads, cfg.n_kv_heads);
        assert!(s > 0);
        assert!(
            blocks.len() * pool.cfg.block_tokens >= pos + s,
            "block table too short for chunk"
        );

        let mut hidden = self.embed(tokens, s);
        let mut sc_guard = self.scratch.borrow_mut();
        let sc = &mut *sc_guard;
        ctx.n_layers = cfg.n_layers;
        for (l, lw) in self.w.layers.iter().enumerate() {
            ctx.layer = l;
            self.layer_attn_inputs(lw, &self.packed[l], &hidden, s, RowPos::Base(pos), sc);

            // ---- selection (block-table-aware KCache) + paged attention ----
            let sel = if pos == 0 || policy.is_dense() {
                Selection::All
            } else {
                let _t = scoped(Phase::Scan);
                let qv = QChunk::new(&sc.q_heads[..nq * s * dh], nq, s, dh);
                let kc = pool.k_cache(blocks, pos, l);
                policy.select(&qv, &kc, budget, ctx)
            };
            ctx.cost.bump_calls();
            {
                let paged = pool.kv_view(blocks, pos, l);
                paged_chunk_attention(
                    &sc.q_heads[..nq * s * dh],
                    nq,
                    s,
                    dh,
                    &sc.k_heads[..nkv * s * dh],
                    &sc.v_heads[..nkv * s * dh],
                    &paged,
                    &sel,
                    &mut sc.attn,
                    fit(&mut sc.attn_heads, nq * s * dh),
                );
            }
            self.layer_attn_output(&self.packed[l], s, &mut hidden, sc);

            {
                let _t = scoped(Phase::Append);
                pool.append_chunk(
                    blocks,
                    l,
                    pos,
                    &sc.k_heads[..nkv * s * dh],
                    &sc.v_heads[..nkv * s * dh],
                    s,
                );
            }

            self.layer_ffn(lw, &self.packed[l], s, &mut hidden, sc);
        }
        hidden
    }

    /// One decode step for a whole batch of sequences — the engine's
    /// serving hot path. Every weight matrix streams through the caches
    /// **once per step** instead of once per sequence: the per-layer
    /// projections and the FFN run as `[B, d] × [d, ·]` GEMMs over all `B`
    /// rows, attention fans out over `(sequence × kv-head)` tasks (each
    /// sequence attends only to its own KV — private buffers or pool block
    /// tables, freely mixed), and the logits head is a single
    /// `[B, d_model] × [d_model, vocab]` GEMM with a fused row-argmax that
    /// never materializes the logits. Returns the greedy next token per
    /// sequence, in batch order.
    ///
    /// Per-sequence numerics are identical to driving [`forward_chunk`]
    /// (s = 1) / [`forward_chunk_paged`] sequence by sequence, so greedy
    /// generations are exactly independent of the batch composition
    /// (pinned in `rust/tests/decode_batch.rs`). Stateful policy context
    /// is per sequence: each slot's cross-layer shared indices are swapped
    /// into `ctx` around its selection call. `pool` must be `Some` iff the
    /// batch contains `DecodeKv::Paged` sequences.
    ///
    /// [`forward_chunk`]: HostModel::forward_chunk
    /// [`forward_chunk_paged`]: HostModel::forward_chunk_paged
    pub fn forward_decode_batch(
        &self,
        seqs: &mut [DecodeSeq],
        mut pool: Option<&mut KvPool>,
        ctx: &mut SelectCtx,
    ) -> Vec<u32> {
        let cfg = &self.w.cfg;
        let b = seqs.len();
        assert!(b > 0);
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        let (nq, nkv) = (cfg.n_q_heads, cfg.n_kv_heads);

        let tokens: Vec<u32> = seqs.iter().map(|s| s.token).collect();
        let positions: Vec<usize> = seqs.iter().map(|s| s.kv.pos()).collect();
        let mut hidden = self.embed(&tokens, b);
        let mut sc_guard = self.scratch.borrow_mut();
        let sc = &mut *sc_guard; // reborrow: allow disjoint field borrows
        // Per-sequence cross-layer policy state (e.g. LessIsMore's shared
        // indices): one slot per sequence, swapped into ctx around its
        // select call so batch-mates never observe each other's state.
        let mut seq_shared: Vec<Option<Vec<Vec<u32>>>> = (0..b).map(|_| None).collect();
        ctx.n_layers = cfg.n_layers;
        for (l, lw) in self.w.layers.iter().enumerate() {
            ctx.layer = l;
            let pl = &self.packed[l];
            self.layer_attn_inputs(lw, pl, &hidden, b, RowPos::PerRow(&positions), sc);

            // ---- per-sequence selection over each private/paged past ----
            let mut sels: Vec<Selection> = Vec::with_capacity(b);
            for (bi, seq) in seqs.iter().enumerate() {
                let t = positions[bi];
                let sel = if t == 0 || seq.policy.is_dense() {
                    Selection::All
                } else {
                    // Gather this sequence's [n_q, dh] query rows out of
                    // the [n_q, B, dh] batch for the selection call.
                    let FwdScratch { q_seq, q_heads, .. } = &mut *sc;
                    let q_seq = fit(q_seq, nq * dh);
                    for h in 0..nq {
                        let src = (h * b + bi) * dh;
                        q_seq[h * dh..(h + 1) * dh].copy_from_slice(&q_heads[src..src + dh]);
                    }
                    let qv = QChunk::new(&q_seq[..nq * dh], nq, 1, dh);
                    let _t = scoped(Phase::Scan);
                    std::mem::swap(&mut ctx.shared_indices, &mut seq_shared[bi]);
                    let sel = match &seq.kv {
                        DecodeKv::Private(st) => {
                            seq.policy.select(&qv, &st.caches[l].k_view(), seq.budget, ctx)
                        }
                        DecodeKv::Paged { blocks, pos } => {
                            let p = pool.as_deref().expect("paged decode without a pool");
                            seq.policy.select(&qv, &p.k_cache(blocks, *pos, l), seq.budget, ctx)
                        }
                    };
                    std::mem::swap(&mut ctx.shared_indices, &mut seq_shared[bi]);
                    sel
                };
                ctx.cost.bump_calls();
                sels.push(sel);
            }

            // ---- one batched attention fan-out over (seq × kv-head) ----
            {
                let pool_ref = pool.as_deref();
                let seq_attn: Vec<(SeqKv, &Selection)> = seqs
                    .iter()
                    .zip(&sels)
                    .map(|(seq, sel)| {
                        let kv = match &seq.kv {
                            DecodeKv::Private(st) => SeqKv::Contig(&st.caches[l]),
                            DecodeKv::Paged { blocks, pos } => SeqKv::Paged(
                                pool_ref
                                    .expect("paged decode without a pool")
                                    .kv_view(blocks, *pos, l),
                            ),
                        };
                        (kv, sel)
                    })
                    .collect();
                batched_decode_attention(
                    &sc.q_heads[..nq * b * dh],
                    nq,
                    b,
                    dh,
                    &sc.k_heads[..nkv * b * dh],
                    &sc.v_heads[..nkv * b * dh],
                    &seq_attn,
                    &mut sc.attn,
                    fit(&mut sc.attn_heads, nq * b * dh),
                );
            }
            self.layer_attn_output(&self.packed[l], b, &mut hidden, sc);

            // ---- append each sequence's token KV straight from the batch
            // layout (no contiguous staging copy) ----
            {
                let _t = scoped(Phase::Append);
                for (bi, seq) in seqs.iter_mut().enumerate() {
                    match &mut seq.kv {
                        DecodeKv::Private(st) => st.caches[l].append_token_strided(
                            &sc.k_heads[..nkv * b * dh],
                            &sc.v_heads[..nkv * b * dh],
                            bi,
                            b,
                        ),
                        DecodeKv::Paged { blocks, pos } => pool
                            .as_deref_mut()
                            .expect("paged decode without a pool")
                            .append_token_strided(
                                blocks,
                                l,
                                *pos,
                                &sc.k_heads[..nkv * b * dh],
                                &sc.v_heads[..nkv * b * dh],
                                bi,
                                b,
                            ),
                    }
                }
            }

            self.layer_ffn(lw, &self.packed[l], b, &mut hidden, sc);
        }
        for seq in seqs.iter_mut() {
            if let DecodeKv::Private(st) = &mut seq.kv {
                st.pos += 1;
            }
        }

        // ---- fused logits head: final-norm all rows, one [B, dm] ×
        // embeddingᵀ GEMM reduced straight to per-row argmax ----
        let _t = scoped(Phase::Gemm);
        let normed = fit(&mut sc.normed, b * dm);
        for i in 0..b {
            rmsnorm(
                &hidden[i * dm..(i + 1) * dm],
                self.w.final_norm.data(),
                cfg.norm_eps,
                &mut normed[i * dm..(i + 1) * dm],
            );
        }
        let mut next = vec![0u32; b];
        matmul_bt_argmax(normed, self.w.embedding.data(), b, dm, cfg.vocab, &mut next);
        next
    }

    /// Score a speculative draft: run `tokens` — the pending decode token
    /// followed by the drafted continuation — as one tiny causal chunk and
    /// return the model's **greedy target at every position** (the token
    /// it would emit after seeing `tokens[..=i]`), computed by one fused
    /// `[s, d_model] × [d_model, vocab]` GEMM with per-row argmax.
    ///
    /// The projections, FFN and logits head run as `[s, ·]` GEMMs over all
    /// positions at once — the weight stream is paid **once per verify
    /// step** instead of once per token, which is the entire speedup of
    /// speculative decoding on this backend. Attention and selection run
    /// **per position, in serial order** over the growing cache: position
    /// `i` selects with its own single query over exactly the
    /// `pos + i`-token cache a serial decode would have seen (earlier
    /// draft positions' KV included — appended one position at a time
    /// through the same strided-append path the batched decode uses), and
    /// attends through the same `s = 1` tile pipeline. Every position's
    /// hidden state — hence every greedy target — is therefore
    /// bit-identical to a non-speculative decode of the same tokens,
    /// under every selection policy and both KV layouts. That exactness
    /// is what makes greedy acceptance lossless rather than approximate;
    /// it is pinned engine-wide in `rust/tests/spec_decode.rs`.
    ///
    /// All `s` tokens' KV is appended (the caller must have ensured
    /// capacity and — for paged sequences — COW exclusivity over
    /// positions `pos..pos + s`); the caller rolls back the rejected tail
    /// via [`SeqState::truncate`] / `KvPool::truncate_seq` after
    /// acceptance. Cross-layer policy state is kept per position, exactly
    /// as the batched decode keeps it per sequence.
    pub fn forward_verify(
        &self,
        kv: &mut DecodeKv,
        tokens: &[u32],
        policy: &dyn SelectionPolicy,
        budget: usize,
        mut pool: Option<&mut KvPool>,
        ctx: &mut SelectCtx,
    ) -> Vec<u32> {
        let cfg = &self.w.cfg;
        let s = tokens.len();
        assert!(s > 0);
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        let (nq, nkv) = (cfg.n_q_heads, cfg.n_kv_heads);
        let pos0 = kv.pos();

        let mut hidden = self.embed(tokens, s);
        let mut sc_guard = self.scratch.borrow_mut();
        let sc = &mut *sc_guard; // reborrow: allow disjoint field borrows
        // Per-position cross-layer policy state (mirrors the batched
        // decode's per-sequence slots): each draft position is its own
        // virtual decode step for stateful policies.
        let mut pos_shared: Vec<Option<Vec<Vec<u32>>>> = (0..s).map(|_| None).collect();
        ctx.n_layers = cfg.n_layers;
        for (l, lw) in self.w.layers.iter().enumerate() {
            ctx.layer = l;
            self.layer_attn_inputs(lw, &self.packed[l], &hidden, s, RowPos::Base(pos0), sc);

            // ---- serial per-position select → attend → append ----
            for i in 0..s {
                let t = pos0 + i;
                {
                    let FwdScratch { q_seq, k_pos, v_pos, q_heads, k_heads, v_heads, .. } =
                        &mut *sc;
                    let q_seq = fit(q_seq, nq * dh);
                    for h in 0..nq {
                        let src = (h * s + i) * dh;
                        q_seq[h * dh..(h + 1) * dh].copy_from_slice(&q_heads[src..src + dh]);
                    }
                    let k_pos = fit(k_pos, nkv * dh);
                    let v_pos = fit(v_pos, nkv * dh);
                    for h in 0..nkv {
                        let src = (h * s + i) * dh;
                        k_pos[h * dh..(h + 1) * dh].copy_from_slice(&k_heads[src..src + dh]);
                        v_pos[h * dh..(h + 1) * dh].copy_from_slice(&v_heads[src..src + dh]);
                    }
                }
                let sel = if t == 0 || policy.is_dense() {
                    Selection::All
                } else {
                    let qv = QChunk::new(&sc.q_seq[..nq * dh], nq, 1, dh);
                    let _t = scoped(Phase::Scan);
                    std::mem::swap(&mut ctx.shared_indices, &mut pos_shared[i]);
                    let sel = match kv {
                        DecodeKv::Private(st) => {
                            policy.select(&qv, &st.caches[l].k_view(), budget, ctx)
                        }
                        DecodeKv::Paged { blocks, .. } => {
                            let p = pool.as_deref().expect("paged verify without a pool");
                            policy.select(&qv, &p.k_cache(blocks, t, l), budget, ctx)
                        }
                    };
                    std::mem::swap(&mut ctx.shared_indices, &mut pos_shared[i]);
                    sel
                };
                ctx.cost.bump_calls();

                {
                    let FwdScratch { q_seq, k_pos, v_pos, attn_pos, attn, attn_heads, .. } =
                        &mut *sc;
                    let out = fit(attn_pos, nq * dh);
                    match kv {
                        DecodeKv::Private(st) => chunk_attention(
                            &q_seq[..nq * dh],
                            nq,
                            1,
                            dh,
                            &k_pos[..nkv * dh],
                            &v_pos[..nkv * dh],
                            &st.caches[l],
                            &sel,
                            attn,
                            out,
                        ),
                        DecodeKv::Paged { blocks, .. } => {
                            let p = pool.as_deref().expect("paged verify without a pool");
                            let paged = p.kv_view(blocks, t, l);
                            paged_chunk_attention(
                                &q_seq[..nq * dh],
                                nq,
                                1,
                                dh,
                                &k_pos[..nkv * dh],
                                &v_pos[..nkv * dh],
                                &paged,
                                &sel,
                                attn,
                                out,
                            );
                        }
                    }
                    // Scatter this position's [n_q, d] rows back into the
                    // chunk-layout [n_q, s, d] attention output.
                    let attn_heads = fit(attn_heads, nq * s * dh);
                    for h in 0..nq {
                        let dst = (h * s + i) * dh;
                        attn_heads[dst..dst + dh].copy_from_slice(&out[h * dh..(h + 1) * dh]);
                    }
                }

                // Append position i's KV before position i + 1 selects —
                // the serial decode order, so later positions see (and
                // policies may prune) earlier draft keys exactly as a
                // non-speculative run would.
                let _ta = scoped(Phase::Append);
                match kv {
                    DecodeKv::Private(st) => st.caches[l].append_token_strided(
                        &sc.k_heads[..nkv * s * dh],
                        &sc.v_heads[..nkv * s * dh],
                        i,
                        s,
                    ),
                    DecodeKv::Paged { blocks, .. } => pool
                        .as_deref_mut()
                        .expect("paged verify without a pool")
                        .append_token_strided(
                            blocks,
                            l,
                            t,
                            &sc.k_heads[..nkv * s * dh],
                            &sc.v_heads[..nkv * s * dh],
                            i,
                            s,
                        ),
                }
            }

            self.layer_attn_output(&self.packed[l], s, &mut hidden, sc);
            self.layer_ffn(lw, &self.packed[l], s, &mut hidden, sc);
        }
        if let DecodeKv::Private(st) = kv {
            st.pos += s;
        }

        // ---- fused per-position logits: one [s, dm] × embeddingᵀ GEMM
        // reduced straight to a greedy target per row ----
        let _t = scoped(Phase::Gemm);
        let normed = fit(&mut sc.normed, s * dm);
        for i in 0..s {
            rmsnorm(
                &hidden[i * dm..(i + 1) * dm],
                self.w.final_norm.data(),
                cfg.norm_eps,
                &mut normed[i * dm..(i + 1) * dm],
            );
        }
        let mut next = vec![0u32; s];
        matmul_bt_argmax(normed, self.w.embedding.data(), s, dm, cfg.vocab, &mut next);
        next
    }

    /// Logits for one hidden row (tied embedding head after final norm)
    /// into a caller-owned buffer — no per-token allocation.
    pub fn logits_into(&self, hidden_row: &[f32], out: &mut Vec<f32>) {
        let cfg = &self.w.cfg;
        let dm = cfg.d_model;
        debug_assert_eq!(hidden_row.len(), dm);
        let mut sc = self.scratch.borrow_mut();
        let normed = fit(&mut sc.norm_row, dm);
        rmsnorm(hidden_row, self.w.final_norm.data(), cfg.norm_eps, normed);
        if out.len() != cfg.vocab {
            out.resize(cfg.vocab, 0.0);
        }
        crate::tensor::matmul::matmul_bt(normed, self.w.embedding.data(), 1, dm, cfg.vocab, out);
    }

    /// Logits for one hidden row. Allocates; steady-state paths use
    /// [`HostModel::logits_into`] or [`HostModel::greedy_next`] (which
    /// never materializes the vocab row at all).
    pub fn logits(&self, hidden_row: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.logits_into(hidden_row, &mut out);
        out
    }

    /// Greedy next token from the last row of `hidden`: final norm into
    /// reusable scratch, then the fused GEMV+argmax — the full-vocab
    /// logits row is never materialized.
    pub fn greedy_next(&self, hidden: &[f32]) -> u32 {
        let _t = scoped(Phase::Gemm);
        let cfg = &self.w.cfg;
        let dm = cfg.d_model;
        let last = &hidden[hidden.len() - dm..];
        let mut sc = self.scratch.borrow_mut();
        let normed = fit(&mut sc.norm_row, dm);
        rmsnorm(last, self.w.final_norm.data(), cfg.norm_eps, normed);
        let mut next = [0u32; 1];
        matmul_bt_argmax(normed, self.w.embedding.data(), 1, dm, cfg.vocab, &mut next);
        next[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::dense::Dense;
    use crate::select::Quoka;

    fn model(preset: &str) -> HostModel {
        let cfg = ModelConfig::preset(preset).unwrap();
        HostModel::new(Weights::generate(&cfg, 1234))
    }

    #[test]
    fn chunked_prefill_equals_single_shot_under_dense() {
        // Chunked prefill with full attention must equal processing the
        // whole prompt at once (Eq. 2's exactness).
        let m = model("tiny");
        let tokens: Vec<u32> = (0..12).map(|i| (i * 37 % 251) as u32).collect();
        let mut ctx = SelectCtx::new(0);

        let mut s1 = SeqState::new(m.cfg());
        let h_once = m.forward_chunk(&mut s1, &tokens, &Dense, usize::MAX, &mut ctx);

        let mut s2 = SeqState::new(m.cfg());
        let mut last = Vec::new();
        for chunk in tokens.chunks(4) {
            last = m.forward_chunk(&mut s2, chunk, &Dense, usize::MAX, &mut ctx);
        }
        let dm = m.cfg().d_model;
        let a = &h_once[h_once.len() - dm..];
        let b = &last[last.len() - dm..];
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        assert_eq!(s1.caches[0].t, 12);
        assert_eq!(s2.caches[0].t, 12);
    }

    #[test]
    fn quoka_with_large_budget_matches_dense() {
        let m = model("tiny");
        let tokens: Vec<u32> = (0..16).map(|i| (i * 13 % 251) as u32).collect();
        let mut ctx = SelectCtx::new(0);
        let mut sd = SeqState::new(m.cfg());
        let mut sq = SeqState::new(m.cfg());
        let (mut hd, mut hq) = (Vec::new(), Vec::new());
        for chunk in tokens.chunks(4) {
            hd = m.forward_chunk(&mut sd, chunk, &Dense, usize::MAX, &mut ctx);
            hq = m.forward_chunk(&mut sq, chunk, &Quoka::default(), 1 << 20, &mut ctx);
        }
        for (x, y) in hd.iter().zip(&hq) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn quoka_error_shrinks_with_budget() {
        // A random-weight model has diffuse attention (the worst case for
        // sparsity), so absolute error at small budgets is large; the
        // QUOKA-relevant property is monotone improvement toward dense as
        // the budget grows.
        let m = model("tiny");
        let tokens: Vec<u32> = (0..64).map(|i| (i * 31 % 251) as u32).collect();
        let err_at = |budget: usize| -> f32 {
            let mut ctx = SelectCtx::new(0);
            let mut sd = SeqState::new(m.cfg());
            let mut sq = SeqState::new(m.cfg());
            let (mut hd, mut hq) = (Vec::new(), Vec::new());
            for chunk in tokens.chunks(16) {
                hd = m.forward_chunk(&mut sd, chunk, &Dense, usize::MAX, &mut ctx);
                hq = m.forward_chunk(&mut sq, chunk, &Quoka::default(), budget, &mut ctx);
            }
            crate::tensor::ops::rel_l2(&hd, &hq)
        };
        let (e8, e40, e64) = (err_at(8), err_at(40), err_at(64));
        assert!(e40 < e8, "e40 {e40} !< e8 {e8}");
        assert!(e64 < 0.05, "budget >= T must be near-exact, got {e64}");
    }

    #[test]
    fn decode_path_and_logits() {
        let m = model("tiny");
        let mut st = SeqState::new(m.cfg());
        let mut ctx = SelectCtx::new(0);
        let h = m.forward_chunk(&mut st, &[1, 2, 3, 4], &Dense, usize::MAX, &mut ctx);
        let next = m.greedy_next(&h);
        assert!((next as usize) < m.cfg().vocab);
        let h2 = m.forward_chunk(&mut st, &[next], &Quoka::default(), 64, &mut ctx);
        assert_eq!(h2.len(), m.cfg().d_model);
        assert_eq!(st.pos, 5);
        let logits = m.logits(&h2);
        assert_eq!(logits.len(), m.cfg().vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn moe_and_nope_variants_run() {
        for preset in ["gptoss-20b-sim", "smollm3-sim"] {
            let cfg = ModelConfig::preset(preset).unwrap();
            // Shrink for test speed.
            let cfg = ModelConfig { d_model: 64, d_ff: 96, n_layers: 2, vocab: 128, ..cfg };
            let m = HostModel::new(Weights::generate(&cfg, 5));
            let mut st = SeqState::new(&cfg);
            let mut ctx = SelectCtx::new(0);
            let h = m.forward_chunk(&mut st, &[5, 6, 7], &Quoka::default(), 8, &mut ctx);
            assert!(h.iter().all(|x| x.is_finite()), "{preset}");
        }
    }

    #[test]
    fn decode_batch_of_one_matches_chunk_decode() {
        // The engine's B=1 decode must be exactly the old serial path:
        // forward_decode_batch([seq]) == forward_chunk(s=1) + greedy_next,
        // including identical cache contents afterward.
        let m = model("tiny");
        let quoka = Quoka::default();
        let mut ctx = SelectCtx::new(0);
        let toks: Vec<u32> = (0..40).map(|i| (i * 29 % 251) as u32).collect();
        let mut st_a = SeqState::new(m.cfg());
        let mut st_b = SeqState::new(m.cfg());
        let (mut ha, mut hb) = (Vec::new(), Vec::new());
        for chunk in toks.chunks(16) {
            ha = m.forward_chunk(&mut st_a, chunk, &quoka, 24, &mut ctx);
            hb = m.forward_chunk(&mut st_b, chunk, &quoka, 24, &mut ctx);
        }
        let mut tok_a = m.greedy_next(&ha);
        let mut tok_b = m.greedy_next(&hb);
        assert_eq!(tok_a, tok_b);
        for _ in 0..4 {
            ctx.begin_step();
            let h = m.forward_chunk(&mut st_a, &[tok_a], &quoka, 24, &mut ctx);
            tok_a = m.greedy_next(&h);
            ctx.begin_step();
            let mut one = [DecodeSeq {
                kv: DecodeKv::Private(&mut st_b),
                token: tok_b,
                policy: &quoka,
                budget: 24,
            }];
            tok_b = m.forward_decode_batch(&mut one, None, &mut ctx)[0];
            assert_eq!(tok_a, tok_b);
        }
        assert_eq!(st_a.pos, st_b.pos);
        for (ca, cb) in st_a.caches.iter().zip(&st_b.caches) {
            assert_eq!(ca.t, cb.t);
            for h in 0..ca.n_kv {
                for i in 0..ca.t {
                    assert_eq!(ca.key(h, i), cb.key(h, i), "key ({h},{i})");
                    assert_eq!(ca.value(h, i), cb.value(h, i), "value ({h},{i})");
                }
            }
        }
    }

    #[test]
    fn forward_verify_targets_and_cache_match_serial_decode() {
        // One fused verify forward over [pending, d1..d4] must produce, at
        // every position, exactly the greedy target a serial decode of the
        // same tokens produces — and leave a bit-identical cache. Run with
        // a sparse policy at a tight budget: per-position selection is the
        // part that would diverge if verification used one joint chunk
        // selection.
        let m = model("tiny");
        let quoka = Quoka::default();
        let toks: Vec<u32> = (0..48).map(|i| (i * 23 % 251) as u32).collect();
        let budget = 16usize;

        // Serial oracle: decode 5 tokens one at a time.
        let mut ctx = SelectCtx::new(0);
        let mut st_a = SeqState::new(m.cfg());
        let mut h = Vec::new();
        for c in toks.chunks(16) {
            h = m.forward_chunk(&mut st_a, c, &quoka, budget, &mut ctx);
        }
        let first = m.greedy_next(&h);
        let mut inputs = vec![first];
        let mut want = Vec::new();
        for i in 0..5 {
            ctx.begin_step();
            let h = m.forward_chunk(&mut st_a, &[inputs[i]], &quoka, budget, &mut ctx);
            let t = m.greedy_next(&h);
            want.push(t);
            inputs.push(t);
        }

        // Fused verify over the same 5 inputs (an oracle-perfect draft).
        let mut ctx = SelectCtx::new(0);
        let mut st_b = SeqState::new(m.cfg());
        let mut h = Vec::new();
        for c in toks.chunks(16) {
            h = m.forward_chunk(&mut st_b, c, &quoka, budget, &mut ctx);
        }
        assert_eq!(m.greedy_next(&h), first);
        ctx.begin_step();
        let mut kv = DecodeKv::Private(&mut st_b);
        let targets = m.forward_verify(&mut kv, &inputs[..5], &quoka, budget, None, &mut ctx);
        assert_eq!(targets, want, "per-position verify targets must equal serial decode");

        // Cache bit-equality at the same depth.
        assert_eq!(st_a.pos, st_b.pos);
        for (ca, cb) in st_a.caches.iter().zip(&st_b.caches) {
            assert_eq!(ca.t, cb.t);
            for hh in 0..ca.n_kv {
                for i in 0..ca.t {
                    assert_eq!(ca.key(hh, i), cb.key(hh, i), "key ({hh},{i})");
                    assert_eq!(ca.value(hh, i), cb.value(hh, i), "value ({hh},{i})");
                }
            }
        }

        // Rollback path: a wrong draft is rejected and truncated away;
        // continuing serially afterwards still reproduces the oracle.
        let mut ctx = SelectCtx::new(0);
        let mut st_c = SeqState::new(m.cfg());
        let mut h = Vec::new();
        for c in toks.chunks(16) {
            h = m.forward_chunk(&mut st_c, c, &quoka, budget, &mut ctx);
        }
        let _ = m.greedy_next(&h);
        // Draft diverges at index 1: only want[0] is accepted, and the
        // correction token is the model's own want[1].
        let bad = [inputs[0], want[0], want[1] ^ 1, 7, 9];
        ctx.begin_step();
        let mut kv = DecodeKv::Private(&mut st_c);
        let targets = m.forward_verify(&mut kv, &bad, &quoka, budget, None, &mut ctx);
        assert_eq!(targets[0], want[0]);
        assert_eq!(targets[1], want[1], "prefix positions are exact regardless of the tail");
        let accepted = targets
            .iter()
            .zip(&bad[1..])
            .take_while(|(t, d)| *t == *d)
            .count();
        assert_eq!(accepted, 1);
        st_c.truncate(toks.len() + 1 + accepted);
        ctx.begin_step();
        let h = m.forward_chunk(&mut st_c, &[targets[accepted]], &quoka, budget, &mut ctx);
        assert_eq!(m.greedy_next(&h), want[2], "post-rollback decode continues the oracle");
    }

    #[test]
    fn logits_into_and_greedy_agree_with_logits() {
        let m = model("tiny");
        let mut st = SeqState::new(m.cfg());
        let mut ctx = SelectCtx::new(0);
        let h = m.forward_chunk(&mut st, &[3, 1, 4, 1, 5], &Dense, usize::MAX, &mut ctx);
        let dm = m.cfg().d_model;
        let last = &h[h.len() - dm..];
        let alloc = m.logits(last);
        let mut reused = vec![7.0f32; 2 * m.cfg().vocab]; // wrong-size buffer is resized
        m.logits_into(last, &mut reused);
        assert_eq!(alloc, reused);
        let want = crate::tensor::ops::topk_indices(&alloc, 1)[0] as u32;
        assert_eq!(m.greedy_next(&h), want);
    }

    #[test]
    fn paged_forward_matches_contiguous() {
        // The paged pipeline (pool pages + block-table attention) must
        // reproduce the private-buffer pipeline on the same tokens, for
        // dense and for QUOKA at a budget whose descend set covers every
        // page (so the block-metadata scan computes identical scores).
        use crate::coordinator::kv_blocks::BlockAllocator;
        use crate::kvpool::{KvPool, PoolCfg};
        let m = model("tiny");
        let cfg = m.cfg().clone();
        let tokens: Vec<u32> = (0..24).map(|i| (i * 17 % 251) as u32).collect();
        let bt = 8usize;
        let quoka = Quoka::default();
        let cases: [(&dyn crate::select::SelectionPolicy, usize); 2] =
            [(&Dense, usize::MAX), (&quoka, 12)];
        for (policy, budget) in cases {
            let mut ctx = SelectCtx::new(0);
            let mut st = SeqState::new(&cfg);
            let mut h_c = Vec::new();
            for chunk in tokens.chunks(8) {
                h_c = m.forward_chunk(&mut st, chunk, policy, budget, &mut ctx);
            }
            let mut alloc = BlockAllocator::new(16, bt);
            let mut pool = KvPool::new(PoolCfg {
                n_layers: cfg.n_layers,
                n_kv: cfg.n_kv_heads,
                d: cfg.d_head,
                block_tokens: bt,
                total_blocks: 16,
            });
            let mut blocks = Vec::new();
            assert!(alloc.ensure(&mut blocks, tokens.len()));
            pool.adopt_new(&blocks);
            let mut pos = 0;
            let mut h_p = Vec::new();
            for chunk in tokens.chunks(8) {
                h_p = m.forward_chunk_paged(&mut pool, &blocks, pos, chunk, policy, budget, &mut ctx);
                pos += chunk.len();
            }
            assert!(
                crate::tensor::ops::rel_l2(&h_c, &h_p) < 1e-4,
                "paged/contiguous divergence {} (budget {budget})",
                crate::tensor::ops::rel_l2(&h_c, &h_p)
            );
        }
    }

    #[test]
    fn deterministic_forward() {
        let m = model("tiny");
        let mut a = SeqState::new(m.cfg());
        let mut b = SeqState::new(m.cfg());
        let mut ctx = SelectCtx::new(3);
        let ha = m.forward_chunk(&mut a, &[9, 8, 7], &Dense, usize::MAX, &mut ctx);
        let hb = m.forward_chunk(&mut b, &[9, 8, 7], &Dense, usize::MAX, &mut ctx);
        assert_eq!(ha, hb);
    }
}
