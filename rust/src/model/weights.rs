//! Deterministic synthetic weights.
//!
//! Weights are generated from a seed with per-tensor derived streams, so
//! the Rust host backend, the PJRT artifact path and the Python test suite
//! can all materialize byte-identical parameters without any checkpoint
//! file (the offline substitution for real model weights, DESIGN.md §3).
//!
//! Initialization follows standard transformer practice (scaled normal,
//! `σ = 1/√fan_in`), which produces the query/key statistics the selection
//! policies operate on.

use super::config::ModelConfig;
use crate::tensor::matmul::PackedB;
use crate::tensor::Tensor;
use crate::util::Rng;

/// One transformer layer's parameters.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    /// `[d_model]` pre-attention RMSNorm gain.
    pub attn_norm: Tensor,
    /// `[d_model, n_q_heads*d_head]`.
    pub wq: Tensor,
    /// `[d_model, n_kv_heads*d_head]`.
    pub wk: Tensor,
    /// `[d_model, n_kv_heads*d_head]`.
    pub wv: Tensor,
    /// `[n_q_heads*d_head, d_model]`.
    pub wo: Tensor,
    /// `[d_model]` pre-FFN RMSNorm gain.
    pub ffn_norm: Tensor,
    /// Dense FFN (SwiGLU): gate/up `[d_model, d_ff]`, down `[d_ff, d_model]`.
    /// For MoE these hold expert 0; extra experts live in `experts`.
    pub w_gate: Tensor,
    pub w_up: Tensor,
    pub w_down: Tensor,
    /// MoE router `[d_model, n_experts]` (empty when dense).
    pub router: Tensor,
    /// Experts 1.. (expert 0 uses the dense tensors above).
    pub experts: Vec<(Tensor, Tensor, Tensor)>,
}

/// Full model parameters.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    /// `[vocab, d_model]` token embedding (also the tied LM head).
    pub embedding: Tensor,
    pub layers: Vec<LayerWeights>,
    /// `[d_model]` final RMSNorm gain.
    pub final_norm: Tensor,
}

/// One layer's projection matrices repacked into the tile-major panel
/// layout the packed GEMM streams ([`PackedB`]). Built once at model load
/// ([`LayerWeights::pack`]) so the pack cost never rides the forward
/// pass. Norm gains and the router stay in [`LayerWeights`] — they feed
/// element-wise kernels, not the GEMM.
#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub wq: PackedB,
    pub wk: PackedB,
    pub wv: PackedB,
    pub wo: PackedB,
    pub w_gate: PackedB,
    pub w_up: PackedB,
    pub w_down: PackedB,
    /// Experts 1.. (expert 0 uses the dense panels above), mirroring
    /// [`LayerWeights::experts`].
    pub experts: Vec<(PackedB, PackedB, PackedB)>,
}

fn pack2d(t: &Tensor) -> PackedB {
    let (k, n) = (t.shape()[0], t.shape()[1]);
    PackedB::pack(t.data(), k, n)
}

impl LayerWeights {
    /// Repack every GEMM operand of this layer (see [`PackedLayer`]).
    pub fn pack(&self) -> PackedLayer {
        PackedLayer {
            wq: pack2d(&self.wq),
            wk: pack2d(&self.wk),
            wv: pack2d(&self.wv),
            wo: pack2d(&self.wo),
            w_gate: pack2d(&self.w_gate),
            w_up: pack2d(&self.w_up),
            w_down: pack2d(&self.w_down),
            experts: self
                .experts
                .iter()
                .map(|(g, u, d)| (pack2d(g), pack2d(u), pack2d(d)))
                .collect(),
        }
    }
}

fn proj(rng: &mut Rng, rows: usize, cols: usize) -> Tensor {
    let sigma = 1.0 / (rows as f32).sqrt();
    Tensor::randn(&[rows, cols], rng, sigma)
}

fn gain(dim: usize) -> Tensor {
    Tensor::from_vec(&[dim], vec![1.0; dim])
}

impl Weights {
    /// Generate the full parameter set for `cfg` from `seed`.
    pub fn generate(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut root = Rng::new(seed);
        let d = cfg.d_model;
        let dq = cfg.n_q_heads * cfg.d_head;
        let dkv = cfg.n_kv_heads * cfg.d_head;
        let embedding = {
            let mut r = root.fork(0xE0B);
            Tensor::randn(&[cfg.vocab, d], &mut r, 0.02)
        };
        let layers = (0..cfg.n_layers)
            .map(|l| {
                let mut r = root.fork(0x1000 + l as u64);
                let n_extra = cfg.n_experts.saturating_sub(1);
                LayerWeights {
                    attn_norm: gain(d),
                    wq: proj(&mut r, d, dq),
                    wk: proj(&mut r, d, dkv),
                    wv: proj(&mut r, d, dkv),
                    wo: proj(&mut r, dq, d),
                    ffn_norm: gain(d),
                    w_gate: proj(&mut r, d, cfg.d_ff),
                    w_up: proj(&mut r, d, cfg.d_ff),
                    w_down: proj(&mut r, cfg.d_ff, d),
                    router: if cfg.n_experts > 0 {
                        proj(&mut r, d, cfg.n_experts)
                    } else {
                        Tensor::zeros(&[0])
                    },
                    experts: (0..n_extra)
                        .map(|_| {
                            (
                                proj(&mut r, d, cfg.d_ff),
                                proj(&mut r, d, cfg.d_ff),
                                proj(&mut r, cfg.d_ff, d),
                            )
                        })
                        .collect(),
                }
            })
            .collect();
        Weights { cfg: cfg.clone(), embedding, layers, final_norm: gain(d) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let cfg = ModelConfig::tiny();
        let a = Weights::generate(&cfg, 7);
        let b = Weights::generate(&cfg, 7);
        assert_eq!(a.embedding, b.embedding);
        assert_eq!(a.layers[1].wq, b.layers[1].wq);
    }

    #[test]
    fn seeds_differ() {
        let cfg = ModelConfig::tiny();
        let a = Weights::generate(&cfg, 1);
        let b = Weights::generate(&cfg, 2);
        assert!(a.embedding.max_abs_diff(&b.embedding) > 0.0);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ModelConfig::preset("gptoss-20b-sim").unwrap();
        let w = Weights::generate(&cfg, 3);
        assert_eq!(w.embedding.shape(), &[cfg.vocab, cfg.d_model]);
        let l = &w.layers[0];
        assert_eq!(l.wq.shape(), &[cfg.d_model, cfg.n_q_heads * cfg.d_head]);
        assert_eq!(l.wk.shape(), &[cfg.d_model, cfg.n_kv_heads * cfg.d_head]);
        assert_eq!(l.router.shape(), &[cfg.d_model, cfg.n_experts]);
        assert_eq!(l.experts.len(), cfg.n_experts - 1);
    }

    #[test]
    fn packed_layers_round_trip() {
        let cfg = ModelConfig::preset("gptoss-20b-sim").unwrap();
        let w = Weights::generate(&cfg, 7);
        let l = &w.layers[0];
        let p = l.pack();
        assert_eq!(p.wq.unpack(), l.wq.data());
        assert_eq!(p.wo.unpack(), l.wo.data());
        assert_eq!(p.w_down.unpack(), l.w_down.data());
        assert_eq!(p.experts.len(), l.experts.len());
        assert_eq!(p.experts[0].1.unpack(), l.experts[0].1.data());
    }

    #[test]
    fn layers_are_independent_streams() {
        let cfg = ModelConfig::tiny();
        let w = Weights::generate(&cfg, 9);
        assert!(w.layers[0].wq.max_abs_diff(&w.layers[1].wq) > 0.0);
    }
}
