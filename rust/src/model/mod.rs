//! Model substrate: configuration, deterministic synthetic weights, the
//! host (pure-Rust) transformer reference, and its attention kernels.
//!
//! The PJRT artifact path (`crate::runtime`) executes the same architecture
//! compiled from JAX; `rust/tests/parity.rs` checks the two agree.

pub mod config;
pub mod weights;
pub mod attention;
pub mod transformer;

pub use attention::KvBuffers;
pub use config::{sim_roster, ModelConfig};
pub use transformer::{DecodeKv, DecodeSeq, HostModel, SeqState};
pub use weights::Weights;
