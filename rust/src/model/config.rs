//! Model configuration and the simulated-model presets.
//!
//! Offline substitution for the paper's checkpoint zoo (DESIGN.md §3): each
//! preset mirrors a paper model's *architecture class* — GQA ratio, RoPE vs
//! NoPE, dense-FFN vs MoE — at a scale the CPU testbed can serve. QUOKA is
//! training-free and purely geometric, so the selection behaviour under
//! test depends on these structural knobs, not on parameter count.

use crate::util::json::Json;

/// Decoder-only transformer configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    /// RoPE base; ignored when `use_rope` is false (NoPE variant).
    pub rope_theta: f32,
    pub use_rope: bool,
    /// MoE expert count (0 ⇒ dense FFN). Top-1 routing when > 0.
    pub n_experts: usize,
    pub norm_eps: f32,
    pub max_seq: usize,
}

impl ModelConfig {
    /// Total parameter count (for reporting).
    pub fn param_count(&self) -> usize {
        let attn = self.d_model * self.d_head * (self.n_q_heads + 2 * self.n_kv_heads)
            + self.n_q_heads * self.d_head * self.d_model;
        let ffn_units = if self.n_experts > 0 { self.n_experts } else { 1 };
        let ffn = ffn_units * 3 * self.d_model * self.d_ff
            + if self.n_experts > 0 { self.d_model * self.n_experts } else { 0 };
        let per_layer = attn + ffn + 2 * self.d_model;
        self.vocab * self.d_model * 2 + self.n_layers * per_layer + self.d_model
    }

    /// GQA group size.
    pub fn group_size(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }

    /// A minimal config for unit tests.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 257,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            rope_theta: 10_000.0,
            use_rope: true,
            n_experts: 0,
            norm_eps: 1e-5,
            max_seq: 4096,
        }
    }

    /// The serving default: a small GQA transformer the CPU PJRT backend
    /// serves end-to-end (the "load a small real model" substitute).
    pub fn serve_small() -> ModelConfig {
        ModelConfig {
            name: "serve-small".into(),
            vocab: 4096,
            d_model: 256,
            n_layers: 4,
            n_q_heads: 8,
            n_kv_heads: 2,
            d_head: 32,
            d_ff: 768,
            rope_theta: 500_000.0,
            use_rope: true,
            n_experts: 0,
            norm_eps: 1e-5,
            max_seq: 65_536,
        }
    }

    /// Construct a preset by name (see [`sim_roster`]).
    pub fn preset(name: &str) -> anyhow::Result<ModelConfig> {
        let base = ModelConfig::serve_small();
        Ok(match name {
            "tiny" => ModelConfig::tiny(),
            "serve-small" => base,
            // Llama-3.2-3B: 24 q heads / 8 kv heads (g=4), RoPE, dense FFN.
            "llama32-3b-sim" => ModelConfig {
                name: name.into(),
                n_layers: 4,
                n_q_heads: 12,
                n_kv_heads: 4,
                d_head: 32,
                rope_theta: 500_000.0,
                ..base
            },
            // Qwen-2.5-3B: 16/2 GQA (g=8), RoPE, dense FFN.
            "qwen25-3b-sim" => ModelConfig {
                name: name.into(),
                n_layers: 4,
                n_q_heads: 16,
                n_kv_heads: 2,
                d_head: 32,
                rope_theta: 1_000_000.0,
                ..base
            },
            // Qwen3-4B: 32/8 (g=4), RoPE.
            "qwen3-4b-sim" => ModelConfig {
                name: name.into(),
                n_layers: 4,
                n_q_heads: 16,
                n_kv_heads: 4,
                d_head: 32,
                rope_theta: 1_000_000.0,
                ..base
            },
            // SmolLM3: 16/4 with NoPE on a subset of layers — modelled as
            // NoPE everywhere (the harder case for positional recall).
            "smollm3-sim" => ModelConfig {
                name: name.into(),
                n_layers: 4,
                n_q_heads: 16,
                n_kv_heads: 4,
                d_head: 32,
                use_rope: false,
                ..base
            },
            // GPT-OSS-20B: MoE FFN (top-1 of 8 scaled-down experts), GQA 8.
            "gptoss-20b-sim" => ModelConfig {
                name: name.into(),
                n_layers: 4,
                n_q_heads: 16,
                n_kv_heads: 2,
                d_head: 32,
                n_experts: 8,
                d_ff: 256,
                ..base
            },
            other => anyhow::bail!("unknown model preset '{other}'"),
        })
    }

    /// Serialize for the AOT manifest handshake.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("vocab", Json::num(self.vocab as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_q_heads", Json::num(self.n_q_heads as f64)),
            ("n_kv_heads", Json::num(self.n_kv_heads as f64)),
            ("d_head", Json::num(self.d_head as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("rope_theta", Json::num(self.rope_theta as f64)),
            ("use_rope", Json::Bool(self.use_rope)),
            ("n_experts", Json::num(self.n_experts as f64)),
            ("norm_eps", Json::num(self.norm_eps as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
        ])
    }

    /// Parse from the AOT manifest.
    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req("name")?.as_str().unwrap_or("?").to_string(),
            vocab: j.req("vocab")?.as_usize().unwrap(),
            d_model: j.req("d_model")?.as_usize().unwrap(),
            n_layers: j.req("n_layers")?.as_usize().unwrap(),
            n_q_heads: j.req("n_q_heads")?.as_usize().unwrap(),
            n_kv_heads: j.req("n_kv_heads")?.as_usize().unwrap(),
            d_head: j.req("d_head")?.as_usize().unwrap(),
            d_ff: j.req("d_ff")?.as_usize().unwrap(),
            rope_theta: j.req("rope_theta")?.as_f64().unwrap() as f32,
            use_rope: j.req("use_rope")?.as_bool().unwrap_or(true),
            n_experts: j.req("n_experts")?.as_usize().unwrap_or(0),
            norm_eps: j.req("norm_eps")?.as_f64().unwrap() as f32,
            max_seq: j.req("max_seq")?.as_usize().unwrap(),
        })
    }
}

/// The simulated roster standing in for the paper's model zoo (Table 1).
pub fn sim_roster() -> Vec<&'static str> {
    vec!["llama32-3b-sim", "qwen25-3b-sim", "qwen3-4b-sim", "smollm3-sim", "gptoss-20b-sim"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_are_consistent() {
        for name in sim_roster().into_iter().chain(["tiny", "serve-small"]) {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.n_q_heads % c.n_kv_heads, 0, "{name}");
            assert!(c.group_size() >= 1);
            assert!(c.param_count() > 0);
        }
        assert!(ModelConfig::preset("bogus").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::preset("gptoss-20b-sim").unwrap();
        let j = c.to_json();
        let back = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn roster_covers_architecture_classes() {
        let cfgs: Vec<_> = sim_roster()
            .into_iter()
            .map(|n| ModelConfig::preset(n).unwrap())
            .collect();
        assert!(cfgs.iter().any(|c| !c.use_rope), "need a NoPE variant");
        assert!(cfgs.iter().any(|c| c.n_experts > 0), "need an MoE variant");
        assert!(cfgs.iter().any(|c| c.group_size() >= 8), "need a wide-GQA variant");
    }
}
