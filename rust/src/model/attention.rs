//! Host attention kernels: chunked-prefill attention over a (possibly
//! sub-selected) KV cache, plus single-query decode attention.
//!
//! Semantics follow paper Eq. (2) + Algorithm 2: for chunk `i`, queries
//! attend to the *selected* subset of the past cache `K_{<i}` (no mask —
//! everything selected is in the past) concatenated with the chunk's own
//! keys under a causal mask. The full K/V is always appended to the cache
//! afterwards; QUOKA sparsifies attention, it does not evict.

use crate::select::Selection;
use crate::tensor::ops::{dot, softmax};

/// Growable per-layer KV storage, layout `[n_kv, capacity, d]` per tensor.
#[derive(Clone, Debug)]
pub struct KvBuffers {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub n_kv: usize,
    pub d: usize,
    /// Valid rows per head.
    pub t: usize,
    /// Allocated rows per head.
    pub capacity: usize,
}

impl KvBuffers {
    pub fn new(n_kv: usize, d: usize, initial_capacity: usize) -> KvBuffers {
        let cap = initial_capacity.max(1);
        KvBuffers {
            k: vec![0.0; n_kv * cap * d],
            v: vec![0.0; n_kv * cap * d],
            n_kv,
            d,
            t: 0,
            capacity: cap,
        }
    }

    /// Append `s` tokens of per-head K/V (layout `[n_kv, s, d]`), growing
    /// geometrically when needed.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], s: usize) {
        debug_assert_eq!(k_new.len(), self.n_kv * s * self.d);
        if self.t + s > self.capacity {
            let new_cap = (self.capacity * 2).max(self.t + s);
            let mut k2 = vec![0.0; self.n_kv * new_cap * self.d];
            let mut v2 = vec![0.0; self.n_kv * new_cap * self.d];
            for h in 0..self.n_kv {
                let src = h * self.capacity * self.d;
                let dst = h * new_cap * self.d;
                let n = self.t * self.d;
                k2[dst..dst + n].copy_from_slice(&self.k[src..src + n]);
                v2[dst..dst + n].copy_from_slice(&self.v[src..src + n]);
            }
            self.k = k2;
            self.v = v2;
            self.capacity = new_cap;
        }
        for h in 0..self.n_kv {
            let dst = h * self.capacity * self.d + self.t * self.d;
            let src = h * s * self.d;
            let n = s * self.d;
            self.k[dst..dst + n].copy_from_slice(&k_new[src..src + n]);
            self.v[dst..dst + n].copy_from_slice(&v_new[src..src + n]);
        }
        self.t += s;
    }

    /// Key row `(h, i)`.
    #[inline]
    pub fn key(&self, h: usize, i: usize) -> &[f32] {
        let base = h * self.capacity * self.d + i * self.d;
        &self.k[base..base + self.d]
    }

    #[inline]
    pub fn value(&self, h: usize, i: usize) -> &[f32] {
        let base = h * self.capacity * self.d + i * self.d;
        &self.v[base..base + self.d]
    }

    /// View as a selection-policy cache.
    pub fn k_view(&self) -> crate::select::KCache<'_> {
        crate::select::KCache::new(&self.k, self.n_kv, self.t, self.capacity, self.d)
    }

    /// Bytes currently resident (both K and V).
    pub fn resident_bytes(&self) -> usize {
        2 * self.n_kv * self.capacity * self.d * 4
    }
}

/// Chunked-prefill attention.
///
/// * `q` — `[n_q_heads, s, d]` RoPE'd queries for the chunk.
/// * `k_self`/`v_self` — `[n_kv, s, d]` the chunk's own keys/values.
/// * `cache` — past KV (`cache.t` rows, *excluding* the current chunk).
/// * `sel` — selection over the past cache.
/// * `out` — `[n_q_heads, s, d]` attention output (overwritten).
///
/// Scratch slices (`scores`) must hold `cache.t + s` f32s.
#[allow(clippy::too_many_arguments)]
pub fn chunk_attention(
    q: &[f32],
    n_q_heads: usize,
    s: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), n_q_heads * s * d);
    debug_assert_eq!(out.len(), n_q_heads * s * d);
    let n_kv = cache.n_kv;
    let g = n_q_heads / n_kv;
    let t = cache.t;

    // Heads are fully independent; fan the per-head kernel across the
    // machine when the work is large enough to amortize thread wake-ups
    // (§Perf: 3.4x on the dense 16k chunk at 8 heads).
    let work = n_q_heads * s * (t + s) * d;
    let threads = if work > 1 << 21 {
        crate::util::threadpool::default_workers().min(n_q_heads)
    } else {
        1
    };
    if threads <= 1 {
        let row = scores;
        for h in 0..n_q_heads {
            head_attention(q, h, g, s, d, k_self, v_self, cache, sel, row, out_slab(out, h, s, d));
        }
    } else {
        let out_ptr = SyncPtr(out.as_mut_ptr());
        let p = &out_ptr;
        crate::util::threadpool::parallel_for(n_q_heads, threads, |h| {
            let mut row = Vec::new();
            // SAFETY: each head writes exclusively to its own out slab.
            let slab = unsafe { std::slice::from_raw_parts_mut(p.0.add(h * s * d), s * d) };
            head_attention(q, h, g, s, d, k_self, v_self, cache, sel, &mut row, slab);
        });
    }
}

struct SyncPtr(*mut f32);
unsafe impl Sync for SyncPtr {}
unsafe impl Send for SyncPtr {}

#[inline]
fn out_slab<'a>(out: &'a mut [f32], h: usize, s: usize, d: usize) -> &'a mut [f32] {
    &mut out[h * s * d..(h + 1) * s * d]
}

/// Attention for one query head over [selected past | causal self].
#[allow(clippy::too_many_arguments)]
fn head_attention(
    q: &[f32],
    h: usize,
    g: usize,
    s: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let kv = h / g;
    let t = cache.t;
    let scale = 1.0 / (d as f32).sqrt();
    // Materialize this head's past indices once.
    let idx: Vec<u32> = sel.head_indices(kv, t);
    let n_past = idx.len();
    let total = n_past + s;
    if scores.len() < total {
        scores.resize(total, 0.0);
    }
    for qi in 0..s {
        let qrow = &q[(h * s + qi) * d..(h * s + qi + 1) * d];
        let row = &mut scores[..total];
        for (slot, &pi) in idx.iter().enumerate() {
            row[slot] = dot(qrow, cache.key(kv, pi as usize)) * scale;
        }
        for sj in 0..s {
            row[n_past + sj] = if sj <= qi {
                dot(qrow, &k_self[(kv * s + sj) * d..(kv * s + sj + 1) * d]) * scale
            } else {
                f32::NEG_INFINITY
            };
        }
        softmax(&mut row[..total]);
        let orow = &mut out[qi * d..(qi + 1) * d];
        orow.iter_mut().for_each(|x| *x = 0.0);
        for (slot, &pi) in idx.iter().enumerate() {
            let w = row[slot];
            if w != 0.0 {
                crate::tensor::ops::axpy(w, cache.value(kv, pi as usize), orow);
            }
        }
        for sj in 0..=qi {
            let w = row[n_past + sj];
            if w != 0.0 {
                crate::tensor::ops::axpy(
                    w,
                    &v_self[(kv * s + sj) * d..(kv * s + sj + 1) * d],
                    orow,
                );
            }
        }
    }
}

/// Single-query decode attention over a selected cache (which must already
/// include all generated tokens; the current token's K/V is passed
/// separately, mirroring the prefill path with `s = 1`).
#[allow(clippy::too_many_arguments)]
pub fn decode_attention(
    q: &[f32],
    n_q_heads: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    chunk_attention(q, n_q_heads, 1, d, k_self, v_self, cache, sel, scores, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::Selection;
    use crate::util::Rng;

    fn setup(t: usize, s: usize, n_q: usize, n_kv: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, KvBuffers) {
        let mut rng = Rng::new(77);
        let q = rng.normal_vec(n_q * s * d, 1.0);
        let ks = rng.normal_vec(n_kv * s * d, 1.0);
        let vs = rng.normal_vec(n_kv * s * d, 1.0);
        let mut cache = KvBuffers::new(n_kv, d, 4);
        // Fill cache via appends of varying size to exercise growth.
        let mut filled = 0;
        while filled < t {
            let step = (t - filled).min(3);
            let kk = rng.normal_vec(n_kv * step * d, 1.0);
            let vv = rng.normal_vec(n_kv * step * d, 1.0);
            cache.append(&kk, &vv, step);
            filled += step;
        }
        (q, ks, vs, cache)
    }

    #[test]
    fn append_and_grow_preserves_rows() {
        let mut rng = Rng::new(1);
        let (n_kv, d) = (2usize, 4usize);
        let mut cache = KvBuffers::new(n_kv, d, 2);
        let k1 = rng.normal_vec(n_kv * 3 * d, 1.0);
        let v1 = rng.normal_vec(n_kv * 3 * d, 1.0);
        cache.append(&k1, &v1, 3);
        let first_key: Vec<f32> = cache.key(1, 0).to_vec();
        let k2 = rng.normal_vec(n_kv * 5 * d, 1.0);
        let v2 = rng.normal_vec(n_kv * 5 * d, 1.0);
        cache.append(&k2, &v2, 5);
        assert_eq!(cache.t, 8);
        assert_eq!(cache.key(1, 0), &first_key[..]);
        assert_eq!(cache.key(0, 4), &k2[d..2 * d]);
    }

    #[test]
    fn dense_attention_weights_sum_to_one() {
        // With all-equal values, output must equal that value regardless of
        // the score distribution (softmax weights sum to 1).
        let (t, s, n_q, n_kv, d) = (6usize, 3usize, 2usize, 1usize, 4usize);
        let (q, ks, _, mut cache) = setup(t, s, n_q, n_kv, d);
        let vs = vec![2.5f32; n_kv * s * d];
        cache.v.iter_mut().for_each(|x| *x = 2.5);
        let mut out = vec![0.0; n_q * s * d];
        let mut scratch = Vec::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut out);
        for x in &out {
            assert!((x - 2.5).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn causal_mask_blocks_future_self_tokens() {
        // First query of the chunk must ignore later chunk tokens: make the
        // past empty and plant a huge value in self position 2; query 0's
        // output must not see it, query 2's must.
        let (s, n_q, n_kv, d) = (3usize, 1usize, 1usize, 4usize);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(s * d, 1.0);
        let ks = rng.normal_vec(s * d, 1.0);
        let mut vs = vec![0.0; s * d];
        vs[2 * d] = 100.0; // value spike at self position 2
        let cache = KvBuffers::new(n_kv, d, 1);
        let mut out = vec![0.0; s * d];
        let mut scratch = Vec::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut out);
        assert!(out[0].abs() < 1.0, "q0 saw the future: {}", out[0]);
        assert!(out[2 * d].abs() > 1.0, "q2 should see position 2");
    }

    #[test]
    fn selection_restricts_past() {
        // Plant a value spike at past index 5; selecting {5} vs excluding it
        // must change the output.
        let (t, s, n_q, n_kv, d) = (10usize, 2usize, 2usize, 2usize, 4usize);
        let (q, ks, vs, mut cache) = setup(t, s, n_q, n_kv, d);
        for h in 0..n_kv {
            let base = h * cache.capacity * d + 5 * d;
            cache.v[base] = 50.0;
        }
        let mut with = vec![0.0; n_q * s * d];
        let mut without = vec![0.0; n_q * s * d];
        let mut scratch = Vec::new();
        let sel_with = Selection::PerHead(vec![vec![1, 5], vec![1, 5]]);
        let sel_without = Selection::PerHead(vec![vec![1, 2], vec![1, 2]]);
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &sel_with, &mut scratch, &mut with);
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &sel_without, &mut scratch, &mut without);
        let diff: f32 = with.iter().zip(&without).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0);
    }

    #[test]
    fn full_selection_equals_all() {
        let (t, s, n_q, n_kv, d) = (8usize, 2usize, 4usize, 2usize, 8usize);
        let (q, ks, vs, cache) = setup(t, s, n_q, n_kv, d);
        let mut a = vec![0.0; n_q * s * d];
        let mut b = vec![0.0; n_q * s * d];
        let mut scratch = Vec::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut a);
        let explicit = Selection::PerHead(vec![(0..t as u32).collect(), (0..t as u32).collect()]);
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &explicit, &mut scratch, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn decode_matches_prefill_s1() {
        let (t, _s, n_q, n_kv, d) = (12usize, 1usize, 2usize, 1usize, 4usize);
        let (q, ks, vs, cache) = setup(t, 1, n_q, n_kv, d);
        let mut a = vec![0.0; n_q * d];
        let mut b = vec![0.0; n_q * d];
        let mut scratch = Vec::new();
        chunk_attention(&q, n_q, 1, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut a);
        decode_attention(&q, n_q, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut b);
        assert_eq!(a, b);
    }
}
