//! Host attention kernels: chunked-prefill attention over a (possibly
//! sub-selected) KV cache, plus single-query decode attention.
//!
//! Semantics follow paper Eq. (2) + Algorithm 2: for chunk `i`, queries
//! attend to the *selected* subset of the past cache `K_{<i}` (no mask —
//! everything selected is in the past) concatenated with the chunk's own
//! keys under a causal mask. The full K/V is always appended to the cache
//! afterwards; QUOKA sparsifies attention, it does not evict.
//!
//! ## Kernel architecture (group-tiled + online softmax)
//!
//! The hot path is a *group-tiled* kernel. Work is split into
//! `(kv_head, query-block)` tasks; each task
//!
//! 1. resolves its KV head's selection **once per GQA group** (the seed
//!    kernel re-materialized the same index list per query head) via the
//!    borrowed [`Selection::head`] view,
//! 2. walks the selected past in key tiles of [`KTILE`] rows, **gathering
//!    each tile's K/V rows into contiguous scratch** so the score and
//!    value loops stream sequential memory instead of chasing random cache
//!    rows (`Selection::All` skips the gather — the head slab is already
//!    contiguous),
//! 3. scores every query of the group against the tile with the
//!    register-blocked [`qk_block`] micro-kernel (2 queries × 4 keys), and
//! 4. folds the tile into the output with a flash-style **online softmax**
//!    ([`online_softmax_update`]): the score buffer shrinks from
//!    O(selected + s) per query to tile size, V accumulation streams the
//!    gathered tile, and a running (max, denominator) pair per query row
//!    replaces the full-row normalization pass.
//!
//! The chunk's own keys are processed the same way with a causal bound
//! (query `i` sees self positions `0..=i`) — no ±∞ score sentinels, masked
//! positions are simply never scored.
//!
//! All tile/state buffers live in a caller-owned [`AttnScratch`] arena
//! (one slot per worker) so steady-state chunk processing performs
//! **no heap allocation** in the attention inner loop.
//!
//! [`KvBuffers`] additionally maintains an **incremental key-norm cache**:
//! `1/‖k‖` per key, computed once at `append` time and exposed through
//! `KCache::inv_norm` to every cosine-scoring selection policy (QUOKA,
//! KeyDiff, …), deleting their per-chunk × per-layer O(T·d)
//! renormalization scans.
//!
//! The same tile pipeline also runs over the **shared paged KV pool**
//! ([`paged_chunk_attention`]): past tiles are resolved through a
//! per-sequence block table (`kvpool::PagedKv`), full selections stream
//! each page's contiguous head-row run in place, and sparse selections
//! gather rows through the page indirection. Only tile *formation*
//! differs — scoring, online softmax and the causal-self part are shared
//! code paths.
//!
//! The seed scalar kernel is kept verbatim as
//! [`reference_chunk_attention`] — the parity oracle for
//! `rust/tests/attn_parity.rs` and the baseline the `micro_hotpath` bench
//! measures speedup against.
//!
//! ## Quantized (int8) KV caches
//!
//! Under [`KvDtype::Int8`] the cache stores **per-row symmetrically
//! quantized** K/V codes (`[n_kv, capacity, d]` i8) plus one f32 dequant
//! scale per row (`[n_kv, capacity]`, the same layout as the norm cache);
//! the f32 `k`/`v` slabs stay empty — an fp32 copy of the cache is never
//! materialized. The tile pipeline is unchanged except that past tiles
//! carry `(i8 codes, f32 scales)` and route through the `_q8` kernels
//! ([`qk_block_q8`] / [`av_accum_q8`]), which fold the scale into the
//! integer dot product in registers (`q · (c·s) = s · (q·c)`). The
//! chunk's own (self) K/V arrives as fresh fp32 activations and is scored
//! exactly; only the *past* is quantized. The key-norm cache keeps exact
//! fp32 norms of the original rows, so cosine-scoring selection policies
//! are unaffected by quantization of the stored keys. fp32 caches are
//! bit-identical to before — int8-vs-fp32 error bounds are pinned in
//! `rust/tests/attn_parity.rs`.

use crate::kvpool::{KvDtype, PagedKv};
use crate::select::{fit, HeadSel, Selection};
use crate::tensor::ops::{
    av_accum, av_accum_q8, dot, l2_norm, qk_block, qk_block_q8, qk_dots, quantize_row_q8, softmax,
};
use crate::util::threadpool::SyncPtr;

/// [`fit`] for the quantized tile arenas.
fn fit_i8(buf: &mut Vec<i8>, n: usize) -> &mut [i8] {
    if buf.len() < n {
        buf.resize(n, 0);
    }
    &mut buf[..n]
}

/// Key rows per gathered tile. 128 rows × d=128 × 4 B = 64 KiB per K/V
/// tile — sized so one K tile + one V tile + the score block stay L2
/// resident while still amortizing the gather.
const KTILE: usize = 128;

/// Query rows per task block (per KV head). Small enough that
/// `n_kv × s/QBLOCK` tasks expose parallelism beyond the KV-head count,
/// large enough that gathered tiles are reused across `g × QBLOCK` query
/// rows.
const QBLOCK: usize = 16;

/// Growable per-layer KV storage, layout `[n_kv, capacity, d]` per tensor.
///
/// Under [`KvDtype::F32`] the rows live in the `k`/`v` f32 slabs and the
/// quantized slabs stay empty; under [`KvDtype::Int8`] the rows live as
/// per-row-quantized codes in `k_q`/`v_q` with dequant scales in
/// `k_scale`/`v_scale` (layout `[n_kv, capacity]`, like the norm cache)
/// and the f32 slabs stay empty.
#[derive(Clone, Debug)]
pub struct KvBuffers {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Int8 key codes, `[n_kv, capacity, d]` (empty under f32).
    pub k_q: Vec<i8>,
    /// Int8 value codes, `[n_kv, capacity, d]` (empty under f32).
    pub v_q: Vec<i8>,
    /// Per-row key dequant scales, `[n_kv, capacity]` (empty under f32).
    pub k_scale: Vec<f32>,
    /// Per-row value dequant scales, `[n_kv, capacity]` (empty under f32).
    pub v_scale: Vec<f32>,
    /// Incremental key-norm cache: `1/‖k(h, i)‖` (0 for zero keys), layout
    /// `[n_kv, capacity]`. Filled at `append` time, so cosine-scoring
    /// policies never rescan the cache to renormalize. Always computed
    /// from the exact fp32 input row, even under int8 storage.
    pub k_inv_norm: Vec<f32>,
    pub dtype: KvDtype,
    pub n_kv: usize,
    pub d: usize,
    /// Valid rows per head.
    pub t: usize,
    /// Allocated rows per head.
    pub capacity: usize,
}

impl KvBuffers {
    pub fn new(n_kv: usize, d: usize, initial_capacity: usize) -> KvBuffers {
        KvBuffers::new_with_dtype(n_kv, d, initial_capacity, KvDtype::F32)
    }

    pub fn new_with_dtype(
        n_kv: usize,
        d: usize,
        initial_capacity: usize,
        dtype: KvDtype,
    ) -> KvBuffers {
        let cap = initial_capacity.max(1);
        let (f32_len, q_len, s_len) = match dtype {
            KvDtype::F32 => (n_kv * cap * d, 0, 0),
            KvDtype::Int8 => (0, n_kv * cap * d, n_kv * cap),
        };
        KvBuffers {
            k: vec![0.0; f32_len],
            v: vec![0.0; f32_len],
            k_q: vec![0; q_len],
            v_q: vec![0; q_len],
            k_scale: vec![0.0; s_len],
            v_scale: vec![0.0; s_len],
            k_inv_norm: vec![0.0; n_kv * cap],
            dtype,
            n_kv,
            d,
            t: 0,
            capacity: cap,
        }
    }

    /// Grow the per-head slabs (geometric doubling) so `need` rows fit.
    fn ensure_capacity(&mut self, need: usize) {
        if need <= self.capacity {
            return;
        }
        let new_cap = (self.capacity * 2).max(need);
        let grow_meta = |old: &[f32], n_kv: usize, cap: usize, t: usize| -> Vec<f32> {
            let mut out = vec![0.0; n_kv * new_cap];
            for h in 0..n_kv {
                out[h * new_cap..h * new_cap + t].copy_from_slice(&old[h * cap..h * cap + t]);
            }
            out
        };
        match self.dtype {
            KvDtype::F32 => {
                let mut k2 = vec![0.0; self.n_kv * new_cap * self.d];
                let mut v2 = vec![0.0; self.n_kv * new_cap * self.d];
                for h in 0..self.n_kv {
                    let src = h * self.capacity * self.d;
                    let dst = h * new_cap * self.d;
                    let n = self.t * self.d;
                    k2[dst..dst + n].copy_from_slice(&self.k[src..src + n]);
                    v2[dst..dst + n].copy_from_slice(&self.v[src..src + n]);
                }
                self.k = k2;
                self.v = v2;
            }
            KvDtype::Int8 => {
                let mut kq2 = vec![0i8; self.n_kv * new_cap * self.d];
                let mut vq2 = vec![0i8; self.n_kv * new_cap * self.d];
                for h in 0..self.n_kv {
                    let src = h * self.capacity * self.d;
                    let dst = h * new_cap * self.d;
                    let n = self.t * self.d;
                    kq2[dst..dst + n].copy_from_slice(&self.k_q[src..src + n]);
                    vq2[dst..dst + n].copy_from_slice(&self.v_q[src..src + n]);
                }
                self.k_q = kq2;
                self.v_q = vq2;
                self.k_scale = grow_meta(&self.k_scale, self.n_kv, self.capacity, self.t);
                self.v_scale = grow_meta(&self.v_scale, self.n_kv, self.capacity, self.t);
            }
        }
        self.k_inv_norm = grow_meta(&self.k_inv_norm, self.n_kv, self.capacity, self.t);
        self.capacity = new_cap;
    }

    /// Append `s` tokens of per-head K/V (layout `[n_kv, s, d]`), growing
    /// geometrically when needed. Inverse key norms for the new rows are
    /// computed here, once, and cached alongside the keys.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], s: usize) {
        debug_assert_eq!(k_new.len(), self.n_kv * s * self.d);
        self.ensure_capacity(self.t + s);
        for h in 0..self.n_kv {
            match self.dtype {
                KvDtype::F32 => {
                    let dst = h * self.capacity * self.d + self.t * self.d;
                    let src = h * s * self.d;
                    let n = s * self.d;
                    self.k[dst..dst + n].copy_from_slice(&k_new[src..src + n]);
                    self.v[dst..dst + n].copy_from_slice(&v_new[src..src + n]);
                }
                KvDtype::Int8 => {
                    for i in 0..s {
                        let src = (h * s + i) * self.d;
                        let dst = h * self.capacity * self.d + (self.t + i) * self.d;
                        let nb = h * self.capacity + self.t + i;
                        self.k_scale[nb] = quantize_row_q8(
                            &k_new[src..src + self.d],
                            &mut self.k_q[dst..dst + self.d],
                        );
                        self.v_scale[nb] = quantize_row_q8(
                            &v_new[src..src + self.d],
                            &mut self.v_q[dst..dst + self.d],
                        );
                    }
                }
            }
            for i in 0..s {
                let row = &k_new[(h * s + i) * self.d..(h * s + i + 1) * self.d];
                let norm = l2_norm(row);
                self.k_inv_norm[h * self.capacity + self.t + i] =
                    if norm > 0.0 { 1.0 / norm } else { 0.0 };
            }
        }
        self.t += s;
    }

    /// Append one token's per-head K/V taken from a **batch-layout** slab
    /// `[n_kv, batch, d]` (head `h` of sequence `seq` at row `h * batch +
    /// seq`) — the layout the batched decode forward produces — without
    /// staging through a contiguous `[n_kv, 1, d]` copy first. Norm-cache
    /// maintenance is identical to [`KvBuffers::append`].
    pub fn append_token_strided(&mut self, k_batch: &[f32], v_batch: &[f32], seq: usize, batch: usize) {
        debug_assert_eq!(k_batch.len(), self.n_kv * batch * self.d);
        debug_assert_eq!(v_batch.len(), self.n_kv * batch * self.d);
        debug_assert!(seq < batch);
        self.ensure_capacity(self.t + 1);
        for h in 0..self.n_kv {
            let src = (h * batch + seq) * self.d;
            let dst = h * self.capacity * self.d + self.t * self.d;
            match self.dtype {
                KvDtype::F32 => {
                    self.k[dst..dst + self.d].copy_from_slice(&k_batch[src..src + self.d]);
                    self.v[dst..dst + self.d].copy_from_slice(&v_batch[src..src + self.d]);
                }
                KvDtype::Int8 => {
                    let nb = h * self.capacity + self.t;
                    self.k_scale[nb] = quantize_row_q8(
                        &k_batch[src..src + self.d],
                        &mut self.k_q[dst..dst + self.d],
                    );
                    self.v_scale[nb] = quantize_row_q8(
                        &v_batch[src..src + self.d],
                        &mut self.v_q[dst..dst + self.d],
                    );
                }
            }
            let norm = l2_norm(&k_batch[src..src + self.d]);
            self.k_inv_norm[h * self.capacity + self.t] =
                if norm > 0.0 { 1.0 / norm } else { 0.0 };
        }
        self.t += 1;
    }

    /// Roll the cache back to `new_t` valid rows (speculative-decode
    /// rollback of rejected draft tokens). Storage and capacity are
    /// untouched — truncated rows are dead until the next `append`
    /// overwrites them — but the per-row metadata of the dropped rows
    /// (norm cache, and dequant scales under int8) is zeroed so the cache
    /// metadata is bit-identical to one that never appended them.
    pub fn truncate(&mut self, new_t: usize) {
        assert!(new_t <= self.t, "truncate({new_t}) beyond t={}", self.t);
        for h in 0..self.n_kv {
            let base = h * self.capacity;
            self.k_inv_norm[base + new_t..base + self.t].fill(0.0);
            if self.dtype == KvDtype::Int8 {
                self.k_scale[base + new_t..base + self.t].fill(0.0);
                self.v_scale[base + new_t..base + self.t].fill(0.0);
            }
        }
        self.t = new_t;
    }

    /// Key row `(h, i)` — fp32 caches only (a quantized cache has no f32
    /// key rows; consume `k_q`/`k_scale` instead).
    #[inline]
    pub fn key(&self, h: usize, i: usize) -> &[f32] {
        debug_assert!(self.dtype == KvDtype::F32, "KvBuffers::key on an int8 cache");
        let base = h * self.capacity * self.d + i * self.d;
        &self.k[base..base + self.d]
    }

    #[inline]
    pub fn value(&self, h: usize, i: usize) -> &[f32] {
        debug_assert!(self.dtype == KvDtype::F32, "KvBuffers::value on an int8 cache");
        let base = h * self.capacity * self.d + i * self.d;
        &self.v[base..base + self.d]
    }

    /// View as a selection-policy cache (carries the incremental norm
    /// cache, so cosine policies skip their renormalization pass; an int8
    /// cache additionally carries its key codes + scales and an empty f32
    /// slab).
    pub fn k_view(&self) -> crate::select::KCache<'_> {
        let kc = crate::select::KCache::with_norms(
            &self.k,
            self.n_kv,
            self.t,
            self.capacity,
            self.d,
            &self.k_inv_norm,
        );
        match self.dtype {
            KvDtype::F32 => kc,
            KvDtype::Int8 => kc.with_quant(&self.k_q, &self.k_scale),
        }
    }

    /// Bytes currently resident (K, V and the per-row metadata), derived
    /// from the actual element width of the cache dtype.
    pub fn resident_bytes(&self) -> usize {
        let rows = self.n_kv * self.capacity;
        let kv_bytes = 2 * rows * self.d * self.dtype.bytes();
        let meta_rows = match self.dtype {
            KvDtype::F32 => rows,      // inv_norm
            KvDtype::Int8 => 3 * rows, // inv_norm + k_scale + v_scale
        };
        kv_bytes + meta_rows * 4
    }
}

/// Reusable scratch arenas for the tiled attention kernel: one slot per
/// *worker* (tasks are strided across workers, each of which reuses its
/// slot serially), grown on demand and reused across calls — zero heap
/// allocation in the steady state, and retained memory scales with core
/// count rather than chunk size.
#[derive(Default)]
pub struct AttnScratch {
    workers: Vec<TaskScratch>,
}

#[derive(Default)]
struct TaskScratch {
    /// Gathered contiguous K rows for the current tile, `[KTILE, d]`.
    k_tile: Vec<f32>,
    /// Gathered contiguous V rows for the current tile, `[KTILE, d]`.
    v_tile: Vec<f32>,
    /// Gathered int8 K codes for the current tile, `[KTILE, d]` (int8
    /// caches only — the fp32 tiles stay empty on that path and vice
    /// versa).
    k_tile_q: Vec<i8>,
    /// Gathered int8 V codes for the current tile, `[KTILE, d]`.
    v_tile_q: Vec<i8>,
    /// Gathered per-row K dequant scales for the current tile, `[KTILE]`.
    k_scale_tile: Vec<f32>,
    /// Gathered per-row V dequant scales for the current tile, `[KTILE]`.
    v_scale_tile: Vec<f32>,
    /// Score block `[QBLOCK, KTILE]` — tile-local, replaces the seed
    /// kernel's O(selected + s) per-query score row.
    scores: Vec<f32>,
    /// Online-softmax running max per (group head, query row).
    m: Vec<f32>,
    /// Online-softmax running denominator per (group head, query row).
    l: Vec<f32>,
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }

    /// Total float-equivalents currently held across all worker arenas
    /// (i8 arenas count 4 codes per float) — test hook for the "no
    /// steady-state allocation" invariant (stable across repeated calls
    /// of the same shape).
    pub fn allocated_floats(&self) -> usize {
        self.workers
            .iter()
            .map(|t| {
                t.k_tile.capacity()
                    + t.v_tile.capacity()
                    + t.k_scale_tile.capacity()
                    + t.v_scale_tile.capacity()
                    + (t.k_tile_q.capacity() + t.v_tile_q.capacity()).div_ceil(4)
                    + t.scores.capacity()
                    + t.m.capacity()
                    + t.l.capacity()
            })
            .sum()
    }
}

/// Chunked-prefill attention (group-tiled, online-softmax kernel).
///
/// * `q` — `[n_q_heads, s, d]` RoPE'd queries for the chunk.
/// * `k_self`/`v_self` — `[n_kv, s, d]` the chunk's own keys/values.
/// * `cache` — past KV (`cache.t` rows, *excluding* the current chunk).
/// * `sel` — selection over the past cache.
/// * `scratch` — reusable tile/state arenas (see [`AttnScratch`]).
/// * `out` — `[n_q_heads, s, d]` attention output (overwritten).
#[allow(clippy::too_many_arguments)]
pub fn chunk_attention(
    q: &[f32],
    n_q_heads: usize,
    s: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    let _t = crate::obs::phase::scoped(crate::obs::phase::Phase::Attn);
    debug_assert_eq!(q.len(), n_q_heads * s * d);
    debug_assert_eq!(out.len(), n_q_heads * s * d);
    let n_kv = cache.n_kv;
    let g = n_q_heads / n_kv;
    let t = cache.t;
    let out_ptr = SyncPtr::new(out.as_mut_ptr());
    run_tiled_tasks(n_q_heads, n_kv, s, QBLOCK, t, d, scratch, |kv, gq_lo, gq_hi, q_lo, q_hi, ts| {
        group_block_attention(
            q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, k_self, v_self, cache, sel, ts, out_ptr,
        );
    });
}

/// Shared task decomposition of the tiled kernels (contiguous, paged and
/// batched-decode): split `(kv_head, query-block[, group-slice])` tasks
/// across workers and run `task(kv, gq_lo, gq_hi, q_lo, q_hi,
/// scratch_slot)` for each, with `qblock` query rows per task (the chunk
/// kernels use [`QBLOCK`]; batched decode uses 1, because each "row" is an
/// independent sequence with its own cache and selection).
///
/// Tasks are fully independent; fan across the machine when the work is
/// large enough to amortize thread wake-ups. Tasks are strided across
/// workers (near-uniform cost per task), each worker serially reusing one
/// scratch slot — so retained scratch is O(workers), not O(tasks). When
/// `(kv_head, q-block)` tasks alone can't occupy the machine — the decode
/// path has one query block, capping tasks at `n_kv` — each GQA group is
/// split across tasks as well (this repeats the tile gather per sub-group,
/// so it's only enabled when tasks are scarce).
#[allow(clippy::too_many_arguments)]
fn run_tiled_tasks<F>(
    n_q_heads: usize,
    n_kv: usize,
    s: usize,
    qblock: usize,
    t: usize,
    d: usize,
    scratch: &mut AttnScratch,
    task: F,
) where
    F: Fn(usize, usize, usize, usize, usize, &mut TaskScratch) + Sync,
{
    let g = n_q_heads / n_kv;
    let n_qblocks = s.div_ceil(qblock);
    let base_tasks = n_kv * n_qblocks;
    let work = n_q_heads * s * (t + s) * d;
    let workers_avail = if work > 1 << 21 {
        crate::util::threadpool::default_workers()
    } else {
        1
    };
    let g_split = if workers_avail > base_tasks {
        workers_avail.div_ceil(base_tasks).min(g).max(1)
    } else {
        1
    };
    let heads_per_task = g.div_ceil(g_split);
    let n_tasks = base_tasks * g_split;
    let workers = workers_avail.min(n_tasks);
    if scratch.workers.len() < workers {
        scratch.workers.resize_with(workers, TaskScratch::default);
    }

    let worker_ptr = SyncPtr::new(scratch.workers.as_mut_ptr());
    crate::util::threadpool::parallel_for(workers, workers, |w| {
        // SAFETY: worker `w` owns exactly one scratch slot, and its strided
        // task set writes exclusively to its own (head, query-row) slabs.
        let ts = unsafe { &mut *worker_ptr.get().add(w) };
        let mut ti = w;
        while ti < n_tasks {
            let kv = ti / (n_qblocks * g_split);
            let rem = ti % (n_qblocks * g_split);
            let qb = rem / g_split;
            let gs = rem % g_split;
            let q_lo = qb * qblock;
            let q_hi = ((qb + 1) * qblock).min(s);
            let gq_lo = gs * heads_per_task;
            let gq_hi = ((gs + 1) * heads_per_task).min(g);
            if gq_lo < gq_hi {
                task(kv, gq_lo, gq_hi, q_lo, q_hi, ts);
            }
            ti += workers;
        }
    });
}

/// Re-borrow one output row `(h, qi)` from the shared output pointer.
///
/// # Safety
/// The caller must be the unique writer of this row for the duration of
/// the borrow (guaranteed by the disjoint task decomposition).
#[inline]
unsafe fn raw_row<'a>(p: SyncPtr<f32>, offset: usize, d: usize) -> &'a mut [f32] {
    std::slice::from_raw_parts_mut(p.get().add(offset), d)
}

/// Prepare a task's online-softmax state and zero its output slabs
/// (accumulated unnormalized, divided by the denominator at the end).
#[allow(clippy::too_many_arguments)]
fn task_init(
    ts: &mut TaskScratch,
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    out: SyncPtr<f32>,
) {
    let rows = (gq_hi - gq_lo) * (q_hi - q_lo);
    let TaskScratch { scores, m, l, .. } = ts;
    fit(m, rows).fill(f32::NEG_INFINITY);
    fit(l, rows).fill(0.0);
    fit(scores, QBLOCK * KTILE);
    for gq in gq_lo..gq_hi {
        let h = kv * g + gq;
        for qi in q_lo..q_hi {
            unsafe { raw_row(out, (h * s + qi) * d, d) }.fill(0.0);
        }
    }
}

/// Score one contiguous K/V tile of the selected past against every query
/// of the task and fold it into the running online-softmax state.
#[allow(clippy::too_many_arguments)]
#[inline]
fn score_past_tile(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    kt: &[f32],
    vt: &[f32],
    tn: usize,
    scale: f32,
    scores: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
    out: SyncPtr<f32>,
) {
    let mb = q_hi - q_lo;
    for gq in gq_lo..gq_hi {
        let h = kv * g + gq;
        let qs = &q[(h * s + q_lo) * d..(h * s + q_hi) * d];
        let blk = &mut scores[..mb * tn];
        qk_block(qs, mb, kt, tn, d, blk);
        for r in 0..mb {
            let row = &mut blk[r * tn..(r + 1) * tn];
            for v in row.iter_mut() {
                *v *= scale;
            }
            let orow = unsafe { raw_row(out, (h * s + q_lo + r) * d, d) };
            let ri = (gq - gq_lo) * mb + r;
            online_softmax_update(row, vt, tn, d, &mut m[ri], &mut l[ri], orow);
        }
    }
}

/// [`score_past_tile`] over an int8 tile: scores come from
/// [`qk_block_q8`] (scale folded into the integer dot product) and the V
/// accumulation streams the i8 value codes through
/// [`online_softmax_update_q8`].
#[allow(clippy::too_many_arguments)]
#[inline]
fn score_past_tile_q8(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    kt: &[i8],
    k_scales: &[f32],
    vt: &[i8],
    v_scales: &[f32],
    tn: usize,
    scale: f32,
    scores: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
    out: SyncPtr<f32>,
) {
    let mb = q_hi - q_lo;
    for gq in gq_lo..gq_hi {
        let h = kv * g + gq;
        let qs = &q[(h * s + q_lo) * d..(h * s + q_hi) * d];
        let blk = &mut scores[..mb * tn];
        qk_block_q8(qs, mb, kt, k_scales, tn, d, blk);
        for r in 0..mb {
            let row = &mut blk[r * tn..(r + 1) * tn];
            for v in row.iter_mut() {
                *v *= scale;
            }
            let orow = unsafe { raw_row(out, (h * s + q_lo + r) * d, d) };
            let ri = (gq - gq_lo) * mb + r;
            online_softmax_update_q8(row, vt, v_scales, tn, d, &mut m[ri], &mut l[ri], orow);
        }
    }
}

/// The causal-self tiles (query `qi` sees self positions `0..=qi`; masked
/// positions are never scored, so no ±∞ sentinels enter the online
/// softmax) followed by the finalize division — shared by the contiguous
/// and paged kernels, whose only difference is how past tiles are formed.
#[allow(clippy::too_many_arguments)]
fn self_tiles_and_finalize(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    k_self: &[f32],
    v_self: &[f32],
    scale: f32,
    scores: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
    out: SyncPtr<f32>,
) {
    let mb = q_hi - q_lo;
    let ks = &k_self[kv * s * d..(kv + 1) * s * d];
    let vs = &v_self[kv * s * d..(kv + 1) * s * d];
    let mut tile_lo = 0;
    while tile_lo < q_hi {
        let tile_hi = (tile_lo + KTILE).min(q_hi);
        let kt = &ks[tile_lo * d..tile_hi * d];
        let vt = &vs[tile_lo * d..tile_hi * d];
        for gq in gq_lo..gq_hi {
            let h = kv * g + gq;
            for qi in q_lo.max(tile_lo)..q_hi {
                let visible = (qi + 1).min(tile_hi) - tile_lo;
                let qrow = &q[(h * s + qi) * d..(h * s + qi + 1) * d];
                let row = &mut scores[..visible];
                qk_dots(qrow, kt, visible, d, row);
                for v in row.iter_mut() {
                    *v *= scale;
                }
                let orow = unsafe { raw_row(out, (h * s + qi) * d, d) };
                let ri = (gq - gq_lo) * mb + (qi - q_lo);
                online_softmax_update(row, vt, visible, d, &mut m[ri], &mut l[ri], orow);
            }
        }
        tile_lo = tile_hi;
    }

    // ---- finalize: divide by the online-softmax denominator ----
    for gq in gq_lo..gq_hi {
        let h = kv * g + gq;
        for r in 0..mb {
            let ri = (gq - gq_lo) * mb + r;
            let orow = unsafe { raw_row(out, (h * s + q_lo + r) * d, d) };
            if l[ri] > 0.0 {
                let inv = 1.0 / l[ri];
                for v in orow.iter_mut() {
                    *v *= inv;
                }
            } else {
                // No visible key at all (t == 0 handled by the self part;
                // defensive for fully-empty rows).
                orow.fill(0.0);
            }
        }
    }
}

/// Tiled attention for one task: query heads `gq_lo..gq_hi` of KV head
/// `kv`'s GQA group over query rows `q_lo..q_hi`.
#[allow(clippy::too_many_arguments)]
fn group_block_attention(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    ts: &mut TaskScratch,
    out: SyncPtr<f32>,
) {
    let t = cache.t;
    let scale = 1.0 / (d as f32).sqrt();
    task_init(ts, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, out);

    let hsel = sel.head(kv, t);
    past_tiles_contig(q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, cache, hsel, ts, out);

    let TaskScratch { scores, m, l, .. } = ts;
    self_tiles_and_finalize(
        q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, k_self, v_self, scale, scores, m, l, out,
    );
}

/// The selected-past tile loop over a **contiguous** cache: gather each
/// tile's K/V rows into contiguous scratch (a full selection streams the
/// head slab in place) and fold it into the online-softmax state. Shared
/// by [`chunk_attention`] tasks and the batched decode kernel. Int8
/// caches route to the quantized twin ([`past_tiles_contig_q8`]).
#[allow(clippy::too_many_arguments)]
fn past_tiles_contig(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    cache: &KvBuffers,
    hsel: HeadSel,
    ts: &mut TaskScratch,
    out: SyncPtr<f32>,
) {
    if cache.dtype == KvDtype::Int8 {
        return past_tiles_contig_q8(q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, cache, hsel, ts, out);
    }
    let t = cache.t;
    let scale = 1.0 / (d as f32).sqrt();
    let n_past = hsel.len();
    let head_base = kv * cache.capacity * d;
    let khead = &cache.k[head_base..head_base + t * d];
    let vhead = &cache.v[head_base..head_base + t * d];
    let TaskScratch { k_tile, v_tile, scores, m, l, .. } = ts;

    let mut tile_lo = 0;
    while tile_lo < n_past {
        let tile_hi = (tile_lo + KTILE).min(n_past);
        let tn = tile_hi - tile_lo;
        // Gather the tile's K/V rows into contiguous scratch; a full
        // selection reads the (already contiguous) head slab in place.
        let (kt, vt): (&[f32], &[f32]) = match hsel {
            HeadSel::All(_) => (&khead[tile_lo * d..tile_hi * d], &vhead[tile_lo * d..tile_hi * d]),
            HeadSel::Idx(idx) => {
                let kt = fit(k_tile, KTILE * d);
                let vt = fit(v_tile, KTILE * d);
                for (o, &pi) in idx[tile_lo..tile_hi].iter().enumerate() {
                    let src = pi as usize * d;
                    kt[o * d..(o + 1) * d].copy_from_slice(&khead[src..src + d]);
                    vt[o * d..(o + 1) * d].copy_from_slice(&vhead[src..src + d]);
                }
                (&kt[..tn * d], &vt[..tn * d])
            }
        };
        score_past_tile(
            q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, kt, vt, tn, scale, scores, m, l, out,
        );
        tile_lo = tile_hi;
    }
}

/// [`past_tiles_contig`] over int8 storage: tiles are `(i8 codes, f32
/// per-row scales)` pairs consumed directly by the `_q8` kernels — no
/// fp32 copy of the cache rows is ever formed, sparse gathers move 1-byte
/// codes plus one scale per row.
#[allow(clippy::too_many_arguments)]
fn past_tiles_contig_q8(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    cache: &KvBuffers,
    hsel: HeadSel,
    ts: &mut TaskScratch,
    out: SyncPtr<f32>,
) {
    let t = cache.t;
    let scale = 1.0 / (d as f32).sqrt();
    let n_past = hsel.len();
    let head_base = kv * cache.capacity * d;
    let khead = &cache.k_q[head_base..head_base + t * d];
    let vhead = &cache.v_q[head_base..head_base + t * d];
    let meta_base = kv * cache.capacity;
    let kscales = &cache.k_scale[meta_base..meta_base + t];
    let vscales = &cache.v_scale[meta_base..meta_base + t];
    let TaskScratch { k_tile_q, v_tile_q, k_scale_tile, v_scale_tile, scores, m, l, .. } = ts;

    let mut tile_lo = 0;
    while tile_lo < n_past {
        let tile_hi = (tile_lo + KTILE).min(n_past);
        let tn = tile_hi - tile_lo;
        let (kt, ksc, vt, vsc): (&[i8], &[f32], &[i8], &[f32]) = match hsel {
            HeadSel::All(_) => (
                &khead[tile_lo * d..tile_hi * d],
                &kscales[tile_lo..tile_hi],
                &vhead[tile_lo * d..tile_hi * d],
                &vscales[tile_lo..tile_hi],
            ),
            HeadSel::Idx(idx) => {
                let kt = fit_i8(k_tile_q, KTILE * d);
                let vt = fit_i8(v_tile_q, KTILE * d);
                let ksc = fit(k_scale_tile, KTILE);
                let vsc = fit(v_scale_tile, KTILE);
                for (o, &pi) in idx[tile_lo..tile_hi].iter().enumerate() {
                    let src = pi as usize * d;
                    kt[o * d..(o + 1) * d].copy_from_slice(&khead[src..src + d]);
                    vt[o * d..(o + 1) * d].copy_from_slice(&vhead[src..src + d]);
                    ksc[o] = kscales[pi as usize];
                    vsc[o] = vscales[pi as usize];
                }
                (&kt[..tn * d], &ksc[..tn], &vt[..tn * d], &vsc[..tn])
            }
        };
        score_past_tile_q8(
            q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, kt, ksc, vt, vsc, tn, scale, scores, m, l,
            out,
        );
        tile_lo = tile_hi;
    }
}

/// [`group_block_attention`] over a **paged** cache: tiles are formed
/// through the block table. Full selections stream each page's
/// (contiguous) head-row run in place — no gather; sparse selections
/// gather rows through the page indirection exactly like the contiguous
/// kernel gathers through the head slab.
#[allow(clippy::too_many_arguments)]
fn group_block_attention_paged(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    k_self: &[f32],
    v_self: &[f32],
    paged: &PagedKv,
    sel: &Selection,
    ts: &mut TaskScratch,
    out: SyncPtr<f32>,
) {
    let t = paged.t;
    let scale = 1.0 / (d as f32).sqrt();
    task_init(ts, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, out);

    let hsel = sel.head(kv, t);
    past_tiles_paged(q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, paged, hsel, ts, out);

    let TaskScratch { scores, m, l, .. } = ts;
    self_tiles_and_finalize(
        q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, k_self, v_self, scale, scores, m, l, out,
    );
}

/// The selected-past tile loop over a **paged** cache: full selections
/// stream each page's (contiguous) head-row run in place — no gather;
/// sparse selections gather rows through the page indirection exactly like
/// the contiguous kernel gathers through the head slab. Shared by
/// [`paged_chunk_attention`] tasks and the batched decode kernel. Int8
/// pools route to the quantized twin ([`past_tiles_paged_q8`]).
#[allow(clippy::too_many_arguments)]
fn past_tiles_paged(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    paged: &PagedKv,
    hsel: HeadSel,
    ts: &mut TaskScratch,
    out: SyncPtr<f32>,
) {
    if paged.dtype == KvDtype::Int8 {
        return past_tiles_paged_q8(q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, paged, hsel, ts, out);
    }
    let t = paged.t;
    let scale = 1.0 / (d as f32).sqrt();
    let TaskScratch { k_tile, v_tile, scores, m, l, .. } = ts;
    match hsel {
        HeadSel::All(_) => {
            let bt = paged.block_tokens;
            let mut pos = 0;
            while pos < t {
                let slot = pos % bt;
                let page = paged.blocks[pos / bt] as usize;
                let tn = (bt - slot).min(t - pos).min(KTILE);
                let base = ((page * paged.n_kv + kv) * bt + slot) * d;
                let kt = &paged.k[base..base + tn * d];
                let vt = &paged.v[base..base + tn * d];
                score_past_tile(
                    q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, kt, vt, tn, scale, scores, m, l,
                    out,
                );
                pos += tn;
            }
        }
        HeadSel::Idx(idx) => {
            let n_past = idx.len();
            let mut tile_lo = 0;
            while tile_lo < n_past {
                let tile_hi = (tile_lo + KTILE).min(n_past);
                let tn = tile_hi - tile_lo;
                let kt = fit(k_tile, KTILE * d);
                let vt = fit(v_tile, KTILE * d);
                for (o, &pi) in idx[tile_lo..tile_hi].iter().enumerate() {
                    let src = paged.row_base(kv, pi as usize);
                    kt[o * d..(o + 1) * d].copy_from_slice(&paged.k[src..src + d]);
                    vt[o * d..(o + 1) * d].copy_from_slice(&paged.v[src..src + d]);
                }
                score_past_tile(
                    q,
                    s,
                    d,
                    g,
                    kv,
                    gq_lo,
                    gq_hi,
                    q_lo,
                    q_hi,
                    &kt[..tn * d],
                    &vt[..tn * d],
                    tn,
                    scale,
                    scores,
                    m,
                    l,
                    out,
                );
                tile_lo = tile_hi;
            }
        }
    }
}

/// [`past_tiles_paged`] over an int8 pool: full selections stream each
/// page's code run plus the matching per-row scale run in place; sparse
/// selections gather codes through the page indirection and scales
/// through the page-metadata slot.
#[allow(clippy::too_many_arguments)]
fn past_tiles_paged_q8(
    q: &[f32],
    s: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    q_lo: usize,
    q_hi: usize,
    paged: &PagedKv,
    hsel: HeadSel,
    ts: &mut TaskScratch,
    out: SyncPtr<f32>,
) {
    let t = paged.t;
    let scale = 1.0 / (d as f32).sqrt();
    let TaskScratch { k_tile_q, v_tile_q, k_scale_tile, v_scale_tile, scores, m, l, .. } = ts;
    match hsel {
        HeadSel::All(_) => {
            let bt = paged.block_tokens;
            let mut pos = 0;
            while pos < t {
                let slot = pos % bt;
                let page = paged.blocks[pos / bt] as usize;
                let tn = (bt - slot).min(t - pos).min(KTILE);
                let meta = (page * paged.n_kv + kv) * bt + slot;
                let base = meta * d;
                score_past_tile_q8(
                    q,
                    s,
                    d,
                    g,
                    kv,
                    gq_lo,
                    gq_hi,
                    q_lo,
                    q_hi,
                    &paged.kq[base..base + tn * d],
                    &paged.k_scale[meta..meta + tn],
                    &paged.vq[base..base + tn * d],
                    &paged.v_scale[meta..meta + tn],
                    tn,
                    scale,
                    scores,
                    m,
                    l,
                    out,
                );
                pos += tn;
            }
        }
        HeadSel::Idx(idx) => {
            let n_past = idx.len();
            let mut tile_lo = 0;
            while tile_lo < n_past {
                let tile_hi = (tile_lo + KTILE).min(n_past);
                let tn = tile_hi - tile_lo;
                let kt = fit_i8(k_tile_q, KTILE * d);
                let vt = fit_i8(v_tile_q, KTILE * d);
                let ksc = fit(k_scale_tile, KTILE);
                let vsc = fit(v_scale_tile, KTILE);
                for (o, &pi) in idx[tile_lo..tile_hi].iter().enumerate() {
                    let src = paged.row_base(kv, pi as usize);
                    let meta = paged.meta_base(kv, pi as usize);
                    kt[o * d..(o + 1) * d].copy_from_slice(&paged.kq[src..src + d]);
                    vt[o * d..(o + 1) * d].copy_from_slice(&paged.vq[src..src + d]);
                    ksc[o] = paged.k_scale[meta];
                    vsc[o] = paged.v_scale[meta];
                }
                score_past_tile_q8(
                    q,
                    s,
                    d,
                    g,
                    kv,
                    gq_lo,
                    gq_hi,
                    q_lo,
                    q_hi,
                    &kt[..tn * d],
                    &ksc[..tn],
                    &vt[..tn * d],
                    &vsc[..tn],
                    tn,
                    scale,
                    scores,
                    m,
                    l,
                    out,
                );
                tile_lo = tile_hi;
            }
        }
    }
}

/// Flash-style online softmax: fold one tile of (already scaled) logits
/// and its V rows into the running `(max, denominator, unnormalized
/// output)` state for a single query row.
fn online_softmax_update(
    logits: &mut [f32],
    v_tile: &[f32],
    n: usize,
    d: usize,
    m: &mut f32,
    l: &mut f32,
    acc: &mut [f32],
) {
    if n == 0 {
        return;
    }
    let mut tile_max = f32::NEG_INFINITY;
    for &v in logits[..n].iter() {
        if v > tile_max {
            tile_max = v;
        }
    }
    let new_m = if *m > tile_max { *m } else { tile_max };
    if *l > 0.0 && new_m > *m {
        // Rescale previously accumulated mass to the new max.
        let corr = (*m - new_m).exp();
        *l *= corr;
        for v in acc.iter_mut() {
            *v *= corr;
        }
    }
    let mut sum = 0.0;
    for v in logits[..n].iter_mut() {
        *v = (*v - new_m).exp();
        sum += *v;
    }
    *l += sum;
    av_accum(&logits[..n], v_tile, n, d, acc);
    *m = new_m;
}

/// [`online_softmax_update`] over an int8 V tile: identical max /
/// rescale / exponentiation, with the accumulation consuming the value
/// codes + per-row scales directly ([`av_accum_q8`]).
#[allow(clippy::too_many_arguments)]
fn online_softmax_update_q8(
    logits: &mut [f32],
    v_codes: &[i8],
    v_scales: &[f32],
    n: usize,
    d: usize,
    m: &mut f32,
    l: &mut f32,
    acc: &mut [f32],
) {
    if n == 0 {
        return;
    }
    let mut tile_max = f32::NEG_INFINITY;
    for &v in logits[..n].iter() {
        if v > tile_max {
            tile_max = v;
        }
    }
    let new_m = if *m > tile_max { *m } else { tile_max };
    if *l > 0.0 && new_m > *m {
        // Rescale previously accumulated mass to the new max.
        let corr = (*m - new_m).exp();
        *l *= corr;
        for v in acc.iter_mut() {
            *v *= corr;
        }
    }
    let mut sum = 0.0;
    for v in logits[..n].iter_mut() {
        *v = (*v - new_m).exp();
        sum += *v;
    }
    *l += sum;
    av_accum_q8(&logits[..n], v_codes, v_scales, n, d, acc);
    *m = new_m;
}

/// Chunked-prefill attention over the **shared paged KV pool**: identical
/// task decomposition and online-softmax math to [`chunk_attention`], with
/// every past-K/V access resolved through the sequence's block table
/// (`paged.blocks`). Numerics match the contiguous kernel to float
/// associativity (tile boundaries follow pages instead of [`KTILE`]);
/// parity against [`reference_chunk_attention`] is pinned in
/// `rust/tests/attn_parity.rs`.
#[allow(clippy::too_many_arguments)]
pub fn paged_chunk_attention(
    q: &[f32],
    n_q_heads: usize,
    s: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    paged: &PagedKv,
    sel: &Selection,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    let _t = crate::obs::phase::scoped(crate::obs::phase::Phase::Attn);
    debug_assert_eq!(q.len(), n_q_heads * s * d);
    debug_assert_eq!(out.len(), n_q_heads * s * d);
    debug_assert_eq!(paged.d, d);
    let n_kv = paged.n_kv;
    let g = n_q_heads / n_kv;
    let t = paged.t;
    let out_ptr = SyncPtr::new(out.as_mut_ptr());
    run_tiled_tasks(n_q_heads, n_kv, s, QBLOCK, t, d, scratch, |kv, gq_lo, gq_hi, q_lo, q_hi, ts| {
        group_block_attention_paged(
            q, s, d, g, kv, gq_lo, gq_hi, q_lo, q_hi, k_self, v_self, paged, sel, ts, out_ptr,
        );
    });
}

/// Per-sequence KV reference for the batched decode kernel: each sequence
/// in a decode batch attends to its own cache, which may live in private
/// contiguous buffers or in the shared paged pool — one batch can mix
/// both.
pub enum SeqKv<'a> {
    /// Private per-sequence buffers ([`KvBuffers`]).
    Contig(&'a KvBuffers),
    /// Shared-pool block-table view.
    Paged(PagedKv<'a>),
}

impl SeqKv<'_> {
    /// Valid (filled) past tokens of this sequence's cache.
    #[inline]
    pub fn t(&self) -> usize {
        match self {
            SeqKv::Contig(c) => c.t,
            SeqKv::Paged(p) => p.t,
        }
    }

    #[inline]
    fn n_kv(&self) -> usize {
        match self {
            SeqKv::Contig(c) => c.n_kv,
            SeqKv::Paged(p) => p.n_kv,
        }
    }
}

/// Batched decode attention: one query token per sequence, `bsz` sequences
/// side by side in the `[n_q_heads, bsz, d]` batch layout the batched
/// forward pass produces (sequence `b`, head `h` at row `h * bsz + b`;
/// `k_self`/`v_self` likewise `[n_kv, bsz, d]`).
///
/// Each sequence attends to its own *selected* past (`seqs[b]`) plus its
/// own current-token key/value only — there is no cross-sequence
/// attention, so the work decomposes into independent `(sequence,
/// kv_head[, group-slice])` tasks, each running the PR-1 tile pipeline
/// ([`past_tiles_contig`] / [`past_tiles_paged`] + online softmax) out of
/// the shared [`AttnScratch`] worker arenas. Per-sequence numerics are
/// identical to [`chunk_attention`] with `s = 1` regardless of `bsz` (same
/// tile boundaries, same accumulation order), which is what pins the
/// batched-vs-serial exact-token parity tests.
#[allow(clippy::too_many_arguments)]
pub fn batched_decode_attention(
    q: &[f32],
    n_q_heads: usize,
    bsz: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    seqs: &[(SeqKv, &Selection)],
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    let _t = crate::obs::phase::scoped(crate::obs::phase::Phase::Attn);
    assert_eq!(seqs.len(), bsz);
    assert!(bsz > 0);
    debug_assert_eq!(q.len(), n_q_heads * bsz * d);
    debug_assert_eq!(out.len(), n_q_heads * bsz * d);
    let n_kv = seqs[0].0.n_kv();
    debug_assert!(seqs.iter().all(|(kv, _)| kv.n_kv() == n_kv));
    let g = n_q_heads / n_kv;
    let t_max = seqs.iter().map(|(kv, _)| kv.t()).max().unwrap_or(0);
    let out_ptr = SyncPtr::new(out.as_mut_ptr());
    // qblock = 1: every task is one sequence × one kv head (× group
    // slice), so parallelism scales with the batch instead of capping at
    // n_kv the way one-sequence decode does.
    run_tiled_tasks(n_q_heads, n_kv, bsz, 1, t_max, d, scratch, |kv, gq_lo, gq_hi, b_lo, b_hi, ts| {
        for b in b_lo..b_hi {
            let (seq_kv, sel) = &seqs[b];
            let t = seq_kv.t();
            task_init(ts, bsz, d, g, kv, gq_lo, gq_hi, b, b + 1, out_ptr);
            let hsel = sel.head(kv, t);
            match seq_kv {
                SeqKv::Contig(cache) => past_tiles_contig(
                    q, bsz, d, g, kv, gq_lo, gq_hi, b, b + 1, cache, hsel, ts, out_ptr,
                ),
                SeqKv::Paged(paged) => past_tiles_paged(
                    q, bsz, d, g, kv, gq_lo, gq_hi, b, b + 1, paged, hsel, ts, out_ptr,
                ),
            }
            let TaskScratch { scores, m, l, .. } = &mut *ts;
            self_single_and_finalize(
                q, bsz, d, g, kv, gq_lo, gq_hi, b, k_self, v_self, scores, m, l, out_ptr,
            );
        }
    });
}

/// The decode batch's causal-self part: sequence `b` sees exactly one self
/// key — its own current token — never its batch neighbors' (rows of the
/// `[n_kv, bsz, d]` self slabs belonging to other sequences are other
/// sequences' tokens, not earlier chunk positions). Folds that single key
/// into the online softmax and performs the finalize division, mirroring
/// [`self_tiles_and_finalize`] at `s = 1`.
#[allow(clippy::too_many_arguments)]
fn self_single_and_finalize(
    q: &[f32],
    bsz: usize,
    d: usize,
    g: usize,
    kv: usize,
    gq_lo: usize,
    gq_hi: usize,
    b: usize,
    k_self: &[f32],
    v_self: &[f32],
    scores: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
    out: SyncPtr<f32>,
) {
    let scale = 1.0 / (d as f32).sqrt();
    let ks = &k_self[(kv * bsz + b) * d..(kv * bsz + b + 1) * d];
    let vs = &v_self[(kv * bsz + b) * d..(kv * bsz + b + 1) * d];
    for gq in gq_lo..gq_hi {
        let h = kv * g + gq;
        let qrow = &q[(h * bsz + b) * d..(h * bsz + b + 1) * d];
        let row = &mut scores[..1];
        qk_dots(qrow, ks, 1, d, row);
        row[0] *= scale;
        let orow = unsafe { raw_row(out, (h * bsz + b) * d, d) };
        let ri = gq - gq_lo; // one query row per head in a decode task
        online_softmax_update(row, vs, 1, d, &mut m[ri], &mut l[ri], orow);
        if l[ri] > 0.0 {
            let inv = 1.0 / l[ri];
            for v in orow.iter_mut() {
                *v *= inv;
            }
        } else {
            orow.fill(0.0);
        }
    }
}

/// Single-query decode attention over a selected cache (which must already
/// include all generated tokens; the current token's K/V is passed
/// separately, mirroring the prefill path with `s = 1`).
#[allow(clippy::too_many_arguments)]
pub fn decode_attention(
    q: &[f32],
    n_q_heads: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) {
    chunk_attention(q, n_q_heads, 1, d, k_self, v_self, cache, sel, scratch, out)
}

/// The seed kernel, kept verbatim as the parity/bench reference: one key
/// at a time over randomly-gathered cache rows, per-head index
/// materialization, a full `O(selected + s)` score row per query,
/// two-pass softmax — including the seed's per-query-head threading, so
/// `micro_hotpath`'s tiled-vs-seed speedup compares equal parallelism and
/// isolates the kernel rewrite. Allocating; never use on the hot path. It
/// exists so `rust/tests/attn_parity.rs` can pin the tiled kernel against
/// the original semantics and so the bench can report an honest speedup.
#[allow(clippy::too_many_arguments)]
pub fn reference_chunk_attention(
    q: &[f32],
    n_q_heads: usize,
    s: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), n_q_heads * s * d);
    debug_assert_eq!(out.len(), n_q_heads * s * d);
    let n_kv = cache.n_kv;
    let g = n_q_heads / n_kv;
    let t = cache.t;
    // The seed's threading heuristic, verbatim.
    let work = n_q_heads * s * (t + s) * d;
    let threads = if work > 1 << 21 {
        crate::util::threadpool::default_workers().min(n_q_heads)
    } else {
        1
    };
    if threads <= 1 {
        let mut scores = Vec::new();
        for h in 0..n_q_heads {
            let slab = &mut out[h * s * d..(h + 1) * s * d];
            reference_head_attention(q, h, g, s, d, k_self, v_self, cache, sel, &mut scores, slab);
        }
    } else {
        let out_ptr = SyncPtr::new(out.as_mut_ptr());
        crate::util::threadpool::parallel_for(n_q_heads, threads, |h| {
            let mut scores = Vec::new();
            // SAFETY: each head writes exclusively to its own out slab.
            let slab =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(h * s * d), s * d) };
            reference_head_attention(q, h, g, s, d, k_self, v_self, cache, sel, &mut scores, slab);
        });
    }
}

/// Seed attention for one query head over [selected past | causal self].
#[allow(clippy::too_many_arguments)]
fn reference_head_attention(
    q: &[f32],
    h: usize,
    g: usize,
    s: usize,
    d: usize,
    k_self: &[f32],
    v_self: &[f32],
    cache: &KvBuffers,
    sel: &Selection,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let kv = h / g;
    let t = cache.t;
    let scale = 1.0 / (d as f32).sqrt();
    // Materialize this head's past indices once (the seed's per-head cost).
    let idx: Vec<u32> = sel.head_indices(kv, t);
    let n_past = idx.len();
    let total = n_past + s;
    if scores.len() < total {
        scores.resize(total, 0.0);
    }
    for qi in 0..s {
        let qrow = &q[(h * s + qi) * d..(h * s + qi + 1) * d];
        let row = &mut scores[..total];
        for (slot, &pi) in idx.iter().enumerate() {
            row[slot] = dot(qrow, cache.key(kv, pi as usize)) * scale;
        }
        for sj in 0..s {
            row[n_past + sj] = if sj <= qi {
                dot(qrow, &k_self[(kv * s + sj) * d..(kv * s + sj + 1) * d]) * scale
            } else {
                f32::NEG_INFINITY
            };
        }
        softmax(&mut row[..total]);
        let orow = &mut out[qi * d..(qi + 1) * d];
        orow.iter_mut().for_each(|x| *x = 0.0);
        for (slot, &pi) in idx.iter().enumerate() {
            let w = row[slot];
            if w != 0.0 {
                crate::tensor::ops::axpy(w, cache.value(kv, pi as usize), orow);
            }
        }
        for sj in 0..=qi {
            let w = row[n_past + sj];
            if w != 0.0 {
                crate::tensor::ops::axpy(
                    w,
                    &v_self[(kv * s + sj) * d..(kv * s + sj + 1) * d],
                    orow,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::Selection;
    use crate::util::Rng;

    fn setup(t: usize, s: usize, n_q: usize, n_kv: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, KvBuffers) {
        let mut rng = Rng::new(77);
        let q = rng.normal_vec(n_q * s * d, 1.0);
        let ks = rng.normal_vec(n_kv * s * d, 1.0);
        let vs = rng.normal_vec(n_kv * s * d, 1.0);
        let mut cache = KvBuffers::new(n_kv, d, 4);
        // Fill cache via appends of varying size to exercise growth.
        let mut filled = 0;
        while filled < t {
            let step = (t - filled).min(3);
            let kk = rng.normal_vec(n_kv * step * d, 1.0);
            let vv = rng.normal_vec(n_kv * step * d, 1.0);
            cache.append(&kk, &vv, step);
            filled += step;
        }
        (q, ks, vs, cache)
    }

    #[test]
    fn append_and_grow_preserves_rows() {
        let mut rng = Rng::new(1);
        let (n_kv, d) = (2usize, 4usize);
        let mut cache = KvBuffers::new(n_kv, d, 2);
        let k1 = rng.normal_vec(n_kv * 3 * d, 1.0);
        let v1 = rng.normal_vec(n_kv * 3 * d, 1.0);
        cache.append(&k1, &v1, 3);
        let first_key: Vec<f32> = cache.key(1, 0).to_vec();
        let k2 = rng.normal_vec(n_kv * 5 * d, 1.0);
        let v2 = rng.normal_vec(n_kv * 5 * d, 1.0);
        cache.append(&k2, &v2, 5);
        assert_eq!(cache.t, 8);
        assert_eq!(cache.key(1, 0), &first_key[..]);
        assert_eq!(cache.key(0, 4), &k2[d..2 * d]);
    }

    #[test]
    fn truncate_rolls_back_to_a_never_appended_state() {
        let mut rng = Rng::new(23);
        let (n_kv, d) = (2usize, 4usize);
        let (base, draft, keep) = (5usize, 4usize, 2usize);
        let kb = rng.normal_vec(n_kv * base * d, 1.0);
        let vb = rng.normal_vec(n_kv * base * d, 1.0);
        let kd = rng.normal_vec(n_kv * draft * d, 1.0);
        let vd = rng.normal_vec(n_kv * draft * d, 1.0);
        let mut spec = KvBuffers::new(n_kv, d, 2);
        spec.append(&kb, &vb, base);
        spec.append(&kd, &vd, draft);
        spec.truncate(base + keep);
        // Oracle: only ever appended base + the accepted prefix.
        let head = |s: &[f32]| -> Vec<f32> {
            (0..n_kv).flat_map(|h| s[h * draft * d..(h * draft + keep) * d].to_vec()).collect()
        };
        let mut want = KvBuffers::new(n_kv, d, 2);
        want.append(&kb, &vb, base);
        want.append(&head(&kd), &head(&vd), keep);
        assert_eq!(spec.t, want.t);
        for h in 0..n_kv {
            for i in 0..spec.t {
                assert_eq!(spec.key(h, i), want.key(h, i), "key ({h},{i})");
                assert_eq!(spec.value(h, i), want.value(h, i), "value ({h},{i})");
                assert_eq!(
                    spec.k_inv_norm[h * spec.capacity + i],
                    want.k_inv_norm[h * want.capacity + i],
                    "norm ({h},{i})"
                );
            }
            // Truncated rows' norm-cache entries are zeroed (dead rows).
            for i in spec.t..base + draft {
                assert_eq!(spec.k_inv_norm[h * spec.capacity + i], 0.0, "stale norm ({h},{i})");
            }
        }
        // Appending after a rollback overwrites the dead rows cleanly.
        let k1 = rng.normal_vec(n_kv * d, 1.0);
        let v1 = rng.normal_vec(n_kv * d, 1.0);
        spec.append(&k1, &v1, 1);
        want.append(&k1, &v1, 1);
        assert_eq!(spec.t, want.t);
        assert_eq!(spec.key(1, spec.t - 1), want.key(1, want.t - 1));
    }

    #[test]
    fn norm_cache_tracks_appends() {
        let (_, _, _, cache) = setup(13, 2, 2, 2, 6);
        for h in 0..cache.n_kv {
            for i in 0..cache.t {
                let n = crate::tensor::ops::l2_norm(cache.key(h, i));
                let want = if n > 0.0 { 1.0 / n } else { 0.0 };
                let got = cache.k_inv_norm[h * cache.capacity + i];
                assert!((got - want).abs() < 1e-6, "({h},{i}): {got} vs {want}");
            }
        }
        let kv = cache.k_view();
        assert!(kv.inv_norms.is_some());
        assert!((kv.inv_norm(0, 3) - cache.k_inv_norm[3]).abs() < 1e-9);
    }

    #[test]
    fn dense_attention_weights_sum_to_one() {
        // With all-equal values, output must equal that value regardless of
        // the score distribution (softmax weights sum to 1).
        let (t, s, n_q, n_kv, d) = (6usize, 3usize, 2usize, 1usize, 4usize);
        let (q, ks, _, mut cache) = setup(t, s, n_q, n_kv, d);
        let vs = vec![2.5f32; n_kv * s * d];
        cache.v.iter_mut().for_each(|x| *x = 2.5);
        let mut out = vec![0.0; n_q * s * d];
        let mut scratch = AttnScratch::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut out);
        for x in &out {
            assert!((x - 2.5).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn causal_mask_blocks_future_self_tokens() {
        // First query of the chunk must ignore later chunk tokens: make the
        // past empty and plant a huge value in self position 2; query 0's
        // output must not see it, query 2's must.
        let (s, n_q, n_kv, d) = (3usize, 1usize, 1usize, 4usize);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(s * d, 1.0);
        let ks = rng.normal_vec(s * d, 1.0);
        let mut vs = vec![0.0; s * d];
        vs[2 * d] = 100.0; // value spike at self position 2
        let cache = KvBuffers::new(n_kv, d, 1);
        let mut out = vec![0.0; s * d];
        let mut scratch = AttnScratch::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut out);
        assert!(out[0].abs() < 1.0, "q0 saw the future: {}", out[0]);
        assert!(out[2 * d].abs() > 1.0, "q2 should see position 2");
    }

    #[test]
    fn selection_restricts_past() {
        // Plant a value spike at past index 5; selecting {5} vs excluding it
        // must change the output.
        let (t, s, n_q, n_kv, d) = (10usize, 2usize, 2usize, 2usize, 4usize);
        let (q, ks, vs, mut cache) = setup(t, s, n_q, n_kv, d);
        for h in 0..n_kv {
            let base = h * cache.capacity * d + 5 * d;
            cache.v[base] = 50.0;
        }
        let mut with = vec![0.0; n_q * s * d];
        let mut without = vec![0.0; n_q * s * d];
        let mut scratch = AttnScratch::new();
        let sel_with = Selection::PerHead(vec![vec![1, 5], vec![1, 5]]);
        let sel_without = Selection::PerHead(vec![vec![1, 2], vec![1, 2]]);
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &sel_with, &mut scratch, &mut with);
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &sel_without, &mut scratch, &mut without);
        let diff: f32 = with.iter().zip(&without).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0);
    }

    #[test]
    fn full_selection_equals_all() {
        let (t, s, n_q, n_kv, d) = (8usize, 2usize, 4usize, 2usize, 8usize);
        let (q, ks, vs, cache) = setup(t, s, n_q, n_kv, d);
        let mut a = vec![0.0; n_q * s * d];
        let mut b = vec![0.0; n_q * s * d];
        let mut scratch = AttnScratch::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut a);
        let explicit = Selection::PerHead(vec![(0..t as u32).collect(), (0..t as u32).collect()]);
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &explicit, &mut scratch, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn decode_matches_prefill_s1() {
        let (t, _s, n_q, n_kv, d) = (12usize, 1usize, 2usize, 1usize, 4usize);
        let (q, ks, vs, cache) = setup(t, 1, n_q, n_kv, d);
        let mut a = vec![0.0; n_q * d];
        let mut b = vec![0.0; n_q * d];
        let mut scratch = AttnScratch::new();
        chunk_attention(&q, n_q, 1, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut a);
        decode_attention(&q, n_q, d, &ks, &vs, &cache, &Selection::All, &mut scratch, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn batched_decode_matches_serial_s1_smoke() {
        // Full matrix in rust/tests/decode_batch.rs; here: a 3-sequence
        // batch (different cache depths + selections) must reproduce three
        // independent chunk_attention(s=1) calls bit-exactly.
        let (n_q, n_kv, d) = (4usize, 2usize, 8usize);
        let depths = [5usize, 12, 9];
        let bsz = depths.len();
        let mut rng = Rng::new(123);
        let caches: Vec<KvBuffers> = depths
            .iter()
            .map(|&t| {
                let mut c = KvBuffers::new(n_kv, d, 4);
                let kk = rng.normal_vec(n_kv * t * d, 1.0);
                let vv = rng.normal_vec(n_kv * t * d, 1.0);
                c.append(&kk, &vv, t);
                c
            })
            .collect();
        let sels = [
            Selection::All,
            Selection::PerHead(vec![vec![0, 3, 7, 11], vec![2, 5, 10]]),
            Selection::PerHead(vec![vec![1, 8], vec![0, 4, 6]]),
        ];
        // Batch layout [h, b, d]; serial layout [h, 1, d] per sequence.
        let qb = rng.normal_vec(n_q * bsz * d, 1.0);
        let ksb = rng.normal_vec(n_kv * bsz * d, 1.0);
        let vsb = rng.normal_vec(n_kv * bsz * d, 1.0);
        let mut scratch = AttnScratch::new();
        let mut got = vec![0.0; n_q * bsz * d];
        let seqs: Vec<(SeqKv, &Selection)> =
            caches.iter().zip(&sels).map(|(c, s)| (SeqKv::Contig(c), s)).collect();
        batched_decode_attention(&qb, n_q, bsz, d, &ksb, &vsb, &seqs, &mut scratch, &mut got);
        for b in 0..bsz {
            let pick = |slab: &[f32], nh: usize| -> Vec<f32> {
                (0..nh).flat_map(|h| slab[(h * bsz + b) * d..(h * bsz + b + 1) * d].to_vec()).collect()
            };
            let (q1, ks1, vs1) = (pick(&qb, n_q), pick(&ksb, n_kv), pick(&vsb, n_kv));
            let mut want = vec![0.0; n_q * d];
            chunk_attention(&q1, n_q, 1, d, &ks1, &vs1, &caches[b], &sels[b], &mut scratch, &mut want);
            assert_eq!(pick(&got, n_q), want, "sequence {b}");
        }
    }

    #[test]
    fn append_token_strided_matches_append() {
        let (n_kv, d, bsz, seq) = (2usize, 4usize, 3usize, 1usize);
        let mut rng = Rng::new(17);
        let kb = rng.normal_vec(n_kv * bsz * d, 1.0);
        let vb = rng.normal_vec(n_kv * bsz * d, 1.0);
        let mut a = KvBuffers::new(n_kv, d, 1);
        a.append_token_strided(&kb, &vb, seq, bsz);
        // Contiguous oracle: gather sequence `seq`'s rows and append.
        let pick = |slab: &[f32]| -> Vec<f32> {
            (0..n_kv).flat_map(|h| slab[(h * bsz + seq) * d..(h * bsz + seq + 1) * d].to_vec()).collect()
        };
        let mut b = KvBuffers::new(n_kv, d, 1);
        b.append(&pick(&kb), &pick(&vb), 1);
        assert_eq!(a.t, 1);
        for h in 0..n_kv {
            assert_eq!(a.key(h, 0), b.key(h, 0));
            assert_eq!(a.value(h, 0), b.value(h, 0));
            assert_eq!(a.k_inv_norm[h * a.capacity], b.k_inv_norm[h * b.capacity]);
        }
    }

    #[test]
    fn tiled_matches_reference_smoke() {
        // The full parity matrix lives in rust/tests/attn_parity.rs; this
        // in-module smoke check catches gross regressions fast.
        let (t, s, n_q, n_kv, d) = (40usize, 9usize, 4usize, 2usize, 12usize);
        let (q, ks, vs, cache) = setup(t, s, n_q, n_kv, d);
        let sel = Selection::PerHead(vec![vec![0, 3, 7, 21, 39], vec![2, 5, 11, 30]]);
        let mut a = vec![0.0; n_q * s * d];
        let mut b = vec![0.0; n_q * s * d];
        let mut scratch = AttnScratch::new();
        chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &sel, &mut scratch, &mut a);
        reference_chunk_attention(&q, n_q, s, d, &ks, &vs, &cache, &sel, &mut b);
        assert!(crate::tensor::ops::rel_l2(&a, &b) < 1e-5);
    }

    #[test]
    fn int8_cache_tracks_f32_attention_within_quant_tolerance() {
        // Full matrix (paged, GQA, odd shapes) in rust/tests/attn_parity.rs.
        let (t, s, n_q, n_kv, d) = (40usize, 5usize, 4usize, 2usize, 16usize);
        let mut rng = Rng::new(91);
        let q = rng.normal_vec(n_q * s * d, 1.0);
        let ks = rng.normal_vec(n_kv * s * d, 1.0);
        let vs = rng.normal_vec(n_kv * s * d, 1.0);
        let mut f32c = KvBuffers::new(n_kv, d, 4);
        let mut q8c = KvBuffers::new_with_dtype(n_kv, d, 4, KvDtype::Int8);
        let mut filled = 0;
        while filled < t {
            let step = (t - filled).min(7);
            let kk = rng.normal_vec(n_kv * step * d, 1.0);
            let vv = rng.normal_vec(n_kv * step * d, 1.0);
            f32c.append(&kk, &vv, step);
            q8c.append(&kk, &vv, step);
            filled += step;
        }
        assert!(q8c.resident_bytes() < f32c.resident_bytes());
        let sels = [
            Selection::All,
            Selection::PerHead(vec![vec![0, 3, 7, 21, 39], vec![2, 5, 11, 30]]),
        ];
        let mut scratch = AttnScratch::new();
        for sel in &sels {
            let mut a = vec![0.0; n_q * s * d];
            let mut b = vec![0.0; n_q * s * d];
            chunk_attention(&q, n_q, s, d, &ks, &vs, &f32c, sel, &mut scratch, &mut a);
            chunk_attention(&q, n_q, s, d, &ks, &vs, &q8c, sel, &mut scratch, &mut b);
            let e = crate::tensor::ops::rel_l2(&b, &a);
            assert!(e < 1e-2, "int8 drifted from f32: rel_l2 {e}");
            assert!(e > 0.0, "int8 path suspiciously bit-exact (not routed through q8?)");
        }
    }

    #[test]
    fn int8_truncate_matches_never_appended_metadata() {
        let mut rng = Rng::new(29);
        let (n_kv, d) = (2usize, 8usize);
        let (base, draft, keep) = (5usize, 3usize, 1usize);
        let kb = rng.normal_vec(n_kv * base * d, 1.0);
        let vb = rng.normal_vec(n_kv * base * d, 1.0);
        let kd = rng.normal_vec(n_kv * draft * d, 1.0);
        let vd = rng.normal_vec(n_kv * draft * d, 1.0);
        let mut spec = KvBuffers::new_with_dtype(n_kv, d, 2, KvDtype::Int8);
        spec.append(&kb, &vb, base);
        spec.append(&kd, &vd, draft);
        spec.truncate(base + keep);
        let head = |s: &[f32]| -> Vec<f32> {
            (0..n_kv).flat_map(|h| s[h * draft * d..(h * draft + keep) * d].to_vec()).collect()
        };
        let mut want = KvBuffers::new_with_dtype(n_kv, d, 2, KvDtype::Int8);
        want.append(&kb, &vb, base);
        want.append(&head(&kd), &head(&vd), keep);
        assert_eq!(spec.t, want.t);
        for h in 0..n_kv {
            for i in 0..spec.t {
                let (sb, wb) = (h * spec.capacity, h * want.capacity);
                assert_eq!(
                    &spec.k_q[(sb + i) * d..(sb + i + 1) * d],
                    &want.k_q[(wb + i) * d..(wb + i + 1) * d],
                    "codes ({h},{i})"
                );
                assert_eq!(spec.k_scale[sb + i].to_bits(), want.k_scale[wb + i].to_bits());
                assert_eq!(spec.v_scale[sb + i].to_bits(), want.v_scale[wb + i].to_bits());
                assert_eq!(spec.k_inv_norm[sb + i].to_bits(), want.k_inv_norm[wb + i].to_bits());
            }
            // Dropped rows' scales and norms are zeroed (dead rows).
            for i in spec.t..base + draft {
                let sb = h * spec.capacity;
                assert_eq!(spec.k_scale[sb + i], 0.0, "stale k scale ({h},{i})");
                assert_eq!(spec.v_scale[sb + i], 0.0, "stale v scale ({h},{i})");
            }
        }
    }
}
