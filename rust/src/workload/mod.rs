//! Workload generators: the offline substitutes for the paper's benchmark
//! suites (DESIGN.md §3). Each produces geometry tasks with ground-truth
//! relevant-KV sets, plus a token-level corpus for end-to-end serving.

pub mod geometry;
pub mod niah;
pub mod ruler;
pub mod longbench;
pub mod math500;
pub mod corpus;
