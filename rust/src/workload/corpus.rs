//! Token-level synthetic corpus + toy tokenizer for end-to-end serving.
//!
//! The serving examples and latency benchmarks feed the engine *token*
//! streams (the accuracy suite feeds Q/K/V geometry directly). This module
//! provides a byte-level tokenizer and a deterministic text corpus with
//! enough n-gram structure that greedy decoding is stable.

use crate::util::Rng;

/// Byte-level tokenizer: token = byte + 1 (0 is BOS/pad).
pub struct ByteTokenizer {
    pub vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> ByteTokenizer {
        assert!(vocab >= 257, "byte tokenizer needs >= 257 ids");
        ByteTokenizer { vocab }
    }

    pub fn bos(&self) -> u32 {
        0
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        std::iter::once(0u32)
            .chain(text.bytes().map(|b| b as u32 + 1))
            .collect()
    }

    pub fn decode(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .filter(|&&t| (1..=256).contains(&t))
            .map(|&t| (t - 1) as u8 as char)
            .collect()
    }
}

/// Deterministic pseudo-text: Markov babble over a small word list, with a
/// "fact" sentence embeddable at a chosen offset (NIAH-style prompts for
/// the serving demo).
pub struct Corpus {
    words: Vec<&'static str>,
    rng: Rng,
}

impl Corpus {
    pub fn new(seed: u64) -> Corpus {
        Corpus {
            words: vec![
                "the", "quick", "brown", "fox", "jumps", "over", "lazy", "dog", "alpha", "beta",
                "gamma", "delta", "prefill", "attention", "cache", "query", "key", "value",
                "chunk", "budget", "select", "cosine", "vector", "token", "stream", "serve",
            ],
            rng: Rng::new(seed),
        }
    }

    /// `n_chars`-long babble text.
    pub fn text(&mut self, n_chars: usize) -> String {
        let mut s = String::with_capacity(n_chars + 16);
        while s.len() < n_chars {
            s.push_str(self.words[self.rng.below(self.words.len())]);
            s.push(' ');
        }
        s.truncate(n_chars);
        s
    }

    /// Prompt with a planted fact sentence at `depth` ∈ [0,1).
    pub fn with_fact(&mut self, n_chars: usize, depth: f32, fact: &str) -> (String, usize) {
        let body = self.text(n_chars);
        let at = ((n_chars as f32 * depth) as usize).min(n_chars.saturating_sub(1));
        let mut out = String::with_capacity(n_chars + fact.len() + 2);
        out.push_str(&body[..at]);
        out.push(' ');
        out.push_str(fact);
        out.push(' ');
        out.push_str(&body[at..]);
        (out, at)
    }
}

/// A synthetic serving request mix for throughput benchmarks.
#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub prompt_tokens: usize,
    pub decode_tokens: usize,
}

/// Build a request mix: `n` requests with prompt lengths log-uniform in
/// `[min_len, max_len]` and a fixed decode budget.
pub fn request_mix(n: usize, min_len: usize, max_len: usize, decode: usize, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.f32();
            let len = (min_len as f32 * (max_len as f32 / min_len as f32).powf(u)) as usize;
            RequestSpec { prompt_tokens: len.max(min_len), decode_tokens: decode }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip() {
        let tok = ByteTokenizer::new(4096);
        let ids = tok.encode("hello QUOKA");
        assert_eq!(ids[0], tok.bos());
        assert_eq!(tok.decode(&ids), "hello QUOKA");
    }

    #[test]
    fn corpus_deterministic_and_sized() {
        let mut a = Corpus::new(1);
        let mut b = Corpus::new(1);
        assert_eq!(a.text(100), b.text(100));
        assert_eq!(a.text(500).len(), 500);
    }

    #[test]
    fn fact_is_planted_at_depth() {
        let mut c = Corpus::new(2);
        let (text, at) = c.with_fact(1000, 0.5, "THE MAGIC NUMBER IS 7421");
        assert!(text.contains("THE MAGIC NUMBER IS 7421"));
        assert!((400..600).contains(&at));
    }

    #[test]
    fn request_mix_in_bounds() {
        let mix = request_mix(50, 256, 4096, 32, 3);
        assert_eq!(mix.len(), 50);
        assert!(mix.iter().all(|r| (256..=4096).contains(&r.prompt_tokens)));
    }
}
