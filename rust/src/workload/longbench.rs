//! LongBench workload (paper §4.3, Tables 3, 6, 7).
//!
//! Six task families mirroring LongBench's categories. QA-style families
//! are needle tasks; summarization / few-shot / code families have no
//! single needle — their quality is attention-output *fidelity* across the
//! prompt (broad tasks are where high sparsity hurts least, matching the
//! paper's per-task table where summarization degrades most gracefully).
//! Scores are reported relative to the dense baseline (dense ≡ 1.0),
//! exactly like the paper's normalized tables.

use super::geometry::{GeometryConfig, GeometryTask, Needle};
use crate::eval::harness::{eval_policy, EvalOpts};
use crate::select::SelectionPolicy;

/// LongBench task families (mapped to the paper's category columns).
pub const FAMILIES: [&str; 6] =
    ["single_qa", "multi_qa", "summarization", "fewshot", "synthetic", "code"];

/// Build one family at prompt length `t`.
pub fn build(family: &str, t: usize, b_cp: usize, seed: u64) -> GeometryTask {
    build_with(family, GeometryConfig { t, b_cp, seed, ..Default::default() })
}

/// Build one family from a geometry prototype (heads/dims set by the
/// caller). Family-specific texture (noise, distractors) overrides the
/// prototype's values.
pub fn build_with(family: &str, proto: GeometryConfig) -> GeometryTask {
    let (t, b_cp) = (proto.t, proto.b_cp);
    let last = t.div_ceil(b_cp) - 1;
    match family {
        // One passage answers the question.
        "single_qa" => GeometryTask::generate(
            proto,
            vec![Needle { key_pos: t / 2, width: 6, query_chunk: last, dir: 0 }],
        ),
        // Evidence spread across documents.
        "multi_qa" => GeometryTask::generate(
            proto,
            (0..3)
                .map(|i| Needle { key_pos: (i + 1) * t / 5, width: 6, query_chunk: last, dir: i })
                .collect(),
        ),
        // Broad attention, no needle: fidelity-only, high dispersion.
        "summarization" => {
            GeometryTask::generate(GeometryConfig { noise: 0.30, ..proto }, vec![])
        }
        // Repeated patterns: moderate dispersion, two weak needles.
        "fewshot" => GeometryTask::generate(
            GeometryConfig { noise: 0.25, ..proto },
            (0..2)
                .map(|i| Needle { key_pos: (i + 1) * t / 4, width: 8, query_chunk: last, dir: i })
                .collect(),
        ),
        // Passage retrieval (PR-en): a hard single needle.
        "synthetic" => GeometryTask::generate(
            GeometryConfig { distractor_frac: 0.05, ..proto },
            vec![Needle { key_pos: t / 7, width: 4, query_chunk: last, dir: 0 }],
        ),
        // Code: strong locality — fidelity-focused with low noise.
        "code" => GeometryTask::generate(GeometryConfig { noise: 0.12, ..proto }, vec![]),
        other => panic!("unknown LongBench family {other}"),
    }
}

/// Per-family normalized scores (dense ≡ 1.0) and their mean.
pub fn scores(
    policy: &dyn SelectionPolicy,
    budget: usize,
    t: usize,
    b_cp: usize,
    seed: u64,
    opts: &EvalOpts,
) -> (Vec<(&'static str, f32)>, f32) {
    scores_with(policy, budget, GeometryConfig { t, b_cp, seed, ..Default::default() }, opts)
}

/// [`scores`] from a geometry prototype.
pub fn scores_with(
    policy: &dyn SelectionPolicy,
    budget: usize,
    proto: GeometryConfig,
    opts: &EvalOpts,
) -> (Vec<(&'static str, f32)>, f32) {
    let mut per = Vec::with_capacity(FAMILIES.len());
    let mut total = 0.0;
    for family in FAMILIES {
        let task = build_with(family, proto.clone());
        let s = eval_policy(&task, policy, budget, opts);
        // Fidelity-only families score pure fidelity; needle families score
        // recall-gated fidelity (dense = 1.0 for both by construction).
        let v = s.score();
        per.push((family, v));
        total += v;
    }
    let mean = total / FAMILIES.len() as f32;
    (per, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::policy_by_name;

    #[test]
    fn families_build_and_dense_is_one() {
        let dense = policy_by_name("dense").unwrap();
        let (per, mean) = scores(dense.as_ref(), usize::MAX, 1024, 128, 0, &EvalOpts::default());
        assert_eq!(per.len(), 6);
        assert!(mean > 0.99, "{mean}");
    }

    #[test]
    fn broad_tasks_degrade_more_gracefully_than_needle_tasks_for_keydiff() {
        // Query-agnostic selection keeps "typical" keys: fine for
        // summarization, fatal for passage retrieval.
        let kd = policy_by_name("keydiff").unwrap();
        let opts = EvalOpts::default();
        let summ = eval_policy(&build("summarization", 2048, 128, 1), kd.as_ref(), 128, &opts);
        let synth = eval_policy(&build("synthetic", 2048, 128, 1), kd.as_ref(), 128, &opts);
        assert!(summ.score() > synth.score(), "{} vs {}", summ.score(), synth.score());
    }
}
